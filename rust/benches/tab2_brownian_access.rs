//! Tables 2, 7, 8 and 9: Brownian Interval vs Virtual Brownian Tree over
//! the paper's three access patterns (sequential, doubly sequential,
//! random), batch sizes (1 / 2560 / 32768) and subinterval counts
//! (10 / 100 / 1000). Minimum over 32 runs, as in Appendix F.6.
//!
//! Expected shape: the Brownian Interval wins uniformly; on the
//! doubly-sequential pattern (SDE solve + adjoint) by ~3–13×.
//!
//! Run the full sweep with `cargo bench --bench tab2_brownian_access`;
//! set `QUICK=1` to trim the largest configurations.

use neuralsde::brownian::{splitmix64, BrownianInterval, BrownianSource, VirtualBrownianTree};
use neuralsde::util::bench::BenchTable;

fn sequential<B: BrownianSource>(src: &mut B, n: usize, out: &mut [f32]) {
    for k in 0..n {
        src.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, out);
    }
}

fn doubly<B: BrownianSource>(src: &mut B, n: usize, out: &mut [f32]) {
    sequential(src, n, out);
    for k in (0..n).rev() {
        src.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, out);
    }
}

fn random<B: BrownianSource>(src: &mut B, n: usize, seed: u64, out: &mut [f32]) {
    // Query every interval exactly once, in a seeded pseudo-random order.
    let mut order: Vec<usize> = (0..n).collect();
    let mut state = seed;
    for i in (1..n).rev() {
        state = splitmix64(state);
        order.swap(i, (state % (i as u64 + 1)) as usize);
    }
    for &k in &order {
        src.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, out);
    }
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let full = std::env::var("FULL").is_ok();
    let batches: &[usize] = if quick { &[1, 2560] } else { &[1, 2560, 32768] };
    let intervals: &[usize] = if quick { &[10, 100] } else { &[10, 100, 1000] };
    let repeats = 32;

    for &pattern in &["sequential", "doubly_sequential", "random"] {
        let table_no = match pattern {
            "sequential" => "Table 7",
            "doubly_sequential" => "Table 8 (and Table 2 right)",
            _ => "Table 9",
        };
        let mut table = BenchTable::new(
            &format!("{table_no}: {pattern} access"),
            repeats,
            2,
        );
        for &b in batches {
            let mut out = vec![0.0f32; b];
            for &n in intervals {
                // The (32768, 1000) cell takes minutes per VBT run (the
                // paper reports 500 s); skip it unless FULL=1.
                if b >= 32768 && n >= 1000 && !full {
                    continue;
                }
                // Scale repeats down on the big cells (min-of-k is stable
                // well before 32 runs there).
                let reps = if b >= 32768 { 5 } else if b >= 2560 && n >= 1000 { 8 } else { repeats };
                for src_kind in ["bi", "vbt"] {
                    let name = format!("{src_kind}/batch={b}/n={n}");
                    table.bench_n(&name, reps, |i| {
                        let seed = i as u64 + 1;
                        match src_kind {
                            "bi" => {
                                let mut s = BrownianInterval::new(0.0, 1.0, b, seed);
                                match pattern {
                                    "sequential" => sequential(&mut s, n, &mut out),
                                    "doubly_sequential" => doubly(&mut s, n, &mut out),
                                    _ => random(&mut s, n, seed, &mut out),
                                }
                            }
                            _ => {
                                let mut s =
                                    VirtualBrownianTree::new(0.0, 1.0, b, seed, 1e-5);
                                match pattern {
                                    "sequential" => sequential(&mut s, n, &mut out),
                                    "doubly_sequential" => doubly(&mut s, n, &mut out),
                                    _ => random(&mut s, n, seed, &mut out),
                                }
                            }
                        }
                    });
                }
            }
        }
        println!("{}", table.render());
        // Speedup summary per configuration.
        for &b in batches {
            for &n in intervals {
                if b >= 32768 && n >= 1000 && !full {
                    continue;
                }
                let bi = table.min_of(&format!("bi/batch={b}/n={n}"));
                let vbt = table.min_of(&format!("vbt/batch={b}/n={n}"));
                println!("  batch={b:<6} n={n:<5} BI speedup {:.2}x", vbt / bi);
            }
        }
        std::fs::create_dir_all("results").ok();
        table
            .write_json(&format!("results/bench_{pattern}.json"))
            .ok();
    }
}
