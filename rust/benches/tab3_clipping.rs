//! Tables 3 / 11: discriminator-step cost of Lipschitz **clipping**
//! (Section 5) vs the unconstrained / gradient-penalty alternatives, on the
//! OU SDE-GAN.
//!
//! `native/*` rows time the pure-Rust step with and without the clip
//! (clipping is a cheap post-optimiser clamp — the paper's point is that it
//! *replaces* the GP's double backward). The double-backward gradient
//! penalty itself is only lowered as an AOT executable, so the full
//! Table-11 comparison (the paper's 1.41× midpoint+clip over midpoint+GP)
//! needs `--features pjrt` + `make artifacts`.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::ou;
use neuralsde::util::bench::BenchTable;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let repeats = if quick { 5 } else { 16 };
    let mut data = ou::generate(256, 1, ou::OuParams::default());
    data.normalise_initial();

    let mut table = BenchTable::new(
        "Tables 3/11: clipping vs gradient penalty (OU SDE-GAN step)",
        repeats,
        2,
    );
    for (name, clip) in [
        ("native/reversible_heun+clipping", true),
        ("native/reversible_heun+unconstrained", false),
    ] {
        let mut cfg = TrainConfig::default();
        cfg.clip = clip;
        let mut trainer = GanTrainer::new(&cfg, 1000).expect("native trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(name, |_| {
            trainer.train_step(&data, &mut rng).expect("step");
        });
    }
    let clip = table.min_of("native/reversible_heun+clipping");
    let unc = table.min_of("native/reversible_heun+unconstrained");
    println!("  native clipping overhead: {:.3}x", clip / unc);

    runtime_rows(&mut table, &data);

    println!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab3_clipping.json").ok();
}

/// The AOT rows, including the double-backward gradient-penalty baseline.
#[cfg(feature = "pjrt")]
fn runtime_rows(table: &mut BenchTable, data: &neuralsde::data::TimeSeriesDataset) {
    use neuralsde::config::SolverKind;
    use neuralsde::runtime::{load_runtime, Runtime};

    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping AOT rows: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    let configs: [(&str, SolverKind, bool); 3] = [
        ("midpoint+gradient_penalty", SolverKind::Midpoint, false),
        ("midpoint+clipping", SolverKind::Midpoint, true),
        ("reversible_heun+clipping", SolverKind::ReversibleHeun, true),
    ];
    for (name, solver, clip) in configs {
        let mut cfg = TrainConfig::default();
        cfg.solver = solver;
        cfg.clip = clip;
        let mut trainer = GanTrainer::from_runtime(&rt, &cfg, 1000).expect("trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(name, |_| {
            trainer.train_step_runtime(&mut rt, data, &mut rng).expect("step");
        });
    }
    let gp = table.min_of("midpoint+gradient_penalty");
    let clip = table.min_of("midpoint+clipping");
    let rh = table.min_of("reversible_heun+clipping");
    println!("  clipping speedup over GP      : {:.2}x", gp / clip);
    println!("  revheun further speedup       : {:.2}x", clip / rh);
    println!("  total (revheun+clip vs mp+GP) : {:.2}x", gp / rh);
}

#[cfg(not(feature = "pjrt"))]
fn runtime_rows(_table: &mut BenchTable, _data: &neuralsde::data::TimeSeriesDataset) {
    eprintln!("gradient-penalty rows need --features pjrt (+ `make artifacts`)");
}
