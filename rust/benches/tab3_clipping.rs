//! Tables 3 / 11: discriminator-step cost of Lipschitz **clipping**
//! (Section 5) vs **gradient penalty** (the double-backward baseline), on
//! the OU SDE-GAN.
//!
//! The paper's 1.41× speedup (midpoint+clip over midpoint+GP) comes from
//! skipping the double backward; reversible Heun adds another 1.09×.
//! Requires `make artifacts`.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{SolverKind, TrainConfig};
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::ou;
use neuralsde::runtime::{load_runtime, Runtime};
use neuralsde::util::bench::BenchTable;

fn main() {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping tab3_clipping: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    let quick = std::env::var("QUICK").is_ok();
    let repeats = if quick { 5 } else { 16 };
    let mut data = ou::generate(256, 1, ou::OuParams::default());
    data.normalise_initial();

    let mut table = BenchTable::new(
        "Tables 3/11: clipping vs gradient penalty (OU SDE-GAN step)",
        repeats,
        2,
    );
    let configs: [(&str, SolverKind, bool); 3] = [
        ("midpoint+gradient_penalty", SolverKind::Midpoint, false),
        ("midpoint+clipping", SolverKind::Midpoint, true),
        ("reversible_heun+clipping", SolverKind::ReversibleHeun, true),
    ];
    for (name, solver, clip) in configs {
        let mut cfg = TrainConfig::default();
        cfg.solver = solver;
        cfg.clip = clip;
        let mut trainer = GanTrainer::new(&rt, &cfg, 1000).expect("trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(name, |_| {
            trainer.train_step(&mut rt, &data, &mut rng).expect("step");
        });
    }
    println!("{}", table.render());
    let gp = table.min_of("midpoint+gradient_penalty");
    let clip = table.min_of("midpoint+clipping");
    let rh = table.min_of("reversible_heun+clipping");
    println!("  clipping speedup over GP      : {:.2}x", gp / clip);
    println!("  revheun further speedup       : {:.2}x", clip / rh);
    println!("  total (revheun+clip vs mp+GP) : {:.2}x", gp / rh);
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab3_clipping.json").ok();
}
