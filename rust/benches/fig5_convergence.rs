//! Figures 5 & 6 as a bench target: strong/weak convergence orders of the
//! reversible Heun method on the additive-noise anharmonic oscillator.
//! Asserts strong order ≈ 1 and weak order ≈ 2 (Appendix D.4).

use neuralsde::solvers::systems::Anharmonic;
use neuralsde::solvers::{estimate_orders, strong_weak_errors, Heun, ReversibleHeun};

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let n_paths = if quick { 4_000 } else { 40_000 };
    let sde = Anharmonic { sigma: 1.0 };
    let steps = [4usize, 8, 16, 32, 64];

    let pts = strong_weak_errors(
        &sde,
        |s, t0, y0| ReversibleHeun::new(s, t0, y0),
        &steps,
        n_paths,
        1.0,
        1.0,
        2021,
    );
    let rh = estimate_orders("reversible_heun", pts);
    let pts = strong_weak_errors(&sde, |_s, _t, _y| Heun::new(1, 1), &steps,
                                 n_paths, 1.0, 1.0, 2021);
    let heun = estimate_orders("heun", pts);

    for rep in [&rh, &heun] {
        println!(
            "{:<18} strong order {:.2}  weak order {:.2}",
            rep.solver, rep.strong_order, rep.weak_order
        );
    }
    assert!(
        (0.8..1.35).contains(&rh.strong_order),
        "revheun strong order {} not ~1",
        rh.strong_order
    );
    // Weak order: the E_N estimator hits the Monte-Carlo noise floor well
    // before the finest h at feasible path counts (the paper used 1e7
    // paths); fit the second-moment error over the coarsest 4 points where
    // the truncation term still dominates.
    let xs: Vec<f64> = rh.points[..4].iter().map(|p| p.h.log2()).collect();
    let ys: Vec<f64> = rh.points[..4]
        .iter()
        .map(|p| p.weak_second.max(1e-300).log2())
        .collect();
    let (_, weak2) = neuralsde::util::stats::linear_fit(&xs, &ys);
    println!("revheun weak order (V_N fit, coarse h): {weak2:.2}");
    assert!(weak2 > 1.4, "revheun weak order {weak2} not ~2");
    println!("fig5/fig6 assertions OK (additive noise: strong ~1, weak ~2)");
}
