//! Tables 1 / 4 / 5: per-training-step wall time.
//!
//! `native/*` rows time the pure-Rust SDE-GAN step (batched reversible-Heun
//! solves + the native adjoint engine + Adadelta/clip/SWA) and need no
//! artifacts. With `--features pjrt` and `make artifacts`, the AOT
//! gradient-executable rows (reversible Heun vs midpoint — the paper's
//! 1.98×/1.25× headline comparison) and the Latent SDE rows run as well.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{DatasetKind, TrainConfig};
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::{ou, weights};
use neuralsde::util::bench::BenchTable;

fn dataset(ds: DatasetKind) -> neuralsde::data::TimeSeriesDataset {
    let mut data = match ds {
        DatasetKind::Ou => ou::generate(256, 1, ou::OuParams::default()),
        DatasetKind::Weights => weights::generate(256, 1, weights::WeightsParams::default()),
        _ => unreachable!(),
    };
    data.normalise_initial();
    data
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let repeats = if quick { 5 } else { 16 };
    let mut table = BenchTable::new(
        "Tables 1/4/5: training-step time (native + AOT backends)",
        repeats,
        2,
    );

    // Native rows: the default-build training path, no artifacts needed.
    for ds in [DatasetKind::Ou, DatasetKind::Weights] {
        let data = dataset(ds);
        let mut cfg = TrainConfig::default();
        cfg.dataset = ds;
        let mut trainer = GanTrainer::new(&cfg, 1000).expect("native trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(&format!("native/gan_{}/reversible_heun", ds.as_str()), |_| {
            trainer.train_step(&data, &mut rng).expect("step");
        });
    }

    runtime_rows(&mut table);

    println!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab1_training_step.json").ok();
}

/// The AOT-executable rows (PJRT feature + artifacts).
#[cfg(feature = "pjrt")]
fn runtime_rows(table: &mut BenchTable) {
    use neuralsde::config::SolverKind;
    use neuralsde::coordinator::LatentTrainer;
    use neuralsde::data::air;
    use neuralsde::runtime::{load_runtime, Runtime};

    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping AOT rows: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    for ds in [DatasetKind::Ou, DatasetKind::Weights] {
        let data = dataset(ds);
        for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
            let mut cfg = TrainConfig::default();
            cfg.dataset = ds;
            cfg.solver = solver;
            let mut trainer = GanTrainer::from_runtime(&rt, &cfg, 1000).expect("trainer");
            let mut rng = SplitPrng::new(7);
            table.bench(&format!("gan_{}/{}", ds.as_str(), solver.as_str()), |_| {
                trainer.train_step_runtime(&mut rt, &data, &mut rng).expect("step");
            });
        }
    }
    // Latent SDE on air.
    let mut data = air::generate(256, 1, air::AirParams::default());
    data.normalise_initial();
    for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
        let mut cfg = TrainConfig::default();
        cfg.dataset = DatasetKind::Air;
        cfg.solver = solver;
        let mut trainer = LatentTrainer::new(&rt, &cfg).expect("trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(&format!("latent_air/{}", solver.as_str()), |_| {
            trainer.train_step(&mut rt, &data, &mut rng).expect("step");
        });
    }
    for model in ["gan_ou", "gan_weights", "latent_air"] {
        let rh = table.min_of(&format!("{model}/reversible_heun"));
        let mp = table.min_of(&format!("{model}/midpoint"));
        println!("  {model:<12} revheun speedup over midpoint: {:.2}x", mp / rh);
    }
}

#[cfg(not(feature = "pjrt"))]
fn runtime_rows(_table: &mut BenchTable) {
    eprintln!("AOT rows need --features pjrt (+ `make artifacts`); native rows above");
}
