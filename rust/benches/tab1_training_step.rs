//! Tables 1 / 4 / 5: per-training-step wall time, reversible Heun vs
//! midpoint, for the SDE-GAN (OU & weights datasets) and the Latent SDE
//! (air dataset).
//!
//! The paper's headline speedups (1.98× on weights, 1.25× on air) come
//! from the reversible Heun method's single vector-field evaluation per
//! step; the same ratio should appear here in the gradient-executable
//! time. Requires `make artifacts`.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{DatasetKind, SolverKind, TrainConfig};
use neuralsde::coordinator::{GanTrainer, LatentTrainer};
use neuralsde::data::{air, ou, weights};
use neuralsde::runtime::{load_runtime, Runtime};
use neuralsde::util::bench::BenchTable;

fn main() {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping tab1_training_step: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    let quick = std::env::var("QUICK").is_ok();
    let repeats = if quick { 5 } else { 16 };
    let mut table = BenchTable::new(
        "Tables 1/4/5: training-step time (revheun vs midpoint)",
        repeats,
        2,
    );

    let datasets = [DatasetKind::Ou, DatasetKind::Weights];
    for ds in datasets {
        let mut data = match ds {
            DatasetKind::Ou => ou::generate(256, 1, ou::OuParams::default()),
            DatasetKind::Weights => weights::generate(256, 1, weights::WeightsParams::default()),
            _ => unreachable!(),
        };
        data.normalise_initial();
        for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
            let mut cfg = TrainConfig::default();
            cfg.dataset = ds;
            cfg.solver = solver;
            let mut trainer = GanTrainer::new(&rt, &cfg, 1000).expect("trainer");
            let mut rng = SplitPrng::new(7);
            table.bench(
                &format!("gan_{}/{}", ds.as_str(), solver.as_str()),
                |_| {
                    trainer.train_step(&mut rt, &data, &mut rng).expect("step");
                },
            );
        }
    }

    // Latent SDE on air.
    let mut data = air::generate(256, 1, air::AirParams::default());
    data.normalise_initial();
    for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
        let mut cfg = TrainConfig::default();
        cfg.dataset = DatasetKind::Air;
        cfg.solver = solver;
        let mut trainer = LatentTrainer::new(&rt, &cfg).expect("trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(&format!("latent_air/{}", solver.as_str()), |_| {
            trainer.train_step(&mut rt, &data, &mut rng).expect("step");
        });
    }

    println!("{}", table.render());
    for model in ["gan_ou", "gan_weights", "latent_air"] {
        let rh = table.min_of(&format!("{model}/reversible_heun"));
        let mp = table.min_of(&format!("{model}/midpoint"));
        println!("  {model:<12} revheun speedup over midpoint: {:.2}x", mp / rh);
    }
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab1_training_step.json").ok();
}
