//! Tables 1 / 4 / 5: per-training-step wall time.
//!
//! `native/*` rows time the pure-Rust SDE-GAN step (batched reversible-Heun
//! solves + the native adjoint engine + Adadelta/clip/SWA) and need no
//! artifacts; `mixed/*` rows rerun the same step with
//! `TrainPrecision::Mixed` (8-wide `f32` forward solves, exact `f64`
//! adjoints through the widened tape) — the `f32_vs_f64` ratios are this
//! optimisation's headline (target ≥1.5× on the solve-bound step). With
//! `--features pjrt` and `make artifacts`, the AOT gradient-executable rows
//! (reversible Heun vs midpoint — the paper's 1.98×/1.25× headline
//! comparison) and the Latent SDE rows run as well.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{DatasetKind, TrainConfig, TrainPrecision};
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::{ou, weights};
use neuralsde::solvers::BatchOptions;
use neuralsde::util::bench::{write_bench_json, BenchTable};
use neuralsde::util::json::Json;

fn dataset(ds: DatasetKind) -> neuralsde::data::TimeSeriesDataset {
    let mut data = match ds {
        DatasetKind::Ou => ou::generate(256, 1, ou::OuParams::default()),
        DatasetKind::Weights => weights::generate(256, 1, weights::WeightsParams::default()),
        _ => unreachable!(),
    };
    data.normalise_initial();
    data
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let repeats = if quick { 5 } else { 16 };
    let mut table = BenchTable::new(
        "Tables 1/4/5: training-step time (native + AOT backends)",
        repeats,
        2,
    );

    // Native rows: the default-build training path, no artifacts needed.
    // Each dataset runs at both precisions on the same data and noise seed;
    // the only difference between the row pairs is the solve element type.
    for ds in [DatasetKind::Ou, DatasetKind::Weights] {
        let data = dataset(ds);
        for precision in [TrainPrecision::F64, TrainPrecision::Mixed] {
            let mut cfg = TrainConfig::default();
            cfg.dataset = ds;
            cfg.precision = precision;
            let mut trainer = GanTrainer::new(&cfg, 1000).expect("native trainer");
            let mut rng = SplitPrng::new(7);
            let label = match precision {
                TrainPrecision::F64 => "native",
                TrainPrecision::Mixed => "mixed",
            };
            table.bench(
                &format!("{label}/gan_{}/reversible_heun", ds.as_str()),
                |_| {
                    trainer.train_step(&data, &mut rng).expect("step");
                },
            );
        }
    }

    // PR-10 overlap rows: the same step with `chunk >= batch`, so every
    // solve is a single chunk and the ONLY available parallelism is the
    // real/fake discriminator-adjoint overlap (`pool::join2`). threads=1 is
    // the sequential reference; threads=2 runs the two CDE adjoint sweeps
    // concurrently on the persistent executor.
    {
        let data = dataset(DatasetKind::Ou);
        for (label, threads) in
            [("overlap/disc_serial/gan_ou", 1usize), ("overlap/disc_overlapped/gan_ou", 2)]
        {
            let cfg = TrainConfig::default();
            let opts = BatchOptions { threads, chunk: cfg.batch.max(1), ..Default::default() };
            let mut trainer =
                GanTrainer::new(&cfg, 1000).expect("native trainer").with_batch_options(opts);
            let mut rng = SplitPrng::new(7);
            table.bench(label, |_| {
                trainer.train_step(&data, &mut rng).expect("step");
            });
        }
    }

    // The tentpole headline: full f64 training step over the mixed step.
    let mut headline: Vec<(&str, Json)> = Vec::new();
    let mut ratios = Vec::new();
    for ds in [DatasetKind::Ou, DatasetKind::Weights] {
        let name = ds.as_str();
        let f64t = table.min_of(&format!("native/gan_{name}/reversible_heun"));
        let f32t = table.min_of(&format!("mixed/gan_{name}/reversible_heun"));
        let ratio = f64t / f32t;
        println!("  gan_{name:<10} f64/mixed training step: {ratio:.2}x");
        ratios.push((format!("f32_vs_f64/gan_{name}"), ratio));
    }
    {
        // PR-10 headline: serial vs overlapped discriminator adjoints.
        let serial = table.min_of("overlap/disc_serial/gan_ou");
        let overlapped = table.min_of("overlap/disc_overlapped/gan_ou");
        let ratio = serial / overlapped;
        println!("  disc_adjoint_overlap  serial/overlapped step: {ratio:.2}x");
        ratios.push(("disc_adjoint_overlap/gan_ou".to_string(), ratio));
    }
    let extras: Vec<Json> = ratios
        .iter()
        .map(|(k, v)| {
            neuralsde::util::json::obj(vec![
                ("name", Json::Str(k.clone())),
                ("speedup", Json::Num(*v)),
            ])
        })
        .collect();
    headline.push(("speedups", Json::Arr(extras)));

    runtime_rows(&mut table);

    println!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab1_training_step.json").ok();
    if quick {
        // Trimmed workloads are not comparable to the tracked trajectory —
        // never let a smoke run overwrite BENCH_pr10.json.
        println!("smoke/QUICK run: skipping BENCH_pr10.json (full run required)");
        return;
    }
    let bench_dir = std::env::var("BENCH_DIR").unwrap_or_else(|_| "..".to_string());
    match write_bench_json(&bench_dir, "pr10", &[&table], headline) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}

/// The AOT-executable rows (PJRT feature + artifacts).
#[cfg(feature = "pjrt")]
fn runtime_rows(table: &mut BenchTable) {
    use neuralsde::config::SolverKind;
    use neuralsde::coordinator::LatentTrainer;
    use neuralsde::data::air;
    use neuralsde::runtime::{load_runtime, Runtime};

    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping AOT rows: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    for ds in [DatasetKind::Ou, DatasetKind::Weights] {
        let data = dataset(ds);
        for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
            let mut cfg = TrainConfig::default();
            cfg.dataset = ds;
            cfg.solver = solver;
            let mut trainer = GanTrainer::from_runtime(&rt, &cfg, 1000).expect("trainer");
            let mut rng = SplitPrng::new(7);
            table.bench(&format!("gan_{}/{}", ds.as_str(), solver.as_str()), |_| {
                trainer.train_step_runtime(&mut rt, &data, &mut rng).expect("step");
            });
        }
    }
    // Latent SDE on air.
    let mut data = air::generate(256, 1, air::AirParams::default());
    data.normalise_initial();
    for solver in [SolverKind::ReversibleHeun, SolverKind::Midpoint] {
        let mut cfg = TrainConfig::default();
        cfg.dataset = DatasetKind::Air;
        cfg.solver = solver;
        let mut trainer = LatentTrainer::new(&rt, &cfg).expect("trainer");
        let mut rng = SplitPrng::new(7);
        table.bench(&format!("latent_air/{}", solver.as_str()), |_| {
            trainer.train_step(&mut rt, &data, &mut rng).expect("step");
        });
    }
    for model in ["gan_ou", "gan_weights", "latent_air"] {
        let rh = table.min_of(&format!("{model}/reversible_heun"));
        let mp = table.min_of(&format!("{model}/midpoint"));
        println!("  {model:<12} revheun speedup over midpoint: {:.2}x", mp / rh);
    }
}

#[cfg(not(feature = "pjrt"))]
fn runtime_rows(_table: &mut BenchTable) {
    eprintln!("AOT rows need --features pjrt (+ `make artifacts`); native rows above");
}
