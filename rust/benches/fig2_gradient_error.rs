//! Figure 2 / Table 6 as a bench target: regenerates the gradient-error
//! table (the numbers, not just timings). Requires `make artifacts`.

use neuralsde::coordinator::gradient_error;
use neuralsde::runtime::{load_runtime, Runtime};

fn main() {
    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping fig2_gradient_error: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    let points = gradient_error::run(&mut rt, 2021).expect("gradient error");
    println!("{}", gradient_error::render(&points));
    // Hard assertions of the paper's claim, so `cargo bench` fails loudly
    // if the reproduction regresses.
    for p in &points {
        match p.solver.as_str() {
            "reversible_heun" => assert!(
                p.rel_err < 1e-10,
                "reversible Heun should be fp-exact, got {} at n={}",
                p.rel_err,
                p.n_steps
            ),
            _ => {
                if p.n_steps <= 16 {
                    assert!(
                        p.rel_err > 1e-8,
                        "{} should show truncation bias, got {} at n={}",
                        p.solver,
                        p.rel_err,
                        p.n_steps
                    );
                }
            }
        }
    }
    println!("fig2 assertions OK (revheun fp-exact; baselines biased)");
}
