//! Figure 2 / Table 6 as a bench target: regenerates the gradient-error
//! table (the numbers, not just timings). The native reversible-Heun
//! adjoint rows run unconditionally; the PJRT solver comparison additionally
//! requires `make artifacts`.

use neuralsde::coordinator::gradient_error;
use neuralsde::runtime::{load_runtime, Runtime};

fn main() {
    // Native rows: pure-Rust adjoint engine, no artifacts needed. Hard
    // assertions of the paper's machine-precision claim for the
    // reconstruction-based gradient.
    let native = gradient_error::run_native(2021);
    println!("{}", gradient_error::render(&native));
    for p in &native {
        match p.solver.as_str() {
            "native_revheun_rec_vs_tape" => assert!(
                p.rel_err < 1e-9,
                "reconstruction gradient should be roundoff-exact, got {} at n={}",
                p.rel_err,
                p.n_steps
            ),
            _ => assert!(
                p.rel_err < 1e-5,
                "adjoint should sit at the FD floor, got {} at n={}",
                p.rel_err,
                p.n_steps
            ),
        }
    }
    println!("native adjoint assertions OK (reconstruction roundoff-exact)");

    // Mixed-precision rows: f32 forward (8-wide lanes) + exact f64 tape
    // backward, vs the all-f64 adjoint on the same Brownian sample. The
    // deviation is the f32 truncation of the forward trajectory — nonzero,
    // but bounded well below any solver-truncation bias.
    let mixed = gradient_error::run_native_mixed(2021);
    println!("{}", gradient_error::render(&mixed));
    for p in &mixed {
        assert!(
            p.rel_err > 0.0 && p.rel_err < 1e-2,
            "f32-forward deviation should be small but nonzero, got {} at n={}",
            p.rel_err,
            p.n_steps
        );
    }
    println!("mixed-precision assertions OK (f32 forward, f64 backward)");

    if !Runtime::artifacts_present("artifacts") {
        eprintln!("skipping PJRT fig2 rows: run `make artifacts` first");
        return;
    }
    let mut rt = load_runtime("artifacts").expect("runtime");
    let points = gradient_error::run(&mut rt, 2021).expect("gradient error");
    println!("{}", gradient_error::render(&points));
    // Hard assertions of the paper's claim, so `cargo bench` fails loudly
    // if the reproduction regresses.
    for p in &points {
        match p.solver.as_str() {
            "reversible_heun" => assert!(
                p.rel_err < 1e-10,
                "reversible Heun should be fp-exact, got {} at n={}",
                p.rel_err,
                p.n_steps
            ),
            _ => {
                if p.n_steps <= 16 {
                    assert!(
                        p.rel_err > 1e-8,
                        "{} should show truncation bias, got {} at n={}",
                        p.solver,
                        p.rel_err,
                        p.n_steps
                    );
                }
            }
        }
    }
    println!("fig2 assertions OK (revheun fp-exact; baselines biased)");
}
