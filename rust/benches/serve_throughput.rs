//! Serving-engine throughput under Poisson load: open-loop arrivals at
//! several request rates against one persistent [`ServeEngine`], reporting
//! sustained `paths/sec` and per-request p50/p99 latency.
//!
//! The workload models the serving story the engine exists for: many small
//! sampling requests (8 paths each, round-robined over 8 sessions so
//! coalescing actually happens) arriving with exponential inter-arrival
//! times — deterministic via an inverse-CDF draw from `splitmix64`, so two
//! runs see the identical arrival schedule. Each request's latency is
//! submit-to-collect wall time, measured by a dedicated collector thread
//! while the driver thread keeps the open-loop schedule.
//!
//! Expected shape: at low rates the engine is latency-bound (one request
//! per mega-batch, latency ≈ a solo solve); as the rate climbs past the
//! solve time, admission coalesces deeper batches and throughput rises
//! well past `rate × width` saturation while p99 grows gracefully instead
//! of collapsing.
//!
//! Two headline workloads ride on top of the single-class sweep:
//!
//! * `packed_vs_fifo/*` — a **mixed-size** workload (width-4 interactive
//!   Poisson arrivals with sharded mega-requests injected mid-stream) run
//!   once under strict-FIFO admission and once under size-aware packing
//!   with the priority lane, reporting per-class p50/p99 and the FIFO ÷
//!   packed interactive-p99 ratio — the number the admission tentpole
//!   exists to improve.
//! * `diag_fast_path/*` — the f32 diagonal-noise market model served at
//!   Monte-Carlo width against its dense-control twin (same fields, dense
//!   `e×d` mat-vec), reporting the diagonal ÷ dense throughput ratio.
//!
//! Results go to `results/bench_serve_throughput.json` and, for the perf
//! trajectory, `BENCH_pr9.json` (`BENCH_DIR` overrides the directory).
//! Pass `--smoke` (or `QUICK=1`) for the trimmed CI workload.

use std::time::{Duration, Instant};

use neuralsde::brownian::splitmix64;
use neuralsde::solvers::systems::{MarketModel, TanhDiagonalBatch};
use neuralsde::solvers::{AdmitPolicy, BatchReversibleHeun, ServeConfig, ServeEngine, Ticket};
use neuralsde::util::bench::{write_bench_json, BenchTable};
use neuralsde::util::json::{obj, Json};

const DIM: usize = 4;
const WIDTH: usize = 8; // paths per request
const N_STEPS: usize = 32;
const N_SESSIONS: usize = 8;
const SMALL_W: usize = 4; // interactive width in the mixed-size workload

/// Uniform in (0, 1] from a counter-keyed splitmix64 draw.
fn uniform(seed: u64, k: u64) -> f64 {
    let bits = splitmix64(seed ^ k.wrapping_mul(0x9E37_79B9));
    ((bits >> 11) as f64 + 1.0) / (1u64 << 53) as f64
}

struct LoadStats {
    paths_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn percentile_ms(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx] * 1e3
}

/// Drive `n_requests` Poisson arrivals at `rate` req/s through a fresh
/// engine; returns sustained throughput and latency percentiles.
fn run_load(rate: f64, n_requests: usize) -> LoadStats {
    let mut cfg = ServeConfig::new(0.0, 1.0, N_STEPS);
    cfg.max_batch = N_SESSIONS * WIDTH;
    cfg.chunk = 16;
    let engine =
        ServeEngine::<BatchReversibleHeun, _>::new(TanhDiagonalBatch::new(DIM, 99), cfg);
    let sessions: Vec<_> =
        (0..N_SESSIONS).map(|s| engine.open_session(1000 + s as u64, WIDTH)).collect();
    let y0 = vec![0.1f64; DIM * WIDTH];

    // Warm the slots, sessions and worker scratch off the clock.
    for &sid in &sessions {
        let t = engine.submit(sid, &y0);
        engine.wait(t).expect("warmup request faulted");
    }

    let (tx, rx) = std::sync::mpsc::channel::<(Ticket, Instant)>();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_requests);
    let wall = Instant::now();
    std::thread::scope(|sc| {
        let eng = &engine;
        let lat = &mut latencies;
        sc.spawn(move || {
            let mut out = Vec::new();
            for (ticket, submitted) in rx {
                eng.wait_into(ticket, &mut out).expect("request faulted under load");
                lat.push(submitted.elapsed().as_secs_f64());
            }
        });
        // Open-loop driver: arrivals keep their schedule no matter how the
        // engine is doing (the property that makes p99 honest).
        let arrival_seed = 0x5EED_u64 ^ rate.to_bits();
        let mut next = Instant::now();
        for r in 0..n_requests {
            let gap = -uniform(arrival_seed, r as u64).ln() / rate;
            next += Duration::from_secs_f64(gap);
            while Instant::now() < next {
                std::hint::spin_loop();
            }
            let sid = sessions[r % sessions.len()];
            tx.send((engine.submit(sid, &y0), Instant::now())).expect("collector died");
        }
        drop(tx); // collector drains and exits
    });
    let wall_s = wall.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    LoadStats {
        paths_per_sec: (n_requests * WIDTH) as f64 / wall_s,
        p50_ms: percentile_ms(&latencies, 0.50),
        p99_ms: percentile_ms(&latencies, 0.99),
    }
}

struct MixedStats {
    paths_per_sec: f64,
    small_p50_ms: f64,
    small_p99_ms: f64,
    huge_p50_ms: f64,
    huge_p99_ms: f64,
}

/// The mixed-size workload: `n_small` width-[`SMALL_W`] interactive
/// requests arrive Poisson at `small_rate`, with `n_huge` sharded
/// `huge_w`-path mega-requests injected at even offsets through the run.
/// One merged deterministic schedule, driven open-loop; per-class latency
/// is collected on a dedicated thread per class so a mega-solve never
/// head-of-line-blocks the measurement itself.
fn run_mixed(
    policy: AdmitPolicy,
    small_rate: f64,
    n_small: usize,
    n_huge: usize,
    huge_w: usize,
) -> MixedStats {
    let mut cfg = ServeConfig::new(0.0, 1.0, N_STEPS);
    cfg.max_batch = 1024;
    cfg.chunk = 64;
    cfg.policy = policy;
    cfg.shard_width = 512; // a draining mega-request leaves half the batch free
    let engine =
        ServeEngine::<BatchReversibleHeun, _>::new(TanhDiagonalBatch::new(DIM, 99), cfg);
    let small_sessions: Vec<_> =
        (0..N_SESSIONS).map(|s| engine.open_session(2000 + s as u64, SMALL_W)).collect();
    let huge_session = engine.open_session(3000, huge_w);
    let y0_small = vec![0.1f64; DIM * SMALL_W];
    let y0_huge = vec![0.1f64; DIM * huge_w];

    // Warm both classes off the clock (slots, grids, worker scratch).
    for &sid in &small_sessions {
        let t = engine.submit(sid, &y0_small);
        engine.wait(t).expect("warmup request faulted");
    }
    let t = engine.submit(huge_session, &y0_huge);
    engine.wait(t).expect("huge warmup request faulted");

    // Merged schedule: small inter-arrivals are inverse-CDF exponential
    // draws; huge requests land at even fractions of the nominal run. The
    // schedule is policy-independent so the fifo/packed comparison sees
    // the identical arrival stream.
    let arrival_seed = 0x4D31_5Eu64;
    let mut events: Vec<(f64, bool, usize)> = Vec::new(); // (time, is_huge, idx)
    let mut t_acc = 0.0f64;
    for r in 0..n_small {
        t_acc += -uniform(arrival_seed, r as u64).ln() / small_rate;
        events.push((t_acc, false, r));
    }
    let nominal = n_small as f64 / small_rate;
    for h in 0..n_huge {
        events.push((nominal * (h + 1) as f64 / (n_huge + 1) as f64, true, h));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));

    let (tx_s, rx_s) = std::sync::mpsc::channel::<(Ticket, Instant)>();
    let (tx_h, rx_h) = std::sync::mpsc::channel::<(Ticket, Instant)>();
    let mut small_lat: Vec<f64> = Vec::with_capacity(n_small);
    let mut huge_lat: Vec<f64> = Vec::with_capacity(n_huge);
    let wall = Instant::now();
    std::thread::scope(|sc| {
        let eng = &engine;
        let sl = &mut small_lat;
        let hl = &mut huge_lat;
        sc.spawn(move || {
            let mut out = Vec::new();
            for (ticket, submitted) in rx_s {
                eng.wait_into(ticket, &mut out).expect("small request faulted under load");
                sl.push(submitted.elapsed().as_secs_f64());
            }
        });
        sc.spawn(move || {
            let mut out = Vec::new();
            for (ticket, submitted) in rx_h {
                eng.wait_into(ticket, &mut out).expect("huge request faulted under load");
                hl.push(submitted.elapsed().as_secs_f64());
            }
        });
        let start = Instant::now();
        for &(at, is_huge, idx) in &events {
            let due = start + Duration::from_secs_f64(at);
            while Instant::now() < due {
                std::hint::spin_loop();
            }
            if is_huge {
                let t = engine.submit(huge_session, &y0_huge);
                tx_h.send((t, Instant::now())).expect("huge collector died");
            } else {
                let sid = small_sessions[idx % small_sessions.len()];
                let t = engine.submit(sid, &y0_small);
                tx_s.send((t, Instant::now())).expect("small collector died");
            }
        }
        drop(tx_s);
        drop(tx_h);
    });
    let wall_s = wall.elapsed().as_secs_f64();
    small_lat.sort_by(f64::total_cmp);
    huge_lat.sort_by(f64::total_cmp);
    let total_paths = n_small * SMALL_W + n_huge * huge_w;
    MixedStats {
        paths_per_sec: total_paths as f64 / wall_s,
        small_p50_ms: percentile_ms(&small_lat, 0.50),
        small_p99_ms: percentile_ms(&small_lat, 0.99),
        huge_p50_ms: percentile_ms(&huge_lat, 0.50),
        huge_p99_ms: percentile_ms(&huge_lat, 0.99),
    }
}

/// Monte-Carlo serving throughput of the f32 market model at `n_paths`
/// per request: the diagonal-noise fast path (`dense: false`) against the
/// dense-control twin (`dense: true` — same fields through the full `e×d`
/// mat-vec). Returns sustained paths/sec over `reps` back-to-back
/// mega-requests on a warm engine.
fn run_diag(dense: bool, n_paths: usize, reps: usize) -> f64 {
    let model = if dense {
        MarketModel::new(DIM, 7).martingale().dense_control()
    } else {
        MarketModel::new(DIM, 7).martingale()
    };
    let mut cfg = ServeConfig::new(0.0, 1.0, N_STEPS);
    cfg.max_batch = 8192;
    cfg.chunk = 256;
    let engine = ServeEngine::<BatchReversibleHeun<f32>, _>::new(model, cfg);
    let sid = engine.open_session(4000, n_paths);
    let y0 = vec![1.0f32; DIM * n_paths];
    let mut out = Vec::new();
    let t = engine.submit(sid, &y0);
    engine.wait_into(t, &mut out).expect("warmup request faulted");
    let wall = Instant::now();
    for _ in 0..reps {
        let t = engine.submit(sid, &y0);
        engine.wait_into(t, &mut out).expect("pricing request faulted");
    }
    (reps * n_paths) as f64 / wall.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::var("QUICK").is_ok() || std::env::args().any(|a| a == "--smoke");
    let rates: &[f64] = if quick { &[500.0] } else { &[250.0, 1000.0, 4000.0] };
    let n_requests = if quick { 80 } else { 1500 };

    let mut table = BenchTable::new("Serve engine: Poisson open-loop load", 1, 0);
    let mut rows: Vec<Json> = Vec::new();
    for &rate in rates {
        let mut stats = None;
        table.bench_n(&format!("poisson/rate={rate}/req={n_requests}"), 1, |_| {
            stats = Some(run_load(rate, n_requests));
        });
        let s = stats.expect("load run did not execute");
        println!(
            "  rate={rate:>6.0}/s  {:>10.0} paths/s  p50 {:>7.3} ms  p99 {:>7.3} ms",
            s.paths_per_sec, s.p50_ms, s.p99_ms
        );
        rows.push(obj(vec![
            ("rate_hz", Json::Num(rate)),
            ("requests", Json::Num(n_requests as f64)),
            ("paths_per_request", Json::Num(WIDTH as f64)),
            ("paths_per_sec", Json::Num(s.paths_per_sec)),
            ("p50_ms", Json::Num(s.p50_ms)),
            ("p99_ms", Json::Num(s.p99_ms)),
        ]));
    }
    // --- packed_vs_fifo: the mixed-size workload, one schedule, both
    // admission policies. The headline is the interactive-class p99 ratio.
    let (small_rate, n_small, n_huge, huge_w) =
        if quick { (500.0, 60, 2, 4096) } else { (2000.0, 600, 6, 16384) };
    let mut mixed = Vec::new();
    for policy in [AdmitPolicy::Fifo, AdmitPolicy::Packed] {
        let mut stats = None;
        table.bench_n(
            &format!("packed_vs_fifo/{}/small={n_small}/huge={n_huge}x{huge_w}", policy.as_str()),
            1,
            |_| {
                stats = Some(run_mixed(policy, small_rate, n_small, n_huge, huge_w));
            },
        );
        let s = stats.expect("mixed load run did not execute");
        println!(
            "  {:>6}  {:>10.0} paths/s  small p50 {:>7.3} / p99 {:>8.3} ms  \
             huge p50 {:>8.1} / p99 {:>8.1} ms",
            policy.as_str(), s.paths_per_sec, s.small_p50_ms, s.small_p99_ms, s.huge_p50_ms,
            s.huge_p99_ms
        );
        rows.push(obj(vec![
            ("workload", Json::Str("mixed_size".into())),
            ("policy", Json::Str(policy.as_str().into())),
            ("small_rate_hz", Json::Num(small_rate)),
            ("small_requests", Json::Num(n_small as f64)),
            ("huge_requests", Json::Num(n_huge as f64)),
            ("huge_paths", Json::Num(huge_w as f64)),
            ("paths_per_sec", Json::Num(s.paths_per_sec)),
            ("small_p50_ms", Json::Num(s.small_p50_ms)),
            ("small_p99_ms", Json::Num(s.small_p99_ms)),
            ("huge_p50_ms", Json::Num(s.huge_p50_ms)),
            ("huge_p99_ms", Json::Num(s.huge_p99_ms)),
        ]));
        mixed.push(s);
    }
    let p99_ratio = mixed[0].small_p99_ms / mixed[1].small_p99_ms;
    println!("  packed_vs_fifo: interactive p99 fifo/packed = {p99_ratio:.2}x");
    rows.push(obj(vec![
        ("workload", Json::Str("mixed_size".into())),
        ("interactive_p99_fifo_over_packed", Json::Num(p99_ratio)),
    ]));

    // --- diag_fast_path: f32 market-model Monte-Carlo serving, diagonal
    // fast path vs the dense-control twin.
    let (mc_paths, mc_reps) = if quick { (16_384, 1) } else { (262_144, 3) };
    let mut rates_ps = [0.0f64; 2];
    for (i, dense) in [false, true].into_iter().enumerate() {
        let label = if dense { "dense_control" } else { "diagonal" };
        let mut pps = 0.0;
        table.bench_n(&format!("diag_fast_path/{label}/paths={mc_paths}"), 1, |_| {
            pps = run_diag(dense, mc_paths, mc_reps);
        });
        println!("  diag_fast_path/{label:>13}: {pps:>12.0} paths/s");
        rows.push(obj(vec![
            ("workload", Json::Str("diag_fast_path".into())),
            ("variant", Json::Str(label.into())),
            ("paths", Json::Num(mc_paths as f64)),
            ("paths_per_sec", Json::Num(pps)),
        ]));
        rates_ps[i] = pps;
    }
    let diag_ratio = rates_ps[0] / rates_ps[1];
    println!("  diag_fast_path: diagonal/dense throughput = {diag_ratio:.2}x");
    rows.push(obj(vec![
        ("workload", Json::Str("diag_fast_path".into())),
        ("diag_over_dense_paths_per_sec", Json::Num(diag_ratio)),
    ]));

    println!("{}", table.render());

    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_serve_throughput.json").ok();
    if quick {
        // Trimmed workloads are not comparable to the tracked trajectory —
        // never let a smoke run overwrite BENCH_pr9.json.
        println!("smoke/QUICK run: skipping BENCH_pr9.json (full run required)");
        return;
    }
    let bench_dir = std::env::var("BENCH_DIR").unwrap_or_else(|_| "..".to_string());
    match write_bench_json(&bench_dir, "pr9", &[&table], vec![("poisson_load", Json::Arr(rows))])
    {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
