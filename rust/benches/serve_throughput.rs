//! Serving-engine throughput under Poisson load: open-loop arrivals at
//! several request rates against one persistent [`ServeEngine`], reporting
//! sustained `paths/sec` and per-request p50/p99 latency.
//!
//! The workload models the serving story the engine exists for: many small
//! sampling requests (8 paths each, round-robined over 8 sessions so
//! coalescing actually happens) arriving with exponential inter-arrival
//! times — deterministic via an inverse-CDF draw from `splitmix64`, so two
//! runs see the identical arrival schedule. Each request's latency is
//! submit-to-collect wall time, measured by a dedicated collector thread
//! while the driver thread keeps the open-loop schedule.
//!
//! Expected shape: at low rates the engine is latency-bound (one request
//! per mega-batch, latency ≈ a solo solve); as the rate climbs past the
//! solve time, admission coalesces deeper batches and throughput rises
//! well past `rate × width` saturation while p99 grows gracefully instead
//! of collapsing.
//!
//! Results go to `results/bench_serve_throughput.json` and, for the perf
//! trajectory, `BENCH_pr7.json` (`BENCH_DIR` overrides the directory).
//! Pass `--smoke` (or `QUICK=1`) for the trimmed CI workload.

use std::time::{Duration, Instant};

use neuralsde::brownian::splitmix64;
use neuralsde::solvers::systems::TanhDiagonalBatch;
use neuralsde::solvers::{BatchReversibleHeun, ServeConfig, ServeEngine, Ticket};
use neuralsde::util::bench::{write_bench_json, BenchTable};
use neuralsde::util::json::{obj, Json};

const DIM: usize = 4;
const WIDTH: usize = 8; // paths per request
const N_STEPS: usize = 32;
const N_SESSIONS: usize = 8;

/// Uniform in (0, 1] from a counter-keyed splitmix64 draw.
fn uniform(seed: u64, k: u64) -> f64 {
    let bits = splitmix64(seed ^ k.wrapping_mul(0x9E37_79B9));
    ((bits >> 11) as f64 + 1.0) / (1u64 << 53) as f64
}

struct LoadStats {
    paths_per_sec: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn percentile_ms(sorted: &[f64], q: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx] * 1e3
}

/// Drive `n_requests` Poisson arrivals at `rate` req/s through a fresh
/// engine; returns sustained throughput and latency percentiles.
fn run_load(rate: f64, n_requests: usize) -> LoadStats {
    let mut cfg = ServeConfig::new(0.0, 1.0, N_STEPS);
    cfg.max_batch = N_SESSIONS * WIDTH;
    cfg.chunk = 16;
    let engine =
        ServeEngine::<BatchReversibleHeun, _>::new(TanhDiagonalBatch::new(DIM, 99), cfg);
    let sessions: Vec<_> =
        (0..N_SESSIONS).map(|s| engine.open_session(1000 + s as u64, WIDTH)).collect();
    let y0 = vec![0.1f64; DIM * WIDTH];

    // Warm the slots, sessions and worker scratch off the clock.
    for &sid in &sessions {
        let t = engine.submit(sid, &y0);
        engine.wait(t).expect("warmup request faulted");
    }

    let (tx, rx) = std::sync::mpsc::channel::<(Ticket, Instant)>();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_requests);
    let wall = Instant::now();
    std::thread::scope(|sc| {
        let eng = &engine;
        let lat = &mut latencies;
        sc.spawn(move || {
            let mut out = Vec::new();
            for (ticket, submitted) in rx {
                eng.wait_into(ticket, &mut out).expect("request faulted under load");
                lat.push(submitted.elapsed().as_secs_f64());
            }
        });
        // Open-loop driver: arrivals keep their schedule no matter how the
        // engine is doing (the property that makes p99 honest).
        let arrival_seed = 0x5EED_u64 ^ rate.to_bits();
        let mut next = Instant::now();
        for r in 0..n_requests {
            let gap = -uniform(arrival_seed, r as u64).ln() / rate;
            next += Duration::from_secs_f64(gap);
            while Instant::now() < next {
                std::hint::spin_loop();
            }
            let sid = sessions[r % sessions.len()];
            tx.send((engine.submit(sid, &y0), Instant::now())).expect("collector died");
        }
        drop(tx); // collector drains and exits
    });
    let wall_s = wall.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    LoadStats {
        paths_per_sec: (n_requests * WIDTH) as f64 / wall_s,
        p50_ms: percentile_ms(&latencies, 0.50),
        p99_ms: percentile_ms(&latencies, 0.99),
    }
}

fn main() {
    let quick = std::env::var("QUICK").is_ok() || std::env::args().any(|a| a == "--smoke");
    let rates: &[f64] = if quick { &[500.0] } else { &[250.0, 1000.0, 4000.0] };
    let n_requests = if quick { 80 } else { 1500 };

    let mut table = BenchTable::new("Serve engine: Poisson open-loop load", 1, 0);
    let mut rows: Vec<Json> = Vec::new();
    for &rate in rates {
        let mut stats = None;
        table.bench_n(&format!("poisson/rate={rate}/req={n_requests}"), 1, |_| {
            stats = Some(run_load(rate, n_requests));
        });
        let s = stats.expect("load run did not execute");
        println!(
            "  rate={rate:>6.0}/s  {:>10.0} paths/s  p50 {:>7.3} ms  p99 {:>7.3} ms",
            s.paths_per_sec, s.p50_ms, s.p99_ms
        );
        rows.push(obj(vec![
            ("rate_hz", Json::Num(rate)),
            ("requests", Json::Num(n_requests as f64)),
            ("paths_per_request", Json::Num(WIDTH as f64)),
            ("paths_per_sec", Json::Num(s.paths_per_sec)),
            ("p50_ms", Json::Num(s.p50_ms)),
            ("p99_ms", Json::Num(s.p99_ms)),
        ]));
    }
    println!("{}", table.render());

    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_serve_throughput.json").ok();
    if quick {
        // Trimmed workloads are not comparable to the tracked trajectory —
        // never let a smoke run overwrite BENCH_pr7.json.
        println!("smoke/QUICK run: skipping BENCH_pr7.json (full run required)");
        return;
    }
    let bench_dir = std::env::var("BENCH_DIR").unwrap_or_else(|_| "..".to_string());
    match write_bench_json(&bench_dir, "pr7", &[&table], vec![("poisson_load", Json::Arr(rows))])
    {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
