//! Table 10: solving + backpropagating an SDE with the Brownian Interval
//! vs the Virtual Brownian Tree as the noise source — plus the batched
//! structure-of-arrays engine vs the per-path seed loop.
//!
//! The workload is the paper's Itô test SDE with diagonal noise,
//! `dX^i = tanh((AX)^i) dt + tanh((BX)^i) dW^i`, solved by Euler–Maruyama
//! forwards over [0, 1] and then re-queried backwards (the adjoint's
//! doubly-sequential access), for d ∈ {1, 10, 16} and 10/100/1000 steps.
//!
//! Expected shape: BI ~2× faster on small problems, up to ~10× on large;
//! the batched engine ≥2× over the per-path loop at batch 1024 on a
//! multi-core host (diagonal fast path + work-stealing thread fan-out),
//! and the `batched_native/` rows (SIMD kernels + hand-batched SoA
//! vector fields, no gather/scatter) beating the `batched/` adapter rows.
//! The `adjoint/*` rows time the full forward+backward reversible-Heun
//! gradient (O(1)-memory reconstruction) against the forward-only
//! `batched_native/revheun` rows — the cost of exact gradients. The
//! `f32/*` rows run the same native solves on the precision-generic
//! engine's 8-wide `f32` lanes (double the SIMD width, half the memory
//! traffic); the `f32_vs_f64/*` headline ratios are the single-precision
//! speedup (target ≥1.5× on the native systems).
//!
//! Results are written to `results/bench_tab10_sde_solve.json` and, for the
//! perf trajectory, `BENCH_pr6.json` (override the directory with
//! `BENCH_DIR`). Pass `--smoke` (or set `QUICK=1`) for the trimmed CI
//! perf-smoke workload.

use neuralsde::brownian::{BrownianInterval, BrownianSource, VirtualBrownianTree};
use neuralsde::solvers::systems::{TanhDiagonal, TanhDiagonalBatch};
use neuralsde::solvers::{
    adjoint_solve_batched, integrate, integrate_batched, BackwardMode, BatchEulerMaruyama,
    BatchOptions, BatchReversibleHeun, CounterGridNoise, EulerMaruyama, NoiseF64,
    NoiseFromSource, ReversibleHeun,
};
use neuralsde::util::bench::{black_box, write_bench_json, BenchTable};
use neuralsde::util::json::Json;

fn solve_and_backward<B: BrownianSource>(src: &mut B, sde: &TanhDiagonal, n: usize) {
    let d = neuralsde::solvers::Sde::dim(sde);
    let y0 = vec![0.1f64; d];
    {
        let mut noise = NoiseFromSource::new(src);
        let mut solver = EulerMaruyama::new(d, d);
        let traj = integrate(sde, &mut solver, &mut noise, &y0, 0.0, 1.0, n);
        black_box(traj);
    }
    // Backward sweep re-queries the same increments right-to-left, which is
    // what the continuous adjoint does.
    let mut dw = vec![0.0f64; d];
    {
        let mut noise = NoiseFromSource::new(src);
        for k in (0..n).rev() {
            noise.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, &mut dw);
        }
    }
    black_box(dw);
}

fn main() {
    // `--smoke` (CI perf smoke job) and QUICK=1 both select the trimmed
    // workload: kernels still execute, wall time stays in seconds.
    let quick = std::env::var("QUICK").is_ok() || std::env::args().any(|a| a == "--smoke");
    let dims: &[usize] = if quick { &[1, 10] } else { &[1, 10, 16] };
    let steps: &[usize] = if quick { &[10, 100] } else { &[10, 100, 1000] };
    let mut table = BenchTable::new("Table 10: SDE solve + adjoint sweep", 32, 2);
    for &d in dims {
        let sde = TanhDiagonal::new(d, 99);
        for &n in steps {
            table.bench(&format!("bi/d={d}/n={n}"), |i| {
                let mut src = BrownianInterval::new(0.0, 1.0, d, i as u64 + 1);
                solve_and_backward(&mut src, &sde, n);
            });
            table.bench(&format!("vbt/d={d}/n={n}"), |i| {
                let mut src = VirtualBrownianTree::new(0.0, 1.0, d, i as u64 + 1, 1e-5);
                solve_and_backward(&mut src, &sde, n);
            });
        }
    }
    println!("{}", table.render());
    for &d in dims {
        for &n in steps {
            let bi = table.min_of(&format!("bi/d={d}/n={n}"));
            let vbt = table.min_of(&format!("vbt/d={d}/n={n}"));
            println!("  d={d:<3} n={n:<5} BI speedup {:.2}x", vbt / bi);
        }
    }

    // ---- Batched SoA engine vs the per-path seed loop (PR1 headline).
    //
    // The per-path baseline is exactly what the seed repo did: `batch`
    // separate `integrate` calls, one trajectory allocation and one dense
    // e×d diffusion mat-vec per path per step. The batched rows solve the
    // same 1024 paths (same per-path noise streams, bit-identical results)
    // through `integrate_batched` with the diagonal fast path, single- and
    // multi-threaded.
    let batch = if quick { 128 } else { 1024 };
    let (d, n) = (16usize, 100usize);
    let sde = TanhDiagonal::new(d, 99);
    let y0p = vec![0.1f64; d];
    let y0b = vec![0.1f64; d * batch]; // same start state, SoA
    let hw = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let thread_counts: Vec<usize> = if hw > 1 { vec![1, hw] } else { vec![1] };
    let reps = if quick { 3 } else { 8 };
    let mut btable = BenchTable::new(
        "Batched SoA engine vs per-path loop (TanhDiagonal d=16, n=100)",
        reps,
        1,
    );

    btable.bench_n(&format!("per_path/euler/batch={batch}"), reps, |i| {
        let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
        for p in 0..batch {
            let mut pn = noise.path(p);
            let mut solver = EulerMaruyama::new(d, d);
            black_box(integrate(&sde, &mut solver, &mut pn, &y0p, 0.0, 1.0, n));
        }
    });
    for &threads in &thread_counts {
        btable.bench_n(&format!("batched/euler/threads={threads}/batch={batch}"), reps, |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
            let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
            black_box(integrate_batched::<BatchEulerMaruyama, _, _>(
                &sde, &noise, &y0b, batch, 0.0, 1.0, n, &opts,
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
    }

    btable.bench_n(&format!("per_path/revheun/batch={batch}"), reps, |i| {
        let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
        for p in 0..batch {
            let mut pn = noise.path(p);
            let mut solver = ReversibleHeun::new(&sde, 0.0, &y0p);
            black_box(integrate(&sde, &mut solver, &mut pn, &y0p, 0.0, 1.0, n));
        }
    });
    for &threads in &thread_counts {
        btable.bench_n(
            &format!("batched/revheun/threads={threads}/batch={batch}"),
            reps,
            |i| {
                let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
                let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
                black_box(integrate_batched::<BatchReversibleHeun, _, _>(
                    &sde, &noise, &y0b, batch, 0.0, 1.0, n, &opts,
                ))
                // Bench-only unwrap: the tanh fields are bounded, no faults.
                .expect("fault-free by construction");
            },
        );
    }

    // Native hand-batched kernels (this PR's headline): the same solves
    // through `TanhDiagonalBatch`, whose SoA mat-vecs skip the blanket
    // adapter's gather/scatter. Same seed, bit-identical trajectories —
    // only the wall clock may differ from the `batched/` rows above.
    let nsde = TanhDiagonalBatch::new(d, 99);
    for &threads in &thread_counts {
        btable.bench_n(
            &format!("batched_native/euler/threads={threads}/batch={batch}"),
            reps,
            |i| {
                let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
                let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
                black_box(integrate_batched::<BatchEulerMaruyama, _, _>(
                    &nsde, &noise, &y0b, batch, 0.0, 1.0, n, &opts,
                ))
                // Bench-only unwrap: the tanh fields are bounded, no faults.
                .expect("fault-free by construction");
            },
        );
    }
    for &threads in &thread_counts {
        btable.bench_n(
            &format!("batched_native/revheun/threads={threads}/batch={batch}"),
            reps,
            |i| {
                let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
                let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
                black_box(integrate_batched::<BatchReversibleHeun, _, _>(
                    &nsde, &noise, &y0b, batch, 0.0, 1.0, n, &opts,
                ))
                // Bench-only unwrap: the tanh fields are bounded, no faults.
                .expect("fault-free by construction");
            },
        );
    }

    // f32 solve path (this PR's headline): the same native solves on the
    // 8-wide f32 lanes — the noise is served as f32 straight from the
    // counter streams, the state/fields stay f32 end to end, no widening
    // anywhere on the hot path.
    let y0b32 = vec![0.1f32; d * batch];
    for &threads in &thread_counts {
        btable.bench_n(&format!("f32/euler/threads={threads}/batch={batch}"), reps, |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
            let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
            black_box(integrate_batched::<BatchEulerMaruyama<f32>, _, _>(
                &nsde, &noise, &y0b32, batch, 0.0, 1.0, n, &opts,
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
    }
    for &threads in &thread_counts {
        btable.bench_n(&format!("f32/revheun/threads={threads}/batch={batch}"), reps, |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
            let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
            black_box(integrate_batched::<BatchReversibleHeun<f32>, _, _>(
                &nsde, &noise, &y0b32, batch, 0.0, 1.0, n, &opts,
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
    }

    // ---- Adjoint engine (this PR's headline): forward + backward through
    // the same native batched reversible-Heun solve, O(1)-memory backward
    // reconstruction vs the stored-tape baseline. Compare against the
    // forward-only `batched_native/revheun` rows for the gradient overhead.
    let mut atable = BenchTable::new(
        "Reversible-Heun adjoint: forward+backward (TanhDiagonal d=16, n=100)",
        reps,
        1,
    );
    let ones = |_p0: usize, _cl: usize, _z: &[f64], g: &mut [f64]| g.fill(1.0);
    for &threads in &thread_counts {
        atable.bench_n(
            &format!("adjoint/revheun/threads={threads}/batch={batch}"),
            reps,
            |i| {
                let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
                let opts = BatchOptions { threads, chunk: 64, ..Default::default() };
                black_box(adjoint_solve_batched(
                    &nsde,
                    &noise,
                    &y0b,
                    batch,
                    0.0,
                    1.0,
                    n,
                    BackwardMode::Reconstruct,
                    &opts,
                    &ones,
                ))
                // Bench-only unwrap: the tanh fields are bounded, no faults.
                .expect("fault-free by construction");
            },
        );
    }
    atable.bench_n(&format!("adjoint/revheun_tape/threads=1/batch={batch}"), reps, |i| {
        let noise = CounterGridNoise::new(i as u64 + 1, d, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 64, ..Default::default() };
        black_box(adjoint_solve_batched(
            &nsde,
            &noise,
            &y0b,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Tape,
            &opts,
            &ones,
        ))
        // Bench-only unwrap: the tanh fields are bounded, no faults.
        .expect("fault-free by construction");
    });
    println!("{}", atable.render());

    println!("{}", btable.render());
    let mut headline: Vec<(&str, Json)> = vec![
        ("batch", Json::Num(batch as f64)),
        ("hw_threads", Json::Num(hw as f64)),
    ];
    let mut speedups = Vec::new();
    for solver in ["euler", "revheun"] {
        let per_path = btable.min_of(&format!("per_path/{solver}/batch={batch}"));
        for &threads in &thread_counts {
            let adapter =
                btable.min_of(&format!("batched/{solver}/threads={threads}/batch={batch}"));
            let native = btable
                .min_of(&format!("batched_native/{solver}/threads={threads}/batch={batch}"));
            let s = per_path / adapter;
            let sn = per_path / native;
            let rel = adapter / native;
            println!(
                "  {solver:<8} threads={threads:<3} batched {s:.2}x  native {sn:.2}x  \
                 native-vs-adapter {rel:.2}x"
            );
            speedups.push((format!("speedup/{solver}/threads={threads}"), s));
            speedups.push((format!("speedup_native/{solver}/threads={threads}"), sn));
            speedups.push((format!("native_vs_adapter/{solver}/threads={threads}"), rel));
        }
    }
    // f32-vs-f64 lane-width win: the native f64 solve over the f32 solve,
    // per solver and thread count — the headline ratio of the precision-
    // generic engine (8-wide lanes + half the memory traffic; target ≥1.5×).
    for solver in ["euler", "revheun"] {
        for &threads in &thread_counts {
            let f64t = btable
                .min_of(&format!("batched_native/{solver}/threads={threads}/batch={batch}"));
            let f32t = btable.min_of(&format!("f32/{solver}/threads={threads}/batch={batch}"));
            let ratio = f64t / f32t;
            println!("  f32       {solver:<8} threads={threads:<3} f64/f32 {ratio:.2}x");
            speedups.push((format!("f32_vs_f64/{solver}/threads={threads}"), ratio));
        }
    }
    // Gradient overhead: adjoint (forward+backward) over forward-only, per
    // thread count — the number that tells training users what exact
    // gradients cost on top of sampling.
    for &threads in &thread_counts {
        let fwd = btable.min_of(&format!("batched_native/revheun/threads={threads}/batch={batch}"));
        let adj = atable.min_of(&format!("adjoint/revheun/threads={threads}/batch={batch}"));
        let ratio = adj / fwd;
        println!("  adjoint   threads={threads:<3} fwd+bwd/fwd {ratio:.2}x");
        speedups.push((format!("adjoint_overhead/revheun/threads={threads}"), ratio));
    }
    let speedup_json: Vec<(String, f64)> = speedups;
    let extras: Vec<Json> = speedup_json
        .iter()
        .map(|(k, v)| {
            neuralsde::util::json::obj(vec![
                ("name", Json::Str(k.clone())),
                ("speedup", Json::Num(*v)),
            ])
        })
        .collect();
    headline.push(("speedups", Json::Arr(extras)));

    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab10_sde_solve.json").ok();
    if quick {
        // Trimmed workloads are not comparable to the tracked trajectory —
        // never let a smoke run overwrite BENCH_pr6.json.
        println!("smoke/QUICK run: skipping BENCH_pr6.json (full run required)");
        return;
    }
    let bench_dir = std::env::var("BENCH_DIR").unwrap_or_else(|_| "..".to_string());
    match write_bench_json(&bench_dir, "pr6", &[&table, &btable, &atable], headline) {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write BENCH json: {e}"),
    }
}
