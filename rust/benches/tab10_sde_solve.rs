//! Table 10: solving + backpropagating an SDE with the Brownian Interval
//! vs the Virtual Brownian Tree as the noise source.
//!
//! The workload is the paper's Itô test SDE with diagonal noise,
//! `dX^i = tanh((AX)^i) dt + tanh((BX)^i) dW^i`, solved by Euler–Maruyama
//! forwards over [0, 1] and then re-queried backwards (the adjoint's
//! doubly-sequential access), for d ∈ {1, 10, 16} and 10/100/1000 steps.
//!
//! Expected shape: BI ~2× faster on small problems, up to ~10× on large.

use neuralsde::brownian::{BrownianInterval, BrownianSource, VirtualBrownianTree};
use neuralsde::solvers::systems::TanhDiagonal;
use neuralsde::solvers::{integrate, EulerMaruyama, NoiseF64, NoiseFromSource};
use neuralsde::util::bench::{black_box, BenchTable};

fn solve_and_backward<B: BrownianSource>(src: &mut B, sde: &TanhDiagonal, n: usize) {
    let d = neuralsde::solvers::Sde::dim(sde);
    let y0 = vec![0.1f64; d];
    {
        let mut noise = NoiseFromSource::new(src);
        let mut solver = EulerMaruyama::new(d, d);
        let traj = integrate(sde, &mut solver, &mut noise, &y0, 0.0, 1.0, n);
        black_box(traj);
    }
    // Backward sweep re-queries the same increments right-to-left, which is
    // what the continuous adjoint does.
    let mut dw = vec![0.0f64; d];
    {
        let mut noise = NoiseFromSource::new(src);
        for k in (0..n).rev() {
            noise.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, &mut dw);
        }
    }
    black_box(dw);
}

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let dims: &[usize] = if quick { &[1, 10] } else { &[1, 10, 16] };
    let steps: &[usize] = if quick { &[10, 100] } else { &[10, 100, 1000] };
    let mut table = BenchTable::new("Table 10: SDE solve + adjoint sweep", 32, 2);
    for &d in dims {
        let sde = TanhDiagonal::new(d, 99);
        for &n in steps {
            table.bench(&format!("bi/d={d}/n={n}"), |i| {
                let mut src = BrownianInterval::new(0.0, 1.0, d, i as u64 + 1);
                solve_and_backward(&mut src, &sde, n);
            });
            table.bench(&format!("vbt/d={d}/n={n}"), |i| {
                let mut src = VirtualBrownianTree::new(0.0, 1.0, d, i as u64 + 1, 1e-5);
                solve_and_backward(&mut src, &sde, n);
            });
        }
    }
    println!("{}", table.render());
    for &d in dims {
        for &n in steps {
            let bi = table.min_of(&format!("bi/d={d}/n={n}"));
            let vbt = table.min_of(&format!("vbt/d={d}/n={n}"));
            println!("  d={d:<3} n={n:<5} BI speedup {:.2}x", vbt / bi);
        }
    }
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_tab10_sde_solve.json").ok();
}
