//! Microbenchmarks of the Layer-3 hot paths, for the EXPERIMENTS.md §Perf
//! iteration log: Brownian Interval query cost (hit/miss), bridge sampling,
//! persistent-vs-rebuilt noise fills, batched stepping, LRU ops, signature
//! features, optimiser steps.

use neuralsde::brownian::{box_muller_fill, BrownianInterval, BrownianSource, LruCache};
use neuralsde::coordinator::noise::{NoiseBackend, StepNoise};
use neuralsde::metrics::{series_features, signature};
use neuralsde::nn::{Adadelta, Optimizer};
use neuralsde::solvers::systems::{TanhDiagonal, TanhDiagonalBatch};
use neuralsde::solvers::{
    adjoint_solve, adjoint_solve_batched, guard, integrate_batched, simd, BackwardMode,
    BatchOptions, BatchReversibleHeun, CounterGridNoise, GuardConfig,
};
use neuralsde::util::bench::{black_box, BenchTable};

fn main() {
    let mut table = BenchTable::new("hot-path micro", 32, 4);

    // Brownian Interval sequential queries (the training fill pattern).
    for &batch in &[256usize, 4096] {
        let mut out = vec![0.0f32; batch];
        table.bench(&format!("bi/seq_fill/batch={batch}/n=31"), |i| {
            let mut bi = BrownianInterval::new(0.0, 1.0, batch, i as u64);
            for k in 0..31 {
                bi.increment(k as f64 / 31.0, (k + 1) as f64 / 31.0, &mut out);
            }
            black_box(&out);
        });
    }

    // Persistent interval: reseed + bulk grid fill per "training step",
    // keeping tree/cache/buffers across steps (vs the rebuild above).
    let grid: Vec<f64> = (0..=31).map(|k| k as f64 / 31.0).collect();
    for &batch in &[256usize, 4096] {
        let mut out = vec![0.0f32; 31 * batch];
        let mut bi = BrownianInterval::new(0.0, 1.0, batch, 1);
        table.bench(&format!("bi/reseed_fill_grid/batch={batch}/n=31"), |i| {
            bi.reseed(i as u64 + 1);
            bi.fill_grid(&grid, &mut out);
            black_box(&out);
        });
    }

    // StepNoise end-to-end — what GanTrainer::train_step calls per step.
    {
        let ts32: Vec<f32> = (0..32).map(|k| k as f32 / 31.0).collect();
        let mut sn = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4096, 7);
        let mut dws = vec![0.0f32; 31 * 4096];
        table.bench("noise/step_noise_fill/batch=4096/n=31", |_| {
            sn.fill(&ts32, &mut dws);
            black_box(&dws);
        });
    }

    // Executor dispatch: the persistent work-stealing pool vs the pre-PR-10
    // per-call scoped-spawn baseline, across fan-out widths. At width 1 the
    // pool runs inline (pure function-call cost); the spawn baseline pays a
    // thread spawn/join either way — the gap is the dispatch overhead every
    // `map_chunks` call used to pay.
    {
        use neuralsde::solvers::pool;
        use std::sync::atomic::{AtomicUsize, Ordering};
        let threads = 4usize;
        for &width in &[1usize, 8, 64, 512] {
            let sink = AtomicUsize::new(0);
            table.bench(&format!("pool/persistent/threads=4/width={width}"), |_| {
                pool::run_tasks(threads, width, &|i| {
                    sink.fetch_add(i + 1, Ordering::Relaxed);
                });
                black_box(sink.load(Ordering::Relaxed));
            });
            table.bench(&format!("pool/scoped_spawn/threads=4/width={width}"), |_| {
                // The historical dispatch: spawn/join a scoped worker set
                // with a shared claim counter, on every call.
                let next = AtomicUsize::new(0);
                let workers = threads.min(width);
                std::thread::scope(|s| {
                    for _ in 0..workers {
                        s.spawn(|| loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= width {
                                break;
                            }
                            sink.fetch_add(i + 1, Ordering::Relaxed);
                        });
                    }
                });
                black_box(sink.load(Ordering::Relaxed));
            });
        }
    }

    // Batched reversible Heun over SoA state (diagonal fast path), through
    // the blanket per-path adapter and through the native hand-batched
    // system — the adapter/native gap is the gather/scatter cost.
    {
        let sde = TanhDiagonal::new(16, 3);
        let y0 = vec![0.1f64; 16 * 256];
        table.bench("batch/revheun_solve/d=16/batch=256/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            black_box(integrate_batched::<BatchReversibleHeun, _, _>(
                &sde,
                &noise,
                &y0,
                256,
                0.0,
                1.0,
                32,
                &BatchOptions { threads: 1, chunk: 64, ..Default::default() },
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
        let nsde = TanhDiagonalBatch::new(16, 3);
        table.bench("batch/revheun_native/d=16/batch=256/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            black_box(integrate_batched::<BatchReversibleHeun, _, _>(
                &nsde,
                &noise,
                &y0,
                256,
                0.0,
                1.0,
                32,
                &BatchOptions { threads: 1, chunk: 64, ..Default::default() },
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
        // The same native solve on the 8-wide f32 lanes (the precision-
        // generic engine's single-precision path, noise served as f32).
        let y032 = vec![0.1f32; 16 * 256];
        table.bench("batch/revheun_native_f32/d=16/batch=256/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            black_box(integrate_batched::<BatchReversibleHeun<f32>, _, _>(
                &nsde,
                &noise,
                &y032,
                256,
                0.0,
                1.0,
                32,
                &BatchOptions { threads: 1, chunk: 64, ..Default::default() },
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
    }

    // Non-finite guard cost: the raw blockwise sweep over one step's worth
    // of lanes, and the full guarded-vs-unguarded solve — the `guard/*`
    // rows pin the <2% overhead contract of the default `check_every = 8`.
    {
        let sde = TanhDiagonalBatch::new(16, 3);
        let y0 = vec![0.1f64; 16 * 256];
        let lanes = vec![0.1f64; 16 * 256];
        table.bench("guard/nonfinite_sweep/4096", |_| {
            black_box(guard::any_nonfinite(&lanes));
        });
        for (label, guard_cfg) in [
            ("guard/revheun_unguarded/d=16/batch=256/n=32", GuardConfig::disabled()),
            ("guard/revheun_guarded/d=16/batch=256/n=32", GuardConfig::default()),
        ] {
            table.bench(label, |i| {
                let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
                black_box(integrate_batched::<BatchReversibleHeun, _, _>(
                    &sde,
                    &noise,
                    &y0,
                    256,
                    0.0,
                    1.0,
                    32,
                    &BatchOptions { threads: 1, chunk: 64, guard: guard_cfg },
                ))
                // Bench-only unwrap: the tanh fields are bounded, no faults.
                .expect("fault-free by construction");
            });
        }
    }

    // Adjoint engine: forward + backward (O(1)-memory reconstruction and
    // stored-tape) vs the forward-only solves above — the gradient
    // overhead per training step.
    {
        let sde = TanhDiagonal::new(16, 3);
        let nsde = TanhDiagonalBatch::new(16, 3);
        let y0p = vec![0.1f64; 16];
        let y0 = vec![0.1f64; 16 * 256];
        let ones = |_p0: usize, _cl: usize, _z: &[f64], g: &mut [f64]| g.fill(1.0);
        table.bench("adjoint/revheun_per_path/d=16/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            let mut pn = noise.path(0);
            black_box(adjoint_solve(
                &sde,
                &y0p,
                0.0,
                1.0,
                32,
                &mut pn,
                BackwardMode::Reconstruct,
                |_z, g| g.fill(1.0),
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
        table.bench("adjoint/revheun_native/d=16/batch=256/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            black_box(adjoint_solve_batched(
                &nsde,
                &noise,
                &y0,
                256,
                0.0,
                1.0,
                32,
                BackwardMode::Reconstruct,
                &BatchOptions { threads: 1, chunk: 64, ..Default::default() },
                &ones,
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
        table.bench("adjoint/revheun_native_tape/d=16/batch=256/n=32", |i| {
            let noise = CounterGridNoise::new(i as u64 + 1, 16, 0.0, 1.0, 32);
            black_box(adjoint_solve_batched(
                &nsde,
                &noise,
                &y0,
                256,
                0.0,
                1.0,
                32,
                BackwardMode::Tape,
                &BatchOptions { threads: 1, chunk: 64, ..Default::default() },
                &ones,
            ))
            // Bench-only unwrap: the tanh fields are bounded, no faults.
            .expect("fault-free by construction");
        });
    }

    // SIMD kernel floor: the fused SoA primitives the batched steppers are
    // built from, at the d=16 × batch=256 lane size the solve rows use.
    {
        let n = 16 * 256;
        let f = vec![0.37f64; n];
        let g0 = vec![0.21f64; n];
        let g1 = vec![0.19f64; n];
        let w = vec![0.023f64; n];
        let mut y = vec![0.1f64; n];
        table.bench("simd/axpy/4096", |_| {
            simd::axpy(1.0e-3, &f, &mut y);
            black_box(&y);
        });
        table.bench("simd/avg_mul_add/4096", |_| {
            simd::avg_mul_add(&g0, &g1, &w, &mut y);
            black_box(&y);
        });
        table.bench("simd/matvec_row/d=16/batch=256", |_| {
            simd::matvec_row(&f[..16 * 256], &g0[..16 * 256], &mut y[..256], 16);
            black_box(&y);
        });
    }

    // The same kernels instantiated at f32 (8-wide unroll): same element
    // count, half the bytes — the per-kernel floor under the f32/* solve
    // rows in tab10.
    {
        let n = 16 * 256;
        let f = vec![0.37f32; n];
        let g0 = vec![0.21f32; n];
        let g1 = vec![0.19f32; n];
        let w = vec![0.023f32; n];
        let mut y = vec![0.1f32; n];
        table.bench("simd/axpy_f32x8/4096", |_| {
            simd::axpy(1.0e-3f32, &f, &mut y);
            black_box(&y);
        });
        table.bench("simd/avg_mul_add_f32x8/4096", |_| {
            simd::avg_mul_add(&g0, &g1, &w, &mut y);
            black_box(&y);
        });
        table.bench("simd/matvec_row_f32x8/d=16/batch=256", |_| {
            simd::matvec_row(&f[..16 * 256], &g0[..16 * 256], &mut y[..256], 16);
            black_box(&y);
        });
    }

    // Raw Gaussian generation (the floor under every bridge sample).
    let mut buf = vec![0.0f32; 4096];
    table.bench("prng/box_muller/4096", |i| {
        box_muller_fill(i as u64, 1.0, &mut buf);
        black_box(&buf);
    });

    // LRU get/put mix.
    table.bench("lru/get_put_mix/10k", |i| {
        let mut c: LruCache<u32, u64> = LruCache::new(128);
        let mut s = i as u64 + 1;
        for k in 0..10_000u32 {
            s = neuralsde::brownian::splitmix64(s);
            if s & 1 == 0 {
                c.put((s % 512) as u32, s);
            } else {
                black_box(c.get(&((s % 512) as u32)));
            }
            black_box(k);
        }
    });

    // Signature features of one series (the metric hot path).
    let series: Vec<f32> = (0..32).map(|k| (k as f32 * 0.3).sin()).collect();
    table.bench("metrics/sig_features/len32_depth3", |_| {
        black_box(series_features(&series, 32, 1, 3));
    });
    let path: Vec<f64> = (0..64).flat_map(|k| [k as f64, (k as f64).cos()]).collect();
    table.bench("metrics/signature/len64_c2_depth5", |_| {
        black_box(signature(&path, 64, 2, 5));
    });

    // Optimiser step on a training-sized parameter vector.
    let n = 4834;
    let mut params = vec![0.1f32; n];
    let grad = vec![0.01f32; n];
    let mut opt = Adadelta::new(1.0, n);
    table.bench("optim/adadelta/4834", |_| {
        opt.step(&mut params, &grad);
    });

    println!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    table.write_json("results/bench_hotpath_micro.json").ok();
}
