//! SGD weight-trajectory dataset (substitute for Appendix F.3).
//!
//! The paper records every weight of a small CNN across 50 epochs of SGD on
//! MNIST, over 10 training runs, and treats each weight's trajectory as a
//! univariate time series. We reproduce the *law-level* structure without
//! MNIST: each trajectory is a weight coordinate relaxing under SGD on a
//! random quadratic with gradient noise,
//!
//! ```text
//! w_{k+1} = w_k − lr · (curv · (w_k − w*) + noise_k),
//! ```
//!
//! with per-run random curvature/targets and per-weight random
//! initialisation — producing the decaying-toward-a-random-limit,
//! noise-perturbed curves the real dataset consists of, over the same
//! length (50).

use super::TimeSeriesDataset;
use crate::brownian::SplitPrng;

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct WeightsParams {
    /// Trajectory length (paper: 50 epochs).
    pub seq_len: usize,
    /// Simulated training runs (paper: 10).
    pub runs: usize,
    /// SGD learning rate in the simulated quadratic.
    pub lr: f64,
    /// Gradient-noise scale.
    pub noise: f64,
}

impl Default for WeightsParams {
    fn default() -> Self {
        Self { seq_len: 50, runs: 10, lr: 0.15, noise: 0.35 }
    }
}

/// Generate `n` weight trajectories (distributed round-robin over runs).
pub fn generate(n: usize, seed: u64, p: WeightsParams) -> TimeSeriesDataset {
    let mut rng = SplitPrng::new(seed);
    // Per-run curvature scale and noise floor (training runs differ).
    let run_curv: Vec<f64> = (0..p.runs)
        .map(|_| 0.3 + 0.5 * rng.next_uniform())
        .collect();
    let run_noise: Vec<f64> = (0..p.runs)
        .map(|_| p.noise * (0.5 + rng.next_uniform()))
        .collect();
    let mut values = Vec::with_capacity(n * p.seq_len);
    for i in 0..n {
        let run = i % p.runs;
        let (z0, z1) = rng.next_normal_pair();
        let w_star = 0.8 * z1; // this weight's limit
        let mut w = z0; // init ~ N(0, 1)
        let curv = run_curv[run] * (0.5 + rng.next_uniform());
        let noise = run_noise[run];
        for _ in 0..p.seq_len {
            values.push(w as f32);
            let (g, _) = rng.next_normal_pair();
            // Noise anneals over training, as empirically in SGD traces.
            let anneal = 1.0 / (1.0 + 0.04 * values.len() as f64 / n as f64);
            w -= p.lr * (curv * (w - w_star) + noise * anneal * g);
        }
    }
    TimeSeriesDataset {
        n,
        seq_len: p.seq_len,
        channels: 1,
        values,
        times: (0..p.seq_len).map(|k| k as f64).collect(),
        labels: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let d = generate(20, 3, WeightsParams::default());
        assert_eq!((d.n, d.seq_len, d.channels), (20, 50, 1));
    }

    #[test]
    fn trajectories_contract_toward_limits() {
        // Spread of |w_t - w_50| should shrink over time on average.
        let d = generate(500, 5, WeightsParams::default());
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..d.n {
            let s = d.series(i);
            let limit = s[49];
            early += (s[1] - limit).abs() as f64;
            late += (s[40] - limit).abs() as f64;
        }
        assert!(late < early * 0.8, "early={early}, late={late}");
    }

    #[test]
    fn deterministic() {
        assert_eq!(
            generate(5, 11, WeightsParams::default()).values,
            generate(5, 11, WeightsParams::default()).values
        );
    }
}
