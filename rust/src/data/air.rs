//! Air-quality-like dataset (substitute for Appendix F.4).
//!
//! The paper uses the UCI Beijing multi-site air-quality dataset: bivariate
//! (PM2.5, O₃) series of length 24 (hourly over a day), labelled by which of
//! 12 measurement stations produced them. The O₃ channel was chosen for its
//! *non-autonomous* behaviour — a peak in the latter half of the day.
//!
//! The synthetic substitute preserves exactly those properties:
//!
//! * channel 0 ("PM2.5"): positive, persistent AR(1) level with
//!   station-dependent baseline;
//! * channel 1 ("O₃"): a late-day Gaussian bump whose amplitude/phase depend
//!   on the station, over a diurnal baseline, plus noise — non-autonomous
//!   by construction;
//! * 12 station labels with distinct (baseline, amplitude, phase) triples,
//!   so label classification (the TSTR metric of Table 5) is meaningful.

use super::TimeSeriesDataset;
use crate::brownian::SplitPrng;

/// Generation parameters.
#[derive(Clone, Copy, Debug)]
pub struct AirParams {
    /// Observations per day (paper: 24).
    pub seq_len: usize,
    /// Number of station classes (paper: 12).
    pub stations: usize,
}

impl Default for AirParams {
    fn default() -> Self {
        Self { seq_len: 24, stations: 12 }
    }
}

/// Generate `n` labelled bivariate series.
pub fn generate(n: usize, seed: u64, p: AirParams) -> TimeSeriesDataset {
    let mut rng = SplitPrng::new(seed);
    // Station signatures.
    let mut base = Vec::new(); // PM2.5 baseline
    let mut amp = Vec::new(); // O3 peak amplitude
    let mut phase = Vec::new(); // O3 peak hour
    for s in 0..p.stations {
        base.push(0.6 + 1.1 * (s as f64 / p.stations as f64) + 0.15 * rng.next_uniform());
        amp.push(1.0 + 0.9 * ((s * 5 % p.stations) as f64 / p.stations as f64));
        phase.push(14.0 + 6.0 * ((s * 7 % p.stations) as f64 / p.stations as f64));
    }
    let mut values = Vec::with_capacity(n * p.seq_len * 2);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let st = i % p.stations;
        labels.push(st as u32);
        // PM2.5: AR(1) around the station baseline, kept positive.
        let (z, _) = rng.next_normal_pair();
        let mut pm = (base[st] + 0.3 * z).max(0.05);
        // Per-day modulation of the ozone peak.
        let (za, zp) = rng.next_normal_pair();
        let day_amp = (amp[st] * (1.0 + 0.15 * za)).max(0.1);
        let day_phase = phase[st] + 0.7 * zp;
        for k in 0..p.seq_len {
            let t = k as f64;
            let (e1, e2) = rng.next_normal_pair();
            pm = (0.85 * pm + 0.15 * base[st] + 0.12 * e1).max(0.02);
            // O3: diurnal baseline + late-day station bump + noise.
            let diurnal = 0.25 * (std::f64::consts::TAU * (t - 6.0) / 24.0).sin();
            let bump = day_amp * (-(t - day_phase).powi(2) / (2.0 * 3.0f64.powi(2))).exp();
            let o3 = 0.3 + diurnal + bump + 0.08 * e2;
            values.push(pm as f32);
            values.push(o3 as f32);
        }
    }
    TimeSeriesDataset {
        n,
        seq_len: p.seq_len,
        channels: 2,
        values,
        times: (0..p.seq_len).map(|k| k as f64).collect(),
        labels: Some(labels),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = generate(24, 3, AirParams::default());
        assert_eq!((d.n, d.seq_len, d.channels), (24, 24, 2));
        let labels = d.labels.as_ref().unwrap();
        assert_eq!(labels.len(), 24);
        assert!(labels.iter().all(|&l| l < 12));
        // Round-robin: every station appears twice in 24 series.
        for s in 0..12u32 {
            assert_eq!(labels.iter().filter(|&&l| l == s).count(), 2);
        }
    }

    #[test]
    fn ozone_peaks_late_day() {
        // Mean O3 over hours 12..22 should exceed mean over hours 0..10 —
        // the non-autonomous structure the paper selected the channel for.
        let d = generate(600, 5, AirParams::default());
        let (mut early, mut late) = (0.0f64, 0.0f64);
        for i in 0..d.n {
            let s = d.series(i);
            for k in 0..10 {
                early += s[k * 2 + 1] as f64;
            }
            for k in 12..22 {
                late += s[k * 2 + 1] as f64;
            }
        }
        assert!(late > 1.3 * early, "early={early}, late={late}");
    }

    #[test]
    fn pm_channel_positive() {
        let d = generate(100, 9, AirParams::default());
        for i in 0..d.n {
            let s = d.series(i);
            for k in 0..d.seq_len {
                assert!(s[k * 2] > 0.0);
            }
        }
    }

    #[test]
    fn stations_are_separable_in_mean() {
        // Distinct stations should have distinct mean PM levels (so label
        // classification has signal).
        let d = generate(1200, 13, AirParams::default());
        let mut by_station = vec![(0.0f64, 0usize); 12];
        for i in 0..d.n {
            let st = d.labels.as_ref().unwrap()[i] as usize;
            let s = d.series(i);
            let m: f64 = (0..d.seq_len).map(|k| s[k * 2] as f64).sum::<f64>()
                / d.seq_len as f64;
            by_station[st].0 += m;
            by_station[st].1 += 1;
        }
        let means: Vec<f64> =
            by_station.iter().map(|(s, c)| s / *c as f64).collect();
        let spread = crate::util::stats::max(&means) - crate::util::stats::min(&means);
        assert!(spread > 0.5, "station means too close: {means:?}");
    }
}
