//! Datasets.
//!
//! The paper evaluates on three datasets; this module provides the
//! substitutions documented in DESIGN.md §4 (the originals are either
//! external downloads or expensive to regenerate):
//!
//! * [`ou`] — the time-dependent Ornstein–Uhlenbeck dataset (Appendix F.7),
//!   which *is* the paper's own synthetic dataset, generated exactly as
//!   specified;
//! * [`weights`] — SGD weight-trajectory-like series standing in for the
//!   MNIST-CNN weights dataset (Appendix F.3);
//! * [`air`] — a bivariate daily series with a late-day ozone-like peak and
//!   12 latent station classes, standing in for the UCI Beijing air-quality
//!   dataset (Appendix F.4).
//!
//! Normalisation follows Appendix F.2: statistics of the *initial value*
//! only, with observation times mapped to mean zero / unit range.

pub mod air;
pub mod ou;
pub mod weights;

use crate::brownian::SplitPrng;

/// A dataset of regularly-sampled time series.
///
/// `values` is `[n_series][seq_len][channels]` flattened row-major; `times`
/// has length `seq_len` and is shared by all series.
#[derive(Clone, Debug)]
pub struct TimeSeriesDataset {
    /// Number of series.
    pub n: usize,
    /// Observations per series.
    pub seq_len: usize,
    /// Channels per observation.
    pub channels: usize,
    /// Flattened values.
    pub values: Vec<f32>,
    /// Shared observation times.
    pub times: Vec<f64>,
    /// Optional class labels (length `n`).
    pub labels: Option<Vec<u32>>,
}

impl TimeSeriesDataset {
    /// Borrow series `i` as a `[seq_len * channels]` slice.
    pub fn series(&self, i: usize) -> &[f32] {
        let stride = self.seq_len * self.channels;
        &self.values[i * stride..(i + 1) * stride]
    }

    /// Normalise in place so the initial values have mean 0 / unit variance
    /// per channel, and times have mean zero and unit range (Appendix F.2).
    /// Returns the per-channel `(mean, std)` used.
    pub fn normalise_initial(&mut self) -> Vec<(f32, f32)> {
        let stride = self.seq_len * self.channels;
        let mut stats = Vec::with_capacity(self.channels);
        for c in 0..self.channels {
            let mut mean = 0.0f64;
            for i in 0..self.n {
                mean += self.values[i * stride + c] as f64;
            }
            mean /= self.n as f64;
            let mut var = 0.0f64;
            for i in 0..self.n {
                var += (self.values[i * stride + c] as f64 - mean).powi(2);
            }
            var /= self.n as f64;
            let sd = var.sqrt().max(1e-7);
            for i in 0..self.n {
                for k in 0..self.seq_len {
                    let v = &mut self.values[i * stride + k * self.channels + c];
                    *v = ((*v as f64 - mean) / sd) as f32;
                }
            }
            stats.push((mean as f32, sd as f32));
        }
        // Times: mean zero, unit range.
        let tmin = self.times.iter().cloned().fold(f64::INFINITY, f64::min);
        let tmax = self.times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let range = (tmax - tmin).max(1e-12);
        let tmean = self.times.iter().sum::<f64>() / self.times.len() as f64;
        for t in &mut self.times {
            *t = (*t - tmean) / range;
        }
        stats
    }

    /// Split into train/val/test by the paper's 70/15/15 (Appendix F.2).
    pub fn split(&self) -> (TimeSeriesDataset, TimeSeriesDataset, TimeSeriesDataset) {
        let n_train = (self.n as f64 * 0.70).round() as usize;
        let n_val = (self.n as f64 * 0.15).round() as usize;
        let take = |lo: usize, hi: usize| -> TimeSeriesDataset {
            let stride = self.seq_len * self.channels;
            TimeSeriesDataset {
                n: hi - lo,
                seq_len: self.seq_len,
                channels: self.channels,
                values: self.values[lo * stride..hi * stride].to_vec(),
                times: self.times.clone(),
                labels: self.labels.as_ref().map(|l| l[lo..hi].to_vec()),
            }
        };
        (
            take(0, n_train),
            take(n_train, (n_train + n_val).min(self.n)),
            take((n_train + n_val).min(self.n), self.n),
        )
    }

    /// Sample a batch of `batch` series (values flattened
    /// `[batch][seq_len][channels]`, plus their labels if present).
    pub fn sample_batch(
        &self,
        batch: usize,
        rng: &mut SplitPrng,
    ) -> (Vec<f32>, Option<Vec<u32>>) {
        let stride = self.seq_len * self.channels;
        let mut values = Vec::with_capacity(batch * stride);
        let mut labels = self.labels.as_ref().map(|_| Vec::with_capacity(batch));
        for _ in 0..batch {
            let i = (rng.next_u64() % self.n as u64) as usize;
            values.extend_from_slice(self.series(i));
            if let (Some(ls), Some(src)) = (&mut labels, &self.labels) {
                ls.push(src[i]);
            }
        }
        (values, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TimeSeriesDataset {
        TimeSeriesDataset {
            n: 4,
            seq_len: 3,
            channels: 2,
            values: (0..24).map(|i| i as f32).collect(),
            times: vec![0.0, 1.0, 2.0],
            labels: Some(vec![0, 1, 0, 1]),
        }
    }

    #[test]
    fn series_slicing() {
        let d = tiny();
        assert_eq!(d.series(1), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn normalise_initial_values() {
        let mut d = tiny();
        d.normalise_initial();
        let stride = d.seq_len * d.channels;
        for c in 0..2 {
            let mean: f32 =
                (0..d.n).map(|i| d.values[i * stride + c]).sum::<f32>() / d.n as f32;
            let var: f32 =
                (0..d.n).map(|i| d.values[i * stride + c].powi(2)).sum::<f32>() / d.n as f32;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-4);
        }
        // Times: mean 0, range 1.
        let tsum: f64 = d.times.iter().sum();
        assert!(tsum.abs() < 1e-12);
        assert!((d.times[2] - d.times[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn split_sizes() {
        let d = TimeSeriesDataset {
            n: 100,
            seq_len: 2,
            channels: 1,
            values: vec![0.0; 200],
            times: vec![0.0, 1.0],
            labels: None,
        };
        let (tr, va, te) = d.split();
        assert_eq!((tr.n, va.n, te.n), (70, 15, 15));
    }

    #[test]
    fn batch_sampling_shapes() {
        let d = tiny();
        let mut rng = SplitPrng::new(1);
        let (v, l) = d.sample_batch(8, &mut rng);
        assert_eq!(v.len(), 8 * 6);
        assert_eq!(l.unwrap().len(), 8);
    }
}
