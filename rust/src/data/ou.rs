//! The time-dependent Ornstein–Uhlenbeck dataset (Appendix F.7):
//! univariate length-32 samples of
//!
//! ```text
//! dY = (ρ t − κ Y) dt + χ dW,   ρ = 0.02, κ = 0.1, χ = 0.4,  t ∈ [0, 31].
//! ```
//!
//! Generated exactly as the paper specifies (this dataset is itself
//! synthetic in the paper). Integration uses Euler–Maruyama with 16
//! substeps per observation, from `Y_0 ~ N(0, 1)`.

use super::TimeSeriesDataset;
use crate::brownian::SplitPrng;

/// OU process parameters (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct OuParams {
    /// Linear-in-time drift coefficient.
    pub rho: f64,
    /// Mean-reversion rate.
    pub kappa: f64,
    /// Noise level.
    pub chi: f64,
    /// Observations per series.
    pub seq_len: usize,
    /// Euler substeps between observations.
    pub substeps: usize,
}

impl Default for OuParams {
    fn default() -> Self {
        Self { rho: 0.02, kappa: 0.1, chi: 0.4, seq_len: 32, substeps: 16 }
    }
}

/// Generate `n` OU sample paths.
pub fn generate(n: usize, seed: u64, p: OuParams) -> TimeSeriesDataset {
    let mut rng = SplitPrng::new(seed);
    let mut values = Vec::with_capacity(n * p.seq_len);
    let dt_obs = 1.0; // t ∈ [0, seq_len - 1], unit spacing as in the paper
    let dt = dt_obs / p.substeps as f64;
    for _ in 0..n {
        let (y0, _) = rng.next_normal_pair();
        let mut y = y0;
        values.push(y as f32);
        let mut t = 0.0f64;
        for _ in 1..p.seq_len {
            for _ in 0..p.substeps {
                let (z, _) = rng.next_normal_pair();
                y += (p.rho * t - p.kappa * y) * dt + p.chi * dt.sqrt() * z;
                t += dt;
            }
            values.push(y as f32);
        }
    }
    TimeSeriesDataset {
        n,
        seq_len: p.seq_len,
        channels: 1,
        values,
        times: (0..p.seq_len).map(|k| k as f64).collect(),
        labels: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let d = generate(10, 1, OuParams::default());
        assert_eq!(d.n, 10);
        assert_eq!(d.seq_len, 32);
        assert_eq!(d.channels, 1);
        assert_eq!(d.values.len(), 320);
    }

    #[test]
    fn stationary_spread_reasonable() {
        // Stationary std of the (κ, χ) OU core is χ/√(2κ) ≈ 0.894; with the
        // ρt drift the late-time mean trends up toward ρt/κ.
        let d = generate(2000, 7, OuParams::default());
        let last: Vec<f64> = (0..d.n).map(|i| d.series(i)[31] as f64).collect();
        let mean = crate::util::stats::mean(&last);
        let sd = crate::util::stats::std_dev(&last);
        // E[Y_t] = ρ(t/κ − (1 − e^{−κt})/κ²) ≈ 4.29 at t = 31.
        assert!((mean - 4.29).abs() < 0.3, "mean={mean}");
        assert!((sd - 0.894).abs() < 0.2, "sd={sd}");
    }

    #[test]
    fn deterministic() {
        let a = generate(3, 9, OuParams::default());
        let b = generate(3, 9, OuParams::default());
        assert_eq!(a.values, b.values);
    }
}
