//! Native neural vector fields: the SDE-GAN generator and the neural-CDE
//! discriminator as in-Rust [`Sde`]/[`BatchSde`] + [`SdeVjp`]/[`BatchSdeVjp`]
//! systems.
//!
//! These are the Layer-2 models of `python/compile/model.py` rebuilt on the
//! native stack — no JAX, no AOT executables:
//!
//! * [`NeuralGenerator`] — `dX = μ_θ(t, X) dt + σ_θ(t, X) ∘ dW` with
//!   LipSwish-MLP fields (`μ` unbounded, `σ` tanh-bounded, dense `x×w`
//!   noise), parameters addressed inside the **full flat θ vector** of
//!   [`GanNetSpec::gen_layout`] — so the θ-gradient the adjoint engine
//!   returns is directly the optimiser's flat gradient (the `ζ`/`ℓ`
//!   segments, which the solve doesn't touch, stay zero and are filled by
//!   the trainer's chain rule at the ends);
//! * [`NeuralDiscriminator`] — the CDE response
//!   `dH = f_φ(t, H) dt + g_φ(t, H) dY` (equation (2)): formally an [`Sde`]
//!   whose "Brownian" increments are the driving path's `ΔY`, served by
//!   [`super::StoredBatchNoise`]. The loss cotangent on the driving path
//!   comes back through the adjoint engine's increment cotangents
//!   ([`SdeVjp::diffusion_dw_vjp`] / [`AdjointGrad::ddw`]);
//! * [`NeuralGeneratorBatch`] / [`NeuralDiscriminatorBatch`] — native SoA
//!   twins whose MLP evaluations run on [`Mlp::forward_batch`] /
//!   [`Mlp::vjp_batch`]: vectorised across paths on the broadcast kernels of
//!   [`super::simd`], never within a path, so batched solves and batched
//!   adjoints are **bit-for-bit equal** to the per-path systems (pinned in
//!   `tests/neural_gan.rs` on the same 1/3/4/7/8/33 remainder batches as the
//!   analytic systems).
//!
//! Time enters every field as the JAX models pass it: prepended to the state
//! (`input = [t, y…]`), and its input-gradient slot is discarded.
//!
//! Serving a trained generator (many concurrent sampling requests rather
//! than one training batch) goes through the persistent [`super::serve`]
//! engine, which coalesces requests into mega-batches — bit-identical to
//! solo solves — and shards million-path Monte-Carlo requests across
//! admission rounds; diagonal-noise systems can ride its 8-wide `f32`
//! fast path.
//!
//! [`AdjointGrad::ddw`]: super::AdjointGrad::ddw

use super::adjoint::{BatchSdeVjp, SdeVjp};
use super::simd::Lane;
use super::{BatchSde, Sde};
use crate::nn::{Activation, GanNetSpec, Mlp};

/// Widen a flat `f32` parameter vector (the training state) to the `f64` the
/// solver layer computes in.
pub fn widen_params(params: &[f32]) -> Vec<f64> {
    params.iter().map(|&p| p as f64).collect()
}

fn with_time(t: f64, y: &[f64], inp: &mut [f64]) {
    inp[0] = t;
    inp[1..1 + y.len()].copy_from_slice(y);
}

fn with_time_batch<T: Lane>(t: f64, y: &[T], inp: &mut [T], dim: usize, batch: usize) {
    debug_assert_eq!(y.len(), dim * batch);
    inp[..batch].fill(T::from_f64(t));
    inp[batch..(1 + dim) * batch].copy_from_slice(y);
}

// ---------------------------------------------------------------------------
// Generator
// ---------------------------------------------------------------------------

/// The SDE-GAN generator's vector fields over the full flat θ of
/// [`GanNetSpec::gen_layout`].
pub struct NeuralGenerator {
    x_dim: usize,
    w_dim: usize,
    mu: Mlp,
    sigma: Mlp,
    params: Vec<f64>,
}

impl NeuralGenerator {
    /// Build from the spec and the full flat θ (`f64`, length
    /// `gen_layout().total`).
    pub fn new(spec: &GanNetSpec, params: Vec<f64>) -> Self {
        let layout = spec.gen_layout();
        assert_eq!(params.len(), layout.total, "theta length != gen layout");
        let mu = Mlp::from_layout(&layout, "mu", Activation::Identity).expect("mu layout");
        let sigma = Mlp::from_layout(&layout, "sigma", Activation::Tanh).expect("sigma layout");
        Self { x_dim: spec.state, w_dim: spec.noise, mu, sigma, params }
    }

    /// Build from the trainer's flat `f32` θ.
    pub fn from_f32(spec: &GanNetSpec, params: &[f32]) -> Self {
        Self::new(spec, widen_params(params))
    }

    /// The flat parameter vector (the [`SdeVjp`] θ-gradient layout).
    pub fn params_flat(&self) -> &[f64] {
        &self.params
    }
}

impl Sde for NeuralGenerator {
    fn dim(&self) -> usize {
        self.x_dim
    }
    fn noise_dim(&self) -> usize {
        self.w_dim
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        let mut inp = vec![0.0f64; 1 + self.x_dim];
        with_time(t, y, &mut inp);
        self.mu.forward(&self.params, &inp, out);
    }
    fn diffusion(&self, t: f64, y: &[f64], out: &mut [f64]) {
        // σ_θ's output reshapes row-major to the dense `x×w` matrix — the
        // same `[e * d]` layout `Sde::diffusion` expects.
        let mut inp = vec![0.0f64; 1 + self.x_dim];
        with_time(t, y, &mut inp);
        self.sigma.forward(&self.params, &inp, out);
    }
}

impl SdeVjp for NeuralGenerator {
    fn param_len(&self) -> usize {
        self.params.len()
    }

    fn drift_vjp(&self, t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]) {
        let mut inp = vec![0.0f64; 1 + self.x_dim];
        with_time(t, y, &mut inp);
        let mut gx = vec![0.0f64; 1 + self.x_dim];
        self.mu.vjp(&self.params, &inp, wf, &mut gx, gth);
        for i in 0..self.x_dim {
            gy[i] += gx[1 + i];
        }
    }

    fn diffusion_vjp(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
    ) {
        // Cotangent of the MLP output through `h = G·dw` is the rank-one
        // `v dwᵀ` in the row-major output layout.
        let (x, w) = (self.x_dim, self.w_dim);
        let mut wout = vec![0.0f64; x * w];
        for i in 0..x {
            for j in 0..w {
                wout[i * w + j] = v[i] * dw[j];
            }
        }
        let mut inp = vec![0.0f64; 1 + x];
        with_time(t, y, &mut inp);
        let mut gx = vec![0.0f64; 1 + x];
        self.sigma.vjp(&self.params, &inp, &wout, &mut gx, gth);
        for i in 0..x {
            gy[i] += gx[1 + i];
        }
    }
}

/// Native SoA twin of [`NeuralGenerator`] — MLPs evaluated over whole path
/// lanes, bit-identical per path to the blanket adapter.
///
/// Holds θ at **both** precisions: the widened `f64` copy drives the exact
/// backward VJPs (and the historical `f64` forward), the native `f32` copy
/// drives the 8-wide [`BatchSde<f32>`] forward without any per-step widening.
pub struct NeuralGeneratorBatch {
    inner: NeuralGenerator,
    params32: Vec<f32>,
}

impl NeuralGeneratorBatch {
    /// Wrap a per-path system (shares its parameters; the `f32` copy is the
    /// narrowing of the `f64` vector — exact when θ originated in `f32`).
    pub fn from_system(inner: NeuralGenerator) -> Self {
        let params32 = inner.params.iter().map(|&p| p as f32).collect();
        Self { inner, params32 }
    }

    /// Build directly from the trainer's flat `f32` θ — the `f32` copy keeps
    /// the trainer's exact bits, the `f64` copy is its exact widening.
    pub fn from_f32(spec: &GanNetSpec, params: &[f32]) -> Self {
        let mut sys = Self::from_system(NeuralGenerator::from_f32(spec, params));
        sys.params32.copy_from_slice(params);
        sys
    }

    /// Refresh both parameter copies in place from the trainer's flat `f32`
    /// θ — no reallocation, no layout re-validation (the per-step
    /// replacement for rebuilding via [`from_f32`](Self::from_f32)).
    pub fn set_params_f32(&mut self, params: &[f32]) {
        assert_eq!(params.len(), self.inner.params.len(), "theta length changed");
        for (w, &p) in self.inner.params.iter_mut().zip(params.iter()) {
            *w = p as f64;
        }
        self.params32.copy_from_slice(params);
    }

    /// The wrapped per-path system.
    pub fn system(&self) -> &NeuralGenerator {
        &self.inner
    }
}

impl BatchSde for NeuralGeneratorBatch {
    fn state_dim(&self) -> usize {
        self.inner.x_dim
    }
    fn brownian_dim(&self) -> usize {
        self.inner.w_dim
    }
    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let x = self.inner.x_dim;
        let mut inp = vec![0.0f64; (1 + x) * batch];
        with_time_batch(t, y, &mut inp, x, batch);
        self.inner.mu.forward_batch(&self.inner.params, &inp, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        // MLP output row `i*w + j` lands on lane `(i*w + j)*batch` — exactly
        // the batch engine's dense `g[(i*d + j)*batch + p]` layout.
        let x = self.inner.x_dim;
        let mut inp = vec![0.0f64; (1 + x) * batch];
        with_time_batch(t, y, &mut inp, x, batch);
        self.inner.sigma.forward_batch(&self.inner.params, &inp, out, batch);
    }
}

/// The 8-wide `f32` forward — same generic MLP kernels over the native
/// `f32` θ copy, no widening anywhere on the hot path. Batched ≡ per-path
/// bitwise at `f32` exactly as the `f64` impl is at `f64`.
impl BatchSde<f32> for NeuralGeneratorBatch {
    fn state_dim(&self) -> usize {
        self.inner.x_dim
    }
    fn brownian_dim(&self) -> usize {
        self.inner.w_dim
    }
    fn drift_batch(&self, t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let x = self.inner.x_dim;
        let mut inp = vec![0.0f32; (1 + x) * batch];
        with_time_batch(t, y, &mut inp, x, batch);
        self.inner.mu.forward_batch(&self.params32, &inp, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let x = self.inner.x_dim;
        let mut inp = vec![0.0f32; (1 + x) * batch];
        with_time_batch(t, y, &mut inp, x, batch);
        self.inner.sigma.forward_batch(&self.params32, &inp, out, batch);
    }
}

impl BatchSdeVjp for NeuralGeneratorBatch {
    fn param_len(&self) -> usize {
        self.inner.params.len()
    }

    fn drift_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let x = self.inner.x_dim;
        let b = batch;
        let mut inp = vec![0.0f64; (1 + x) * b];
        with_time_batch(t, y, &mut inp, x, b);
        let mut gx = vec![0.0f64; (1 + x) * b];
        self.inner.mu.vjp_batch(&self.inner.params, &inp, wf, &mut gx, gth, b);
        for i in 0..x {
            super::simd::add(&gx[(1 + i) * b..(2 + i) * b], &mut gy[i * b..(i + 1) * b]);
        }
    }

    fn diffusion_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let (x, w) = (self.inner.x_dim, self.inner.w_dim);
        let b = batch;
        let mut wout = vec![0.0f64; x * w * b];
        for i in 0..x {
            for j in 0..w {
                let lane = &mut wout[(i * w + j) * b..(i * w + j + 1) * b];
                for p in 0..b {
                    lane[p] = v[i * b + p] * dw[j * b + p];
                }
            }
        }
        let mut inp = vec![0.0f64; (1 + x) * b];
        with_time_batch(t, y, &mut inp, x, b);
        let mut gx = vec![0.0f64; (1 + x) * b];
        self.inner.sigma.vjp_batch(&self.inner.params, &inp, &wout, &mut gx, gth, b);
        for i in 0..x {
            super::simd::add(&gx[(1 + i) * b..(2 + i) * b], &mut gy[i * b..(i + 1) * b]);
        }
    }

    fn diffusion_dw_vjp_batch(&self, t: f64, y: &[f64], v: &[f64], gdw: &mut [f64], batch: usize) {
        // Forward σ once, then the per-path contraction over lanes —
        // ascending `i` per lane, matching the per-path default's order.
        let (x, w) = (self.inner.x_dim, self.inner.w_dim);
        let b = batch;
        let mut inp = vec![0.0f64; (1 + x) * b];
        with_time_batch(t, y, &mut inp, x, b);
        let mut g = vec![0.0f64; x * w * b];
        self.inner.sigma.forward_batch(&self.inner.params, &inp, &mut g, b);
        for j in 0..w {
            for p in 0..b {
                let mut acc = gdw[j * b + p];
                for i in 0..x {
                    acc += g[(i * w + j) * b + p] * v[i * b + p];
                }
                gdw[j * b + p] = acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Discriminator (neural CDE)
// ---------------------------------------------------------------------------

/// The SDE-GAN discriminator's CDE response fields over the full flat φ of
/// [`GanNetSpec::disc_layout`]. An [`Sde`] whose driving increments are the
/// observed path's `ΔY` (`noise_dim == data_dim`).
pub struct NeuralDiscriminator {
    h_dim: usize,
    y_dim: usize,
    f: Mlp,
    g: Mlp,
    params: Vec<f64>,
}

impl NeuralDiscriminator {
    /// Build from the spec and the full flat φ (`f64`, length
    /// `disc_layout().total`).
    pub fn new(spec: &GanNetSpec, params: Vec<f64>) -> Self {
        let layout = spec.disc_layout();
        assert_eq!(params.len(), layout.total, "phi length != disc layout");
        let f = Mlp::from_layout(&layout, "f", Activation::Tanh).expect("f layout");
        let g = Mlp::from_layout(&layout, "g", Activation::Tanh).expect("g layout");
        Self { h_dim: spec.disc_state, y_dim: spec.data_dim, f, g, params }
    }

    /// Build from the trainer's flat `f32` φ.
    pub fn from_f32(spec: &GanNetSpec, params: &[f32]) -> Self {
        Self::new(spec, widen_params(params))
    }

    /// The flat parameter vector (the [`SdeVjp`] θ-gradient layout).
    pub fn params_flat(&self) -> &[f64] {
        &self.params
    }
}

impl Sde for NeuralDiscriminator {
    fn dim(&self) -> usize {
        self.h_dim
    }
    fn noise_dim(&self) -> usize {
        self.y_dim
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        let mut inp = vec![0.0f64; 1 + self.h_dim];
        with_time(t, y, &mut inp);
        self.f.forward(&self.params, &inp, out);
    }
    fn diffusion(&self, t: f64, y: &[f64], out: &mut [f64]) {
        let mut inp = vec![0.0f64; 1 + self.h_dim];
        with_time(t, y, &mut inp);
        self.g.forward(&self.params, &inp, out);
    }
}

impl SdeVjp for NeuralDiscriminator {
    fn param_len(&self) -> usize {
        self.params.len()
    }

    fn drift_vjp(&self, t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]) {
        let mut inp = vec![0.0f64; 1 + self.h_dim];
        with_time(t, y, &mut inp);
        let mut gx = vec![0.0f64; 1 + self.h_dim];
        self.f.vjp(&self.params, &inp, wf, &mut gx, gth);
        for i in 0..self.h_dim {
            gy[i] += gx[1 + i];
        }
    }

    fn diffusion_vjp(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
    ) {
        let (e, d) = (self.h_dim, self.y_dim);
        let mut wout = vec![0.0f64; e * d];
        for i in 0..e {
            for j in 0..d {
                wout[i * d + j] = v[i] * dw[j];
            }
        }
        let mut inp = vec![0.0f64; 1 + e];
        with_time(t, y, &mut inp);
        let mut gx = vec![0.0f64; 1 + e];
        self.g.vjp(&self.params, &inp, &wout, &mut gx, gth);
        for i in 0..e {
            gy[i] += gx[1 + i];
        }
    }
}

/// Native SoA twin of [`NeuralDiscriminator`], bit-identical per path to the
/// blanket adapter. Like [`NeuralGeneratorBatch`], it holds φ at both
/// precisions so the `f32` forward never widens.
pub struct NeuralDiscriminatorBatch {
    inner: NeuralDiscriminator,
    params32: Vec<f32>,
}

impl NeuralDiscriminatorBatch {
    /// Wrap a per-path system (shares its parameters; the `f32` copy is the
    /// narrowing of the `f64` vector — exact when φ originated in `f32`).
    pub fn from_system(inner: NeuralDiscriminator) -> Self {
        let params32 = inner.params.iter().map(|&p| p as f32).collect();
        Self { inner, params32 }
    }

    /// Build directly from the trainer's flat `f32` φ — the `f32` copy keeps
    /// the trainer's exact bits, the `f64` copy is its exact widening.
    pub fn from_f32(spec: &GanNetSpec, params: &[f32]) -> Self {
        let mut sys = Self::from_system(NeuralDiscriminator::from_f32(spec, params));
        sys.params32.copy_from_slice(params);
        sys
    }

    /// Refresh both parameter copies in place from the trainer's flat `f32`
    /// φ — no reallocation, no layout re-validation.
    pub fn set_params_f32(&mut self, params: &[f32]) {
        assert_eq!(params.len(), self.inner.params.len(), "phi length changed");
        for (w, &p) in self.inner.params.iter_mut().zip(params.iter()) {
            *w = p as f64;
        }
        self.params32.copy_from_slice(params);
    }

    /// The wrapped per-path system.
    pub fn system(&self) -> &NeuralDiscriminator {
        &self.inner
    }
}

impl BatchSde for NeuralDiscriminatorBatch {
    fn state_dim(&self) -> usize {
        self.inner.h_dim
    }
    fn brownian_dim(&self) -> usize {
        self.inner.y_dim
    }
    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let e = self.inner.h_dim;
        let mut inp = vec![0.0f64; (1 + e) * batch];
        with_time_batch(t, y, &mut inp, e, batch);
        self.inner.f.forward_batch(&self.inner.params, &inp, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let e = self.inner.h_dim;
        let mut inp = vec![0.0f64; (1 + e) * batch];
        with_time_batch(t, y, &mut inp, e, batch);
        self.inner.g.forward_batch(&self.inner.params, &inp, out, batch);
    }
}

/// The 8-wide `f32` CDE forward over the native `f32` φ copy.
impl BatchSde<f32> for NeuralDiscriminatorBatch {
    fn state_dim(&self) -> usize {
        self.inner.h_dim
    }
    fn brownian_dim(&self) -> usize {
        self.inner.y_dim
    }
    fn drift_batch(&self, t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let e = self.inner.h_dim;
        let mut inp = vec![0.0f32; (1 + e) * batch];
        with_time_batch(t, y, &mut inp, e, batch);
        self.inner.f.forward_batch(&self.params32, &inp, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let e = self.inner.h_dim;
        let mut inp = vec![0.0f32; (1 + e) * batch];
        with_time_batch(t, y, &mut inp, e, batch);
        self.inner.g.forward_batch(&self.params32, &inp, out, batch);
    }
}

impl BatchSdeVjp for NeuralDiscriminatorBatch {
    fn param_len(&self) -> usize {
        self.inner.params.len()
    }

    fn drift_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let e = self.inner.h_dim;
        let b = batch;
        let mut inp = vec![0.0f64; (1 + e) * b];
        with_time_batch(t, y, &mut inp, e, b);
        let mut gx = vec![0.0f64; (1 + e) * b];
        self.inner.f.vjp_batch(&self.inner.params, &inp, wf, &mut gx, gth, b);
        for i in 0..e {
            super::simd::add(&gx[(1 + i) * b..(2 + i) * b], &mut gy[i * b..(i + 1) * b]);
        }
    }

    fn diffusion_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let (e, d) = (self.inner.h_dim, self.inner.y_dim);
        let b = batch;
        let mut wout = vec![0.0f64; e * d * b];
        for i in 0..e {
            for j in 0..d {
                let lane = &mut wout[(i * d + j) * b..(i * d + j + 1) * b];
                for p in 0..b {
                    lane[p] = v[i * b + p] * dw[j * b + p];
                }
            }
        }
        let mut inp = vec![0.0f64; (1 + e) * b];
        with_time_batch(t, y, &mut inp, e, b);
        let mut gx = vec![0.0f64; (1 + e) * b];
        self.inner.g.vjp_batch(&self.inner.params, &inp, &wout, &mut gx, gth, b);
        for i in 0..e {
            super::simd::add(&gx[(1 + i) * b..(2 + i) * b], &mut gy[i * b..(i + 1) * b]);
        }
    }

    fn diffusion_dw_vjp_batch(&self, t: f64, y: &[f64], v: &[f64], gdw: &mut [f64], batch: usize) {
        let (e, d) = (self.inner.h_dim, self.inner.y_dim);
        let b = batch;
        let mut inp = vec![0.0f64; (1 + e) * b];
        with_time_batch(t, y, &mut inp, e, b);
        let mut g = vec![0.0f64; e * d * b];
        self.inner.g.forward_batch(&self.inner.params, &inp, &mut g, b);
        for j in 0..d {
            for p in 0..b {
                let mut acc = gdw[j * b + p];
                for i in 0..e {
                    acc += g[(i * d + j) * b + p] * v[i * b + p];
                }
                gdw[j * b + p] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{aos_to_soa, BatchSde, Sde};
    use super::*;
    use crate::brownian::SplitPrng;

    fn tiny_spec() -> GanNetSpec {
        GanNetSpec {
            data_dim: 1,
            state: 3,
            hidden: 4,
            noise: 2,
            init_noise: 2,
            disc_state: 3,
            disc_hidden: 4,
        }
    }

    fn random_params(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = SplitPrng::new(seed);
        (0..n).map(|_| rng.next_normal_pair().0 * 0.3).collect()
    }

    #[test]
    fn generator_field_shapes_and_time_dependence() {
        let spec = tiny_spec();
        let gen = NeuralGenerator::new(&spec, random_params(spec.gen_layout().total, 3));
        assert_eq!(Sde::dim(&gen), 3);
        assert_eq!(Sde::noise_dim(&gen), 2);
        let y = [0.1, -0.2, 0.3];
        let mut f0 = [0.0; 3];
        let mut f1 = [0.0; 3];
        gen.drift(0.0, &y, &mut f0);
        gen.drift(0.5, &y, &mut f1);
        assert_ne!(f0, f1, "time must enter the drift");
        let mut g = [0.0; 6];
        gen.diffusion(0.0, &y, &mut g);
        assert!(g.iter().all(|v| v.abs() <= 1.0), "tanh-bounded diffusion");
    }

    #[test]
    fn batched_fields_bit_identical_to_per_path() {
        let spec = tiny_spec();
        let theta = random_params(spec.gen_layout().total, 5);
        let gen = NeuralGenerator::new(&spec, theta.clone());
        let genb = NeuralGeneratorBatch::from_system(NeuralGenerator::new(&spec, theta));
        for &b in &[1usize, 3, 4, 7, 8, 33] {
            let aos: Vec<f64> = (0..3 * b).map(|i| 0.03 * (i % 11) as f64 - 0.1).collect();
            let soa = aos_to_soa(&aos, 3, b);
            let mut fb = vec![0.0; 3 * b];
            let mut gb = vec![0.0; 6 * b];
            genb.drift_batch(0.3, &soa, &mut fb, b);
            genb.diffusion_batch(0.3, &soa, &mut gb, b);
            for p in 0..b {
                let yp = &aos[p * 3..(p + 1) * 3];
                let mut fp = [0.0; 3];
                let mut gp = [0.0; 6];
                gen.drift(0.3, yp, &mut fp);
                gen.diffusion(0.3, yp, &mut gp);
                for i in 0..3 {
                    assert_eq!(fb[i * b + p], fp[i], "drift b={b} p={p} i={i}");
                }
                for r in 0..6 {
                    assert_eq!(gb[r * b + p], gp[r], "diffusion b={b} p={p} r={r}");
                }
            }
        }
    }

    #[test]
    fn discriminator_noise_dim_is_data_dim() {
        let spec = tiny_spec();
        let disc = NeuralDiscriminator::new(&spec, random_params(spec.disc_layout().total, 9));
        assert_eq!(Sde::dim(&disc), 3);
        assert_eq!(Sde::noise_dim(&disc), 1);
        let discb = NeuralDiscriminatorBatch::from_system(NeuralDiscriminator::new(
            &spec,
            random_params(spec.disc_layout().total, 9),
        ));
        assert_eq!(BatchSde::<f64>::state_dim(&discb), 3);
        assert_eq!(BatchSde::<f64>::brownian_dim(&discb), 1);
        assert_eq!(BatchSde::<f32>::state_dim(&discb), 3);
        assert_eq!(BatchSde::<f32>::brownian_dim(&discb), 1);
    }

    #[test]
    fn f32_batched_fields_bit_identical_to_per_path_mlp() {
        // The f32 forward lanes against per-path generic MLP evaluation at
        // f32 — the batched ≡ per-path pin at single precision, on batches
        // straddling the 8-wide unroll.
        let spec = tiny_spec();
        let theta: Vec<f32> =
            random_params(spec.gen_layout().total, 5).iter().map(|&v| v as f32).collect();
        let genb = NeuralGeneratorBatch::from_f32(&spec, &theta);
        let theta32 = genb.params32.clone();
        let (x, w) = (3usize, 2usize);
        for &b in &[1usize, 3, 4, 7, 8, 33] {
            let aos: Vec<f32> = (0..x * b).map(|i| 0.03 * (i % 11) as f32 - 0.1).collect();
            let mut soa = vec![0.0f32; x * b];
            for p in 0..b {
                for i in 0..x {
                    soa[i * b + p] = aos[p * x + i];
                }
            }
            let mut fb = vec![0.0f32; x * b];
            let mut gb = vec![0.0f32; x * w * b];
            genb.drift_batch(0.3, &soa, &mut fb, b);
            genb.diffusion_batch(0.3, &soa, &mut gb, b);
            for p in 0..b {
                let mut inp = vec![0.0f32; 1 + x];
                inp[0] = 0.3f64 as f32; // Lane::from_f64's exact rounding
                inp[1..].copy_from_slice(&aos[p * x..(p + 1) * x]);
                let mut fp = [0.0f32; 3];
                let mut gp = [0.0f32; 6];
                genb.system().mu.forward(&theta32, &inp, &mut fp);
                genb.system().sigma.forward(&theta32, &inp, &mut gp);
                for i in 0..x {
                    assert_eq!(fb[i * b + p], fp[i], "f32 drift b={b} p={p} i={i}");
                }
                for r in 0..x * w {
                    assert_eq!(gb[r * b + p], gp[r], "f32 diffusion b={b} p={p} r={r}");
                }
            }
        }
    }
}
