//! Numerical SDE solvers in pure Rust.
//!
//! These implement the paper's solver contribution — the **reversible Heun
//! method** (Section 3, Algorithms 1 and 2) — alongside the baselines it is
//! compared against (Euler–Maruyama, the midpoint method, standard Heun).
//! They operate on plain `f64` state over user-supplied vector fields, and
//! power the numerical experiments that don't involve neural networks:
//! convergence order studies (Figures 5/6), the absolute-stability analysis
//! (Appendix D.5), and the Table-10 solve-speed benchmark. The *neural*
//! (batched, trained) solves run through the AOT-compiled JAX twins of
//! these steppers (`python/compile/sdeint.py`) driven by
//! [`crate::coordinator`]; pytest cross-checks the two implementations.
//!
//! Two driver APIs share the same steppers' arithmetic:
//!
//! * [`integrate`] — one path at a time over `Vec<f64>` state;
//! * [`integrate_batched`] (the batch engine) — a structure-of-arrays
//!   `[dim × batch]` solve with a diagonal-noise fast path, SIMD inner
//!   loops ([`simd`]) and work-stealing chunk dispatch on the process-wide
//!   persistent executor ([`pool`] — spawn-once parked workers, no per-call
//!   thread spawn/join), bit-for-bit equal to per-path integration for
//!   every solver, thread count and
//!   steal schedule. The batch engine is **precision-generic** over the
//!   sealed [`simd::Lane`] element type: `f64` runs the historical 4-wide
//!   kernels, `f32` runs 8-wide lanes end to end (systems, steppers, noise
//!   — no widening on the hot path), with the same association rule in both
//!   instantiations.
//!
//! Gradients are native too: the [`adjoint`] module runs the reversible
//! Heun method *backwards* (Algorithm 2), reconstructing the forward
//! trajectory in O(1) memory and accumulating exact discrete gradients
//! through the analytic vector-Jacobian products of [`SdeVjp`] /
//! [`BatchSdeVjp`] — see [`adjoint_solve`] and [`adjoint_solve_batched`].
//! Losses that read the whole trajectory (path-dependent discriminators)
//! inject per-step cotangents during the backward sweep
//! ([`adjoint_solve_steps`] / [`adjoint_solve_batched_steps`]), and solves
//! driven by data increments recover the cotangent on the driving path via
//! [`AdjointGrad::ddw`]. The [`neural`] module implements the SDE-GAN's
//! LipSwish-MLP generator and neural-CDE discriminator as native systems on
//! this stack.

pub mod adjoint;
mod batch;
mod classic;
mod convergence;
pub mod guard;
pub mod neural;
pub mod pool;
mod reversible_heun;
pub mod serve;
pub mod simd;
mod stability;
pub mod systems;

pub use adjoint::{
    adjoint_solve, adjoint_solve_batched, adjoint_solve_batched_mixed,
    adjoint_solve_batched_steps, adjoint_solve_batched_steps_mixed, adjoint_solve_steps,
    max_vjp_fd_error, AdjointGrad, BackwardMode, BatchSdeVjp, GridReplayNoise, SdeVjp,
    MIXED_DRIFT_TOL,
};
pub use batch::{
    aos_to_soa, integrate_batched, integrate_batched_guarded, map_chunks, map_chunks_isolated,
    soa_to_aos, terminal_states, BatchEulerMaruyama, BatchHeun, BatchMidpoint, BatchNoise,
    BatchOptions, BatchReversibleHeun, BatchSde, BatchStepper, ChunkPanic, CounterGridNoise,
    PathNoiseF64, StoredBatchNoise, StoredPathNoise,
};
pub use guard::{
    FaultCause, FaultPlan, FaultyBatchNoise, GuardConfig, GuardedSolve, PanicOnSentinel,
    SolveError, SolveFault,
};
pub use classic::{EulerMaruyama, Heun, Midpoint};
pub use serve::{
    request_seed, AdmitPolicy, ServeConfig, ServeEngine, SessionId, SessionNoise, Ticket,
    NOISE_BLOCK,
};
pub use simd::Lane;
pub use convergence::{
    estimate_orders, strong_weak_errors, ConvergenceReport, FineBrownianGrid,
};
pub use reversible_heun::{ReversibleHeun, RevHeunState};
pub use stability::{revheun_stability_bounded, Complex};

/// A (Stratonovich, unless a solver documents otherwise) SDE
/// `dY = f(t, Y) dt + g(t, Y) dW` with `Y ∈ R^dim`, `W ∈ R^noise_dim`.
pub trait Sde {
    /// State dimension `e`.
    fn dim(&self) -> usize;
    /// Brownian dimension `d`.
    fn noise_dim(&self) -> usize;
    /// Drift `f(t, y)` into `out` (`dim` long).
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]);
    /// Diffusion matrix `g(t, y)` into `out`, row-major `dim x noise_dim`.
    fn diffusion(&self, t: f64, y: &[f64], out: &mut [f64]);

    /// True when `noise_dim() == dim()` and [`diffusion`](Self::diffusion)
    /// is diagonal (`g[i][j] == 0` for `i != j`) — the dominant case in the
    /// paper's models. The batched engine then skips the dense `e×d`
    /// mat-vec in favour of an elementwise product with
    /// [`diffusion_diag`](Self::diffusion_diag).
    fn diffusion_is_diagonal(&self) -> bool {
        false
    }

    /// The diagonal of the diffusion matrix into `out` (`dim` long). Only
    /// meaningful when [`diffusion_is_diagonal`](Self::diffusion_is_diagonal)
    /// returns true; the default extracts it from the dense matrix, so
    /// diagonal SDEs should override it to avoid the dense evaluation.
    fn diffusion_diag(&self, t: f64, y: &[f64], out: &mut [f64]) {
        let e = self.dim();
        let d = self.noise_dim();
        debug_assert_eq!(e, d, "diffusion_diag requires noise_dim == dim");
        let mut dense = vec![0.0; e * d];
        self.diffusion(t, y, &mut dense);
        for i in 0..e {
            out[i] = dense[i * d + i];
        }
    }
}

/// Apply a diffusion matrix to a noise increment: `out += mat · dw`.
#[inline]
pub fn apply_diffusion(mat: &[f64], dw: &[f64], out: &mut [f64]) {
    let d = dw.len();
    for (i, o) in out.iter_mut().enumerate() {
        let row = &mat[i * d..(i + 1) * d];
        let mut acc = 0.0;
        for j in 0..d {
            acc += row[j] * dw[j];
        }
        *o += acc;
    }
}

/// `f64` Brownian increments for the solver layer.
///
/// Implemented by [`FineBrownianGrid`] natively and by any
/// [`crate::brownian::BrownianSource`] via [`NoiseFromSource`].
pub trait NoiseF64 {
    /// Write `W(t) - W(s)` into `out`.
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]);
}

/// Adapter: use an `f32` Brownian source (e.g. the Brownian Interval) as
/// solver noise.
pub struct NoiseFromSource<'a, B: crate::brownian::BrownianSource> {
    src: &'a mut B,
    buf: Vec<f32>,
}

impl<'a, B: crate::brownian::BrownianSource> NoiseFromSource<'a, B> {
    /// Wrap a Brownian source.
    pub fn new(src: &'a mut B) -> Self {
        let n = src.size();
        Self { src, buf: vec![0.0; n] }
    }
}

impl<'a, B: crate::brownian::BrownianSource> NoiseF64 for NoiseFromSource<'a, B> {
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]) {
        self.src.increment(s, t, &mut self.buf);
        for (o, &x) in out.iter_mut().zip(self.buf.iter()) {
            *o = x as f64;
        }
    }
}

/// A fixed-step solver: advances `(t, y)` by `dt` given the Brownian
/// increment for the step.
pub trait FixedStepSolver {
    /// Vector-field evaluations per step (the quantity the paper's speedups
    /// are measured in — reversible Heun costs 1, midpoint/Heun cost 2).
    const FIELD_EVALS_PER_STEP: usize;

    /// Advance `y` in place from `t` to `t + dt` using increment `dw`.
    fn step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64], y: &mut [f64]);
}

/// Integrate `sde` from `y0` over `[t0, t1]` in `n_steps` fixed steps,
/// returning the state at every grid point (including `y0`), flattened
/// `[(n_steps + 1) * dim]`.
pub fn integrate<S: Sde, M: FixedStepSolver, N: NoiseF64>(
    sde: &S,
    solver: &mut M,
    noise: &mut N,
    y0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
) -> Vec<f64> {
    assert_eq!(y0.len(), sde.dim());
    let dt = (t1 - t0) / n_steps as f64;
    let mut traj = Vec::with_capacity((n_steps + 1) * sde.dim());
    traj.extend_from_slice(y0);
    let mut y = y0.to_vec();
    let mut dw = vec![0.0f64; sde.noise_dim()];
    for k in 0..n_steps {
        let s = t0 + k as f64 * dt;
        let t = t0 + (k + 1) as f64 * dt;
        noise.increment(s, t, &mut dw);
        solver.step(sde, s, t - s, &dw, &mut y);
        traj.extend_from_slice(&y);
    }
    traj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_diffusion_matches_matvec() {
        // 2x3 matrix.
        let mat = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let dw = [1.0, 0.0, -1.0];
        let mut out = [10.0, 20.0];
        apply_diffusion(&mat, &dw, &mut out);
        assert_eq!(out, [10.0 + (1.0 - 3.0), 20.0 + (4.0 - 6.0)]);
    }
}
