//! SIMD kernels for the batch engine's structure-of-arrays hot loops —
//! **precision-generic** over a sealed [`Lane`] element type.
//!
//! The SoA layout in [`super::batch`] was chosen so that, for any component
//! `i`, the values of all paths live contiguously (`y[i * batch + p]` for
//! `p = 0..batch`). Every inner loop of the batched steppers is therefore a
//! unit-stride sweep over a lane of `batch` elements, and those sweeps are
//! what this module implements: unrolled fused kernels whose unroll width is
//! the element type's [`Lane::LANES`] — **4 for `f64`** (one AVX2 register,
//! `f64x4`-shaped) and **8 for `f32`** (`f32x8`: double the lane width and
//! half the memory traffic per path). `std::simd` is still nightly-only;
//! `LANES` independent scalar statements per iteration is the shape LLVM
//! reliably turns into packed `vfmadd`/`vmulps`/`vmulpd` ops on stable.
//!
//! # Bit-identity invariants
//!
//! The batch engine guarantees batched results are **bit-for-bit equal** to
//! per-path integration *at the same element precision*. These kernels
//! preserve that guarantee because the vectorisation is *across paths*,
//! never within one path's arithmetic:
//!
//! * each output element depends only on the same index of the inputs (or,
//!   for the mat-vec kernels, on a per-path reduction whose `j` loop runs in
//!   exactly the scalar order), so unrolling `LANES` paths per iteration
//!   reorders nothing *within* a path;
//! * every kernel's per-element expression is written token-for-token as the
//!   scalar steppers write it (`0.5 * (a + b) * c`, not `(a + b) * (0.5 * c)`
//!   — same literal association, hence same rounding);
//! * seeded-accumulator variants (`*_seeded`) exist separately from the
//!   zero-accumulator ones because `(y + a) + b` and `y + (a + b)` round
//!   differently: each call site uses the variant matching the scalar code.
//!
//! The invariant is **per element type**: changing the element type changes
//! the lane width (and, of course, the rounding of each operation), but the
//! association rule — operand order, reduction order, seeded-vs-zero
//! accumulation — is shared by both instantiations, because both run the
//! *same* generic token stream. An `f32` batched solve is therefore
//! bit-identical to an `f32` per-path solve exactly as the `f64` one is to
//! its per-path reference, and the `f64` kernels' bits are untouched by the
//! genericisation (`Lane::from_f64` is the identity on `f64`).
//!
//! Consequently these kernels are drop-in replacements for per-component
//! loops — same bits out, fewer instructions retired — and the
//! `batch_engine` integration tests pin that equivalence in both precisions
//! on batch sizes that exercise both the unrolled body and the scalar
//! remainder (1, 3, 4, 7, 8, 33 around the 4- and 8-wide unrolls).

use std::fmt::Debug;
use std::ops::{Add, AddAssign, Mul, Neg, Sub, SubAssign};

mod sealed {
    pub trait Sealed {}
    impl Sealed for f64 {}
    impl Sealed for f32 {}
}

/// Sealed element type of the SoA kernels: `f64` (4-wide lanes) or `f32`
/// (8-wide lanes).
///
/// The trait carries exactly what the kernels and the batched steppers
/// need — the unroll width, the literal constants appearing in the stepper
/// expressions (`0.5`, `2.0`), and lossless-where-possible conversions. It
/// is sealed: the bit-identity contract is proven per instantiation by the
/// test suite, so foreign element types cannot claim it.
pub trait Lane:
    sealed::Sealed
    + Copy
    + Send
    + Sync
    + PartialEq
    + PartialOrd
    + Debug
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + 'static
{
    /// Unroll width of every kernel (one vector register of `Self`).
    const LANES: usize;
    /// Additive identity.
    const ZERO: Self;
    /// The literal `0.5` (exact in both precisions).
    const HALF: Self;
    /// The literal `2.0` (exact in both precisions).
    const TWO: Self;

    /// Convert from `f64` (identity on `f64`; rounds on `f32`). The batched
    /// steppers route scalar step quantities (`Δt`) through this, so the
    /// `f64` instantiation sees the exact bits the scalar steppers see.
    fn from_f64(x: f64) -> Self;
    /// Widen to `f64` (exact in both precisions).
    fn to_f64(self) -> f64;
    /// Convert from `f32` (identity on `f32`; exact widening on `f64`).
    fn from_f32(x: f32) -> Self;
    /// Convert a whole `f32` buffer — **zero-copy for `f32`** (the vector is
    /// returned as-is), an exact widening map for `f64`. The noise glue uses
    /// this to serve a Brownian source's native `f32` grid to `f32` lanes
    /// without any widening copy.
    fn vec_from_f32(v: Vec<f32>) -> Vec<Self>;
    /// `tanh` at this precision.
    fn lane_tanh(self) -> Self;
    /// `|self|` at this precision.
    fn lane_abs(self) -> Self;
    /// The logistic sigmoid `1 / (1 + e^{-x})` at this precision, written
    /// token-for-token as [`crate::nn::mlp`]'s scalar `sigmoid` so the `f64`
    /// instantiation of the generic LipSwish layers keeps its exact bits.
    fn lane_sigmoid(self) -> Self;
}

impl Lane for f64 {
    const LANES: usize = 4;
    const ZERO: Self = 0.0;
    const HALF: Self = 0.5;
    const TWO: Self = 2.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn from_f32(x: f32) -> Self {
        x as f64
    }
    #[inline]
    fn vec_from_f32(v: Vec<f32>) -> Vec<Self> {
        v.iter().map(|&x| x as f64).collect()
    }
    #[inline(always)]
    fn lane_tanh(self) -> Self {
        self.tanh()
    }
    #[inline(always)]
    fn lane_abs(self) -> Self {
        self.abs()
    }
    #[inline(always)]
    fn lane_sigmoid(self) -> Self {
        1.0 / (1.0 + (-self).exp())
    }
}

impl Lane for f32 {
    const LANES: usize = 8;
    const ZERO: Self = 0.0;
    const HALF: Self = 0.5;
    const TWO: Self = 2.0;

    #[inline(always)]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn from_f32(x: f32) -> Self {
        x
    }
    #[inline]
    fn vec_from_f32(v: Vec<f32>) -> Vec<Self> {
        v
    }
    #[inline(always)]
    fn lane_tanh(self) -> Self {
        self.tanh()
    }
    #[inline(always)]
    fn lane_abs(self) -> Self {
        self.abs()
    }
    #[inline(always)]
    fn lane_sigmoid(self) -> Self {
        1.0 / (1.0 + (-self).exp())
    }
}

/// Unroll width of the `f64` kernels (kept for callers that size buffers to
/// the historical 4-wide constant; prefer [`Lane::LANES`]).
pub const LANES: usize = <f64 as Lane>::LANES;

/// The widest unroll of any instantiation — accumulator arrays inside the
/// mat-vec kernels are sized to this and only their first `T::LANES` slots
/// are touched.
const MAX_LANES: usize = <f32 as Lane>::LANES;

/// `y[i] += x[i] * a` — scaled accumulate (drift application).
#[inline]
pub fn axpy<T: Lane>(a: T, x: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += x[i + l] * a;
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += x[i] * a;
        i += 1;
    }
}

/// `y[i] += 0.5 * x[i] * a` — half-scaled accumulate (midpoint half step).
#[inline]
pub fn axpy_half<T: Lane>(a: T, x: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += T::HALF * x[i + l] * a;
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += T::HALF * x[i] * a;
        i += 1;
    }
}

/// `y[i] = 0.5 * x[i]` — halve into (midpoint half increments).
#[inline]
pub fn scale_half<T: Lane>(x: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] = T::HALF * x[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] = T::HALF * x[i];
        i += 1;
    }
}

/// `y[i] += g[i] * w[i]` — elementwise fused multiply-accumulate (diagonal
/// diffusion apply).
#[inline]
pub fn mul_add<T: Lane>(g: &[T], w: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert!(g.len() == n && w.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += g[i + l] * w[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += g[i] * w[i];
        i += 1;
    }
}

/// `y[i] -= g[i] * w[i]` — elementwise fused multiply-subtract (diagonal
/// reverse step).
#[inline]
pub fn mul_sub<T: Lane>(g: &[T], w: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert!(g.len() == n && w.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] -= g[i + l] * w[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] -= g[i] * w[i];
        i += 1;
    }
}

/// `y[i] += 0.5 * (u[i] + v[i]) * a` — trapezoidal drift accumulate.
#[inline]
pub fn avg_axpy<T: Lane>(u: &[T], v: &[T], a: T, y: &mut [T]) {
    let n = y.len();
    debug_assert!(u.len() == n && v.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += T::HALF * (u[i + l] + v[i + l]) * a;
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += T::HALF * (u[i] + v[i]) * a;
        i += 1;
    }
}

/// `y[i] -= 0.5 * (u[i] + v[i]) * a` — trapezoidal drift subtract (reverse
/// step).
#[inline]
pub fn avg_axpy_sub<T: Lane>(u: &[T], v: &[T], a: T, y: &mut [T]) {
    let n = y.len();
    debug_assert!(u.len() == n && v.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] -= T::HALF * (u[i + l] + v[i + l]) * a;
        }
        i += T::LANES;
    }
    while i < n {
        y[i] -= T::HALF * (u[i] + v[i]) * a;
        i += 1;
    }
}

/// `y[i] += 0.5 * (g0[i] + g1[i]) * w[i]` — trapezoidal diagonal diffusion
/// accumulate.
#[inline]
pub fn avg_mul_add<T: Lane>(g0: &[T], g1: &[T], w: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert!(g0.len() == n && g1.len() == n && w.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += T::HALF * (g0[i + l] + g1[i + l]) * w[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += T::HALF * (g0[i] + g1[i]) * w[i];
        i += 1;
    }
}

/// `y[i] -= 0.5 * (g0[i] + g1[i]) * w[i]` — trapezoidal diagonal diffusion
/// subtract (reverse step).
#[inline]
pub fn avg_mul_sub<T: Lane>(g0: &[T], g1: &[T], w: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert!(g0.len() == n && g1.len() == n && w.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] -= T::HALF * (g0[i + l] + g1[i + l]) * w[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] -= T::HALF * (g0[i] + g1[i]) * w[i];
        i += 1;
    }
}

/// `out[i] = 2.0 * z[i] - zh[i] + mu[i] * dt` — the reversible-Heun leapfrog
/// extrapolation (forward step).
#[inline]
pub fn leapfrog<T: Lane>(z: &[T], zh: &[T], mu: &[T], dt: T, out: &mut [T]) {
    let n = out.len();
    debug_assert!(z.len() == n && zh.len() == n && mu.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            out[i + l] = T::TWO * z[i + l] - zh[i + l] + mu[i + l] * dt;
        }
        i += T::LANES;
    }
    while i < n {
        out[i] = T::TWO * z[i] - zh[i] + mu[i] * dt;
        i += 1;
    }
}

/// `out[i] = 2.0 * z[i] - zh[i] - mu[i] * dt` — the reversible-Heun leapfrog
/// extrapolation with negated drift (reverse step).
#[inline]
pub fn leapfrog_sub<T: Lane>(z: &[T], zh: &[T], mu: &[T], dt: T, out: &mut [T]) {
    let n = out.len();
    debug_assert!(z.len() == n && zh.len() == n && mu.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            out[i + l] = T::TWO * z[i + l] - zh[i + l] - mu[i + l] * dt;
        }
        i += T::LANES;
    }
    while i < n {
        out[i] = T::TWO * z[i] - zh[i] - mu[i] * dt;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Dense mat-vec row kernels.
//
// One component row of the dense `e×d` diffusion apply: `g` holds the `d`
// noise-channel lanes of component `i` (`g[j * b + p]`), `w` the SoA noise
// (`w[j * b + p]`), `y` the component's state lane (`b` paths). The `j`
// reduction runs in ascending order — the scalar order — with `LANES` paths'
// accumulators carried per iteration.
// ---------------------------------------------------------------------------

/// Zero-seeded accumulate-then-add: `y[p] += Σ_j g[j*b+p] * w[j*b+p]`.
#[inline]
pub fn matvec_row<T: Lane>(g: &[T], w: &[T], y: &mut [T], d: usize) {
    let b = y.len();
    debug_assert!(g.len() == d * b && w.len() == d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for j in 0..d {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] += g[o + l] * w[o + l];
            }
        }
        for l in 0..T::LANES {
            y[p + l] += acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = T::ZERO;
        for j in 0..d {
            acc += g[j * b + p] * w[j * b + p];
        }
        y[p] += acc;
        p += 1;
    }
}

/// Zero-seeded trapezoidal accumulate-then-add:
/// `y[p] += Σ_j 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg<T: Lane>(g0: &[T], g1: &[T], w: &[T], y: &mut [T], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for j in 0..d {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] += T::HALF * (g0[o + l] + g1[o + l]) * w[o + l];
            }
        }
        for l in 0..T::LANES {
            y[p + l] += acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = T::ZERO;
        for j in 0..d {
            let o = j * b + p;
            acc += T::HALF * (g0[o] + g1[o]) * w[o];
        }
        y[p] += acc;
        p += 1;
    }
}

/// Seeded sequential subtract: `y[p] = (..(y[p] - t_0) - t_1 ..) - t_{d-1}`
/// with `t_j = g[j*b+p] * w[j*b+p]`. Kept separate from the zero-seeded
/// variant because the association differs (see module docs).
#[inline]
pub fn matvec_row_sub_seeded<T: Lane>(g: &[T], w: &[T], y: &mut [T], d: usize) {
    let b = y.len();
    debug_assert!(g.len() == d * b && w.len() == d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for l in 0..T::LANES {
            acc[l] = y[p + l];
        }
        for j in 0..d {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] -= g[o + l] * w[o + l];
            }
        }
        for l in 0..T::LANES {
            y[p + l] = acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            acc -= g[j * b + p] * w[j * b + p];
        }
        y[p] = acc;
        p += 1;
    }
}

/// Seeded sequential trapezoidal accumulate:
/// `y[p] = (..(y[p] + t_0)..) + t_{d-1}` with
/// `t_j = 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg_seeded<T: Lane>(g0: &[T], g1: &[T], w: &[T], y: &mut [T], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for l in 0..T::LANES {
            acc[l] = y[p + l];
        }
        for j in 0..d {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] += T::HALF * (g0[o + l] + g1[o + l]) * w[o + l];
            }
        }
        for l in 0..T::LANES {
            y[p + l] = acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            let o = j * b + p;
            acc += T::HALF * (g0[o] + g1[o]) * w[o];
        }
        y[p] = acc;
        p += 1;
    }
}

/// Seeded sequential trapezoidal subtract:
/// `y[p] = (..(y[p] - t_0)..) - t_{d-1}` with
/// `t_j = 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg_sub_seeded<T: Lane>(g0: &[T], g1: &[T], w: &[T], y: &mut [T], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for l in 0..T::LANES {
            acc[l] = y[p + l];
        }
        for j in 0..d {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] -= T::HALF * (g0[o + l] + g1[o + l]) * w[o + l];
            }
        }
        for l in 0..T::LANES {
            y[p + l] = acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            let o = j * b + p;
            acc -= T::HALF * (g0[o] + g1[o]) * w[o];
        }
        y[p] = acc;
        p += 1;
    }
}

// ---------------------------------------------------------------------------
// Fused VJP kernels for the adjoint engine.
//
// The backward pass of the reversible-Heun adjoint combines cotangents with
// the same lane discipline as the forward kernels: elementwise across path
// lanes, association written token-for-token as the per-path adjoint writes
// it, so batched gradients are bit-identical to per-path gradients.
// ---------------------------------------------------------------------------

/// `out[i] = x[i] * a` — scaled copy (drift cotangent weight `w · Δt`).
#[inline]
pub fn scale<T: Lane>(a: T, x: &[T], out: &mut [T]) {
    let n = out.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            out[i + l] = x[i + l] * a;
        }
        i += T::LANES;
    }
    while i < n {
        out[i] = x[i] * a;
        i += 1;
    }
}

/// `y[i] += x[i]` — plain lane accumulate (bias gradients and cotangent
/// merges in the neural-MLP VJPs).
#[inline]
pub fn add<T: Lane>(x: &[T], y: &mut [T]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            y[i + l] += x[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        y[i] += x[i];
        i += 1;
    }
}

/// `out[i] = x[i] + 0.5 * y[i]` — the adjoint's combined diffusion
/// cotangent `w + ½ λ_z`.
#[inline]
pub fn add_half<T: Lane>(x: &[T], y: &[T], out: &mut [T]) {
    let n = out.len();
    debug_assert!(x.len() == n && y.len() == n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            out[i + l] = x[i + l] + T::HALF * y[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        out[i] = x[i] + T::HALF * y[i];
        i += 1;
    }
}

/// `out[i] = -x[i]` — cotangent negation (the `−w` seed of `λ_ẑ`).
#[inline]
pub fn neg<T: Lane>(x: &[T], out: &mut [T]) {
    let n = out.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % T::LANES;
    let mut i = 0;
    while i < nb {
        for l in 0..T::LANES {
            out[i + l] = -x[i + l];
        }
        i += T::LANES;
    }
    while i < n {
        out[i] = -x[i];
        i += 1;
    }
}

/// Seeded strided broadcast mat-vec (the transposed-matrix VJP row):
/// `out[p] = (..(out[p] + m[0]·x[0·b+p]) ..) + m[(k-1)·stride]·x[(k-1)·b+p]`
/// with `k = x.len() / out.len()` terms taken at stride `stride` from `m` —
/// i.e. one *column* of a row-major matrix applied across path lanes, seeded
/// sequential so the per-path association matches the scalar
/// `acc = gy[j]; for i { acc += m[i*d + j] * s[i]; }` loop exactly.
#[inline]
pub fn broadcast_matvec_strided_seeded<T: Lane>(m: &[T], stride: usize, x: &[T], out: &mut [T]) {
    let b = out.len();
    debug_assert_eq!(x.len() % b, 0);
    let k = x.len() / b;
    debug_assert!(k == 0 || m.len() > (k - 1) * stride);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for l in 0..T::LANES {
            acc[l] = out[p + l];
        }
        for i in 0..k {
            let mi = m[i * stride];
            let o = i * b + p;
            for l in 0..T::LANES {
                acc[l] += mi * x[o + l];
            }
        }
        for l in 0..T::LANES {
            out[p + l] = acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = out[p];
        for i in 0..k {
            acc += m[i * stride] * x[i * b + p];
        }
        out[p] = acc;
        p += 1;
    }
}

/// Broadcast mat-vec row: `out[p] = Σ_j m[j] * x[j*b+p]` — one row of a
/// shared (per-system, not per-path) matrix applied across all path lanes.
/// The native hand-batched systems build on this: the matrix entry is a
/// scalar broadcast over `LANES` path lanes at a time, and the `j` reduction
/// order is the scalar `matvec`'s, so per-path results are bit-identical to
/// the per-path adapter.
#[inline]
pub fn broadcast_matvec<T: Lane>(m: &[T], x: &[T], out: &mut [T]) {
    let b = out.len();
    let d = m.len();
    debug_assert_eq!(x.len(), d * b);
    let nb = b - b % T::LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [T::ZERO; MAX_LANES];
        for (j, &mj) in m.iter().enumerate() {
            let o = j * b + p;
            for l in 0..T::LANES {
                acc[l] += mj * x[o + l];
            }
        }
        for l in 0..T::LANES {
            out[p + l] = acc[l];
        }
        p += T::LANES;
    }
    while p < b {
        let mut acc = T::ZERO;
        for (j, &mj) in m.iter().enumerate() {
            acc += mj * x[j * b + p];
        }
        out[p] = acc;
        p += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lengths exercising zero, partial and multiple unrolled blocks plus
    /// every remainder size, for both the 4-wide and the 8-wide unroll.
    const SIZES: [usize; 10] = [1, 2, 3, 4, 5, 7, 8, 9, 17, 33];

    fn data(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::brownian::SplitPrng::new(seed);
        (0..n).map(|_| rng.next_normal_pair().0).collect()
    }

    fn data32(n: usize, seed: u64) -> Vec<f32> {
        data(n, seed).iter().map(|&x| x as f32).collect()
    }

    #[test]
    fn elementwise_kernels_match_scalar_loops_bitwise() {
        for &n in &SIZES {
            let x = data(n, 1);
            let u = data(n, 2);
            let w = data(n, 3);
            let y0 = data(n, 4);
            let a = 0.0721;

            let mut y = y0.clone();
            axpy(a, &x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i] * a, "axpy n={n} i={i}");
            }

            let mut y = y0.clone();
            axpy_half(a, &x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + 0.5 * x[i] * a, "axpy_half n={n} i={i}");
            }

            let mut y = vec![0.0; n];
            scale_half(&x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], 0.5 * x[i], "scale_half n={n} i={i}");
            }

            let mut y = y0.clone();
            add(&x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i], "add n={n} i={i}");
            }

            let mut y = y0.clone();
            mul_add(&x, &w, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i] * w[i], "mul_add n={n} i={i}");
            }

            let mut y = y0.clone();
            mul_sub(&x, &w, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] - x[i] * w[i], "mul_sub n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_axpy(&x, &u, a, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + 0.5 * (x[i] + u[i]) * a, "avg_axpy n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_axpy_sub(&x, &u, a, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] - 0.5 * (x[i] + u[i]) * a, "avg_axpy_sub n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_mul_add(&x, &u, &w, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] + 0.5 * (x[i] + u[i]) * w[i],
                    "avg_mul_add n={n} i={i}"
                );
            }

            let mut y = y0.clone();
            avg_mul_sub(&x, &u, &w, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] - 0.5 * (x[i] + u[i]) * w[i],
                    "avg_mul_sub n={n} i={i}"
                );
            }

            let mut out = vec![0.0; n];
            leapfrog(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(out[i], 2.0 * x[i] - u[i] + w[i] * a, "leapfrog n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            leapfrog_sub(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(
                    out[i],
                    2.0 * x[i] - u[i] - w[i] * a,
                    "leapfrog_sub n={n} i={i}"
                );
            }

            let mut out = vec![0.0; n];
            scale(a, &x, &mut out);
            for i in 0..n {
                assert_eq!(out[i], x[i] * a, "scale n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            add_half(&x, &u, &mut out);
            for i in 0..n {
                assert_eq!(out[i], x[i] + 0.5 * u[i], "add_half n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            neg(&x, &mut out);
            for i in 0..n {
                assert_eq!(out[i], -x[i], "neg n={n} i={i}");
            }
        }
    }

    #[test]
    fn elementwise_kernels_match_scalar_loops_bitwise_f32() {
        // The 8-wide f32 instantiation against plain f32 scalar expressions:
        // same association, same bits — the f32 twin of the f64 pin above.
        for &n in &SIZES {
            let x = data32(n, 1);
            let u = data32(n, 2);
            let w = data32(n, 3);
            let y0 = data32(n, 4);
            let a = 0.0721f32;

            let mut y = y0.clone();
            axpy(a, &x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i] * a, "axpy f32 n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_axpy(&x, &u, a, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] + 0.5 * (x[i] + u[i]) * a,
                    "avg_axpy f32 n={n} i={i}"
                );
            }

            let mut y = y0.clone();
            avg_mul_add(&x, &u, &w, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] + 0.5 * (x[i] + u[i]) * w[i],
                    "avg_mul_add f32 n={n} i={i}"
                );
            }

            let mut y = y0.clone();
            mul_sub(&x, &w, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] - x[i] * w[i], "mul_sub f32 n={n} i={i}");
            }

            let mut out = vec![0.0f32; n];
            leapfrog(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(
                    out[i],
                    2.0 * x[i] - u[i] + w[i] * a,
                    "leapfrog f32 n={n} i={i}"
                );
            }

            let mut out = vec![0.0f32; n];
            leapfrog_sub(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(
                    out[i],
                    2.0 * x[i] - u[i] - w[i] * a,
                    "leapfrog_sub f32 n={n} i={i}"
                );
            }
        }
    }

    #[test]
    fn strided_seeded_matvec_matches_scalar_column_loop() {
        for &b in &SIZES {
            for d in [1usize, 2, 3, 5] {
                // Row-major d×d matrix, SoA input [d * b], one output column
                // per j: the transposed-matrix VJP access pattern.
                let m = data(d * d, 20);
                let x = data(d * b, 21);
                let y0 = data(b, 22);
                for j in 0..d {
                    let mut y = y0.clone();
                    broadcast_matvec_strided_seeded(&m[j..], d, &x, &mut y);
                    for p in 0..b {
                        let mut acc = y0[p];
                        for i in 0..d {
                            acc += m[i * d + j] * x[i * b + p];
                        }
                        assert_eq!(y[p], acc, "strided seeded b={b} d={d} j={j} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn matvec_kernels_match_scalar_loops_bitwise() {
        for &b in &SIZES {
            for d in [1usize, 2, 3, 5] {
                let g0 = data(d * b, 10);
                let g1 = data(d * b, 11);
                let w = data(d * b, 12);
                let y0 = data(b, 13);

                let mut y = y0.clone();
                matvec_row(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        acc += g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], y0[p] + acc, "matvec_row b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        let o = j * b + p;
                        acc += 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], y0[p] + acc, "matvec_row_avg b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_sub_seeded(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        acc -= g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], acc, "matvec_row_sub_seeded b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg_seeded(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        let o = j * b + p;
                        acc += 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], acc, "matvec_row_avg_seeded b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg_sub_seeded(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        let o = j * b + p;
                        acc -= 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], acc, "matvec_row_avg_sub_seeded b={b} d={d} p={p}");
                }

                let m = data(d, 14);
                let mut out = vec![0.0; b];
                broadcast_matvec(&m, &g0, &mut out);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        acc += m[j] * g0[j * b + p];
                    }
                    assert_eq!(out[p], acc, "broadcast_matvec b={b} d={d} p={p}");
                }
            }
        }
    }

    #[test]
    fn matvec_kernels_match_scalar_loops_bitwise_f32() {
        for &b in &SIZES {
            for d in [1usize, 2, 3, 5] {
                let g0 = data32(d * b, 10);
                let g1 = data32(d * b, 11);
                let w = data32(d * b, 12);
                let y0 = data32(b, 13);

                let mut y = y0.clone();
                matvec_row(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = 0.0f32;
                    for j in 0..d {
                        acc += g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], y0[p] + acc, "matvec_row f32 b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg_seeded(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        let o = j * b + p;
                        acc += 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], acc, "matvec_row_avg_seeded f32 b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_sub_seeded(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        acc -= g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], acc, "matvec_row_sub_seeded f32 b={b} d={d} p={p}");
                }

                let m = data32(d, 14);
                let mut out = vec![0.0f32; b];
                broadcast_matvec(&m, &g0, &mut out);
                for p in 0..b {
                    let mut acc = 0.0f32;
                    for j in 0..d {
                        acc += m[j] * g0[j * b + p];
                    }
                    assert_eq!(out[p], acc, "broadcast_matvec f32 b={b} d={d} p={p}");
                }
            }
        }
    }

    #[test]
    fn lane_constants_and_conversions() {
        assert_eq!(<f64 as Lane>::LANES, 4);
        assert_eq!(<f32 as Lane>::LANES, 8);
        assert_eq!(f64::from_f64(0.1), 0.1);
        assert_eq!(f32::from_f64(0.1), 0.1f32);
        assert_eq!(f64::from_f32(0.25f32), 0.25);
        // vec_from_f32 is exact widening for f64, identity for f32.
        let src = vec![0.5f32, -1.25, 3.0];
        assert_eq!(<f64 as Lane>::vec_from_f32(src.clone()), vec![0.5f64, -1.25, 3.0]);
        assert_eq!(<f32 as Lane>::vec_from_f32(src.clone()), src);
        // lane_sigmoid pins the exact scalar expression in both precisions.
        assert_eq!(0.3f64.lane_sigmoid(), 1.0 / (1.0 + (-0.3f64).exp()));
        assert_eq!(0.3f32.lane_sigmoid(), 1.0 / (1.0 + (-0.3f32).exp()));
    }
}
