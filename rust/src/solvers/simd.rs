//! SIMD kernels for the batch engine's structure-of-arrays hot loops.
//!
//! The SoA layout in [`super::batch`] was chosen so that, for any component
//! `i`, the values of all paths live contiguously (`y[i * batch + p]` for
//! `p = 0..batch`). Every inner loop of the batched steppers is therefore a
//! unit-stride sweep over a lane of `batch` doubles, and those sweeps are
//! what this module implements: 4-wide manually-unrolled fused kernels
//! (`f64x4`-style — `std::simd` is still nightly-only, and four independent
//! scalar statements per iteration is the shape LLVM reliably turns into
//! `vfmadd`/`vmulpd` packed ops on stable).
//!
//! # Bit-identity invariants
//!
//! The batch engine guarantees batched results are **bit-for-bit equal** to
//! per-path integration. These kernels preserve that guarantee because the
//! vectorisation is *across paths*, never within one path's arithmetic:
//!
//! * each output element depends only on the same index of the inputs (or,
//!   for the mat-vec kernels, on a per-path reduction whose `j` loop runs in
//!   exactly the scalar order), so unrolling four paths per iteration
//!   reorders nothing *within* a path;
//! * every kernel's per-element expression is written token-for-token as the
//!   scalar steppers write it (`0.5 * (a + b) * c`, not `(a + b) * (0.5 * c)`
//!   — same literal association, hence same rounding);
//! * seeded-accumulator variants (`*_seeded`) exist separately from the
//!   zero-accumulator ones because `(y + a) + b` and `y + (a + b)` round
//!   differently: each call site uses the variant matching the scalar code.
//!
//! Consequently these kernels are drop-in replacements for the previous
//! per-component loops — same bits out, fewer instructions retired — and the
//! `batch_engine` integration tests pin that equivalence on batch sizes that
//! exercise both the unrolled body and the scalar remainder (1, 3, 4, 7, 8,
//! 33).

/// Unroll width of every kernel (one AVX2 register of `f64`).
pub const LANES: usize = 4;

/// `y[i] += x[i] * a` — scaled accumulate (drift application).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += x[i] * a;
        y[i + 1] += x[i + 1] * a;
        y[i + 2] += x[i + 2] * a;
        y[i + 3] += x[i + 3] * a;
        i += LANES;
    }
    while i < n {
        y[i] += x[i] * a;
        i += 1;
    }
}

/// `y[i] += 0.5 * x[i] * a` — half-scaled accumulate (midpoint half step).
#[inline]
pub fn axpy_half(a: f64, x: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += 0.5 * x[i] * a;
        y[i + 1] += 0.5 * x[i + 1] * a;
        y[i + 2] += 0.5 * x[i + 2] * a;
        y[i + 3] += 0.5 * x[i + 3] * a;
        i += LANES;
    }
    while i < n {
        y[i] += 0.5 * x[i] * a;
        i += 1;
    }
}

/// `y[i] = 0.5 * x[i]` — halve into (midpoint half increments).
#[inline]
pub fn scale_half(x: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] = 0.5 * x[i];
        y[i + 1] = 0.5 * x[i + 1];
        y[i + 2] = 0.5 * x[i + 2];
        y[i + 3] = 0.5 * x[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] = 0.5 * x[i];
        i += 1;
    }
}

/// `y[i] += g[i] * w[i]` — elementwise fused multiply-accumulate (diagonal
/// diffusion apply).
#[inline]
pub fn mul_add(g: &[f64], w: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(g.len() == n && w.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += g[i] * w[i];
        y[i + 1] += g[i + 1] * w[i + 1];
        y[i + 2] += g[i + 2] * w[i + 2];
        y[i + 3] += g[i + 3] * w[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] += g[i] * w[i];
        i += 1;
    }
}

/// `y[i] -= g[i] * w[i]` — elementwise fused multiply-subtract (diagonal
/// reverse step).
#[inline]
pub fn mul_sub(g: &[f64], w: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(g.len() == n && w.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] -= g[i] * w[i];
        y[i + 1] -= g[i + 1] * w[i + 1];
        y[i + 2] -= g[i + 2] * w[i + 2];
        y[i + 3] -= g[i + 3] * w[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] -= g[i] * w[i];
        i += 1;
    }
}

/// `y[i] += 0.5 * (u[i] + v[i]) * a` — trapezoidal drift accumulate.
#[inline]
pub fn avg_axpy(u: &[f64], v: &[f64], a: f64, y: &mut [f64]) {
    let n = y.len();
    debug_assert!(u.len() == n && v.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += 0.5 * (u[i] + v[i]) * a;
        y[i + 1] += 0.5 * (u[i + 1] + v[i + 1]) * a;
        y[i + 2] += 0.5 * (u[i + 2] + v[i + 2]) * a;
        y[i + 3] += 0.5 * (u[i + 3] + v[i + 3]) * a;
        i += LANES;
    }
    while i < n {
        y[i] += 0.5 * (u[i] + v[i]) * a;
        i += 1;
    }
}

/// `y[i] -= 0.5 * (u[i] + v[i]) * a` — trapezoidal drift subtract (reverse
/// step).
#[inline]
pub fn avg_axpy_sub(u: &[f64], v: &[f64], a: f64, y: &mut [f64]) {
    let n = y.len();
    debug_assert!(u.len() == n && v.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] -= 0.5 * (u[i] + v[i]) * a;
        y[i + 1] -= 0.5 * (u[i + 1] + v[i + 1]) * a;
        y[i + 2] -= 0.5 * (u[i + 2] + v[i + 2]) * a;
        y[i + 3] -= 0.5 * (u[i + 3] + v[i + 3]) * a;
        i += LANES;
    }
    while i < n {
        y[i] -= 0.5 * (u[i] + v[i]) * a;
        i += 1;
    }
}

/// `y[i] += 0.5 * (g0[i] + g1[i]) * w[i]` — trapezoidal diagonal diffusion
/// accumulate.
#[inline]
pub fn avg_mul_add(g0: &[f64], g1: &[f64], w: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(g0.len() == n && g1.len() == n && w.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += 0.5 * (g0[i] + g1[i]) * w[i];
        y[i + 1] += 0.5 * (g0[i + 1] + g1[i + 1]) * w[i + 1];
        y[i + 2] += 0.5 * (g0[i + 2] + g1[i + 2]) * w[i + 2];
        y[i + 3] += 0.5 * (g0[i + 3] + g1[i + 3]) * w[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] += 0.5 * (g0[i] + g1[i]) * w[i];
        i += 1;
    }
}

/// `y[i] -= 0.5 * (g0[i] + g1[i]) * w[i]` — trapezoidal diagonal diffusion
/// subtract (reverse step).
#[inline]
pub fn avg_mul_sub(g0: &[f64], g1: &[f64], w: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert!(g0.len() == n && g1.len() == n && w.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] -= 0.5 * (g0[i] + g1[i]) * w[i];
        y[i + 1] -= 0.5 * (g0[i + 1] + g1[i + 1]) * w[i + 1];
        y[i + 2] -= 0.5 * (g0[i + 2] + g1[i + 2]) * w[i + 2];
        y[i + 3] -= 0.5 * (g0[i + 3] + g1[i + 3]) * w[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] -= 0.5 * (g0[i] + g1[i]) * w[i];
        i += 1;
    }
}

/// `out[i] = 2.0 * z[i] - zh[i] + mu[i] * dt` — the reversible-Heun leapfrog
/// extrapolation (forward step).
#[inline]
pub fn leapfrog(z: &[f64], zh: &[f64], mu: &[f64], dt: f64, out: &mut [f64]) {
    let n = out.len();
    debug_assert!(z.len() == n && zh.len() == n && mu.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        out[i] = 2.0 * z[i] - zh[i] + mu[i] * dt;
        out[i + 1] = 2.0 * z[i + 1] - zh[i + 1] + mu[i + 1] * dt;
        out[i + 2] = 2.0 * z[i + 2] - zh[i + 2] + mu[i + 2] * dt;
        out[i + 3] = 2.0 * z[i + 3] - zh[i + 3] + mu[i + 3] * dt;
        i += LANES;
    }
    while i < n {
        out[i] = 2.0 * z[i] - zh[i] + mu[i] * dt;
        i += 1;
    }
}

/// `out[i] = 2.0 * z[i] - zh[i] - mu[i] * dt` — the reversible-Heun leapfrog
/// extrapolation with negated drift (reverse step).
#[inline]
pub fn leapfrog_sub(z: &[f64], zh: &[f64], mu: &[f64], dt: f64, out: &mut [f64]) {
    let n = out.len();
    debug_assert!(z.len() == n && zh.len() == n && mu.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        out[i] = 2.0 * z[i] - zh[i] - mu[i] * dt;
        out[i + 1] = 2.0 * z[i + 1] - zh[i + 1] - mu[i + 1] * dt;
        out[i + 2] = 2.0 * z[i + 2] - zh[i + 2] - mu[i + 2] * dt;
        out[i + 3] = 2.0 * z[i + 3] - zh[i + 3] - mu[i + 3] * dt;
        i += LANES;
    }
    while i < n {
        out[i] = 2.0 * z[i] - zh[i] - mu[i] * dt;
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// Dense mat-vec row kernels.
//
// One component row of the dense `e×d` diffusion apply: `g` holds the `d`
// noise-channel lanes of component `i` (`g[j * b + p]`), `w` the SoA noise
// (`w[j * b + p]`), `y` the component's state lane (`b` paths). The `j`
// reduction runs in ascending order — the scalar order — with four paths'
// accumulators carried per iteration.
// ---------------------------------------------------------------------------

/// Zero-seeded accumulate-then-add: `y[p] += Σ_j g[j*b+p] * w[j*b+p]`.
#[inline]
pub fn matvec_row(g: &[f64], w: &[f64], y: &mut [f64], d: usize) {
    let b = y.len();
    debug_assert!(g.len() == d * b && w.len() == d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [0.0f64; LANES];
        for j in 0..d {
            let o = j * b + p;
            acc[0] += g[o] * w[o];
            acc[1] += g[o + 1] * w[o + 1];
            acc[2] += g[o + 2] * w[o + 2];
            acc[3] += g[o + 3] * w[o + 3];
        }
        y[p] += acc[0];
        y[p + 1] += acc[1];
        y[p + 2] += acc[2];
        y[p + 3] += acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = 0.0;
        for j in 0..d {
            acc += g[j * b + p] * w[j * b + p];
        }
        y[p] += acc;
        p += 1;
    }
}

/// Zero-seeded trapezoidal accumulate-then-add:
/// `y[p] += Σ_j 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg(g0: &[f64], g1: &[f64], w: &[f64], y: &mut [f64], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [0.0f64; LANES];
        for j in 0..d {
            let o = j * b + p;
            acc[0] += 0.5 * (g0[o] + g1[o]) * w[o];
            acc[1] += 0.5 * (g0[o + 1] + g1[o + 1]) * w[o + 1];
            acc[2] += 0.5 * (g0[o + 2] + g1[o + 2]) * w[o + 2];
            acc[3] += 0.5 * (g0[o + 3] + g1[o + 3]) * w[o + 3];
        }
        y[p] += acc[0];
        y[p + 1] += acc[1];
        y[p + 2] += acc[2];
        y[p + 3] += acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = 0.0;
        for j in 0..d {
            let o = j * b + p;
            acc += 0.5 * (g0[o] + g1[o]) * w[o];
        }
        y[p] += acc;
        p += 1;
    }
}

/// Seeded sequential subtract: `y[p] = (..(y[p] - t_0) - t_1 ..) - t_{d-1}`
/// with `t_j = g[j*b+p] * w[j*b+p]`. Kept separate from the zero-seeded
/// variant because the association differs (see module docs).
#[inline]
pub fn matvec_row_sub_seeded(g: &[f64], w: &[f64], y: &mut [f64], d: usize) {
    let b = y.len();
    debug_assert!(g.len() == d * b && w.len() == d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [y[p], y[p + 1], y[p + 2], y[p + 3]];
        for j in 0..d {
            let o = j * b + p;
            acc[0] -= g[o] * w[o];
            acc[1] -= g[o + 1] * w[o + 1];
            acc[2] -= g[o + 2] * w[o + 2];
            acc[3] -= g[o + 3] * w[o + 3];
        }
        y[p] = acc[0];
        y[p + 1] = acc[1];
        y[p + 2] = acc[2];
        y[p + 3] = acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            acc -= g[j * b + p] * w[j * b + p];
        }
        y[p] = acc;
        p += 1;
    }
}

/// Seeded sequential trapezoidal accumulate:
/// `y[p] = (..(y[p] + t_0)..) + t_{d-1}` with
/// `t_j = 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg_seeded(g0: &[f64], g1: &[f64], w: &[f64], y: &mut [f64], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [y[p], y[p + 1], y[p + 2], y[p + 3]];
        for j in 0..d {
            let o = j * b + p;
            acc[0] += 0.5 * (g0[o] + g1[o]) * w[o];
            acc[1] += 0.5 * (g0[o + 1] + g1[o + 1]) * w[o + 1];
            acc[2] += 0.5 * (g0[o + 2] + g1[o + 2]) * w[o + 2];
            acc[3] += 0.5 * (g0[o + 3] + g1[o + 3]) * w[o + 3];
        }
        y[p] = acc[0];
        y[p + 1] = acc[1];
        y[p + 2] = acc[2];
        y[p + 3] = acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            let o = j * b + p;
            acc += 0.5 * (g0[o] + g1[o]) * w[o];
        }
        y[p] = acc;
        p += 1;
    }
}

/// Seeded sequential trapezoidal subtract:
/// `y[p] = (..(y[p] - t_0)..) - t_{d-1}` with
/// `t_j = 0.5 * (g0[j*b+p] + g1[j*b+p]) * w[j*b+p]`.
#[inline]
pub fn matvec_row_avg_sub_seeded(g0: &[f64], g1: &[f64], w: &[f64], y: &mut [f64], d: usize) {
    let b = y.len();
    debug_assert!(g0.len() == d * b && g1.len() == d * b && w.len() == d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [y[p], y[p + 1], y[p + 2], y[p + 3]];
        for j in 0..d {
            let o = j * b + p;
            acc[0] -= 0.5 * (g0[o] + g1[o]) * w[o];
            acc[1] -= 0.5 * (g0[o + 1] + g1[o + 1]) * w[o + 1];
            acc[2] -= 0.5 * (g0[o + 2] + g1[o + 2]) * w[o + 2];
            acc[3] -= 0.5 * (g0[o + 3] + g1[o + 3]) * w[o + 3];
        }
        y[p] = acc[0];
        y[p + 1] = acc[1];
        y[p + 2] = acc[2];
        y[p + 3] = acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = y[p];
        for j in 0..d {
            let o = j * b + p;
            acc -= 0.5 * (g0[o] + g1[o]) * w[o];
        }
        y[p] = acc;
        p += 1;
    }
}

// ---------------------------------------------------------------------------
// Fused VJP kernels for the adjoint engine.
//
// The backward pass of the reversible-Heun adjoint combines cotangents with
// the same lane discipline as the forward kernels: elementwise across path
// lanes, association written token-for-token as the per-path adjoint writes
// it, so batched gradients are bit-identical to per-path gradients.
// ---------------------------------------------------------------------------

/// `out[i] = x[i] * a` — scaled copy (drift cotangent weight `w · Δt`).
#[inline]
pub fn scale(a: f64, x: &[f64], out: &mut [f64]) {
    let n = out.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        out[i] = x[i] * a;
        out[i + 1] = x[i + 1] * a;
        out[i + 2] = x[i + 2] * a;
        out[i + 3] = x[i + 3] * a;
        i += LANES;
    }
    while i < n {
        out[i] = x[i] * a;
        i += 1;
    }
}

/// `y[i] += x[i]` — plain lane accumulate (bias gradients and cotangent
/// merges in the neural-MLP VJPs).
#[inline]
pub fn add(x: &[f64], y: &mut [f64]) {
    let n = y.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        y[i] += x[i];
        y[i + 1] += x[i + 1];
        y[i + 2] += x[i + 2];
        y[i + 3] += x[i + 3];
        i += LANES;
    }
    while i < n {
        y[i] += x[i];
        i += 1;
    }
}

/// `out[i] = x[i] + 0.5 * y[i]` — the adjoint's combined diffusion
/// cotangent `w + ½ λ_z`.
#[inline]
pub fn add_half(x: &[f64], y: &[f64], out: &mut [f64]) {
    let n = out.len();
    debug_assert!(x.len() == n && y.len() == n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        out[i] = x[i] + 0.5 * y[i];
        out[i + 1] = x[i + 1] + 0.5 * y[i + 1];
        out[i + 2] = x[i + 2] + 0.5 * y[i + 2];
        out[i + 3] = x[i + 3] + 0.5 * y[i + 3];
        i += LANES;
    }
    while i < n {
        out[i] = x[i] + 0.5 * y[i];
        i += 1;
    }
}

/// `out[i] = -x[i]` — cotangent negation (the `−w` seed of `λ_ẑ`).
#[inline]
pub fn neg(x: &[f64], out: &mut [f64]) {
    let n = out.len();
    debug_assert_eq!(x.len(), n);
    let nb = n - n % LANES;
    let mut i = 0;
    while i < nb {
        out[i] = -x[i];
        out[i + 1] = -x[i + 1];
        out[i + 2] = -x[i + 2];
        out[i + 3] = -x[i + 3];
        i += LANES;
    }
    while i < n {
        out[i] = -x[i];
        i += 1;
    }
}

/// Seeded strided broadcast mat-vec (the transposed-matrix VJP row):
/// `out[p] = (..(out[p] + m[0]·x[0·b+p]) ..) + m[(k-1)·stride]·x[(k-1)·b+p]`
/// with `k = x.len() / out.len()` terms taken at stride `stride` from `m` —
/// i.e. one *column* of a row-major matrix applied across path lanes, seeded
/// sequential so the per-path association matches the scalar
/// `acc = gy[j]; for i { acc += m[i*d + j] * s[i]; }` loop exactly.
#[inline]
pub fn broadcast_matvec_strided_seeded(m: &[f64], stride: usize, x: &[f64], out: &mut [f64]) {
    let b = out.len();
    debug_assert_eq!(x.len() % b, 0);
    let k = x.len() / b;
    debug_assert!(k == 0 || m.len() > (k - 1) * stride);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [out[p], out[p + 1], out[p + 2], out[p + 3]];
        for i in 0..k {
            let mi = m[i * stride];
            let o = i * b + p;
            acc[0] += mi * x[o];
            acc[1] += mi * x[o + 1];
            acc[2] += mi * x[o + 2];
            acc[3] += mi * x[o + 3];
        }
        out[p] = acc[0];
        out[p + 1] = acc[1];
        out[p + 2] = acc[2];
        out[p + 3] = acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = out[p];
        for i in 0..k {
            acc += m[i * stride] * x[i * b + p];
        }
        out[p] = acc;
        p += 1;
    }
}

/// Broadcast mat-vec row: `out[p] = Σ_j m[j] * x[j*b+p]` — one row of a
/// shared (per-system, not per-path) matrix applied across all path lanes.
/// The native hand-batched systems build on this: the matrix entry is a
/// scalar broadcast over four path lanes, and the `j` reduction order is the
/// scalar `matvec`'s, so per-path results are bit-identical to the per-path
/// adapter.
#[inline]
pub fn broadcast_matvec(m: &[f64], x: &[f64], out: &mut [f64]) {
    let b = out.len();
    let d = m.len();
    debug_assert_eq!(x.len(), d * b);
    let nb = b - b % LANES;
    let mut p = 0;
    while p < nb {
        let mut acc = [0.0f64; LANES];
        for (j, &mj) in m.iter().enumerate() {
            let o = j * b + p;
            acc[0] += mj * x[o];
            acc[1] += mj * x[o + 1];
            acc[2] += mj * x[o + 2];
            acc[3] += mj * x[o + 3];
        }
        out[p] = acc[0];
        out[p + 1] = acc[1];
        out[p + 2] = acc[2];
        out[p + 3] = acc[3];
        p += LANES;
    }
    while p < b {
        let mut acc = 0.0;
        for (j, &mj) in m.iter().enumerate() {
            acc += mj * x[j * b + p];
        }
        out[p] = acc;
        p += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lengths exercising zero, partial and multiple unrolled blocks plus
    /// every remainder size.
    const SIZES: [usize; 8] = [1, 2, 3, 4, 5, 7, 8, 33];

    fn data(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = crate::brownian::SplitPrng::new(seed);
        (0..n).map(|_| rng.next_normal_pair().0).collect()
    }

    #[test]
    fn elementwise_kernels_match_scalar_loops_bitwise() {
        for &n in &SIZES {
            let x = data(n, 1);
            let u = data(n, 2);
            let w = data(n, 3);
            let y0 = data(n, 4);
            let a = 0.0721;

            let mut y = y0.clone();
            axpy(a, &x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i] * a, "axpy n={n} i={i}");
            }

            let mut y = y0.clone();
            axpy_half(a, &x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + 0.5 * x[i] * a, "axpy_half n={n} i={i}");
            }

            let mut y = vec![0.0; n];
            scale_half(&x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], 0.5 * x[i], "scale_half n={n} i={i}");
            }

            let mut y = y0.clone();
            add(&x, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i], "add n={n} i={i}");
            }

            let mut y = y0.clone();
            mul_add(&x, &w, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + x[i] * w[i], "mul_add n={n} i={i}");
            }

            let mut y = y0.clone();
            mul_sub(&x, &w, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] - x[i] * w[i], "mul_sub n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_axpy(&x, &u, a, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] + 0.5 * (x[i] + u[i]) * a, "avg_axpy n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_axpy_sub(&x, &u, a, &mut y);
            for i in 0..n {
                assert_eq!(y[i], y0[i] - 0.5 * (x[i] + u[i]) * a, "avg_axpy_sub n={n} i={i}");
            }

            let mut y = y0.clone();
            avg_mul_add(&x, &u, &w, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] + 0.5 * (x[i] + u[i]) * w[i],
                    "avg_mul_add n={n} i={i}"
                );
            }

            let mut y = y0.clone();
            avg_mul_sub(&x, &u, &w, &mut y);
            for i in 0..n {
                assert_eq!(
                    y[i],
                    y0[i] - 0.5 * (x[i] + u[i]) * w[i],
                    "avg_mul_sub n={n} i={i}"
                );
            }

            let mut out = vec![0.0; n];
            leapfrog(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(out[i], 2.0 * x[i] - u[i] + w[i] * a, "leapfrog n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            leapfrog_sub(&x, &u, &w, a, &mut out);
            for i in 0..n {
                assert_eq!(
                    out[i],
                    2.0 * x[i] - u[i] - w[i] * a,
                    "leapfrog_sub n={n} i={i}"
                );
            }

            let mut out = vec![0.0; n];
            scale(a, &x, &mut out);
            for i in 0..n {
                assert_eq!(out[i], x[i] * a, "scale n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            add_half(&x, &u, &mut out);
            for i in 0..n {
                assert_eq!(out[i], x[i] + 0.5 * u[i], "add_half n={n} i={i}");
            }

            let mut out = vec![0.0; n];
            neg(&x, &mut out);
            for i in 0..n {
                assert_eq!(out[i], -x[i], "neg n={n} i={i}");
            }
        }
    }

    #[test]
    fn strided_seeded_matvec_matches_scalar_column_loop() {
        for &b in &SIZES {
            for d in [1usize, 2, 3, 5] {
                // Row-major d×d matrix, SoA input [d * b], one output column
                // per j: the transposed-matrix VJP access pattern.
                let m = data(d * d, 20);
                let x = data(d * b, 21);
                let y0 = data(b, 22);
                for j in 0..d {
                    let mut y = y0.clone();
                    broadcast_matvec_strided_seeded(&m[j..], d, &x, &mut y);
                    for p in 0..b {
                        let mut acc = y0[p];
                        for i in 0..d {
                            acc += m[i * d + j] * x[i * b + p];
                        }
                        assert_eq!(y[p], acc, "strided seeded b={b} d={d} j={j} p={p}");
                    }
                }
            }
        }
    }

    #[test]
    fn matvec_kernels_match_scalar_loops_bitwise() {
        for &b in &SIZES {
            for d in [1usize, 2, 3, 5] {
                let g0 = data(d * b, 10);
                let g1 = data(d * b, 11);
                let w = data(d * b, 12);
                let y0 = data(b, 13);

                let mut y = y0.clone();
                matvec_row(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        acc += g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], y0[p] + acc, "matvec_row b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        let o = j * b + p;
                        acc += 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], y0[p] + acc, "matvec_row_avg b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_sub_seeded(&g0, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        acc -= g0[j * b + p] * w[j * b + p];
                    }
                    assert_eq!(y[p], acc, "matvec_row_sub_seeded b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg_seeded(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        let o = j * b + p;
                        acc += 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], acc, "matvec_row_avg_seeded b={b} d={d} p={p}");
                }

                let mut y = y0.clone();
                matvec_row_avg_sub_seeded(&g0, &g1, &w, &mut y, d);
                for p in 0..b {
                    let mut acc = y0[p];
                    for j in 0..d {
                        let o = j * b + p;
                        acc -= 0.5 * (g0[o] + g1[o]) * w[o];
                    }
                    assert_eq!(y[p], acc, "matvec_row_avg_sub_seeded b={b} d={d} p={p}");
                }

                let m = data(d, 14);
                let mut out = vec![0.0; b];
                broadcast_matvec(&m, &g0, &mut out);
                for p in 0..b {
                    let mut acc = 0.0;
                    for j in 0..d {
                        acc += m[j] * g0[j * b + p];
                    }
                    assert_eq!(out[p], acc, "broadcast_matvec b={b} d={d} p={p}");
                }
            }
        }
    }
}
