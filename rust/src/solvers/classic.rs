//! Baseline fixed-step solvers: Euler–Maruyama (Itô), the midpoint method
//! and Heun's method (both Stratonovich). Midpoint and Heun each make two
//! vector-field evaluations per step — the cost the reversible Heun method
//! halves (paper Section 3, "Computational efficiency").

use super::{apply_diffusion, FixedStepSolver, Sde};

/// Euler–Maruyama: `y' = y + f(t, y) dt + g(t, y) dW` (converges to the
/// **Itô** solution; used for the Table-10 benchmark whose test SDE is Itô).
pub struct EulerMaruyama {
    f: Vec<f64>,
    g: Vec<f64>,
}

impl EulerMaruyama {
    /// Allocate scratch for an SDE of the given dimensions.
    pub fn new(dim: usize, noise_dim: usize) -> Self {
        Self { f: vec![0.0; dim], g: vec![0.0; dim * noise_dim] }
    }
}

impl FixedStepSolver for EulerMaruyama {
    const FIELD_EVALS_PER_STEP: usize = 1;

    fn step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64], y: &mut [f64]) {
        self.f.fill(0.0);
        sde.drift(t, y, &mut self.f);
        sde.diffusion(t, y, &mut self.g);
        for i in 0..y.len() {
            y[i] += self.f[i] * dt;
        }
        apply_diffusion(&self.g, dw, y);
    }
}

/// Midpoint method (Stratonovich, strong order 0.5):
/// `ỹ = y + ½ f dt + ½ g dW` evaluated at `(t, y)`, then a full step with
/// the fields evaluated at `(t + dt/2, ỹ)`.
pub struct Midpoint {
    f: Vec<f64>,
    g: Vec<f64>,
    mid: Vec<f64>,
}

impl Midpoint {
    /// Allocate scratch for an SDE of the given dimensions.
    pub fn new(dim: usize, noise_dim: usize) -> Self {
        Self { f: vec![0.0; dim], g: vec![0.0; dim * noise_dim], mid: vec![0.0; dim] }
    }
}

impl FixedStepSolver for Midpoint {
    const FIELD_EVALS_PER_STEP: usize = 2;

    fn step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64], y: &mut [f64]) {
        // Half step.
        sde.drift(t, y, &mut self.f);
        sde.diffusion(t, y, &mut self.g);
        self.mid.copy_from_slice(y);
        for i in 0..y.len() {
            self.mid[i] += 0.5 * self.f[i] * dt;
        }
        let half_dw: Vec<f64> = dw.iter().map(|&x| 0.5 * x).collect();
        apply_diffusion(&self.g, &half_dw, &mut self.mid);
        // Full step with midpoint fields.
        sde.drift(t + 0.5 * dt, &self.mid, &mut self.f);
        sde.diffusion(t + 0.5 * dt, &self.mid, &mut self.g);
        for i in 0..y.len() {
            y[i] += self.f[i] * dt;
        }
        apply_diffusion(&self.g, dw, y);
    }
}

/// Heun's method / trapezoidal rule (Stratonovich, strong order 0.5; weak
/// order 2.0 for additive noise — Appendix D.4).
pub struct Heun {
    f0: Vec<f64>,
    g0: Vec<f64>,
    f1: Vec<f64>,
    g1: Vec<f64>,
    pred: Vec<f64>,
}

impl Heun {
    /// Allocate scratch for an SDE of the given dimensions.
    pub fn new(dim: usize, noise_dim: usize) -> Self {
        Self {
            f0: vec![0.0; dim],
            g0: vec![0.0; dim * noise_dim],
            f1: vec![0.0; dim],
            g1: vec![0.0; dim * noise_dim],
            pred: vec![0.0; dim],
        }
    }
}

impl FixedStepSolver for Heun {
    const FIELD_EVALS_PER_STEP: usize = 2;

    fn step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64], y: &mut [f64]) {
        sde.drift(t, y, &mut self.f0);
        sde.diffusion(t, y, &mut self.g0);
        // Euler predictor.
        self.pred.copy_from_slice(y);
        for i in 0..y.len() {
            self.pred[i] += self.f0[i] * dt;
        }
        apply_diffusion(&self.g0, dw, &mut self.pred);
        // Trapezoidal corrector.
        sde.drift(t + dt, &self.pred, &mut self.f1);
        sde.diffusion(t + dt, &self.pred, &mut self.g1);
        for i in 0..y.len() {
            y[i] += 0.5 * (self.f0[i] + self.f1[i]) * dt;
        }
        let d = dw.len();
        for i in 0..y.len() {
            let mut acc = 0.0;
            for j in 0..d {
                acc += 0.5 * (self.g0[i * d + j] + self.g1[i * d + j]) * dw[j];
            }
            y[i] += acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::systems::ScalarLinear;
    use super::super::{integrate, FineBrownianGrid};
    use super::*;

    /// With zero noise all solvers must integrate the ODE y' = a y.
    fn ode_error<M: FixedStepSolver>(solver: &mut M) -> f64 {
        let sde = ScalarLinear { a: 1.0, b: 0.0 };
        let mut noise = FineBrownianGrid::new(1, 1024, 1.0, 7);
        let traj = integrate(&sde, solver, &mut noise, &[1.0], 0.0, 1.0, 256);
        let last = traj[traj.len() - 1];
        (last - 1.0f64.exp()).abs()
    }

    #[test]
    fn solvers_integrate_odes() {
        assert!(ode_error(&mut EulerMaruyama::new(1, 1)) < 1e-2);
        assert!(ode_error(&mut Midpoint::new(1, 1)) < 1e-4);
        assert!(ode_error(&mut Heun::new(1, 1)) < 1e-4);
    }

    #[test]
    fn midpoint_and_heun_agree_to_leading_order() {
        let sde = ScalarLinear { a: 0.5, b: 0.4 };
        let mut noise1 = FineBrownianGrid::new(1, 4096, 1.0, 11);
        let mut noise2 = FineBrownianGrid::new(1, 4096, 1.0, 11);
        let t1 = integrate(&sde, &mut Midpoint::new(1, 1), &mut noise1, &[1.0], 0.0, 1.0, 512);
        let t2 = integrate(&sde, &mut Heun::new(1, 1), &mut noise2, &[1.0], 0.0, 1.0, 512);
        let (a, b) = (t1[t1.len() - 1], t2[t2.len() - 1]);
        assert!((a - b).abs() < 5e-3, "midpoint {a} vs heun {b}");
    }
}
