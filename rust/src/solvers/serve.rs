//! Sampling-as-a-service: a persistent, zero-allocation serving engine.
//!
//! The ROADMAP's north star is serving trained SDE-GANs at scale, and the
//! production workload of a trained model is **sampling** — many concurrent,
//! small requests, not one big offline batch. [`super::integrate_batched`]
//! is built for the offline-training shape, and a 7-path request wastes
//! the 8-wide `f32` SIMD lanes. This module serves the same solves through
//! a long-lived engine instead:
//!
//! * **One process-wide executor** — admission rounds dispatch their chunks
//!   on the same persistent work-stealing pool ([`super::pool`]) that runs
//!   every training and offline solve: spawn-once parked workers, no
//!   per-call thread spawn/join, and no second serve-private pool (the
//!   pre-PR-10 split). The engine itself owns no threads; whichever caller
//!   blocks in [`ServeEngine::wait_into`] (or calls
//!   [`ServeEngine::flush`]) *drives* the next admission round through the
//!   pool, and concurrent waiters park until the driver's round completes.
//! * **Size-aware admission packing** — a request is just a set of rows in
//!   the `[component × batch]` SoA state, so admission is *lane
//!   assignment*: the front door packs queued requests into one SoA
//!   mega-batch of up to [`ServeConfig::max_batch`] lanes, which the pool
//!   solves as a single chunked solve. Under [`AdmitPolicy::Packed`] (the
//!   default) admission is deadline-preserving first-fit: a request that
//!   does not fit the remaining lanes keeps its queue position (the head
//!   of each queue is always admitted first into the next empty batch, so
//!   nothing starves) while smaller requests behind it bin-pack into the
//!   leftover capacity; [`AdmitPolicy::Fifo`] keeps the strict PR-7 order
//!   as a measurable baseline. Because the engine's SIMD kernels vectorise
//!   *across paths and never within one path's arithmetic*, the packed
//!   solve is **bit-for-bit identical** to solving each request as its own
//!   batch — for every lane assignment, packing order, chunk size and
//!   thread count (pinned by `tests/serve_engine.rs`).
//! * **Priority lane** — requests no wider than
//!   [`ServeConfig::priority_width`] queue separately and are admitted
//!   first every round, so an interactive request is never stuck behind a
//!   mega-request: its worst case is one bounded mega-batch round, not a
//!   10⁶-path drain.
//! * **Sharded mega-requests** — a request wider than
//!   [`ServeConfig::shard_width`] is split into per-shard lane ranges
//!   admitted across consecutive mega-batch rounds, each shard chunked
//!   across the persistent pool exactly like any other lanes (the same
//!   work-stealing/chunk discipline as `map_chunks`, the same per-worker
//!   `Scratch`/`reinit` zero-alloc contract). Shard faults are charged
//!   back to the owning request; sibling shards and co-packed bystanders
//!   keep their exact bits. A session may therefore be arbitrarily wider
//!   than `max_batch` — the 10⁶-path Monte-Carlo shape.
//! * **Session eviction** — above [`ServeConfig::max_sessions`] resident
//!   sessions, the least-recently-used session's heavy state (Brownian
//!   tree, staging buffers) is dropped; with
//!   [`ServeConfig::session_ttl_ms`] set, sessions untouched for that many
//!   wall-clock milliseconds are dropped too, so an idle working set
//!   shrinks without waiting for capacity pressure. Request noise is a
//!   pure function of `(session seed, request counter, path)`
//!   ([`request_seed`]), so an evicted or expired session is rebuilt
//!   **bit-identically** on its next admission by replaying the counter —
//!   eviction is invisible in the bits.
//! * **Per-session persistent Brownian state** — each session owns a
//!   [`SessionNoise`]: one [`BrownianInterval`] whose node arena, LRU slot
//!   arena and recycled buffers survive across requests
//!   ([`BrownianInterval::reseed`]), with the per-request seed derived
//!   deterministically from the session seed and request counter
//!   ([`request_seed`]). A request's noise depends only on its session —
//!   never on which mega-batch lane it landed in or what other sessions
//!   are doing — which is what makes coalescing invisible in the bits.
//! * **Zero-allocation steady state** — the mega-batch buffers, slot pool,
//!   per-worker scratch and steppers ([`BatchStepper::reinit`]) are all
//!   preallocated and reused; a warm engine serves requests without
//!   allocating (the per-worker scratch carries a debug assertion on its
//!   capacity signature, and `tests/serve_engine.rs` pins the whole
//!   submit→solve→collect cycle at zero allocations with a counting global
//!   allocator).
//! * **Fault quarantine per request** — non-finite lanes and panicking
//!   vector fields follow the PR-6 fault contract: a dirty chunk is re-run
//!   bit-identically to localise exact `(step, path, component)`
//!   coordinates, a panicked chunk is re-run lane by lane under
//!   `catch_unwind`, and the faults are charged to the *owning request*
//!   (request-relative path indices). The faulted request's
//!   [`ServeEngine::wait`] returns the structured [`SolveError`], its slot
//!   is released back to the admission queue, and every other in-flight
//!   request's bits are untouched.
//!
//! Waiters collect results with [`ServeEngine::wait_into`], which swaps the
//! trajectory out of the slot into a caller-owned buffer — callers that
//! reuse their buffer keep the whole round trip allocation-free.

use super::batch::{BatchSde, BatchStepper};
use super::guard::{self, FaultCause, GuardConfig, SolveError, SolveFault};
use super::pool;
use super::simd::Lane;
use crate::brownian::{splitmix64, BrownianInterval, BrownianSource};
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::{Duration, Instant};

/// The deterministic per-request seed of a session: request `counter` of a
/// session opened with `base` reseeds its Brownian tree with this value
/// (the same splitmix derivation the training loop's `StepNoise` uses).
/// Public so references — a per-request solve that must match the serving
/// engine bit-for-bit — can reconstruct any request's noise offline.
pub fn request_seed(base: u64, counter: u64) -> u64 {
    splitmix64(base ^ counter.wrapping_mul(0x9E37_79B9))
}

/// Paths per Brownian block of a wide session: sessions up to this many
/// paths draw all channels from one [`BrownianInterval`] (the historical
/// PR-7 derivation, bits unchanged); wider sessions derive their noise in
/// independent `NOISE_BLOCK`-path blocks, each from the same bounded-size
/// interval reseeded with a block-keyed splitmix of the request seed. This
/// keeps the Brownian tree's node arena (whose per-node payload scales with
/// channel count) bounded no matter how wide the session is — the property
/// that makes 10⁶-path sessions serveable. Either way a request's noise is
/// a pure function of `(session seed, request counter, path index)`.
pub const NOISE_BLOCK: usize = 1024;

/// The block-`b` reseed of a wide session's request: splitmix of the
/// request seed and the block index.
fn block_seed(rseed: u64, b: u64) -> u64 {
    splitmix64(rseed ^ (b + 1).wrapping_mul(0xD1B5_4A32_D192_ED03))
}

/// A session's persistent Brownian state: one [`BrownianInterval`] (node
/// arena, LRU arena and recycled buffers survive across requests via
/// [`BrownianInterval::reseed`]), the fixed solve grid, and the request
/// counter. Each request draws a fresh, deterministic sample keyed by
/// [`request_seed`] — so a request's noise is a pure function of
/// `(session seed, request index, path index)`, independent of coalescing,
/// packing order and sharding. Sessions wider than [`NOISE_BLOCK`] draw in
/// independent path blocks (see [`NOISE_BLOCK`]) so the tree stays small.
///
/// The grid layout is `[k][p][j]` (step-major, then path, then channel) —
/// exactly what [`super::StoredBatchNoise::from_f32_grid`] consumes, which
/// is how tests rebuild a request's noise for the per-request reference
/// solve.
pub struct SessionNoise {
    bi: BrownianInterval,
    /// Staging for one `NOISE_BLOCK`-path block (empty when the session
    /// fits a single block).
    block: Vec<f32>,
    grid: Vec<f32>,
    ts: Vec<f64>,
    base: u64,
    counter: u64,
    n_paths: usize,
    nd: usize,
}

impl SessionNoise {
    /// Persistent noise for requests of `n_paths` paths with `noise_dim`
    /// Brownian channels each, over the fixed grid of `n_steps` uniform
    /// steps spanning `[t0, t1]`.
    pub fn new(
        seed: u64,
        noise_dim: usize,
        n_paths: usize,
        t0: f64,
        t1: f64,
        n_steps: usize,
    ) -> Self {
        assert!(noise_dim >= 1 && n_paths >= 1 && n_steps >= 1 && t1 > t0);
        let size = noise_dim * n_paths.min(NOISE_BLOCK);
        let dt = (t1 - t0) / n_steps as f64;
        Self {
            bi: BrownianInterval::new(t0, t1, size, seed),
            block: if n_paths > NOISE_BLOCK {
                vec![0.0f32; n_steps * noise_dim * NOISE_BLOCK]
            } else {
                Vec::new()
            },
            grid: vec![0.0f32; n_steps * noise_dim * n_paths],
            ts: (0..=n_steps).map(|k| t0 + k as f64 * dt).collect(),
            base: seed,
            counter: 0,
            n_paths,
            nd: noise_dim,
        }
    }

    /// Paths per request for this session.
    pub fn n_paths(&self) -> usize {
        self.n_paths
    }

    /// Requests drawn so far (the next request uses this counter value).
    pub fn requests_drawn(&self) -> u64 {
        self.counter
    }

    /// Fill `out` with request `counter`'s noise grid
    /// (`[n_steps][n_paths][noise_dim]`) without touching this session's
    /// own request counter. The engine assigns counters at *submit* time
    /// and draws at admission time through this method, so neither packing
    /// order nor sharding can ever change which sample a request gets.
    /// Steady state (an `out` that has reached capacity) allocates nothing.
    pub fn fill_request(&mut self, counter: u64, out: &mut Vec<f32>) {
        let n_steps = self.ts.len() - 1;
        let (m, nd) = (self.n_paths, self.nd);
        out.clear();
        out.resize(n_steps * m * nd, 0.0);
        let rseed = request_seed(self.base, counter);
        if m <= NOISE_BLOCK {
            self.bi.reseed(rseed);
            self.bi.fill_grid(&self.ts, out);
            return;
        }
        // Wide session: independent NOISE_BLOCK-path blocks, each one
        // bulk-fill descent of the same bounded tree, copied row-contiguous
        // into the request grid. The last partial block draws a full block
        // and uses its leading paths (deterministic, width-independent of
        // the solve's shard layout).
        let bw = NOISE_BLOCK;
        for b in 0..(m + bw - 1) / bw {
            self.bi.reseed(block_seed(rseed, b as u64));
            self.bi.fill_grid(&self.ts, &mut self.block);
            let p0 = b * bw;
            let mb = bw.min(m - p0);
            for k in 0..n_steps {
                let src = &self.block[k * bw * nd..k * bw * nd + mb * nd];
                out[(k * m + p0) * nd..(k * m + p0) * nd + mb * nd].copy_from_slice(src);
            }
        }
    }

    /// Draw the next request's noise grid (`[n_steps][n_paths][noise_dim]`)
    /// — reseed the persistent tree with [`request_seed`] and bulk-fill the
    /// grid. Steady state (same grid every request, the serving case)
    /// reuses the node arena and every buffer: no allocation.
    pub fn next_request(&mut self) -> &[f32] {
        let c = self.counter;
        self.counter += 1;
        let mut g = std::mem::take(&mut self.grid);
        self.fill_request(c, &mut g);
        self.grid = g;
        &self.grid
    }
}

/// Admission-packing policy of the serving front door. Never affects bits
/// — a request's noise is keyed by its session and submit-time counter —
/// only which requests share a mega-batch round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// Strict arrival order (the PR-7 behaviour): the queue head blocks
    /// admission when it does not fit the remaining lanes. Kept as the
    /// measurable baseline for the `packed_vs_fifo` bench rows.
    Fifo,
    /// Deadline-preserving size-aware packing (the default): the priority
    /// queue drains before the bulk queue each round, and within a queue a
    /// head that does not fit keeps its position (it is admitted first
    /// into the next empty batch — no starvation) while smaller requests
    /// behind it first-fit into the leftover capacity.
    Packed,
}

impl AdmitPolicy {
    /// Parse from the CLI/manifest string form.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" => Some(Self::Fifo),
            "packed" => Some(Self::Packed),
            _ => None,
        }
    }

    /// String form used in bench rows and artifact names.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Fifo => "fifo",
            Self::Packed => "packed",
        }
    }
}

/// Knobs for [`ServeEngine`]. The solve grid (`t0`, `t1`, `n_steps`) is
/// fixed per engine — serving a trained model samples one horizon — which
/// is what lets every buffer be preallocated.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Solve interval start.
    pub t0: f64,
    /// Solve interval end.
    pub t1: f64,
    /// Fixed solver steps per request.
    pub n_steps: usize,
    /// Mega-batch capacity in lanes (paths) per admission round. Requests
    /// wider than [`shard_width`](Self::shard_width) span several rounds.
    pub max_batch: usize,
    /// Persistent worker threads (min 1).
    pub threads: usize,
    /// Lanes per work unit inside a mega-batch solve. Never affects bits —
    /// the engine invariant — only load balance.
    pub chunk: usize,
    /// Fault-tolerance knobs (normalised once per worker via
    /// [`GuardConfig::normalised`]).
    pub guard: GuardConfig,
    /// When true (the default), workers admit queued requests as soon as
    /// the pool is free — lowest latency. When false, requests only queue
    /// until [`ServeEngine::flush`] opens the gate for one admission round
    /// — the deterministic-coalescing mode the bitwise tests use. (A
    /// sharded mega-request needs one flush per shard round in this mode.)
    pub auto_admit: bool,
    /// Admission-packing policy (default [`AdmitPolicy::Packed`]).
    pub policy: AdmitPolicy,
    /// Maximum lanes one request may occupy in a single mega-batch round;
    /// wider requests are sharded across consecutive rounds. `0` (the
    /// default) means `max_batch`. Setting it *below* `max_batch` reserves
    /// `max_batch - shard_width` lanes per round for other traffic while a
    /// mega-request drains. Never affects bits.
    pub shard_width: usize,
    /// Requests at most this wide ride the priority admission lane under
    /// [`AdmitPolicy::Packed`] (default 8 — the interactive shape).
    pub priority_width: usize,
    /// Resident-session cap for LRU eviction: above this many sessions
    /// with live Brownian state, the least-recently-used one's heavy state
    /// is dropped and rebuilt bit-identically on its next admission. `0`
    /// (the default) disables eviction. Re-admission of an evicted session
    /// allocates (the rebuild), so the steady-state zero-allocation pin
    /// assumes the working set fits the cap.
    pub max_sessions: usize,
    /// Wall-clock session TTL in milliseconds: a session whose last submit
    /// is older than this has its heavy Brownian state dropped on the next
    /// door sweep (any `open_session`/`submit`), independent of the
    /// capacity-LRU cap. `0` (the default) disables the TTL. Exactly like
    /// capacity eviction, an expired session is rebuilt **bit-identically**
    /// on its next admission by seed-and-counter replay — the TTL changes
    /// memory residency, never bits.
    pub session_ttl_ms: u64,
}

impl ServeConfig {
    /// Defaults for a grid: 256-lane mega-batches, one worker per core,
    /// 64-lane chunks, default guards, immediate admission, size-aware
    /// packing, no sharding below `max_batch`, no session cap.
    pub fn new(t0: f64, t1: f64, n_steps: usize) -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self {
            t0,
            t1,
            n_steps,
            max_batch: 256,
            threads,
            chunk: 64,
            guard: GuardConfig::default(),
            auto_admit: true,
            policy: AdmitPolicy::Packed,
            shard_width: 0,
            priority_width: 8,
            max_sessions: 0,
            session_ttl_ms: 0,
        }
    }

    /// Effective per-round lane cap of a single request: `shard_width`
    /// clamped into `[1, max_batch]`, with `0` meaning `max_batch`.
    fn shard_lanes(&self) -> usize {
        let s = if self.shard_width == 0 { self.max_batch } else { self.shard_width };
        s.clamp(1, self.max_batch)
    }
}

/// Handle to a session opened with [`ServeEngine::open_session`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionId(usize);

/// Handle to a submitted request; redeem exactly once with
/// [`ServeEngine::wait`] / [`ServeEngine::wait_into`].
#[derive(Clone, Copy, Debug)]
pub struct Ticket {
    slot: usize,
    gen: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotState {
    Free,
    Queued,
    InFlight,
    Done,
    Faulted,
}

/// One request's slot in the pool: reused across requests (the buffers keep
/// their capacity), so steady-state submission allocates nothing.
struct Slot<T> {
    state: SlotState,
    gen: u64,
    session: usize,
    n_paths: usize,
    /// Noise counter assigned at submit time — packing order and sharding
    /// can never change which sample this request draws.
    counter: u64,
    /// Request initial state, SoA `[dim * n_paths]`.
    y0: Vec<T>,
    /// The request's noise grid (`[k][p][j]`), drawn once when its first
    /// shard is admitted and read by every later shard round. Lives in the
    /// slot (not the session) so wide requests survive session eviction
    /// and interleaved same-session traffic.
    grid: Vec<f32>,
    grid_ready: bool,
    /// Paths admitted so far — the shard cursor of a wide request.
    admitted: usize,
    /// Result trajectory, SoA `[(n_steps + 1) * dim * n_paths]` — exactly
    /// what [`super::integrate_batched`] returns for `batch = n_paths`.
    out: Vec<T>,
    /// Faults charged to this request (request-relative path indices).
    faults: Vec<SolveFault>,
}

impl<T> Slot<T> {
    fn new() -> Self {
        Self {
            state: SlotState::Free,
            gen: 0,
            session: 0,
            n_paths: 0,
            counter: 0,
            y0: Vec::new(),
            grid: Vec::new(),
            grid_ready: false,
            admitted: 0,
            out: Vec::new(),
            faults: Vec::new(),
        }
    }
}

/// One session at the front door: the evictable Brownian state plus the
/// replay metadata (`seed`, `counter_next`) that rebuilds it bit-for-bit.
struct Session {
    noise: Option<SessionNoise>,
    seed: u64,
    n_paths: usize,
    /// Next request counter, assigned at submit time.
    counter_next: u64,
    /// LRU tick of the last submit on this session.
    last_used: u64,
    /// Wall-clock time of the last submit, for
    /// [`ServeConfig::session_ttl_ms`] expiry.
    last_touch: Instant,
}

/// The in-flight mega-batch round. Its chunks are dispatched as one
/// [`pool::run_tasks`] job by the driving waiter, so no cursor/remaining
/// bookkeeping lives here anymore.
struct Active {
    lanes: usize,
    n_chunks: usize,
}

/// Front-door state, under one mutex: the admission queues, the slot pool,
/// the sessions, and the lane map of the active batch.
struct Door<T> {
    /// Priority admission lane (requests ≤ `priority_width` under
    /// [`AdmitPolicy::Packed`]): drained before `pending_lo` every round.
    pending_hi: VecDeque<usize>,
    /// Bulk admission lane.
    pending_lo: VecDeque<usize>,
    free_slots: Vec<usize>,
    slots: Vec<Slot<T>>,
    sessions: Vec<Session>,
    /// Sessions with live Brownian state (`noise.is_some()`).
    resident: usize,
    /// Monotone LRU clock, bumped per submit.
    tick: u64,
    /// Mega lane → `(slot, request-relative path)` for the active batch.
    lane_map: Vec<(usize, usize)>,
    active: Option<Active>,
    gate_open: bool,
}

/// Drop the least-recently-used resident sessions until the cap holds
/// (`keep` — the session just touched — is never the victim). Eviction
/// only drops rebuildable state, so it is always safe: a victim with
/// queued requests just pays the rebuild at its next admission.
fn evict_over_cap<T>(door: &mut Door<T>, cap: usize, keep: usize) {
    if cap == 0 {
        return;
    }
    while door.resident > cap {
        let victim = door
            .sessions
            .iter()
            .enumerate()
            .filter(|(s, sess)| *s != keep && sess.noise.is_some())
            .min_by_key(|(_, sess)| sess.last_used)
            .map(|(s, _)| s);
        match victim {
            Some(s) => {
                door.sessions[s].noise = None;
                door.resident -= 1;
            }
            None => break,
        }
    }
}

/// Drop the heavy state of every resident session (except `keep`, the one
/// being touched) whose last submit is older than the wall-clock TTL.
/// Swept on every `open_session`/`submit`, so an idle working set shrinks
/// without waiting for the capacity cap. Like capacity eviction this only
/// drops rebuildable state: the next admission replays the seed and
/// counter bit-identically.
fn expire_sessions<T>(door: &mut Door<T>, cfg: &ServeConfig, keep: usize) {
    if cfg.session_ttl_ms == 0 {
        return;
    }
    let ttl = Duration::from_millis(cfg.session_ttl_ms);
    let now = Instant::now();
    let Door { sessions, resident, .. } = door;
    for (s, sess) in sessions.iter_mut().enumerate() {
        if s != keep && sess.noise.is_some() && now.duration_since(sess.last_touch) > ttl {
            sess.noise = None;
            *resident -= 1;
        }
    }
}

/// The solve inputs of the active batch, preallocated at `max_batch`
/// capacity. Behind an `RwLock` so admission (one writer, under the door
/// lock) and the solving workers (readers) don't serialise the solve on
/// the door mutex.
struct Arena<T> {
    /// `[(k * nd + j) * max_batch + lane]` — [`super::StoredBatchNoise`]'s
    /// SoA layout at `batch = max_batch`.
    noise: Vec<T>,
    /// `[i * max_batch + lane]`.
    y0: Vec<T>,
}

struct Shared<T, S> {
    cfg: ServeConfig,
    sde: S,
    dim: usize,
    nd: usize,
    door: Mutex<Door<T>>,
    done_cv: Condvar,
    arena: RwLock<Arena<T>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // As in `map_chunks`: the lock is never held across user vector-field
    // code, so poisoning cannot leave the door inconsistent — recover.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

fn wlock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Per-worker scratch, preallocated at full-chunk shapes so the
/// steady-state solve path never allocates. The capacity signature is
/// recorded once and debug-asserted after every chunk — a reallocation on
/// the serving loop is a contract violation, not a slowdown.
struct Scratch<T> {
    y: Vec<T>,
    y2: Vec<T>,
    dw: Vec<T>,
    traj: Vec<T>,
    firsts: Vec<Option<SolveFault>>,
    faults: Vec<SolveFault>,
    lane_y: Vec<T>,
    lane_dw: Vec<T>,
    lane_traj: Vec<T>,
    sig: [usize; 9],
}

impl<T: Lane> Scratch<T> {
    fn new(dim: usize, nd: usize, n_steps: usize, chunk: usize) -> Self {
        let mut s = Self {
            y: vec![T::ZERO; dim * chunk],
            y2: vec![T::ZERO; dim * chunk],
            dw: vec![T::ZERO; nd * chunk],
            traj: Vec::with_capacity((n_steps + 1) * dim * chunk),
            firsts: Vec::with_capacity(chunk),
            faults: Vec::with_capacity(chunk),
            lane_y: vec![T::ZERO; dim],
            lane_dw: vec![T::ZERO; nd],
            lane_traj: Vec::with_capacity((n_steps + 1) * dim),
            sig: [0; 9],
        };
        s.sig = s.capacity_signature();
        s
    }

    fn capacity_signature(&self) -> [usize; 9] {
        [
            self.y.capacity(),
            self.y2.capacity(),
            self.dw.capacity(),
            self.traj.capacity(),
            self.firsts.capacity(),
            self.faults.capacity(),
            self.lane_y.capacity(),
            self.lane_dw.capacity(),
            self.lane_traj.capacity(),
        ]
    }
}

/// One participant's solve state: preallocated scratch plus a reusable
/// stepper (`reinit`, never `for_chunk`, per chunk — zero steady-state
/// stepper allocations). Checked out of the engine's fixed slot pool by
/// the pool tasks of an admission round; the executor caps a round's
/// concurrency at `threads`, so a free slot always exists.
struct WorkerState<M: BatchStepper> {
    scr: Scratch<M::Elem>,
    stepper: M,
}

/// A long-lived sampling engine over one SDE and one solve grid.
///
/// Generic exactly like [`super::integrate_batched`]: the stepper `M`
/// fixes the element type (`BatchReversibleHeun` for the historical `f64`
/// bits, `BatchReversibleHeun<f32>` for the 8-wide lanes), the system `S`
/// is any [`BatchSde`] at that precision. See the module docs for the
/// architecture; `tests/serve_engine.rs` pins the bitwise, isolation and
/// zero-allocation contracts.
///
/// The engine owns no threads: admission rounds are *driven* by whichever
/// caller blocks in [`wait_into`](Self::wait_into) (or calls
/// [`flush`](Self::flush)), and their chunk fan-out runs on the
/// process-wide persistent executor ([`super::pool`]).
pub struct ServeEngine<M, S>
where
    M: BatchStepper,
    S: BatchSde<M::Elem>,
{
    shared: Shared<M::Elem, S>,
    /// Fixed checkout pool of per-participant solve state, sized
    /// `cfg.threads`.
    workers: Vec<Mutex<Option<WorkerState<M>>>>,
    /// Held by the caller currently driving an admission round; `try_lock`
    /// only (never blocking while the door mutex is held), so the
    /// door → drive order cannot deadlock against the driver's
    /// drive → door order.
    drive: Mutex<()>,
}

impl<M, S> ServeEngine<M, S>
where
    M: BatchStepper + Send,
    S: BatchSde<M::Elem>,
{
    /// Preallocate the mega-batch arena and the per-participant
    /// scratch/stepper pool (executor workers are process-wide and spawn
    /// lazily on the first dispatched round).
    pub fn new(sde: S, cfg: ServeConfig) -> Self {
        assert!(cfg.t1 > cfg.t0, "need t1 > t0");
        assert!(cfg.n_steps >= 1 && cfg.max_batch >= 1);
        let dim = sde.state_dim();
        let nd = sde.brownian_dim();
        let cap = cfg.max_batch;
        let threads = cfg.threads.max(1);
        let chunk = cfg.chunk.max(1);
        let workers = (0..threads)
            .map(|_| {
                let scr = Scratch::<M::Elem>::new(dim, nd, cfg.n_steps, chunk);
                let stepper = M::for_chunk(&sde, cfg.t0, &scr.y, chunk);
                Mutex::new(Some(WorkerState { scr, stepper }))
            })
            .collect();
        let shared = Shared {
            sde,
            dim,
            nd,
            door: Mutex::new(Door {
                pending_hi: VecDeque::with_capacity(cap),
                pending_lo: VecDeque::with_capacity(cap),
                free_slots: Vec::with_capacity(cap),
                slots: Vec::new(),
                sessions: Vec::new(),
                resident: 0,
                tick: 0,
                lane_map: Vec::with_capacity(cap),
                active: None,
                gate_open: cfg.auto_admit,
            }),
            done_cv: Condvar::new(),
            arena: RwLock::new(Arena {
                noise: vec![<M::Elem as Lane>::ZERO; cfg.n_steps * nd * cap],
                y0: vec![<M::Elem as Lane>::ZERO; dim * cap],
            }),
            cfg,
        };
        Self { shared, workers, drive: Mutex::new(()) }
    }

    /// Open a session: persistent Brownian state for requests of `n_paths`
    /// paths each, keyed by `seed`. Sessions live as long as the engine
    /// (above [`ServeConfig::max_sessions`] only replay metadata survives
    /// eviction — the bits never change). A session may be wider than
    /// `max_batch`: its requests are sharded across admission rounds.
    pub fn open_session(&self, seed: u64, n_paths: usize) -> SessionId {
        assert!(n_paths >= 1, "need at least one path per request");
        let cfg = &self.shared.cfg;
        let noise = SessionNoise::new(seed, self.shared.nd, n_paths, cfg.t0, cfg.t1, cfg.n_steps);
        let mut door = lock(&self.shared.door);
        door.tick += 1;
        let sess = Session {
            noise: Some(noise),
            seed,
            n_paths,
            counter_next: 0,
            last_used: door.tick,
            last_touch: Instant::now(),
        };
        door.sessions.push(sess);
        door.resident += 1;
        let id = door.sessions.len() - 1;
        expire_sessions(&mut door, cfg, id);
        evict_over_cap(&mut door, cfg.max_sessions, id);
        SessionId(id)
    }

    /// Resident sessions with live Brownian state (evicted sessions keep
    /// only replay metadata). Introspection for tests and capacity tuning.
    pub fn resident_sessions(&self) -> usize {
        lock(&self.shared.door).resident
    }

    /// Queue one sampling request: solve the session's `n_paths` paths from
    /// the SoA initial state `y0` (`[dim * n_paths]`) with the session's
    /// next Brownian sample (counter assigned here, so admission order
    /// never changes the sample). Returns immediately; redeem the ticket
    /// with [`wait`](Self::wait) / [`wait_into`](Self::wait_into).
    pub fn submit(&self, session: SessionId, y0: &[M::Elem]) -> Ticket {
        let sh = &self.shared;
        let mut door = lock(&sh.door);
        door.tick += 1;
        let tick = door.tick;
        let (m, counter) = {
            let sess = &mut door.sessions[session.0];
            sess.last_used = tick;
            sess.last_touch = Instant::now();
            let c = sess.counter_next;
            sess.counter_next += 1;
            (sess.n_paths, c)
        };
        assert_eq!(y0.len(), sh.dim * m, "y0 must be SoA [dim * n_paths] at the session width");
        let si = match door.free_slots.pop() {
            Some(si) => si,
            None => {
                door.slots.push(Slot::new());
                door.slots.len() - 1
            }
        };
        let gen = {
            let slot = &mut door.slots[si];
            slot.state = SlotState::Queued;
            slot.session = session.0;
            slot.n_paths = m;
            slot.counter = counter;
            slot.grid_ready = false;
            slot.admitted = 0;
            slot.y0.clear();
            slot.y0.extend_from_slice(y0);
            slot.faults.clear();
            slot.gen
        };
        let hi = sh.cfg.policy == AdmitPolicy::Packed && m <= sh.cfg.priority_width;
        if hi {
            door.pending_hi.push_back(si);
        } else {
            door.pending_lo.push_back(si);
        }
        expire_sessions(&mut door, &sh.cfg, session.0);
        evict_over_cap(&mut door, sh.cfg.max_sessions, session.0);
        drop(door);
        Ticket { slot: si, gen }
    }

    /// Open the admission gate for one round (the `auto_admit: false`
    /// coalescing mode) and synchronously drive it to completion: queued
    /// requests are packed into one mega-batch round under the configured
    /// [`AdmitPolicy`], solved across the persistent executor, and their
    /// slots marked collectable before this returns. A sharded
    /// mega-request consumes one flush per shard round in gated mode.
    /// Extra flushes (nothing admissible, or another caller is already
    /// driving a round) are harmless no-ops beyond opening the gate.
    pub fn flush(&self) {
        {
            let mut door = lock(&self.shared.door);
            door.gate_open = true;
        }
        // Block (don't try_lock) on the drive mutex: a waiter's futile
        // drive attempt may be mid-flight with the gate still closed, and
        // a try-lock flush racing it would return without driving — with
        // every waiter parked and nobody left to run the now-open round.
        // Blocking is safe here: no other engine lock is held.
        let _driving = self.drive.lock().unwrap_or_else(|e| e.into_inner());
        let _ = self.drive_round();
        let door = lock(&self.shared.door);
        self.shared.done_cv.notify_all();
        drop(door);
    }

    /// Block until the request completes, swapping its trajectory into
    /// `out` (`[(n_steps + 1) * dim * n_paths]`, bit-identical to
    /// [`super::integrate_batched`] over the same noise) and releasing the
    /// slot back to the pool. Callers that reuse `out` across requests
    /// keep the steady-state round trip allocation-free. A faulted request
    /// returns the structured [`SolveError`] (request-relative path
    /// coordinates) — its quarantine never touches other requests' bits.
    ///
    /// The blocked waiter is the engine's motor: if no other caller is
    /// driving, it admits and solves rounds itself (through the shared
    /// executor) until its ticket completes; otherwise it parks on the
    /// done condvar until the current driver's round finishes.
    pub fn wait_into(
        &self,
        ticket: Ticket,
        out: &mut Vec<M::Elem>,
    ) -> Result<(), SolveError> {
        let sh = &self.shared;
        loop {
            {
                let mut door = lock(&sh.door);
                if let Some(res) = collect_slot(&mut door, ticket, out) {
                    return res;
                }
            }
            // Not ready: drive a round ourselves if nobody else is.
            if self.drive_once() {
                continue;
            }
            // Someone else is driving, or nothing is admissible yet (gated
            // mode waiting on a flush): park until the next round
            // completes. The driver notifies `done_cv` under the door
            // lock (at finalize and at drive-lock release), and we
            // re-check the slot under that same lock before waiting, so
            // no wakeup is lost.
            let mut door = lock(&sh.door);
            if let Some(res) = collect_slot(&mut door, ticket, out) {
                return res;
            }
            drop(sh.done_cv.wait(door).unwrap_or_else(|e| e.into_inner()));
        }
    }

    /// Non-blocking poll of a ticket: `None` while the request is still
    /// queued or in flight (the ticket stays redeemable), `Some` once it
    /// completed — with exactly [`wait_into`](Self::wait_into)'s collect
    /// semantics (trajectory swapped into `out`, slot released). Lets a
    /// caller interleave interactive traffic while a sharded mega-request
    /// drains.
    pub fn try_wait_into(
        &self,
        ticket: Ticket,
        out: &mut Vec<M::Elem>,
    ) -> Option<Result<(), SolveError>> {
        collect_slot(&mut lock(&self.shared.door), ticket, out)
    }

    /// Allocating convenience over [`wait_into`](Self::wait_into).
    pub fn wait(&self, ticket: Ticket) -> Result<Vec<M::Elem>, SolveError> {
        let mut out = Vec::new();
        self.wait_into(ticket, &mut out)?;
        Ok(out)
    }

    /// Try to become the driver for one admission round. Returns true when
    /// a round was admitted and solved to completion (its slots are now
    /// collectable), false when another caller holds the drive lock or
    /// nothing was admissible. Never blocks on the drive lock — a second
    /// waiter parks on `done_cv` instead, which the winning driver
    /// notifies under the door lock, so the try-lock race cannot strand
    /// anyone.
    fn drive_once(&self) -> bool {
        let Ok(_driving) = self.drive.try_lock() else {
            return false;
        };
        let progressed = self.drive_round();
        // Wake parked waiters whether or not a round ran: one of them must
        // re-evaluate now that the drive lock is free (their admissible
        // work may have arrived while we held it).
        let door = lock(&self.shared.door);
        self.shared.done_cv.notify_all();
        drop(door);
        progressed
    }

    /// Admit one mega-batch round and solve it across the process-wide
    /// executor ([`pool`]). Caller holds the drive lock. Lock order is
    /// door → arena throughout; neither is held across the fan-out (each
    /// chunk task re-acquires the arena read lock, matching the old worker
    /// loop's locking exactly — so the solve-order bits are unchanged).
    fn drive_round(&self) -> bool {
        let sh = &self.shared;
        let (lanes, n_chunks) = {
            let mut door = lock(&sh.door);
            let mut arena = wlock(&sh.arena);
            if !try_admit(&sh.cfg, sh.dim, sh.nd, &mut door, &mut arena) {
                return false;
            }
            let a = door.active.as_ref().expect("serve: admitted round has no active batch");
            (a.lanes, a.n_chunks)
        };
        let gcfg = sh.cfg.guard.normalised();
        let chunk = sh.cfg.chunk.max(1);
        pool::run_tasks(sh.cfg.threads.max(1), n_chunks, &|c| {
            let mut ws = self.checkout();
            {
                let arena = rlock(&sh.arena);
                solve_chunk::<M, S>(
                    &sh.cfg, &gcfg, &sh.sde, sh.dim, sh.nd, &arena, c, lanes, &mut ws.stepper,
                    &mut ws.scr,
                );
            }
            {
                let mut door = lock(&sh.door);
                record_chunk(
                    &mut door, sh.dim, sh.cfg.n_steps, chunk, c, lanes, &ws.scr.traj,
                    &mut ws.scr.faults,
                );
            }
            self.checkin(ws);
        });
        let mut door = lock(&sh.door);
        finalize(&mut door, lanes);
        sh.done_cv.notify_all();
        true
    }

    /// Check a per-participant solve state out of the fixed slot pool.
    /// The executor caps a round's concurrency at `threads`, and the pool
    /// holds exactly `threads` states, so a free slot always exists — the
    /// sweep spins (with yields) only across transient try_lock contention
    /// on the slot mutexes, never on a genuinely empty pool.
    fn checkout(&self) -> WorkerState<M> {
        loop {
            for slot in &self.workers {
                if let Ok(mut s) = slot.try_lock() {
                    if let Some(ws) = s.take() {
                        return ws;
                    }
                }
            }
            std::thread::yield_now();
        }
    }

    /// Return a solve state to the first empty slot (one always exists:
    /// states only leave slots via [`checkout`](Self::checkout)).
    fn checkin(&self, ws: WorkerState<M>) {
        let mut ws = Some(ws);
        loop {
            for slot in &self.workers {
                if let Ok(mut s) = slot.try_lock() {
                    if s.is_none() {
                        *s = ws.take();
                        return;
                    }
                }
            }
            std::thread::yield_now();
        }
    }
}

/// Collect a completed ticket's result out of its slot, releasing the slot
/// back to the pool. `None` while the request is queued or in flight.
/// Caller holds the door mutex.
fn collect_slot<T>(
    door: &mut Door<T>,
    ticket: Ticket,
    out: &mut Vec<T>,
) -> Option<Result<(), SolveError>> {
    let slot = &mut door.slots[ticket.slot];
    assert_eq!(slot.gen, ticket.gen, "serve: stale ticket (already collected?)");
    match slot.state {
        SlotState::Done => {
            out.clear();
            std::mem::swap(&mut slot.out, out);
            slot.state = SlotState::Free;
            slot.gen += 1;
            door.free_slots.push(ticket.slot);
            Some(Ok(()))
        }
        SlotState::Faulted => {
            let faults = std::mem::take(&mut slot.faults);
            slot.state = SlotState::Free;
            slot.gen += 1;
            door.free_slots.push(ticket.slot);
            Some(Err(SolveError::new("serve: request faulted", faults)))
        }
        _ => None,
    }
}

/// Admit `take` lanes of request `si` (request paths `p0 .. p0 + take`)
/// into the arena at mega-lane `base`: draw the request's noise grid on
/// first admission (rebuilding an evicted session bit-identically from its
/// replay metadata), transpose the shard's noise and initial state into
/// the SoA arena, and extend the lane map. Returns 1 when a session
/// rebuild made it resident again. Caller holds the door mutex (fields
/// split-borrowed) and the arena write lock.
#[allow(clippy::too_many_arguments)]
fn admit_range<T: Lane>(
    cfg: &ServeConfig,
    dim: usize,
    nd: usize,
    slots: &mut [Slot<T>],
    sessions: &mut [Session],
    lane_map: &mut Vec<(usize, usize)>,
    arena: &mut Arena<T>,
    si: usize,
    p0: usize,
    take: usize,
    base: usize,
) -> usize {
    let n_steps = cfg.n_steps;
    let cap = cfg.max_batch;
    let slot = &mut slots[si];
    let m = slot.n_paths;
    let mut rebuilt = 0usize;
    if !slot.grid_ready {
        // First shard of this request: draw the whole request's sample
        // once. The noise is keyed by (session seed, submit-time counter)
        // alone — lane placement, co-packed neighbours and the shard
        // layout cannot affect it.
        let sess = &mut sessions[slot.session];
        if sess.noise.is_none() {
            sess.noise =
                Some(SessionNoise::new(sess.seed, nd, m, cfg.t0, cfg.t1, cfg.n_steps));
            rebuilt = 1;
        }
        let noise = sess.noise.as_mut().expect("serve: session noise just rebuilt");
        let mut grid = std::mem::take(&mut slot.grid);
        noise.fill_request(slot.counter, &mut grid);
        slot.grid = grid;
        slot.grid_ready = true;
        slot.out.clear();
        slot.out.resize((n_steps + 1) * dim * m, T::ZERO);
        slot.faults.clear();
        slot.state = SlotState::InFlight;
    }
    // The transpose writes exactly `StoredBatchNoise::from_f32_grid`'s
    // lanes at batch = max_batch, shifted to this shard's lane range.
    for k in 0..n_steps {
        for t in 0..take {
            let row = (k * m + p0 + t) * nd;
            for j in 0..nd {
                arena.noise[(k * nd + j) * cap + base + t] = T::from_f32(slot.grid[row + j]);
            }
        }
    }
    for i in 0..dim {
        for t in 0..take {
            arena.y0[i * cap + base + t] = slot.y0[i * m + p0 + t];
        }
    }
    for t in 0..take {
        lane_map.push((si, p0 + t));
    }
    slot.admitted += take;
    rebuilt
}

/// Pack queued requests into the arena as one mega-batch round. Priority
/// lane first, then bulk; within a queue, [`AdmitPolicy::Packed`] first-fits
/// past a head that does not fit (deadline-preserving: the head is always
/// admitted first into the next empty batch) while [`AdmitPolicy::Fifo`]
/// stops at it. Requests wider than the shard width contribute one lane
/// range per round and keep their queue position until fully admitted.
/// Caller holds the door mutex and the arena write lock (lock order: door
/// → arena, always). Returns false when nothing was admitted.
fn try_admit<T: Lane>(
    cfg: &ServeConfig,
    dim: usize,
    nd: usize,
    door: &mut Door<T>,
    arena: &mut Arena<T>,
) -> bool {
    if door.active.is_some() || !door.gate_open {
        return false;
    }
    if door.pending_hi.is_empty() && door.pending_lo.is_empty() {
        return false;
    }
    let cap = cfg.max_batch;
    let shard = cfg.shard_lanes();
    let fifo = cfg.policy == AdmitPolicy::Fifo;
    let Door { pending_hi, pending_lo, slots, sessions, lane_map, resident, .. } = door;
    lane_map.clear();
    let mut lanes = 0usize;
    for queue in [pending_hi, pending_lo] {
        let mut i = 0usize;
        while lanes < cap {
            let Some(&si) = queue.get(i) else { break };
            let m = slots[si].n_paths;
            let done = slots[si].admitted;
            let rem = m - done;
            let take = if m <= shard {
                // Atomic request: all lanes in one round or none.
                if rem <= cap - lanes {
                    rem
                } else {
                    0
                }
            } else {
                // Sharded mega-request: one lane range per round, capped
                // at the shard width so co-packed traffic keeps flowing.
                rem.min(shard).min(cap - lanes)
            };
            if take == 0 {
                if fifo {
                    break; // strict FIFO: never skip ahead of the head
                }
                i += 1; // packed: bin-pack smaller requests behind it
                continue;
            }
            *resident +=
                admit_range(cfg, dim, nd, slots, sessions, lane_map, arena, si, done, take, lanes);
            lanes += take;
            if slots[si].admitted == m {
                queue.remove(i);
            } else {
                i += 1; // partial shard: keeps its place for the next round
            }
        }
    }
    if lanes == 0 {
        return false;
    }
    // Admission-time rebuilds may push the resident count back over the
    // cap; re-evict immediately (the drawn grids live in the slots, so even
    // a just-rebuilt session is safe to drop again).
    evict_over_cap(door, cfg.max_sessions, usize::MAX);
    if !cfg.auto_admit {
        door.gate_open = false; // one flush = one admission round
    }
    let chunk = cfg.chunk.max(1);
    let n_chunks = (lanes + chunk - 1) / chunk;
    door.active = Some(Active { lanes, n_chunks });
    true
}

/// Mark every fully-admitted slot of the finished round Done or Faulted —
/// a sharded request only completes with its final shard's round (rounds
/// are sequential, so all earlier shards are already recorded). Caller
/// holds the door mutex; `wait_into` picks the slots up via `done_cv`.
fn finalize<T>(door: &mut Door<T>, lanes: usize) {
    for l in 0..lanes {
        let (si, _) = door.lane_map[l];
        let slot = &mut door.slots[si];
        if slot.state == SlotState::InFlight && slot.admitted == slot.n_paths {
            slot.state =
                if slot.faults.is_empty() { SlotState::Done } else { SlotState::Faulted };
        }
    }
    door.active = None;
}

/// Copy one solved chunk's lanes from the worker's scratch into the owning
/// slots, and charge its faults to the owning requests (request-relative
/// path indices). Caller holds the door mutex.
fn record_chunk<T: Lane>(
    door: &mut Door<T>,
    dim: usize,
    n_steps: usize,
    chunk: usize,
    c: usize,
    lanes: usize,
    traj: &[T],
    faults: &mut Vec<SolveFault>,
) {
    let l0 = c * chunk;
    let cl = chunk.min(lanes - l0);
    for f in faults.drain(..) {
        let (si, p) = door.lane_map[l0 + f.path];
        door.slots[si].faults.push(SolveFault { path: p, ..f });
    }
    for q in 0..cl {
        let (si, p) = door.lane_map[l0 + q];
        let m = door.slots[si].n_paths;
        let out = &mut door.slots[si].out;
        for k in 0..=n_steps {
            for i in 0..dim {
                out[(k * dim + i) * m + p] = traj[(k * dim + i) * cl + q];
            }
        }
    }
}

/// Solve one chunk of the active mega-batch into `scr.traj`
/// (`[(k * dim + i) * cl + q]`), with the engine's guard contract: sweep at
/// the guard cadence, localise dirty chunks by a bit-identical re-run, and
/// re-run panicked chunks lane by lane under `catch_unwind`. Faults land in
/// `scr.faults` with chunk-relative `path` indices.
#[allow(clippy::too_many_arguments)]
fn solve_chunk<M, S>(
    cfg: &ServeConfig,
    gcfg: &GuardConfig,
    sde: &S,
    dim: usize,
    nd: usize,
    arena: &Arena<M::Elem>,
    c: usize,
    lanes: usize,
    stepper: &mut M,
    scr: &mut Scratch<M::Elem>,
) where
    M: BatchStepper,
    S: BatchSde<M::Elem>,
{
    let zero = <M::Elem as Lane>::ZERO;
    let cap = cfg.max_batch;
    let chunk = cfg.chunk.max(1);
    let l0 = c * chunk;
    let cl = chunk.min(lanes - l0);
    let n_steps = cfg.n_steps;
    let t0 = cfg.t0;
    let dt = (cfg.t1 - cfg.t0) / n_steps as f64;
    scr.faults.clear();

    // First pass — the steady-state hot loop. Same gather, grid arithmetic
    // and step sequence as `integrate_batched`'s run_chunk, so every lane's
    // bits equal the per-request solve's.
    let outcome = {
        let Scratch { y, dw, traj, .. } = &mut *scr;
        y.clear();
        y.resize(dim * cl, zero);
        for i in 0..dim {
            for q in 0..cl {
                y[i * cl + q] = arena.y0[i * cap + l0 + q];
            }
        }
        traj.clear();
        dw.clear();
        dw.resize(nd * cl, zero);
        // `reinit` evaluates the vector field at (t0, y0), so it must sit
        // inside the unwind guard too — a panicking field at step zero
        // quarantines like any other, instead of killing the worker.
        catch_unwind(AssertUnwindSafe(|| {
            stepper.reinit(sde, t0, y, cl);
            traj.extend_from_slice(y);
            let mut dirty = false;
            for k in 0..n_steps {
                let s = t0 + k as f64 * dt;
                let t = t0 + (k + 1) as f64 * dt;
                for j in 0..nd {
                    for q in 0..cl {
                        dw[j * cl + q] = arena.noise[(k * nd + j) * cap + l0 + q];
                    }
                }
                stepper.step(sde, s, t - s, dw, y, cl);
                traj.extend_from_slice(y);
                if gcfg.sweep_due(k + 1, n_steps) && guard::any_nonfinite(y) {
                    dirty = true;
                }
            }
            dirty
        }))
    };

    match outcome {
        Ok(false) => {}
        Ok(true) => {
            // Localisation: re-run the chunk bit-identically with a
            // per-step, per-lane sweep — exactly the forward engine's
            // strategy. The first pass's trajectory stays valid for
            // surviving lanes.
            let Scratch { y2, dw, firsts, faults, .. } = &mut *scr;
            y2.clear();
            y2.resize(dim * cl, zero);
            for i in 0..dim {
                for q in 0..cl {
                    y2[i * cl + q] = arena.y0[i * cap + l0 + q];
                }
            }
            stepper.reinit(sde, t0, y2, cl);
            firsts.clear();
            firsts.resize(cl, None);
            for k in 0..n_steps {
                let s = t0 + k as f64 * dt;
                let t = t0 + (k + 1) as f64 * dt;
                for j in 0..nd {
                    for q in 0..cl {
                        dw[j * cl + q] = arena.noise[(k * nd + j) * cap + l0 + q];
                    }
                }
                stepper.step(sde, s, t - s, dw, y2, cl);
                for (q, slot) in firsts.iter_mut().enumerate() {
                    if slot.is_some() {
                        continue;
                    }
                    for i in 0..dim {
                        if !y2[i * cl + q].to_f64().is_finite() {
                            *slot = Some(SolveFault {
                                step: k,
                                path: q,
                                component: i,
                                cause: FaultCause::NonFinite,
                            });
                            break;
                        }
                    }
                }
            }
            faults.extend(firsts.drain(..).flatten());
        }
        Err(_chunk_panic) => {
            // Re-run lane by lane: only the offending lane reports a
            // panic fault (with its last-started step); chunk-mates get
            // their exact single-lane bits — the same lanes the
            // per-request reference produces.
            let Scratch { traj, lane_y, lane_dw, lane_traj, faults, .. } = &mut *scr;
            traj.clear();
            traj.resize((n_steps + 1) * dim * cl, zero);
            for q in 0..cl {
                let l = l0 + q;
                let progress = Cell::new(0usize);
                let res = catch_unwind(AssertUnwindSafe(|| {
                    lane_y.clear();
                    lane_y.resize(dim, zero);
                    for i in 0..dim {
                        lane_y[i] = arena.y0[i * cap + l];
                    }
                    stepper.reinit(sde, t0, lane_y, 1);
                    lane_traj.clear();
                    lane_traj.extend_from_slice(lane_y);
                    lane_dw.clear();
                    lane_dw.resize(nd, zero);
                    for k in 0..n_steps {
                        progress.set(k);
                        let s = t0 + k as f64 * dt;
                        let t = t0 + (k + 1) as f64 * dt;
                        for j in 0..nd {
                            lane_dw[j] = arena.noise[(k * nd + j) * cap + l];
                        }
                        stepper.step(sde, s, t - s, lane_dw, lane_y, 1);
                        lane_traj.extend_from_slice(lane_y);
                    }
                }));
                let fault = match res {
                    Ok(()) => {
                        let mut found = None;
                        'scan: for b in 1..=n_steps {
                            for i in 0..dim {
                                if !lane_traj[b * dim + i].to_f64().is_finite() {
                                    found = Some(SolveFault {
                                        step: b - 1,
                                        path: q,
                                        component: i,
                                        cause: FaultCause::NonFinite,
                                    });
                                    break 'scan;
                                }
                            }
                        }
                        found
                    }
                    Err(payload) => Some(SolveFault {
                        step: progress.get(),
                        path: q,
                        component: 0,
                        cause: FaultCause::VectorFieldPanic {
                            payload: guard::panic_message(payload),
                        },
                    }),
                };
                match fault {
                    None => {
                        for k in 0..=n_steps {
                            for i in 0..dim {
                                traj[(k * dim + i) * cl + q] = lane_traj[k * dim + i];
                            }
                        }
                    }
                    Some(f) => {
                        faults.push(f);
                        // Hold the lane at its initial state: finite,
                        // deterministic — the request errors anyway, its
                        // trajectory is never handed out.
                        for k in 0..=n_steps {
                            for i in 0..dim {
                                traj[(k * dim + i) * cl + q] = arena.y0[i * cap + l];
                            }
                        }
                    }
                }
            }
        }
    }

    // The zero-allocation contract of the serving loop: a warmed worker's
    // scratch never reallocates (the test suite additionally pins the whole
    // engine with a counting global allocator).
    debug_assert_eq!(
        scr.capacity_signature(),
        scr.sig,
        "serve: steady-state solve reallocated worker scratch"
    );
}

#[cfg(test)]
mod tests {
    use super::super::systems::TanhDiagonalBatch;
    use super::super::{integrate_batched, BatchOptions, BatchReversibleHeun, StoredBatchNoise};
    use super::*;

    fn reference_solve(
        seed: u64,
        counter_start: u64,
        n_requests: usize,
        n_paths: usize,
        sde: &TanhDiagonalBatch,
        y0: &[f64],
    ) -> Vec<Vec<f64>> {
        // Rebuild each request's noise exactly as the engine's session
        // does, then solve it as its own batch.
        let d = 4usize;
        let mut sess = SessionNoise::new(seed, d, n_paths, 0.0, 1.0, 16);
        assert_eq!(sess.requests_drawn(), counter_start);
        let mut outs = Vec::new();
        for _ in 0..n_requests {
            let grid = sess.next_request();
            let noise = StoredBatchNoise::<f64>::from_f32_grid(0.0, 1.0, 16, d, n_paths, grid);
            let opts = BatchOptions { threads: 1, chunk: 5, ..Default::default() };
            outs.push(
                integrate_batched::<BatchReversibleHeun, _, _>(
                    sde, &noise, y0, n_paths, 0.0, 1.0, 16, &opts,
                )
                .expect("reference solve faulted"),
            );
        }
        outs
    }

    #[test]
    fn single_request_matches_integrate_batched_bitwise() {
        let sde = TanhDiagonalBatch::new(4, 99);
        let n_paths = 6usize;
        let y0 = vec![0.1f64; 4 * n_paths];
        let mut cfg = ServeConfig::new(0.0, 1.0, 16);
        cfg.max_batch = 32;
        cfg.threads = 2;
        cfg.chunk = 4;
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde, cfg);
        let sess = engine.open_session(7, n_paths);
        let sde_ref = TanhDiagonalBatch::new(4, 99);
        let expect = reference_solve(7, 0, 2, n_paths, &sde_ref, &y0);
        let t0 = engine.submit(sess, &y0);
        let got0 = engine.wait(t0).expect("request faulted");
        let t1 = engine.submit(sess, &y0);
        let got1 = engine.wait(t1).expect("request faulted");
        assert_eq!(got0, expect[0], "request 0 must be bit-identical");
        assert_eq!(got1, expect[1], "request 1 advances the session counter");
    }

    #[test]
    #[should_panic(expected = "stale ticket")]
    fn tickets_are_single_use() {
        let sde = TanhDiagonalBatch::new(2, 1);
        let engine =
            ServeEngine::<BatchReversibleHeun, _>::new(sde, ServeConfig::new(0.0, 1.0, 4));
        let sess = engine.open_session(3, 2);
        let t = engine.submit(sess, &[0.1; 4]);
        engine.wait(t).expect("request faulted");
        let _ = engine.wait(t); // panics: the slot was released
    }

    #[test]
    fn request_seed_is_the_step_noise_derivation() {
        assert_eq!(request_seed(42, 0), splitmix64(42));
        assert_ne!(request_seed(42, 1), request_seed(42, 0));
        assert_ne!(request_seed(43, 0), request_seed(42, 0));
    }

    #[test]
    fn expired_sessions_rebuild_bit_identically() {
        let sde = TanhDiagonalBatch::new(4, 99);
        let n_paths = 5usize;
        let y0 = vec![0.1f64; 4 * n_paths];
        let mut cfg = ServeConfig::new(0.0, 1.0, 16);
        cfg.max_batch = 32;
        cfg.threads = 2;
        cfg.chunk = 4;
        cfg.session_ttl_ms = 1;
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde, cfg);
        let a = engine.open_session(7, n_paths);
        let sde_ref = TanhDiagonalBatch::new(4, 99);
        let expect = reference_solve(7, 0, 2, n_paths, &sde_ref, &y0);

        let t = engine.submit(a, &y0);
        let got0 = engine.wait(t).expect("request faulted");
        assert_eq!(engine.resident_sessions(), 1);

        // Let `a` age past the TTL, then touch the door via a fresh
        // session: the sweep drops `a`'s Brownian state (only the new
        // session stays resident).
        std::thread::sleep(Duration::from_millis(10));
        let _b = engine.open_session(11, n_paths);
        assert_eq!(
            engine.resident_sessions(),
            1,
            "TTL sweep must expire the idle session"
        );

        // Submitting on the expired session replays (seed, counter) into a
        // rebuilt Brownian tree: request 1's bits are exactly what an
        // never-expired session would have produced.
        let t = engine.submit(a, &y0);
        let got1 = engine.wait(t).expect("request faulted");
        assert_eq!(got0, expect[0]);
        assert_eq!(got1, expect[1], "post-expiry rebuild must replay the counter bit-identically");
    }
}
