//! Fault tolerance for the solve/adjoint/training hot path: structured
//! errors, non-finite guards, and deterministic fault injection.
//!
//! The ROADMAP's serving-scale north star means a single non-finite sample
//! or a panicking vector field must not abort the whole process. This module
//! provides the shared vocabulary the engines speak when something goes
//! wrong:
//!
//! * [`SolveFault`] / [`SolveError`] — one fault is one `(step, path,
//!   component, cause)` record with exact coordinates; an error is the full
//!   list of faults a solve detected before giving up. Every fallible entry
//!   point ([`super::integrate_batched`], the `adjoint_solve*` family,
//!   `GanTrainer::train_step`) returns `Result<_, SolveError>`-shaped
//!   results built from these.
//! * [`GuardConfig`] — the knobs: blockwise `is_finite` sweeps every
//!   `check_every` steps (near-zero overhead — the `guard/*` rows of the
//!   `hotpath_micro` bench pin it below 2%), and the reconstruction-drift
//!   watchdog (`checkpoint_every` / `drift_tol`) that degrades the adjoint's
//!   `Reconstruct` mode to `Tape` instead of returning wrong gradients.
//! * [`FaultPlan`] / [`FaultyBatchNoise`] / [`PanicOnSentinel`] —
//!   deterministic fault injection for tests: plant a NaN in one increment
//!   lane, panic the noise fill for one path, or panic a drift evaluation
//!   when a sentinel state value is seen. `tests/fault_tolerance.rs` drives
//!   every recovery path through these, bit-deterministically.
//!
//! # Coordinate conventions
//!
//! `SolveFault::step` is the grid step whose *update* first produced the
//! faulty value: a NaN injected into the increment consumed by step `s`
//! is reported as `step == s` (the state at grid point `s + 1` is the first
//! non-finite one). Forward solves localise faults exactly by re-running the
//! offending chunk with a per-step sweep; adjoint sweeps report at the
//! guard's sweep cadence (set `check_every = 1` for exact coordinates).
//! Panic faults from the batched adjoint carry chunk-granularity coordinates
//! (the chunk's first path, step 0); the forward engine re-runs panicked
//! chunks path-by-path and reports the exact path and last-started step.

use super::batch::{BatchNoise, BatchSde};
use super::simd::Lane;
use std::any::Any;
use std::fmt;

/// Why a lane (or a training step) was faulted.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultCause {
    /// A non-finite value (NaN or ±∞) appeared in a state, cotangent,
    /// gradient, or loss lane.
    NonFinite,
    /// The reversible-Heun backward reconstruction drifted past tolerance
    /// against a sparse forward checkpoint (the instability mode analysed by
    /// McCallum & Foster for stiff systems). Recoverable: the adjoint falls
    /// back to `Tape` mode instead of surfacing this as an error, so it only
    /// appears in faults when the fallback itself was impossible.
    ReconstructionDrift {
        /// Max-abs deviation of the reconstructed state from the checkpoint.
        drift: f64,
        /// The tolerance that was breached (relative to the checkpoint's
        /// max-abs state, floored at 1).
        tol: f64,
    },
    /// A vector-field / noise evaluation panicked; the payload is the panic
    /// message (or a placeholder for non-string payloads).
    VectorFieldPanic {
        /// Stringified panic payload.
        payload: String,
    },
}

impl fmt::Display for FaultCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultCause::NonFinite => write!(f, "non-finite value"),
            FaultCause::ReconstructionDrift { drift, tol } => {
                write!(f, "reconstruction drift {drift:e} > tol {tol:e}")
            }
            FaultCause::VectorFieldPanic { payload } => {
                write!(f, "vector-field panic: {payload}")
            }
        }
    }
}

/// One structured fault: exact coordinates plus cause. See the module docs
/// for the step/path/component conventions per engine.
#[derive(Clone, Debug, PartialEq)]
pub struct SolveFault {
    /// Grid step whose update first produced the faulty value (training
    /// faults: the trainer's step counter).
    pub step: usize,
    /// Global path index (0 for per-path solves and training faults).
    pub path: usize,
    /// State/gradient component index (0 for panics and loss faults).
    pub component: usize,
    /// What went wrong.
    pub cause: FaultCause,
}

impl fmt::Display for SolveFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "step {} path {} component {}: {}",
            self.step, self.path, self.component, self.cause
        )
    }
}

/// Structured solve error: the faults a fallible entry point detected
/// before aborting (or, for quarantine-mode solves, before every path
/// died). Implements [`std::error::Error`], so it threads through
/// `anyhow::Result` at the coordinator layer unchanged.
#[derive(Clone, Debug)]
pub struct SolveError {
    /// Which entry point (and phase) detected the faults.
    pub context: &'static str,
    /// Every fault detected, in ascending chunk order.
    pub faults: Vec<SolveFault>,
}

impl SolveError {
    /// Bundle faults under a context label.
    pub fn new(context: &'static str, faults: Vec<SolveFault>) -> Self {
        Self { context, faults }
    }
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {} fault(s)", self.context, self.faults.len())?;
        for fault in self.faults.iter().take(4) {
            write!(f, "; {fault}")?;
        }
        if self.faults.len() > 4 {
            write!(f, "; …")?;
        }
        Ok(())
    }
}

impl std::error::Error for SolveError {}

/// Guard knobs for the fallible solve/adjoint entry points. Lives inside
/// [`super::BatchOptions`] for the batched engines; the per-path adjoint
/// uses the defaults.
///
/// Tuning: `check_every` trades detection latency for sweep cost — at the
/// default of 8 the sweep touches each lane once per 8 steps, which the
/// `hotpath_micro` `guard/*` rows pin below 2% of a batched
/// reversible-Heun solve; 0 disables the sweeps (and with them non-finite
/// detection). `checkpoint_every` / `drift_tol` control the adjoint's
/// divergence watchdog: a sparse forward checkpoint every `checkpoint_every`
/// steps is compared against the backward reconstruction, and a relative
/// drift above `drift_tol` (scaled by the checkpoint's max-abs state,
/// floored at 1) degrades the remaining sweep from `Reconstruct` to `Tape`
/// — O(1) memory becomes O(n), gradients stay exact. A negative `drift_tol`
/// forces the fallback at the first checkpoint (the test hook); 0 for
/// `checkpoint_every` disables the watchdog.
///
/// Every engine canonicalises its copy through [`normalised`](Self::normalised)
/// once at entry and then asks [`sweep_due`](Self::sweep_due) /
/// [`backward_sweep_due`](Self::backward_sweep_due) /
/// [`checkpoint_due`](Self::checkpoint_due) instead of reimplementing the
/// cadence arithmetic — `check_every` and `checkpoint_every` share one
/// definition of the `0` / `1` / `usize::MAX` edges by construction.
#[derive(Clone, Copy, Debug)]
pub struct GuardConfig {
    /// Sweep state/cotangent lanes for non-finite values every this many
    /// steps (and at the terminal step). 0 disables.
    pub check_every: usize,
    /// Store a sparse forward checkpoint every this many steps for the
    /// adjoint's reconstruction-drift watchdog. 0 disables.
    pub checkpoint_every: usize,
    /// Relative reconstruction-drift tolerance; breach triggers the
    /// `Reconstruct` → `Tape` fallback. Negative forces the fallback at the
    /// first checkpoint (deterministic test hook).
    pub drift_tol: f64,
}

impl Default for GuardConfig {
    fn default() -> Self {
        // Same tolerance the debug-mode replay assert uses, so the release
        // watchdog and the debug invariant agree on what "drifted" means.
        Self { check_every: 8, checkpoint_every: 16, drift_tol: 1e-6 }
    }
}

impl GuardConfig {
    /// All guards off — the pre-fault-tolerance hot path, for overhead
    /// comparisons (`hotpath_micro` `guard/*` rows).
    pub fn disabled() -> Self {
        Self { check_every: 0, checkpoint_every: 0, drift_tol: 1e-6 }
    }

    /// The canonical form every engine runs on — **the single place the
    /// cadence knobs are validated**. Semantics (identical for both
    /// fields, by construction):
    ///
    /// * `0` disables that guard entirely — no sweep / no checkpoint is
    ///   ever due, and no engine may compute `step % 0` (the cadence
    ///   helpers below gate the modulo on the zero check);
    /// * `1` fires on every step;
    /// * `usize::MAX` is valid and effectively means "terminal only":
    ///   [`sweep_due`](Self::sweep_due) still fires at the final step, and
    ///   [`checkpoint_due`](Self::checkpoint_due) stores exactly the
    ///   step-0 checkpoint.
    ///
    /// A NaN `drift_tol` is normalised to the default tolerance: the
    /// watchdog compares with `!(drift <= tol · scale)`, so a NaN would
    /// silently force the `Reconstruct → Tape` fallback at every
    /// checkpoint instead of being reported as a configuration error.
    /// (Negative `drift_tol` stays as-is — it is the documented
    /// force-the-fallback test hook.)
    #[must_use]
    pub fn normalised(mut self) -> Self {
        if self.drift_tol.is_nan() {
            self.drift_tol = GuardConfig::default().drift_tol;
        }
        self
    }

    /// True when the non-finite sweep is due after completing
    /// `steps_done` of `n_steps` forward steps: at the `check_every`
    /// cadence and unconditionally at the terminal step (so nothing
    /// escapes detection), never when disabled (`check_every == 0`).
    #[inline]
    pub fn sweep_due(&self, steps_done: usize, n_steps: usize) -> bool {
        self.check_every != 0 && (steps_done % self.check_every == 0 || steps_done == n_steps)
    }

    /// True when a backward sweep is due at grid step `k` — the adjoint's
    /// cadence form (no terminal special case: the backward sweep's `k = 0`
    /// endpoint is on-cadence for every `check_every`).
    #[inline]
    pub fn backward_sweep_due(&self, k: usize) -> bool {
        self.check_every != 0 && k % self.check_every == 0
    }

    /// True when the drift watchdog stores (or compares) a sparse forward
    /// checkpoint at grid step `k`; never when disabled
    /// (`checkpoint_every == 0`).
    #[inline]
    pub fn checkpoint_due(&self, k: usize) -> bool {
        self.checkpoint_every != 0 && k % self.checkpoint_every == 0
    }
}

/// True if any lane holds a non-finite value — the cheap blockwise sweep
/// the engines run every [`GuardConfig::check_every`] steps. Precision-
/// generic: `f32` lanes widen through [`Lane::to_f64`] (the identity for
/// `f64`), so both instantiations share one definition of "finite".
#[inline]
pub fn any_nonfinite<T: Lane>(lanes: &[T]) -> bool {
    lanes.iter().any(|v| !v.to_f64().is_finite())
}

/// First non-finite lane in chunk-SoA layout `[dim * chunk]`, scanned path-
/// major (ascending path, then ascending component) so the report is the
/// lowest faulted path's first bad component. Returns `(component, q)`.
pub fn first_nonfinite<T: Lane>(lanes: &[T], dim: usize, chunk: usize) -> Option<(usize, usize)> {
    for q in 0..chunk {
        for i in 0..dim {
            if !lanes[i * chunk + q].to_f64().is_finite() {
                return Some((i, q));
            }
        }
    }
    None
}

/// Stringify a caught panic payload (`&str` and `String` payloads pass
/// through; anything else gets a placeholder).
pub fn panic_message(payload: Box<dyn Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// A quarantine-mode solve result: the full SoA trajectory with faulted
/// lanes replaced (by the refill closure's trajectory, or by the path's
/// initial state held constant), plus the structured fault report.
/// Surviving paths are bit-identical to an uninjected solve with the same
/// lane assignment — the engine's batched ≡ per-path invariant.
#[derive(Clone, Debug)]
pub struct GuardedSolve<T> {
    /// SoA trajectory `[(n_steps + 1) * dim * batch]`, as
    /// [`super::integrate_batched`] returns.
    pub traj: Vec<T>,
    /// One fault per quarantined path (its first), ascending path order
    /// within each chunk.
    pub faults: Vec<SolveFault>,
    /// Global indices of the dropped paths, ascending.
    pub quarantined: Vec<usize>,
}

// ---------------------------------------------------------------------------
// Deterministic fault injection
// ---------------------------------------------------------------------------

/// Coordinates of a planned NaN injection into a noise increment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NanSite {
    /// Grid step whose increment is corrupted.
    pub step: usize,
    /// Global path index.
    pub path: usize,
    /// Brownian channel.
    pub channel: usize,
}

/// Coordinates of a planned panic during a noise fill.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PanicSite {
    /// Grid step at which the fill panics.
    pub step: usize,
    /// Global path index whose presence in the fill triggers the panic.
    pub path: usize,
}

/// Coordinates of a planned cotangent-lane corruption (applied by a test's
/// `grad_step` closure via [`FaultPlan::corrupt_grad_lanes`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GradSite {
    /// Backward-sweep grid step at which the corruption lands.
    pub step: usize,
    /// Global path index.
    pub path: usize,
    /// State component of the cotangent lane.
    pub component: usize,
}

/// A deterministic fault-injection plan: which increments turn NaN, which
/// fills panic, which cotangent lanes get corrupted. Pure data — the same
/// plan replayed against the same solve produces the same faults bit-for-
/// bit, which is what lets `tests/fault_tolerance.rs` assert exact
/// coordinates and bit-identical recovery.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// NaN injections into noise increments.
    pub nans: Vec<NanSite>,
    /// Panics during noise fills.
    pub panics: Vec<PanicSite>,
    /// Cotangent-lane corruptions for adjoint sweeps.
    pub grads: Vec<GradSite>,
}

impl FaultPlan {
    /// Empty plan.
    pub fn new() -> Self {
        Self::default()
    }

    /// Plan a NaN in channel `channel` of path `path`'s increment at grid
    /// step `step`.
    pub fn inject_nan(mut self, step: usize, path: usize, channel: usize) -> Self {
        self.nans.push(NanSite { step, path, channel });
        self
    }

    /// Plan a panic when the fill for grid step `step` covers path `path`.
    pub fn panic_in_fill(mut self, step: usize, path: usize) -> Self {
        self.panics.push(PanicSite { step, path });
        self
    }

    /// Plan a cotangent corruption at `(step, path, component)` of the
    /// backward sweep.
    pub fn corrupt_grad(mut self, step: usize, path: usize, component: usize) -> Self {
        self.grads.push(GradSite { step, path, component });
        self
    }

    /// Apply the planned gradient corruptions to a chunk's cotangent lanes
    /// (`lz`, `[dim * chunk_len]` covering global paths
    /// `p0 .. p0 + chunk_len`) at backward step `k` — call this from a
    /// `grad_step` closure to inject bit-deterministically.
    pub fn corrupt_grad_lanes(&self, k: usize, p0: usize, chunk_len: usize, lz: &mut [f64]) {
        for site in &self.grads {
            if site.step == k && site.path >= p0 && site.path < p0 + chunk_len {
                lz[site.component * chunk_len + (site.path - p0)] = f64::NAN;
            }
        }
    }
}

/// A [`BatchNoise`] wrapper that applies a [`FaultPlan`] on top of an inner
/// source: planned panics fire first (the fill never completes), then
/// planned NaNs overwrite the inner source's increments. Paths the plan
/// doesn't name see bit-identical increments to the bare inner source, so
/// surviving lanes of a quarantine-mode solve match an uninjected run
/// exactly.
pub struct FaultyBatchNoise<'a, N> {
    inner: &'a N,
    plan: FaultPlan,
}

impl<'a, N> FaultyBatchNoise<'a, N> {
    /// Wrap `inner` with `plan`.
    pub fn new(inner: &'a N, plan: FaultPlan) -> Self {
        Self { inner, plan }
    }
}

impl<T: Lane, N: BatchNoise<T>> BatchNoise<T> for FaultyBatchNoise<'_, N> {
    fn brownian_dim(&self) -> usize {
        self.inner.brownian_dim()
    }

    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [T]) {
        for site in &self.plan.panics {
            if site.step == k && site.path >= p0 && site.path < p0 + chunk {
                panic!(
                    "[fault-injection] planned noise panic at step {} path {}",
                    site.step, site.path
                );
            }
        }
        self.inner.fill_step(k, s, t, p0, chunk, out);
        for site in &self.plan.nans {
            if site.step == k && site.path >= p0 && site.path < p0 + chunk {
                out[site.channel * chunk + (site.path - p0)] = T::from_f64(f64::NAN);
            }
        }
    }
}

/// A [`BatchSde`] wrapper whose **drift** panics whenever any state lane
/// equals `sentinel` exactly — plant the sentinel in one path's initial
/// state to make exactly that path's drift evaluations panic (at step 0,
/// during the stepper's initial field evaluation) while every other path's
/// lanes stay bit-identical to the bare inner system.
pub struct PanicOnSentinel<'a, S> {
    inner: &'a S,
    sentinel: f64,
}

impl<'a, S> PanicOnSentinel<'a, S> {
    /// Wrap `inner`, panicking on `sentinel` state values.
    pub fn new(inner: &'a S, sentinel: f64) -> Self {
        Self { inner, sentinel }
    }
}

impl<T: Lane, S: BatchSde<T>> BatchSde<T> for PanicOnSentinel<'_, S> {
    fn state_dim(&self) -> usize {
        self.inner.state_dim()
    }

    fn brownian_dim(&self) -> usize {
        self.inner.brownian_dim()
    }

    fn diagonal_noise(&self) -> bool {
        self.inner.diagonal_noise()
    }

    fn drift_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize) {
        if y.iter().any(|v| v.to_f64() == self.sentinel) {
            panic!("[fault-injection] sentinel drift panic");
        }
        self.inner.drift_batch(t, y, out, batch);
    }

    fn diffusion_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize) {
        self.inner.diffusion_batch(t, y, out, batch);
    }

    fn diffusion_diag_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize) {
        self.inner.diffusion_diag_batch(t, y, out, batch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_helpers_find_exact_lane() {
        let mut lanes = vec![0.0f64; 3 * 4]; // dim 3, chunk 4
        assert!(!any_nonfinite(&lanes));
        assert_eq!(first_nonfinite(&lanes, 3, 4), None);
        lanes[1 * 4 + 2] = f64::NAN; // component 1, path 2
        lanes[2 * 4 + 3] = f64::INFINITY; // component 2, path 3
        assert!(any_nonfinite(&lanes));
        // Path-major scan: path 2's component 1 comes before path 3's.
        assert_eq!(first_nonfinite(&lanes, 3, 4), Some((1, 2)));
    }

    #[test]
    fn fault_display_carries_coordinates() {
        let err = SolveError::new(
            "test",
            vec![SolveFault {
                step: 5,
                path: 3,
                component: 1,
                cause: FaultCause::NonFinite,
            }],
        );
        let s = format!("{err}");
        assert!(s.contains("step 5") && s.contains("path 3"), "{s}");
    }

    #[test]
    fn cadence_helpers_zero_one_max_edges() {
        // check_every = 0: disabled — never due, and no `% 0` is evaluated.
        let off = GuardConfig { check_every: 0, checkpoint_every: 0, ..Default::default() };
        for k in 0..200usize {
            assert!(!off.sweep_due(k, 100));
            assert!(!off.backward_sweep_due(k));
            assert!(!off.checkpoint_due(k));
        }
        assert!(!off.sweep_due(100, 100), "terminal step stays off when disabled");

        // check_every = 1: every step.
        let every = GuardConfig { check_every: 1, checkpoint_every: 1, ..Default::default() };
        for k in 1..=100usize {
            assert!(every.sweep_due(k, 100));
            assert!(every.backward_sweep_due(k - 1));
            assert!(every.checkpoint_due(k - 1));
        }

        // check_every = usize::MAX: terminal-only sweeps, step-0-only
        // checkpoint — valid, no overflow, no panic.
        let max = GuardConfig {
            check_every: usize::MAX,
            checkpoint_every: usize::MAX,
            ..Default::default()
        };
        for k in 1..100usize {
            assert!(!max.sweep_due(k, 100));
            assert!(!max.backward_sweep_due(k));
        }
        assert!(max.sweep_due(100, 100), "terminal step always swept when enabled");
        assert!(max.backward_sweep_due(0));
        assert!(max.checkpoint_due(0));
        assert!(!max.checkpoint_due(99));

        // The default cadence fires where the historical inline arithmetic
        // did: (k+1) % 8 == 0 or terminal.
        let dflt = GuardConfig::default();
        assert!(dflt.sweep_due(8, 100) && dflt.sweep_due(16, 100) && dflt.sweep_due(100, 100));
        assert!(!dflt.sweep_due(9, 100));
        assert!(dflt.checkpoint_due(0) && dflt.checkpoint_due(16) && !dflt.checkpoint_due(8));
    }

    #[test]
    fn normalised_fixes_nan_tolerance_only() {
        let cfg = GuardConfig { drift_tol: f64::NAN, ..Default::default() }.normalised();
        assert_eq!(cfg.drift_tol, GuardConfig::default().drift_tol);
        // Negative tolerance is the documented force-fallback hook: preserved.
        let hook = GuardConfig { drift_tol: -1.0, ..Default::default() }.normalised();
        assert_eq!(hook.drift_tol, -1.0);
        // Zero cadences are already canonical: identity.
        let off = GuardConfig::disabled().normalised();
        assert_eq!(off.check_every, 0);
        assert_eq!(off.checkpoint_every, 0);
    }

    #[test]
    fn plan_corrupts_only_named_lane() {
        let plan = FaultPlan::new().corrupt_grad(3, 5, 1);
        let mut lz = vec![1.0f64; 2 * 4]; // dim 2, chunk 4, p0 = 4
        plan.corrupt_grad_lanes(2, 4, 4, &mut lz);
        assert!(lz.iter().all(|v| v.is_finite()), "wrong step must not fire");
        plan.corrupt_grad_lanes(3, 4, 4, &mut lz);
        assert!(lz[1 * 4 + 1].is_nan(), "component 1 of path 5 (q = 1)");
        assert_eq!(lz.iter().filter(|v| v.is_nan()).count(), 1);
    }
}
