//! Native reverse-mode adjoint engine for the reversible Heun method
//! (paper Section 3, Algorithm 2 — "optimise then discretise" made exact).
//!
//! Backpropagating through an SDE solve usually forces a choice: store the
//! whole forward trajectory (O(n) memory) or integrate a backward adjoint
//! SDE and eat its truncation error (Li et al. 2020). The reversible Heun
//! scheme removes the choice: its step is *algebraically invertible*, so the
//! backward pass reconstructs the forward trajectory state-by-state via
//! [`ReversibleHeun::reverse_step`] in O(1) memory, and the accumulated
//! cotangents are the **exact** derivatives of the discrete forward solve —
//! zero truncation error, limited only by roundoff (the paper's Figure 2).
//!
//! The engine is layered on the batch engine of [`super::batch`]:
//!
//! * [`SdeVjp`] / [`BatchSdeVjp`] — analytic vector-Jacobian products of the
//!   drift and diffusion with respect to state and parameters, per path and
//!   over SoA lanes (every per-path [`SdeVjp`] is a [`BatchSdeVjp`] through
//!   a blanket gather/scatter adapter, mirroring [`BatchSde`]);
//! * [`adjoint_solve`] — per-path forward + backward sweep returning
//!   `∂L/∂y₀` and `∂L/∂θ` for a terminal loss `L(z_N)`;
//! * [`adjoint_solve_batched`] — the SoA twin over `[dim × batch]` lanes
//!   with a chunked thread fan-out; per-path lane arithmetic runs on the
//!   fused VJP kernels of [`super::simd`], so batched gradients are
//!   **bit-for-bit equal** to per-path gradients (θ-gradients are kept in
//!   per-path lanes and reduced in ascending path order at the very end,
//!   independent of chunking and threading);
//! * [`BackwardMode`] — `Reconstruct` (O(1) memory, the paper's algorithm)
//!   vs `Tape` (store the forward `ẑ` trajectory and backprop through it).
//!   Both differentiate the same discrete map; their difference is pure
//!   reconstruction roundoff, which is what the machine-precision rows of
//!   [`crate::coordinator::gradient_error`] measure;
//! * [`GridReplayNoise`] — backward-pass Brownian reconstruction: one
//!   [`BrownianSource::fill_grid`] descent up front, then O(1) replay of
//!   `ΔW` in any order — the doubly-sequential access pattern the Brownian
//!   Interval (Section 4) was built for.
//!
//! # The backward recursion
//!
//! With the forward step (dropping the step index, `′` = next)
//!
//! ```text
//! ẑ′ = 2z − ẑ + f(t, ẑ) Δt + g(t, ẑ) ΔW
//! z′ = z + ½ (f(t, ẑ) + f(t′, ẑ′)) Δt + ½ (g(t, ẑ) + g(t′, ẑ′)) ΔW
//! ```
//!
//! the cotangents `(λ_z, λ_ẑ) = (∂L/∂z, ∂L/∂ẑ)` pull back as
//!
//! ```text
//! w   = λ_ẑ′ + J_f(t′, ẑ′)ᵀ (½Δt λ_z′) + J_{g·ΔW}(t′, ẑ′)ᵀ (½ λ_z′)
//! λ_z = λ_z′ + 2w
//! λ_ẑ = −w + J_f(t, ẑ)ᵀ (Δt (w + ½λ_z′)) + J_{g·ΔW}(t, ẑ)ᵀ (w + ½λ_z′)
//! ```
//!
//! with the same weights driving the parameter accumulation
//! `∂L/∂θ += (∂f/∂θ)ᵀ(·) + (∂(g·ΔW)/∂θ)ᵀ(·)` at both evaluation points, and
//! `∂L/∂y₀ = λ_z + λ_ẑ` at step 0 (where `z₀ = ẑ₀ = y₀`). The `ẑ`
//! states the Jacobians are evaluated at come from running
//! [`ReversibleHeun::reverse_step`] in lockstep with the cotangent
//! recursion, replaying the forward noise in reverse.
//!
//! In debug builds the `Reconstruct` backward replays each reconstructed
//! state forward again and asserts it reproduces the pre-reverse state
//! (the reconstruction-drift invariant); release builds skip the check.
//!
//! # Fault tolerance
//!
//! Every adjoint entry point returns `Result<AdjointGrad, SolveError>`:
//! non-finite states and cotangents are caught by blockwise sweeps at the
//! [`GuardConfig::check_every`] cadence, and the `Reconstruct` backward
//! carries a **divergence watchdog** — sparse forward checkpoints every
//! [`GuardConfig::checkpoint_every`] steps are compared against the
//! backward reconstruction, and a relative drift beyond
//! [`GuardConfig::drift_tol`] (the failure mode stiff systems exhibit, per
//! McCallum & Foster) degrades the *remaining* sweep to `Tape` mode by
//! replaying the forward prefix into an exact tape: O(1) memory becomes
//! O(n), gradients stay exact, and [`AdjointGrad::fallbacks`] counts the
//! events. The per-path API uses [`GuardConfig::default`]; the batched API
//! reads `opts.guard`. Because the chunk is the watchdog unit in the
//! batched sweep and drift stays at roundoff in healthy solves, the
//! batched ≡ per-path bit-identity is unchanged with guards enabled.

use super::batch::{
    map_chunks_isolated, BatchNoise, BatchOptions, BatchReversibleHeun, BatchSde, BatchStepper,
};
use super::guard::{self, FaultCause, GuardConfig, SolveError, SolveFault};
use super::simd::Lane;
use super::{simd, NoiseF64, ReversibleHeun, Sde};
use crate::brownian::BrownianSource;
use crate::util::stats;

/// Analytic vector-Jacobian products of a per-path [`Sde`]'s vector fields.
///
/// The parameter gradient layout (`gth`, length [`param_len`](Self::param_len))
/// is fixed per implementation and documented there; it is what
/// [`adjoint_solve`] returns as `dtheta` and what the optimisers in
/// [`crate::nn`] consume as a flat gradient (`nn::step_f64`).
pub trait SdeVjp: Sde {
    /// Number of trainable parameters `θ`.
    fn param_len(&self) -> usize;

    /// Accumulate the drift VJP: `gy += J_f(t, y)ᵀ wf` and
    /// `gth += (∂f/∂θ)ᵀ wf`. Both outputs are `+=` accumulated, never
    /// overwritten.
    fn drift_vjp(&self, t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]);

    /// Accumulate the diffusion VJP through the applied increment
    /// `h(y) = g(t, y) · dw`: `gy += J_h(t, y)ᵀ v` and
    /// `gth += (∂h/∂θ)ᵀ v`. The cotangent arrives factored as `(v, dw)`
    /// (`v` of length `dim`, `dw` of length `noise_dim`) because every
    /// adjoint-step cotangent of the diffusion matrix is the rank-one
    /// `v ΔWᵀ` — implementations exploit their sparsity (diagonal systems
    /// touch only `v[i] * dw[i]`).
    fn diffusion_vjp(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
    );

    /// Accumulate the **increment** cotangent of the applied diffusion
    /// `h = g(t, y) · dw`: `gdw[j] += Σ_i g[i][j] v[i]` (ascending `i`,
    /// seeded on the existing `gdw` entry). This is what lets a solve driven
    /// by *data* increments — the neural-CDE discriminator, whose controls
    /// are the path's `ΔY` — backpropagate onto the path itself.
    ///
    /// The default evaluates the dense diffusion matrix and contracts;
    /// implementations with structure (or a cheaper forward) may override,
    /// keeping the same per-path association.
    fn diffusion_dw_vjp(&self, t: f64, y: &[f64], v: &[f64], gdw: &mut [f64]) {
        let e = self.dim();
        let d = self.noise_dim();
        let mut g = vec![0.0; e * d];
        self.diffusion(t, y, &mut g);
        for j in 0..d {
            let mut acc = gdw[j];
            for i in 0..e {
                acc += g[i * d + j] * v[i];
            }
            gdw[j] = acc;
        }
    }
}

/// Analytic VJPs over structure-of-arrays lanes, mirroring [`SdeVjp`] the
/// way [`BatchSde`] mirrors [`Sde`].
///
/// Layouts follow the batch engine: `y`, `wf`, `v`, `gy` are `[dim * batch]`,
/// `dw` is `[noise_dim * batch]`, and `gth` is **per-path lanes**
/// `[param_len * batch]` (`gth[m * batch + p]` is path `p`'s running
/// gradient of parameter `m`). Keeping θ in lanes — rather than summing
/// across paths inside the call — is what lets the batched adjoint reduce
/// over paths once, in ascending order, and so stay bit-identical to the
/// per-path adjoint.
pub trait BatchSdeVjp: BatchSde {
    /// Number of trainable parameters `θ`.
    fn param_len(&self) -> usize;

    /// Batched [`SdeVjp::drift_vjp`] over SoA lanes (`+=` accumulated).
    fn drift_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    );

    /// Batched [`SdeVjp::diffusion_vjp`] over SoA lanes (`+=` accumulated).
    fn diffusion_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    );

    /// Batched [`SdeVjp::diffusion_dw_vjp`] over SoA lanes: `gdw` is
    /// `[noise_dim * batch]`, seeded-accumulated with the per-path
    /// association (ascending `i` per lane). Default: dense
    /// [`BatchSde::diffusion_batch`] evaluation and lane-wise contraction.
    fn diffusion_dw_vjp_batch(&self, t: f64, y: &[f64], v: &[f64], gdw: &mut [f64], batch: usize) {
        let e = self.state_dim();
        let d = self.brownian_dim();
        let mut g = vec![0.0; e * d * batch];
        self.diffusion_batch(t, y, &mut g, batch);
        for j in 0..d {
            for p in 0..batch {
                let mut acc = gdw[j * batch + p];
                for i in 0..e {
                    acc += g[(i * d + j) * batch + p] * v[i * batch + p];
                }
                gdw[j * batch + p] = acc;
            }
        }
    }
}

/// Blanket adapter: every per-path [`SdeVjp`] is a [`BatchSdeVjp`] by
/// gather → per-path VJP → scatter. The per-path arithmetic is the scalar
/// implementation itself, so adapted batched gradients agree with per-path
/// gradients bit-for-bit (the same guarantee the forward blanket adapter
/// gives).
impl<S: SdeVjp + Sync> BatchSdeVjp for S {
    fn param_len(&self) -> usize {
        SdeVjp::param_len(self)
    }

    fn drift_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let e = Sde::dim(self);
        let pl = SdeVjp::param_len(self);
        let mut yp = vec![0.0; e];
        let mut wp = vec![0.0; e];
        let mut gyp = vec![0.0; e];
        let mut gtp = vec![0.0; pl];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
                wp[i] = wf[i * batch + p];
                gyp[i] = gy[i * batch + p];
            }
            for m in 0..pl {
                gtp[m] = gth[m * batch + p];
            }
            self.drift_vjp(t, &yp, &wp, &mut gyp, &mut gtp);
            for i in 0..e {
                gy[i * batch + p] = gyp[i];
            }
            for m in 0..pl {
                gth[m * batch + p] = gtp[m];
            }
        }
    }

    fn diffusion_vjp_batch(
        &self,
        t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let e = Sde::dim(self);
        let d = Sde::noise_dim(self);
        let pl = SdeVjp::param_len(self);
        let mut yp = vec![0.0; e];
        let mut vp = vec![0.0; e];
        let mut dwp = vec![0.0; d];
        let mut gyp = vec![0.0; e];
        let mut gtp = vec![0.0; pl];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
                vp[i] = v[i * batch + p];
                gyp[i] = gy[i * batch + p];
            }
            for j in 0..d {
                dwp[j] = dw[j * batch + p];
            }
            for m in 0..pl {
                gtp[m] = gth[m * batch + p];
            }
            self.diffusion_vjp(t, &yp, &vp, &dwp, &mut gyp, &mut gtp);
            for i in 0..e {
                gy[i * batch + p] = gyp[i];
            }
            for m in 0..pl {
                gth[m * batch + p] = gtp[m];
            }
        }
    }

    fn diffusion_dw_vjp_batch(&self, t: f64, y: &[f64], v: &[f64], gdw: &mut [f64], batch: usize) {
        // Route through the per-path method (rather than the dense default)
        // so a per-path override's arithmetic — and bits — carry over.
        let e = Sde::dim(self);
        let d = Sde::noise_dim(self);
        let mut yp = vec![0.0; e];
        let mut vp = vec![0.0; e];
        let mut gdwp = vec![0.0; d];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
                vp[i] = v[i * batch + p];
            }
            for j in 0..d {
                gdwp[j] = gdw[j * batch + p];
            }
            self.diffusion_dw_vjp(t, &yp, &vp, &mut gdwp);
            for j in 0..d {
                gdw[j * batch + p] = gdwp[j];
            }
        }
    }
}

/// How the backward pass obtains the forward trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackwardMode {
    /// Reconstruct each forward state in closed form via
    /// [`ReversibleHeun::reverse_step`] — O(1) memory, the paper's
    /// Algorithm 2. Gradients are exact up to reconstruction roundoff.
    Reconstruct,
    /// Store the forward `ẑ` trajectory (O(n) memory) and backprop through
    /// the stored states — classic discretise-then-optimise, the reference
    /// the `Reconstruct` mode is compared against for the machine-precision
    /// claim.
    Tape,
}

/// Gradients of a (terminal or whole-trajectory) loss through a
/// reversible-Heun solve.
#[derive(Clone, Debug)]
pub struct AdjointGrad {
    /// Terminal solution estimate `z_N` (per-path `[dim]`; batched SoA
    /// `[dim * batch]`).
    pub terminal: Vec<f64>,
    /// `∂L/∂y₀`, same shape as the initial state.
    pub dy0: Vec<f64>,
    /// `∂L/∂θ`, flat `[param_len]` (batched: summed over paths in ascending
    /// path order).
    pub dtheta: Vec<f64>,
    /// `∂L/∂ΔW_k` per grid step — empty unless requested (`want_ddw`).
    /// Per-path layout `[n_steps * noise_dim]` (`ddw[k * d + j]`); batched
    /// SoA `[(k * d + j) * batch + p]`. For a CDE driven by data increments
    /// this is the loss cotangent on the driving path's `ΔY`.
    pub ddw: Vec<f64>,
    /// How many times the divergence watchdog degraded a `Reconstruct`
    /// sweep to `Tape` (per path; batched: summed over chunks). 0 on a
    /// healthy solve and in `Tape`/mixed modes.
    pub fallbacks: usize,
}

/// Run one path forward over `[t0, t1]` in `n_steps` reversible-Heun steps,
/// then backward, returning the exact discrete gradients of the terminal
/// loss seeded by `grad_terminal` (called once with `z_N` to fill
/// `∂L/∂z_N`).
///
/// `noise` is queried forward and then *again in reverse* — any
/// deterministic source works ([`super::CounterGridNoise`] paths,
/// [`GridReplayNoise`], or [`super::NoiseFromSource`] over a Brownian
/// source), which is exactly the re-queryable contract the Brownian
/// Interval provides.
///
/// Terminal-only convenience over [`adjoint_solve_steps`], which handles
/// whole-trajectory losses and noise cotangents.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve<S, N, G>(
    sde: &S,
    y0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    noise: &mut N,
    mode: BackwardMode,
    grad_terminal: G,
) -> Result<AdjointGrad, SolveError>
where
    S: SdeVjp,
    N: NoiseF64,
    G: FnOnce(&[f64], &mut [f64]),
{
    let mut seed = Some(grad_terminal);
    adjoint_solve_steps(sde, y0, t0, t1, n_steps, noise, mode, false, |k, z, lz| {
        if k == n_steps {
            if let Some(g) = seed.take() {
                g(z, lz);
            }
        }
    })
}

/// The general per-path adjoint: gradients of a loss that may read **every**
/// grid point, `L = Σ_k l_k(z_k)`, with optional per-step noise cotangents.
///
/// `grad_step(k, z_k, λ_z)` is called during the backward sweep for
/// `k = n_steps` (the terminal state, before the first reverse step) down to
/// `k = 0`, in that order; it must **accumulate** `∂l_k/∂z_k` into the
/// running cotangent `λ_z` (`+=` — for a terminal-only loss, write only at
/// `k == n_steps`). The injected cotangents ride the same exact backward
/// recursion as the terminal seed, so a path-dependent loss — e.g. the
/// Wasserstein discriminator reading the generator's whole trajectory —
/// backpropagates with zero truncation error too.
///
/// With `want_ddw`, the increment cotangents `∂L/∂ΔW_k` are accumulated via
/// [`SdeVjp::diffusion_dw_vjp`] at both evaluation points of each step
/// (`∂L/∂ΔW_k = g(t′, ẑ′)ᵀ(½λ_z′) + g(t, ẑ)ᵀ(w + ½λ_z′)` — the same two
/// diffusion cotangents Stage A and Stage B already compute) and returned in
/// [`AdjointGrad::ddw`]; a CDE driven by data increments chains them onto
/// the driving path.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve_steps<S, N, G>(
    sde: &S,
    y0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    noise: &mut N,
    mode: BackwardMode,
    want_ddw: bool,
    mut grad_step: G,
) -> Result<AdjointGrad, SolveError>
where
    S: SdeVjp,
    N: NoiseF64,
    G: FnMut(usize, &[f64], &mut [f64]),
{
    let e = sde.dim();
    let d = sde.noise_dim();
    assert_eq!(y0.len(), e, "y0 must be [dim]");
    assert!(n_steps >= 1);
    let pl = sde.param_len();
    let dtg = (t1 - t0) / n_steps as f64;
    let tape_on = matches!(mode, BackwardMode::Tape);
    // The per-path API has no options struct; it runs the default guards
    // (the batched twin reads `opts.guard` and must use the same values for
    // the batched ≡ per-path pin to cover watchdog decisions).
    let gcfg = GuardConfig::default().normalised();
    // Tape mode never reconstructs, so it needs no drift checkpoints: the
    // watchdog copy zeroes `checkpoint_every` (0 = disabled, per the
    // canonical semantics `GuardConfig::normalised` documents).
    let wcfg = GuardConfig {
        checkpoint_every: if tape_on { 0 } else { gcfg.checkpoint_every },
        ..gcfg
    };
    let ckpt_every = wcfg.checkpoint_every;

    // Forward pass — the same grid arithmetic as `integrate`, so the solve
    // being differentiated is bit-identical to what a driver loop runs. The
    // tape stores ẑ (the Jacobian evaluation points) and z (the states the
    // loss reads).
    let mut solver = ReversibleHeun::new(sde, t0, y0);
    let mut dw = vec![0.0f64; d];
    let mut tape: Vec<f64> = Vec::with_capacity(if tape_on { (n_steps + 1) * e } else { 0 });
    let mut tape_z: Vec<f64> = Vec::with_capacity(if tape_on { (n_steps + 1) * e } else { 0 });
    // Sparse (z, ẑ) checkpoints for the divergence watchdog: block `ci`
    // holds the forward state at grid point `ci * ckpt_every`.
    let mut ck_z: Vec<f64> = Vec::new();
    let mut ck_zh: Vec<f64> = Vec::new();
    for k in 0..n_steps {
        if tape_on {
            tape.extend_from_slice(&solver.state().zh);
            tape_z.extend_from_slice(&solver.state().z);
        }
        if wcfg.checkpoint_due(k) {
            ck_z.extend_from_slice(&solver.state().z);
            ck_zh.extend_from_slice(&solver.state().zh);
        }
        let s = t0 + k as f64 * dtg;
        let t = t0 + (k + 1) as f64 * dtg;
        noise.increment(s, t, &mut dw);
        solver.forward_step(sde, s, t - s, &dw);
        // Blockwise non-finite sweep at the guard cadence (and at the
        // terminal step). Reported at cadence precision: the first bad step
        // may be up to `check_every - 1` earlier (set `check_every = 1` for
        // exact coordinates).
        if gcfg.sweep_due(k + 1, n_steps) {
            if let Some((i, _)) = guard::first_nonfinite(&solver.state().z, e, 1) {
                return Err(SolveError::new(
                    "adjoint_solve_steps: forward state",
                    vec![SolveFault {
                        step: k,
                        path: 0,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }],
                ));
            }
        }
    }
    if tape_on {
        tape.extend_from_slice(&solver.state().zh);
        tape_z.extend_from_slice(&solver.state().z);
    }
    let terminal = solver.state().z.clone();

    // Cotangent seed: the loss's terminal contribution ∂l_N/∂z_N.
    let mut lz = vec![0.0f64; e];
    let mut lzh = vec![0.0f64; e];
    grad_step(n_steps, &terminal, &mut lz);
    let mut gth = vec![0.0f64; pl];
    let mut ddw = vec![0.0f64; if want_ddw { n_steps * d } else { 0 }];

    let mut vg = vec![0.0f64; e];
    let mut wf = vec![0.0f64; e];
    let mut wa = vec![0.0f64; e];
    // Whether the sweep currently reads the tape: starts at the caller's
    // mode and flips (once) from reconstruction to tape when the watchdog
    // trips.
    let mut use_tape = tape_on;
    let mut fallbacks = 0usize;
    let mut dwr = vec![0.0f64; d];
    #[cfg(debug_assertions)]
    let mut chk = ReversibleHeun::new(sde, t1, &terminal);
    // Reusable pre-reverse snapshot for the debug drift check — hoisted out
    // of the loop so the check costs copies, not allocations, per step.
    #[cfg(debug_assertions)]
    let mut pre = solver.state().clone();

    for k in (0..n_steps).rev() {
        let s = t0 + k as f64 * dtg;
        let t = t0 + (k + 1) as f64 * dtg;
        let h = t - s;
        // The forward step evaluated its fields at `s + h` (the `t + dt`
        // token in `forward_step`); the backward must use the same value.
        let t_hi = s + h;
        noise.increment(s, t, &mut dw);

        // Stage A — total cotangent of ẑ_{k+1}:
        //   w = λ_ẑ + J_f(t′,ẑ′)ᵀ(½Δt λ_z) + J_{g·ΔW}(t′,ẑ′)ᵀ(½ λ_z).
        simd::scale_half(&lz, &mut vg);
        simd::scale(h, &vg, &mut wf);
        wa.copy_from_slice(&lzh);
        // ẑ_{k+1} is still the solver's current state (reverse_step runs
        // below) or a tape slice — borrow, don't copy. On the step the
        // watchdog trips, the live pre-reverse ẑ_{k+1} read here is the
        // bit-exact forward value (no reconstruction has touched it yet),
        // which is why a first-backward-step fallback reproduces an
        // all-Tape sweep bitwise.
        let zh_hi: &[f64] =
            if use_tape { &tape[(k + 1) * e..(k + 2) * e] } else { &solver.state().zh };
        sde.drift_vjp(t_hi, zh_hi, &wf, &mut wa, &mut gth);
        sde.diffusion_vjp(t_hi, zh_hi, &vg, &dw, &mut wa, &mut gth);
        if want_ddw {
            sde.diffusion_dw_vjp(t_hi, zh_hi, &vg, &mut ddw[k * d..(k + 1) * d]);
        }

        // Reconstruct the state at t_k (Algorithm 2), or read the tape.
        if !use_tape {
            #[cfg(debug_assertions)]
            {
                let st = solver.state();
                pre.z.copy_from_slice(&st.z);
                pre.zh.copy_from_slice(&st.zh);
                pre.mu.copy_from_slice(&st.mu);
                pre.sigma.copy_from_slice(&st.sigma);
            }
            solver.reverse_step(sde, t, h, &dw);
            #[cfg(debug_assertions)]
            {
                // Reconstruction-drift invariant: stepping the reconstructed
                // state forward again must reproduce the pre-reverse state.
                // The release-mode watchdog below enforces the same
                // invariant at checkpoint granularity, with a fallback
                // instead of an abort.
                chk.set_state(solver.state().clone());
                chk.forward_step(sde, s, h, &dw);
                let scale0 = pre.z.iter().fold(1.0f64, |m, v| m.max(v.abs()));
                let drift = chk.state().max_abs_diff(&pre);
                debug_assert!(
                    drift <= 1e-6 * scale0,
                    "reversible-Heun reconstruction drift {drift:e} at step {k}"
                );
            }
            // Divergence watchdog: compare the reconstruction against the
            // sparse forward checkpoint at this grid point. On a breach
            // (or a NaN drift — `!(NaN <= x)`), degrade the rest of the
            // sweep to Tape mode: replay the forward prefix into an exact
            // tape (bit-identical to a Tape-mode forward — same noise,
            // same arithmetic) and stop reconstructing. Gradients stay
            // exact; O(1) memory becomes O(k) for the remaining segment.
            if wcfg.checkpoint_due(k) {
                let ci = k / ckpt_every;
                let cz = &ck_z[ci * e..(ci + 1) * e];
                let czh = &ck_zh[ci * e..(ci + 1) * e];
                let st = solver.state();
                let mut drift = 0.0f64;
                for i in 0..e {
                    drift = drift.max((st.z[i] - cz[i]).abs()).max((st.zh[i] - czh[i]).abs());
                }
                let scale = cz.iter().fold(1.0f64, |m, v| m.max(v.abs()));
                if !(drift <= gcfg.drift_tol * scale) {
                    tape.clear();
                    tape_z.clear();
                    let mut re = ReversibleHeun::new(sde, t0, y0);
                    for kk in 0..k {
                        tape.extend_from_slice(&re.state().zh);
                        tape_z.extend_from_slice(&re.state().z);
                        let ss = t0 + kk as f64 * dtg;
                        let tt = t0 + (kk + 1) as f64 * dtg;
                        noise.increment(ss, tt, &mut dwr);
                        re.forward_step(sde, ss, tt - ss, &dwr);
                    }
                    tape.extend_from_slice(&re.state().zh);
                    tape_z.extend_from_slice(&re.state().z);
                    use_tape = true;
                    fallbacks += 1;
                }
            }
        }
        let zh_lo: &[f64] =
            if use_tape { &tape[k * e..(k + 1) * e] } else { &solver.state().zh };

        // Stage B — pull back to (z_k, ẑ_k):
        //   λ_ẑ = −w + J_f(t,ẑ)ᵀ(Δt(w + ½λ_z)) + J_{g·ΔW}(t,ẑ)ᵀ(w + ½λ_z)
        //   λ_z = λ_z + 2w.
        simd::add_half(&wa, &lz, &mut vg);
        simd::scale(h, &vg, &mut wf);
        simd::neg(&wa, &mut lzh);
        sde.drift_vjp(s, zh_lo, &wf, &mut lzh, &mut gth);
        sde.diffusion_vjp(s, zh_lo, &vg, &dw, &mut lzh, &mut gth);
        if want_ddw {
            sde.diffusion_dw_vjp(s, zh_lo, &vg, &mut ddw[k * d..(k + 1) * d]);
        }
        simd::axpy(2.0, &wa, &mut lz);

        // Per-step loss cotangent: the loss read z_k too.
        let z_lo: &[f64] =
            if use_tape { &tape_z[k * e..(k + 1) * e] } else { &solver.state().z };
        grad_step(k, z_lo, &mut lz);

        // Cotangent sweep at the guard cadence: a non-finite λ (an exploding
        // VJP, a corrupted loss cotangent) surfaces here instead of
        // poisoning dθ silently. Same cadence-precision caveat as the
        // forward sweep.
        if gcfg.backward_sweep_due(k) {
            if let Some((i, _)) = guard::first_nonfinite(&lz, e, 1)
                .or_else(|| guard::first_nonfinite(&lzh, e, 1))
            {
                return Err(SolveError::new(
                    "adjoint_solve_steps: backward cotangent",
                    vec![SolveFault {
                        step: k,
                        path: 0,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }],
                ));
            }
        }
    }

    // z₀ = ẑ₀ = y₀ ⟹ ∂L/∂y₀ = λ_z + λ_ẑ.
    let mut dy0 = vec![0.0f64; e];
    for i in 0..e {
        dy0[i] = lz[i] + lzh[i];
    }
    Ok(AdjointGrad { terminal, dy0, dtheta: gth, ddw, fallbacks })
}

/// Batched-SoA adjoint over `[dim × batch]` lanes: forward + backward per
/// fixed-size path chunk, fanned across `opts.threads` participants of the
/// same work-stealing chunk scheduler as the forward engine
/// ([`super::map_chunks`], dispatching on the persistent process-wide
/// executor [`super::pool`] — no per-call thread spawn/join).
///
/// `grad_terminal` is called once per chunk with
/// `(path_offset, chunk_len, terminal_z_lanes, out_lanes)` and must fill the
/// chunk's `∂L/∂z_N` lanes (`[dim * chunk_len]`, pre-zeroed).
///
/// Determinism and bit-identity: each path's lane arithmetic runs on the
/// same fused kernels the per-path sweep uses and touches only its own
/// lane; θ-gradients accumulate in per-path lanes and are reduced over
/// paths in ascending order after all chunks complete. The result is
/// bit-identical for every `threads`/`chunk` setting — and bit-identical to
/// `batch` separate [`adjoint_solve`] runs whose `dtheta` are summed in
/// ascending path order.
///
/// Terminal-only convenience over [`adjoint_solve_batched_steps`].
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve_batched<S, N, G>(
    sde: &S,
    noise: &N,
    y0: &[f64],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    mode: BackwardMode,
    opts: &BatchOptions,
    grad_terminal: &G,
) -> Result<AdjointGrad, SolveError>
where
    S: BatchSdeVjp,
    N: BatchNoise,
    G: Fn(usize, usize, &[f64], &mut [f64]) + Sync,
{
    adjoint_solve_batched_steps(
        sde,
        noise,
        y0,
        batch,
        t0,
        t1,
        n_steps,
        mode,
        false,
        opts,
        &|k, p0, cl, z, lz| {
            if k == n_steps {
                grad_terminal(p0, cl, z, lz);
            }
        },
    )
}

/// The general batched adjoint: whole-trajectory losses and per-step noise
/// cotangents over SoA lanes — the batched twin of [`adjoint_solve_steps`].
///
/// `grad_step(k, path_offset, chunk_len, z_lanes, λ_z_lanes)` is called for
/// `k = n_steps` down to `0` per chunk and must **accumulate** the chunk's
/// `∂l_k/∂z_k` lanes (`[dim * chunk_len]`) into the running cotangent. With
/// `want_ddw`, [`AdjointGrad::ddw`] holds `∂L/∂ΔW` as
/// `[(k * noise_dim + j) * batch + p]`.
///
/// Per-path bit-identity extends to both features: injections touch only
/// their own lanes and `ddw` accumulates with the per-path association at
/// the same two evaluation points, so batched results equal per-path
/// [`adjoint_solve_steps`] runs bit-for-bit across every batch/chunk/thread
/// setting.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve_batched_steps<S, N, G>(
    sde: &S,
    noise: &N,
    y0: &[f64],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    mode: BackwardMode,
    want_ddw: bool,
    opts: &BatchOptions,
    grad_step: &G,
) -> Result<AdjointGrad, SolveError>
where
    S: BatchSdeVjp,
    N: BatchNoise,
    G: Fn(usize, usize, usize, &[f64], &mut [f64]) + Sync,
{
    let e = sde.state_dim();
    let nd = sde.brownian_dim();
    let pl = sde.param_len();
    assert_eq!(y0.len(), e * batch, "y0 must be SoA [dim * batch]");
    assert_eq!(noise.brownian_dim(), nd, "noise/sde Brownian dimension mismatch");
    assert!(n_steps >= 1 && batch >= 1);
    let chunk = opts.chunk_for(batch);
    let n_chunks = (batch + chunk - 1) / chunk;
    let dtg = (t1 - t0) / n_steps as f64;
    let tape_on = matches!(mode, BackwardMode::Tape);
    let gcfg = opts.guard.normalised();
    // Tape mode never reconstructs: disable the watchdog in its copy.
    let wcfg = GuardConfig {
        checkpoint_every: if tape_on { 0 } else { gcfg.checkpoint_every },
        ..gcfg
    };
    let ckpt_every = wcfg.checkpoint_every;

    // One chunk's forward + backward sweep: returns (terminal z lanes,
    // dy0 lanes, per-path θ lanes, ddw lanes, watchdog fallbacks), all
    // lanes `[· * chunk_len]` — or the chunk's faults. Gradients sum over
    // paths, so one faulted path poisons the whole reduction: the batched
    // adjoint is strict (no quarantine), unlike the forward engine.
    type ChunkGrad = (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, usize);
    let run_chunk = |c: usize| -> Result<ChunkGrad, Vec<SolveFault>> {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        let mut yc = vec![0.0f64; e * cl];
        for i in 0..e {
            for q in 0..cl {
                yc[i * cl + q] = y0[i * batch + p0 + q];
            }
        }
        let mut stepper = BatchReversibleHeun::for_chunk(sde, t0, &yc, cl);
        let mut dw = vec![0.0f64; nd * cl];
        let mut tape: Vec<f64> =
            Vec::with_capacity(if tape_on { (n_steps + 1) * e * cl } else { 0 });
        let mut tape_z: Vec<f64> =
            Vec::with_capacity(if tape_on { (n_steps + 1) * e * cl } else { 0 });
        // Sparse (z, ẑ) checkpoint lanes for the divergence watchdog:
        // block `ci` holds the chunk's forward state at grid point
        // `ci * ckpt_every`.
        let mut ck_z: Vec<f64> = Vec::new();
        let mut ck_zh: Vec<f64> = Vec::new();
        for k in 0..n_steps {
            if tape_on {
                tape.extend_from_slice(stepper.zh());
                tape_z.extend_from_slice(stepper.z());
            }
            if wcfg.checkpoint_due(k) {
                ck_z.extend_from_slice(stepper.z());
                ck_zh.extend_from_slice(stepper.zh());
            }
            let s = t0 + k as f64 * dtg;
            let t = t0 + (k + 1) as f64 * dtg;
            noise.fill_step(k, s, t, p0, cl, &mut dw);
            stepper.forward_step(sde, s, t - s, &dw);
            // Blockwise non-finite sweep at the guard cadence (and at the
            // terminal step); cadence-precision coordinates, exact at
            // `check_every = 1`.
            if gcfg.sweep_due(k + 1, n_steps) {
                if let Some((i, q)) = guard::first_nonfinite(stepper.z(), e, cl) {
                    return Err(vec![SolveFault {
                        step: k,
                        path: p0 + q,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }]);
                }
            }
        }
        if tape_on {
            tape.extend_from_slice(stepper.zh());
            tape_z.extend_from_slice(stepper.z());
        }
        let terminal = stepper.z().to_vec();

        let mut lz = vec![0.0f64; e * cl];
        let mut lzh = vec![0.0f64; e * cl];
        grad_step(n_steps, p0, cl, &terminal, &mut lz);
        let mut gth = vec![0.0f64; pl * cl];
        let mut ddw = vec![0.0f64; if want_ddw { n_steps * nd * cl } else { 0 }];

        let mut vg = vec![0.0f64; e * cl];
        let mut wf = vec![0.0f64; e * cl];
        let mut wa = vec![0.0f64; e * cl];
        let mut use_tape = tape_on;
        let mut fallbacks = 0usize;
        let mut dwr = vec![0.0f64; nd * cl];
        #[cfg(debug_assertions)]
        let mut chk = BatchReversibleHeun::for_chunk(sde, t1, &terminal, cl);
        // Reusable pre-reverse snapshot lanes for the debug drift check —
        // hoisted out of the backward sweep so each step copies into the
        // same four buffers instead of allocating four fresh vectors.
        #[cfg(debug_assertions)]
        let (mut pre_z, mut pre_zh, mut pre_mu, mut pre_sigma) = (
            stepper.z().to_vec(),
            stepper.zh().to_vec(),
            stepper.mu().to_vec(),
            stepper.sigma().to_vec(),
        );

        for k in (0..n_steps).rev() {
            let s = t0 + k as f64 * dtg;
            let t = t0 + (k + 1) as f64 * dtg;
            let h = t - s;
            let t_hi = s + h;
            noise.fill_step(k, s, t, p0, cl, &mut dw);

            // Stage A (same kernel sequence as the per-path sweep).
            simd::scale_half(&lz, &mut vg);
            simd::scale(h, &vg, &mut wf);
            wa.copy_from_slice(&lzh);
            // ẑ_{k+1} lanes: the stepper's current state (reverse_step runs
            // below) or a tape slice — borrow, don't copy.
            let zh_hi: &[f64] = if use_tape {
                &tape[(k + 1) * e * cl..(k + 2) * e * cl]
            } else {
                stepper.zh()
            };
            sde.drift_vjp_batch(t_hi, zh_hi, &wf, &mut wa, &mut gth, cl);
            sde.diffusion_vjp_batch(t_hi, zh_hi, &vg, &dw, &mut wa, &mut gth, cl);
            if want_ddw {
                sde.diffusion_dw_vjp_batch(
                    t_hi,
                    zh_hi,
                    &vg,
                    &mut ddw[k * nd * cl..(k + 1) * nd * cl],
                    cl,
                );
            }

            if !use_tape {
                #[cfg(debug_assertions)]
                {
                    pre_z.copy_from_slice(stepper.z());
                    pre_zh.copy_from_slice(stepper.zh());
                    pre_mu.copy_from_slice(stepper.mu());
                    pre_sigma.copy_from_slice(stepper.sigma());
                }
                stepper.reverse_step(sde, t, h, &dw);
                #[cfg(debug_assertions)]
                {
                    chk.set_state(stepper.z(), stepper.zh(), stepper.mu(), stepper.sigma());
                    chk.forward_step(sde, s, h, &dw);
                    let md = |a: &[f64], b: &[f64]| {
                        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max)
                    };
                    let drift = md(chk.z(), &pre_z)
                        .max(md(chk.zh(), &pre_zh))
                        .max(md(chk.mu(), &pre_mu))
                        .max(md(chk.sigma(), &pre_sigma));
                    let scale0 = pre_z.iter().fold(1.0f64, |m, v| m.max(v.abs()));
                    debug_assert!(
                        drift <= 1e-6 * scale0,
                        "batched reconstruction drift {drift:e} at step {k}"
                    );
                }
                // Divergence watchdog over the chunk's lanes — the chunk is
                // the fallback unit (all its paths degrade together). In
                // healthy solves drift stays at roundoff and the watchdog
                // never fires, so the batched ≡ per-path bit-identity is
                // untouched; a breach (or NaN drift) replays the forward
                // prefix into an exact tape, bit-identical to a Tape-mode
                // forward of the same chunk.
                if wcfg.checkpoint_due(k) {
                    let ci = k / ckpt_every;
                    let cz = &ck_z[ci * e * cl..(ci + 1) * e * cl];
                    let czh = &ck_zh[ci * e * cl..(ci + 1) * e * cl];
                    let mut drift = 0.0f64;
                    for i in 0..e * cl {
                        drift = drift
                            .max((stepper.z()[i] - cz[i]).abs())
                            .max((stepper.zh()[i] - czh[i]).abs());
                    }
                    let scale = cz.iter().fold(1.0f64, |m, v| m.max(v.abs()));
                    if !(drift <= gcfg.drift_tol * scale) {
                        tape.clear();
                        tape_z.clear();
                        let mut re = BatchReversibleHeun::for_chunk(sde, t0, &yc, cl);
                        for kk in 0..k {
                            tape.extend_from_slice(re.zh());
                            tape_z.extend_from_slice(re.z());
                            let ss = t0 + kk as f64 * dtg;
                            let tt = t0 + (kk + 1) as f64 * dtg;
                            noise.fill_step(kk, ss, tt, p0, cl, &mut dwr);
                            re.forward_step(sde, ss, tt - ss, &dwr);
                        }
                        tape.extend_from_slice(re.zh());
                        tape_z.extend_from_slice(re.z());
                        use_tape = true;
                        fallbacks += 1;
                    }
                }
            }
            let zh_lo: &[f64] =
                if use_tape { &tape[k * e * cl..(k + 1) * e * cl] } else { stepper.zh() };

            // Stage B.
            simd::add_half(&wa, &lz, &mut vg);
            simd::scale(h, &vg, &mut wf);
            simd::neg(&wa, &mut lzh);
            sde.drift_vjp_batch(s, zh_lo, &wf, &mut lzh, &mut gth, cl);
            sde.diffusion_vjp_batch(s, zh_lo, &vg, &dw, &mut lzh, &mut gth, cl);
            if want_ddw {
                sde.diffusion_dw_vjp_batch(
                    s,
                    zh_lo,
                    &vg,
                    &mut ddw[k * nd * cl..(k + 1) * nd * cl],
                    cl,
                );
            }
            simd::axpy(2.0, &wa, &mut lz);

            // Per-step loss cotangents on z_k.
            let z_lo: &[f64] =
                if use_tape { &tape_z[k * e * cl..(k + 1) * e * cl] } else { stepper.z() };
            grad_step(k, p0, cl, z_lo, &mut lz);

            // Cotangent sweep at the guard cadence: exact (step, path,
            // component) at `check_every = 1`, cadence precision otherwise.
            if gcfg.backward_sweep_due(k) {
                if let Some((i, q)) = guard::first_nonfinite(&lz, e, cl)
                    .or_else(|| guard::first_nonfinite(&lzh, e, cl))
                {
                    return Err(vec![SolveFault {
                        step: k,
                        path: p0 + q,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }]);
                }
            }
        }
        let mut dy0 = vec![0.0f64; e * cl];
        for i in 0..e * cl {
            dy0[i] = lz[i] + lzh[i];
        }
        Ok((terminal, dy0, gth, ddw, fallbacks))
    };

    let chunk_results = map_chunks_isolated(n_chunks, opts.threads, run_chunk);
    let mut chunk_grads: Vec<ChunkGrad> = Vec::with_capacity(n_chunks);
    let mut faults: Vec<SolveFault> = Vec::new();
    for (c, res) in chunk_results.into_iter().enumerate() {
        match res {
            Ok(Ok(g)) => chunk_grads.push(g),
            Ok(Err(fs)) => faults.extend(fs),
            // Chunk-granularity coordinates for a panicking vector field:
            // the chunk's first path at step 0 (the adjoint has no
            // per-path re-run — gradients sum across paths, so the solve
            // is strict either way).
            Err(p) => faults.push(SolveFault {
                step: 0,
                path: c * chunk,
                component: 0,
                cause: FaultCause::VectorFieldPanic { payload: p.payload },
            }),
        }
    }
    if !faults.is_empty() {
        return Err(SolveError::new("adjoint_solve_batched_steps", faults));
    }

    // Scatter chunk lanes back to the full batch, then reduce θ over paths
    // in ascending path order — the association of the per-path reference
    // (Σ_p dθ_p, p = 0..batch), independent of chunking and threading.
    let mut terminal = vec![0.0f64; e * batch];
    let mut dy0 = vec![0.0f64; e * batch];
    let mut gth_lanes = vec![0.0f64; pl * batch];
    let mut ddw = vec![0.0f64; if want_ddw { n_steps * nd * batch } else { 0 }];
    let mut fallbacks = 0usize;
    for (c, (tz, dz, gt, dd, fb)) in chunk_grads.iter().enumerate() {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        for i in 0..e {
            terminal[i * batch + p0..i * batch + p0 + cl]
                .copy_from_slice(&tz[i * cl..(i + 1) * cl]);
            dy0[i * batch + p0..i * batch + p0 + cl].copy_from_slice(&dz[i * cl..(i + 1) * cl]);
        }
        for m in 0..pl {
            gth_lanes[m * batch + p0..m * batch + p0 + cl]
                .copy_from_slice(&gt[m * cl..(m + 1) * cl]);
        }
        if want_ddw {
            for r in 0..n_steps * nd {
                ddw[r * batch + p0..r * batch + p0 + cl]
                    .copy_from_slice(&dd[r * cl..(r + 1) * cl]);
            }
        }
        fallbacks += fb;
    }
    let dtheta = reduce_theta_ascending(&gth_lanes, pl, batch);
    Ok(AdjointGrad { terminal, dy0, dtheta, ddw, fallbacks })
}

/// Sum per-path θ lanes over paths in **ascending path order** — the
/// association of the per-path reference (`Σ_p dθ_p`, `p = 0..batch`),
/// shared by every batched adjoint variant so the reduction order cannot
/// drift between them.
fn reduce_theta_ascending(gth_lanes: &[f64], pl: usize, batch: usize) -> Vec<f64> {
    let mut dtheta = vec![0.0f64; pl];
    for m in 0..pl {
        let mut acc = 0.0f64;
        for p in 0..batch {
            acc += gth_lanes[m * batch + p];
        }
        dtheta[m] = acc;
    }
    dtheta
}

/// Mixed-precision batched adjoint: the **forward** trajectory runs in
/// `f32` on the 8-wide SIMD lanes (half the memory traffic of the `f64`
/// forward), its `ẑ` tape is widened once per step, and the **backward**
/// sweep is the exact `f64` Tape-mode cotangent recursion over that tape —
/// i.e. the discretise-then-optimise gradient of the *`f32`* discrete
/// forward map, contracted through the `f64` VJPs on the widened increments
/// the forward consumed.
///
/// `sde` and `sde32` must be the two precision instantiations of the same
/// system (e.g. a [`super::systems::TanhDiagonalBatch`], which implements
/// `BatchSde` at both precisions — or a
/// [`super::neural::NeuralGeneratorBatch`], which implements both on one
/// value); `noise32` drives the forward and, after exact widening, the
/// backward. The returned gradients deviate from the all-`f64`
/// [`adjoint_solve_batched`] only by the forward's single-precision
/// rounding — [`crate::coordinator::gradient_error::run_native_mixed`]
/// measures exactly that deviation.
///
/// Terminal-only convenience over [`adjoint_solve_batched_steps_mixed`]
/// (Tape mode, no increment cotangents), narrowing `y0` once up front.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve_batched_mixed<S, S32, N32, G>(
    sde: &S,
    sde32: &S32,
    noise32: &N32,
    y0: &[f64],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    opts: &BatchOptions,
    grad_terminal: &G,
) -> Result<AdjointGrad, SolveError>
where
    S: BatchSdeVjp,
    S32: BatchSde<f32>,
    N32: BatchNoise<f32>,
    G: Fn(usize, usize, &[f64], &mut [f64]) + Sync,
{
    let y032: Vec<f32> = y0.iter().map(|&v| v as f32).collect();
    adjoint_solve_batched_steps_mixed(
        sde,
        sde32,
        noise32,
        &y032,
        batch,
        t0,
        t1,
        n_steps,
        BackwardMode::Tape,
        false,
        opts,
        &|k, p0, cl, z, lz| {
            if k == n_steps {
                grad_terminal(p0, cl, z, lz);
            }
        },
    )
}

/// Drift-tolerance floor for the mixed `Reconstruct` watchdog: the `f32`
/// algebraic inversion reconstructs at single-precision roundoff
/// (ε ≈ 1.2e-7, compounded across the sweep), so the `f64` default
/// [`GuardConfig::drift_tol`] of `1e-6` would flag perfectly healthy
/// solves. The effective threshold is `max(opts.guard.drift_tol, this)` —
/// the same headroom over `f32` ε that `1e-6` gives over `f64` ε would be
/// ≳ 1, so `1e-3` is the conservative end: genuine stiff-system divergence
/// (growth by orders of magnitude) still trips it immediately.
pub const MIXED_DRIFT_TOL: f64 = 1e-3;

/// The general mixed-precision batched adjoint — the mixed twin of
/// [`adjoint_solve_batched_steps`]: per-step loss cotangents, increment
/// cotangents ([`AdjointGrad::ddw`]), and the full guard/fault/watchdog
/// contract, over an `f32` forward and an **exact** `f64` backward.
///
/// The forward solve runs on the 8-wide `f32` lanes (`y0` arrives already
/// narrowed, `[dim * batch]` SoA); every state the backward sweep touches is
/// the exact `f64` widening of an `f32` forward state, so the accumulated
/// cotangents are the exact discretise-then-optimise derivatives of the
/// `f32` discrete map — the deviation from the all-`f64` gradient is the
/// forward's single-precision rounding only.
///
/// Modes:
/// * [`BackwardMode::Tape`] — the forward `(z, ẑ)` trajectory is widened
///   into `f64` tapes once per step; the backward is the pure `f64`
///   cotangent recursion over those tapes. Results are **bit-deterministic
///   across every `threads`/`chunk` setting** (lane arithmetic per path,
///   ascending θ reduction) — this is the mode the mixed training route
///   uses.
/// * [`BackwardMode::Reconstruct`] — O(1) memory: the `f32` reverse step
///   reconstructs the forward states, widened into per-step scratch for the
///   `f64` VJPs. The divergence watchdog compares reconstruction against
///   sparse `f32` checkpoints at `max(drift_tol,` [`MIXED_DRIFT_TOL`]`)`
///   relative drift and on breach replays the `f32` forward prefix into
///   exact widened tapes (Reconstruct→Tape fallback,
///   [`AdjointGrad::fallbacks`] counts the events). Because `f32`
///   reconstruction roundoff is chunk-shape-dependent *when the watchdog
///   fires*, only Tape mode carries the cross-fanout bit-determinism
///   guarantee.
///
/// Faults follow [`adjoint_solve_batched_steps`]: non-finite forward lanes
/// at the `check_every` cadence, backward cotangent sweeps, a terminal θ
/// sweep, and panic isolation per chunk — all reported as structured
/// [`SolveError`]s.
#[allow(clippy::too_many_arguments)]
pub fn adjoint_solve_batched_steps_mixed<S, S32, N32, G>(
    sde: &S,
    sde32: &S32,
    noise32: &N32,
    y0: &[f32],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    mode: BackwardMode,
    want_ddw: bool,
    opts: &BatchOptions,
    grad_step: &G,
) -> Result<AdjointGrad, SolveError>
where
    S: BatchSdeVjp,
    S32: BatchSde<f32>,
    N32: BatchNoise<f32>,
    G: Fn(usize, usize, usize, &[f64], &mut [f64]) + Sync,
{
    let e = sde.state_dim();
    let nd = sde.brownian_dim();
    let pl = sde.param_len();
    assert_eq!(sde32.state_dim(), e, "sde/sde32 state dimension mismatch");
    assert_eq!(sde32.brownian_dim(), nd, "sde/sde32 Brownian dimension mismatch");
    assert_eq!(y0.len(), e * batch, "y0 must be SoA [dim * batch]");
    assert_eq!(noise32.brownian_dim(), nd, "noise/sde Brownian dimension mismatch");
    assert!(n_steps >= 1 && batch >= 1);
    let chunk = opts.chunk_for(batch);
    let n_chunks = (batch + chunk - 1) / chunk;
    let dtg = (t1 - t0) / n_steps as f64;
    let tape_on = matches!(mode, BackwardMode::Tape);
    let gcfg = opts.guard.normalised();
    // Tape mode never reconstructs: disable the watchdog in its copy.
    let wcfg = GuardConfig {
        checkpoint_every: if tape_on { 0 } else { gcfg.checkpoint_every },
        ..gcfg
    };
    let ckpt_every = wcfg.checkpoint_every;
    let drift_tol = gcfg.drift_tol.max(MIXED_DRIFT_TOL);

    type ChunkGrad = (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, usize);
    let run_chunk = |c: usize| -> Result<ChunkGrad, Vec<SolveFault>> {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        // f32 forward on 8-wide lanes, taping (z, ẑ) widened to f64.
        let mut yc32 = vec![0.0f32; e * cl];
        for i in 0..e {
            for q in 0..cl {
                yc32[i * cl + q] = y0[i * batch + p0 + q];
            }
        }
        let mut fwd = <BatchReversibleHeun<f32> as BatchStepper>::for_chunk(sde32, t0, &yc32, cl);
        let mut dw32 = vec![0.0f32; nd * cl];
        let mut tape: Vec<f64> =
            Vec::with_capacity(if tape_on { (n_steps + 1) * e * cl } else { 0 });
        let mut tape_z: Vec<f64> =
            Vec::with_capacity(if tape_on { (n_steps + 1) * e * cl } else { 0 });
        // Sparse f32 (z, ẑ) checkpoint lanes for the divergence watchdog.
        let mut ck_z: Vec<f32> = Vec::new();
        let mut ck_zh: Vec<f32> = Vec::new();
        for k in 0..n_steps {
            if tape_on {
                tape.extend(fwd.zh().iter().map(|&v| v as f64));
                tape_z.extend(fwd.z().iter().map(|&v| v as f64));
            }
            if wcfg.checkpoint_due(k) {
                ck_z.extend_from_slice(fwd.z());
                ck_zh.extend_from_slice(fwd.zh());
            }
            let s = t0 + k as f64 * dtg;
            let t = t0 + (k + 1) as f64 * dtg;
            noise32.fill_step(k, s, t, p0, cl, &mut dw32);
            fwd.forward_step(sde32, s, t - s, &dw32);
            // Non-finite sweep on the f32 forward (narrowing passes
            // overflow through as ±∞, so divergence stays visible here).
            if gcfg.sweep_due(k + 1, n_steps) {
                if let Some((i, q)) = guard::first_nonfinite(fwd.z(), e, cl) {
                    return Err(vec![SolveFault {
                        step: k,
                        path: p0 + q,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }]);
                }
            }
        }
        if tape_on {
            tape.extend(fwd.zh().iter().map(|&v| v as f64));
            tape_z.extend(fwd.z().iter().map(|&v| v as f64));
        }
        let terminal: Vec<f64> = fwd.z().iter().map(|&v| v as f64).collect();

        // Exact f64 backward over the (widened) f32 trajectory.
        let mut lz = vec![0.0f64; e * cl];
        let mut lzh = vec![0.0f64; e * cl];
        grad_step(n_steps, p0, cl, &terminal, &mut lz);
        let mut gth = vec![0.0f64; pl * cl];
        let mut ddw = vec![0.0f64; if want_ddw { n_steps * nd * cl } else { 0 }];
        let mut vg = vec![0.0f64; e * cl];
        let mut wf = vec![0.0f64; e * cl];
        let mut wa = vec![0.0f64; e * cl];
        let mut dw = vec![0.0f64; nd * cl];
        let mut dwr32 = vec![0.0f32; nd * cl];
        // Per-step widened-state scratch for Reconstruct mode (the Tape
        // path borrows tape slices instead). The mixed Reconstruct sweep
        // has no debug replay-assert — the f64 engine's 1e-6 bound is an
        // f64-roundoff invariant; here the watchdog below owns divergence
        // detection at the f32-appropriate threshold.
        let mut zh_hi64 = vec![0.0f64; e * cl];
        let mut zh_lo64 = vec![0.0f64; e * cl];
        let mut z_lo64 = vec![0.0f64; e * cl];
        let mut use_tape = tape_on;
        let mut fallbacks = 0usize;
        for k in (0..n_steps).rev() {
            let s = t0 + k as f64 * dtg;
            let t = t0 + (k + 1) as f64 * dtg;
            let h = t - s;
            let t_hi = s + h;
            // The increments the f32 forward consumed, widened exactly.
            noise32.fill_step(k, s, t, p0, cl, &mut dw32);
            for (o, &v) in dw.iter_mut().zip(&dw32) {
                *o = v as f64;
            }

            // Stage A (same kernel sequence as the all-f64 sweep).
            simd::scale_half(&lz, &mut vg);
            simd::scale(h, &vg, &mut wf);
            wa.copy_from_slice(&lzh);
            let zh_hi: &[f64] = if use_tape {
                &tape[(k + 1) * e * cl..(k + 2) * e * cl]
            } else {
                for (o, &v) in zh_hi64.iter_mut().zip(fwd.zh()) {
                    *o = v as f64;
                }
                &zh_hi64
            };
            sde.drift_vjp_batch(t_hi, zh_hi, &wf, &mut wa, &mut gth, cl);
            sde.diffusion_vjp_batch(t_hi, zh_hi, &vg, &dw, &mut wa, &mut gth, cl);
            if want_ddw {
                sde.diffusion_dw_vjp_batch(
                    t_hi,
                    zh_hi,
                    &vg,
                    &mut ddw[k * nd * cl..(k + 1) * nd * cl],
                    cl,
                );
            }

            if !use_tape {
                fwd.reverse_step(sde32, t, h, &dw32);
                // Divergence watchdog over the chunk's f32 lanes at the
                // mixed threshold; a breach replays the f32 forward prefix
                // into exact widened tapes (Reconstruct→Tape fallback).
                if wcfg.checkpoint_due(k) {
                    let ci = k / ckpt_every;
                    let cz = &ck_z[ci * e * cl..(ci + 1) * e * cl];
                    let czh = &ck_zh[ci * e * cl..(ci + 1) * e * cl];
                    let mut drift = 0.0f64;
                    for i in 0..e * cl {
                        drift = drift
                            .max((fwd.z()[i] as f64 - cz[i] as f64).abs())
                            .max((fwd.zh()[i] as f64 - czh[i] as f64).abs());
                    }
                    let scale = cz.iter().fold(1.0f64, |m, v| m.max((*v as f64).abs()));
                    if !(drift <= drift_tol * scale) {
                        tape.clear();
                        tape_z.clear();
                        let mut re = <BatchReversibleHeun<f32> as BatchStepper>::for_chunk(
                            sde32, t0, &yc32, cl,
                        );
                        for kk in 0..k {
                            tape.extend(re.zh().iter().map(|&v| v as f64));
                            tape_z.extend(re.z().iter().map(|&v| v as f64));
                            let ss = t0 + kk as f64 * dtg;
                            let tt = t0 + (kk + 1) as f64 * dtg;
                            noise32.fill_step(kk, ss, tt, p0, cl, &mut dwr32);
                            re.forward_step(sde32, ss, tt - ss, &dwr32);
                        }
                        tape.extend(re.zh().iter().map(|&v| v as f64));
                        tape_z.extend(re.z().iter().map(|&v| v as f64));
                        use_tape = true;
                        fallbacks += 1;
                    }
                }
            }
            let zh_lo: &[f64] = if use_tape {
                &tape[k * e * cl..(k + 1) * e * cl]
            } else {
                for (o, &v) in zh_lo64.iter_mut().zip(fwd.zh()) {
                    *o = v as f64;
                }
                &zh_lo64
            };

            // Stage B.
            simd::add_half(&wa, &lz, &mut vg);
            simd::scale(h, &vg, &mut wf);
            simd::neg(&wa, &mut lzh);
            sde.drift_vjp_batch(s, zh_lo, &wf, &mut lzh, &mut gth, cl);
            sde.diffusion_vjp_batch(s, zh_lo, &vg, &dw, &mut lzh, &mut gth, cl);
            if want_ddw {
                sde.diffusion_dw_vjp_batch(
                    s,
                    zh_lo,
                    &vg,
                    &mut ddw[k * nd * cl..(k + 1) * nd * cl],
                    cl,
                );
            }
            simd::axpy(2.0, &wa, &mut lz);

            // Per-step loss cotangents on z_k (the widened f32 state — the
            // state the loss actually read).
            let z_lo: &[f64] = if use_tape {
                &tape_z[k * e * cl..(k + 1) * e * cl]
            } else {
                for (o, &v) in z_lo64.iter_mut().zip(fwd.z()) {
                    *o = v as f64;
                }
                &z_lo64
            };
            grad_step(k, p0, cl, z_lo, &mut lz);

            // Cotangent sweep at the guard cadence.
            if gcfg.backward_sweep_due(k) {
                if let Some((i, q)) = guard::first_nonfinite(&lz, e, cl)
                    .or_else(|| guard::first_nonfinite(&lzh, e, cl))
                {
                    return Err(vec![SolveFault {
                        step: k,
                        path: p0 + q,
                        component: i,
                        cause: FaultCause::NonFinite,
                    }]);
                }
            }
        }
        let mut dy0 = vec![0.0f64; e * cl];
        for i in 0..e * cl {
            dy0[i] = lz[i] + lzh[i];
        }
        // Terminal θ sweep (the mixed contract): a non-finite θ lane
        // reports at step 0 with the first offending lane.
        if gcfg.check_every != 0 {
            if let Some((i, q)) = guard::first_nonfinite(&gth, pl, cl) {
                return Err(vec![SolveFault {
                    step: 0,
                    path: p0 + q,
                    component: i,
                    cause: FaultCause::NonFinite,
                }]);
            }
        }
        Ok((terminal, dy0, gth, ddw, fallbacks))
    };

    let chunk_results = map_chunks_isolated(n_chunks, opts.threads, run_chunk);
    let mut chunk_grads: Vec<ChunkGrad> = Vec::with_capacity(n_chunks);
    let mut faults: Vec<SolveFault> = Vec::new();
    for (c, res) in chunk_results.into_iter().enumerate() {
        match res {
            Ok(Ok(g)) => chunk_grads.push(g),
            Ok(Err(fs)) => faults.extend(fs),
            Err(p) => faults.push(SolveFault {
                step: 0,
                path: c * chunk,
                component: 0,
                cause: FaultCause::VectorFieldPanic { payload: p.payload },
            }),
        }
    }
    if !faults.is_empty() {
        return Err(SolveError::new("adjoint_solve_batched_steps_mixed", faults));
    }

    // Scatter and reduce exactly as the all-f64 engine does: θ over paths
    // in ascending path order, independent of chunking and threading.
    let mut terminal = vec![0.0f64; e * batch];
    let mut dy0 = vec![0.0f64; e * batch];
    let mut gth_lanes = vec![0.0f64; pl * batch];
    let mut ddw = vec![0.0f64; if want_ddw { n_steps * nd * batch } else { 0 }];
    let mut fallbacks = 0usize;
    for (c, (tz, dz, gt, dd, fb)) in chunk_grads.iter().enumerate() {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        for i in 0..e {
            terminal[i * batch + p0..i * batch + p0 + cl]
                .copy_from_slice(&tz[i * cl..(i + 1) * cl]);
            dy0[i * batch + p0..i * batch + p0 + cl].copy_from_slice(&dz[i * cl..(i + 1) * cl]);
        }
        for m in 0..pl {
            gth_lanes[m * batch + p0..m * batch + p0 + cl]
                .copy_from_slice(&gt[m * cl..(m + 1) * cl]);
        }
        if want_ddw {
            for r in 0..n_steps * nd {
                ddw[r * batch + p0..r * batch + p0 + cl]
                    .copy_from_slice(&dd[r * cl..(r + 1) * cl]);
            }
        }
        fallbacks += fb;
    }
    let dtheta = reduce_theta_ascending(&gth_lanes, pl, batch);
    Ok(AdjointGrad { terminal, dy0, dtheta, ddw, fallbacks })
}

/// Backward-pass Brownian replay: pulls every increment of a uniform grid
/// out of a [`BrownianSource`] in **one** [`fill_grid`] descent, then serves
/// them as [`NoiseF64`] in any order — forward for the solve, right-to-left
/// for the adjoint sweep. Bit-identical to querying the source per step
/// (the `fill_grid` contract).
///
/// Generic over the stored element type: `GridReplayNoise<f64>` (the
/// default) widens at fill time exactly as [`super::NoiseFromSource`]
/// widens; `GridReplayNoise<f32>` keeps the source's native `f32` grid
/// **without any conversion pass** ([`Lane::vec_from_f32`] hands the fill
/// buffer over as-is) and widens only at query time.
///
/// [`fill_grid`]: BrownianSource::fill_grid
pub struct GridReplayNoise<T: Lane = f64> {
    t0: f64,
    dt: f64,
    n_steps: usize,
    size: usize,
    vals: Vec<T>,
}

impl<T: Lane> GridReplayNoise<T> {
    /// Fill the `n_steps`-interval uniform grid over `[t0, t1]` from `src`.
    pub fn from_source<B: BrownianSource>(src: &mut B, t0: f64, t1: f64, n_steps: usize) -> Self {
        assert!(t1 > t0 && n_steps >= 1);
        let size = src.size();
        let dt = (t1 - t0) / n_steps as f64;
        let ts: Vec<f64> = (0..=n_steps).map(|k| t0 + k as f64 * dt).collect();
        let mut buf = vec![0.0f32; n_steps * size];
        src.fill_grid(&ts, &mut buf);
        let vals = T::vec_from_f32(buf);
        Self { t0, dt, n_steps, size, vals }
    }

    /// Brownian channels per query.
    pub fn size(&self) -> usize {
        self.size
    }

    /// The stored increments of grid step `k` at the native element type —
    /// the direct read path for `f32` consumers (the [`NoiseF64`] view is
    /// `f64`-only so that un-annotated `from_source` calls keep inferring
    /// the default precision).
    pub fn step(&self, k: usize) -> &[T] {
        assert!(k < self.n_steps, "step {k} off the replay grid");
        &self.vals[k * self.size..(k + 1) * self.size]
    }
}

impl NoiseF64 for GridReplayNoise<f64> {
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]) {
        // Hard asserts, not debug: a mis-gridded query in a release build
        // would otherwise silently return the wrong increment (the replay
        // only ever holds single grid steps).
        let k = ((s - self.t0) / self.dt).round() as usize;
        assert!(k < self.n_steps, "query off the replay grid: s={s}");
        assert!(
            ((t - s) - self.dt).abs() < self.dt * 1e-9,
            "GridReplayNoise serves single grid steps, got [{s}, {t}]"
        );
        out.copy_from_slice(&self.vals[k * self.size..(k + 1) * self.size]);
    }
}

/// Test support: worst absolute error of an [`SdeVjp`] implementation
/// against central finite differences with step `h`, probing the scalar
/// observables `wf · f(t, y)` (drift) and `v · (g(t, y) · dw)` (diffusion)
/// in both the state and the parameter directions.
///
/// `rebuild` must construct the system from a flat parameter vector laid
/// out as the impl's θ-gradient; pass the current parameters in `params`
/// (empty for parameter-free systems).
#[allow(clippy::too_many_arguments)]
pub fn max_vjp_fd_error<S, F>(
    rebuild: F,
    params: &[f64],
    t: f64,
    y: &[f64],
    wf: &[f64],
    v: &[f64],
    dw: &[f64],
    h: f64,
) -> f64
where
    S: SdeVjp,
    F: Fn(&[f64]) -> S,
{
    let sde = rebuild(params);
    let e = sde.dim();
    let d = sde.noise_dim();
    let pl = sde.param_len();
    assert_eq!(params.len(), pl, "params must match param_len()");
    let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(u, w)| u * w).sum::<f64>();
    let drift_obs = |s: &S, yy: &[f64]| {
        let mut f = vec![0.0; e];
        s.drift(t, yy, &mut f);
        dot(wf, &f)
    };
    let diff_obs = |s: &S, yy: &[f64]| {
        let mut g = vec![0.0; e * d];
        s.diffusion(t, yy, &mut g);
        let mut hv = vec![0.0; e];
        super::apply_diffusion(&g, dw, &mut hv);
        dot(v, &hv)
    };

    let mut gy_f = vec![0.0; e];
    let mut gth_f = vec![0.0; pl];
    sde.drift_vjp(t, y, wf, &mut gy_f, &mut gth_f);
    let mut gy_g = vec![0.0; e];
    let mut gth_g = vec![0.0; pl];
    sde.diffusion_vjp(t, y, v, dw, &mut gy_g, &mut gth_g);

    let mut worst = 0.0f64;
    let fd_y_f = stats::central_gradient(|yy| drift_obs(&sde, yy), y, h);
    let fd_y_g = stats::central_gradient(|yy| diff_obs(&sde, yy), y, h);
    for i in 0..e {
        worst = worst.max((gy_f[i] - fd_y_f[i]).abs());
        worst = worst.max((gy_g[i] - fd_y_g[i]).abs());
    }
    if pl > 0 {
        let fd_th_f = stats::central_gradient(|pp| drift_obs(&rebuild(pp), y), params, h);
        let fd_th_g = stats::central_gradient(|pp| diff_obs(&rebuild(pp), y), params, h);
        for m in 0..pl {
            worst = worst.max((gth_f[m] - fd_th_f[m]).abs());
            worst = worst.max((gth_g[m] - fd_th_g[m]).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::super::systems::ScalarLinear;
    use super::super::CounterGridNoise;
    use super::*;

    #[test]
    fn scalar_linear_vjps_match_finite_differences() {
        let mk = |p: &[f64]| ScalarLinear { a: p[0], b: p[1] };
        let err = max_vjp_fd_error(
            mk,
            &[0.3, 0.5],
            0.0,
            &[1.2],
            &[0.7],
            &[-0.4],
            &[0.9],
            1e-6,
        );
        assert!(err < 1e-9, "VJP-vs-FD error {err}");
    }

    #[test]
    fn adjoint_matches_exact_linear_jacobian() {
        // For the linear SDE the discrete reversible-Heun map is linear in
        // (z, ẑ), so ∂z_N/∂y0 is an exact product of per-step 2×2 Jacobians
        // — the adjoint must reproduce it to roundoff.
        let (a, b) = (0.3f64, 0.5f64);
        let sde = ScalarLinear { a, b };
        let n = 64usize;
        let noise = CounterGridNoise::new(11, 1, 0.0, 1.0, n);
        let mut pn = noise.path(0);
        let g = adjoint_solve(
            &sde,
            &[1.0],
            0.0,
            1.0,
            n,
            &mut pn,
            BackwardMode::Reconstruct,
            |_z, gz| gz[0] = 1.0,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        // Reference: [dz_N/dz0, dz_N/dẑ0] = [1, 0] · Π_k M_k, seeded [1; 1]
        // because z0 = ẑ0 = y0.
        let h = 1.0 / n as f64;
        let (mut rz, mut rzh) = (1.0f64, 0.0f64); // row vector [∂/∂z, ∂/∂ẑ]
        for k in (0..n).rev() {
            let dw = noise.value(0, k, 0);
            let c = 0.5 * a * h + 0.5 * b * dw;
            let dzh_dz = 2.0;
            let dzh_dzh = -1.0 + a * h + b * dw;
            let dz_dz = 1.0 + c * dzh_dz;
            let dz_dzh = c * (1.0 + dzh_dzh);
            let (nz, nzh) = (rz * dz_dz + rzh * dzh_dz, rz * dz_dzh + rzh * dzh_dzh);
            rz = nz;
            rzh = nzh;
        }
        let reference = rz + rzh;
        let rel = (g.dy0[0] - reference).abs() / reference.abs().max(1e-300);
        assert!(rel < 1e-10, "adjoint {} vs exact {} (rel {rel:e})", g.dy0[0], reference);
    }

    #[test]
    fn tape_and_reconstruct_agree_to_roundoff() {
        let sde = ScalarLinear { a: 0.2, b: 0.4 };
        let n = 100usize;
        let noise = CounterGridNoise::new(5, 1, 0.0, 1.0, n);
        let run = |mode| {
            let mut pn = noise.path(0);
            adjoint_solve(&sde, &[0.8], 0.0, 1.0, n, &mut pn, mode, |_z, gz| gz[0] = 1.0)
                .expect("fault-free by construction") // test-only unwrap: no injection here
        };
        let rec = run(BackwardMode::Reconstruct);
        let tape = run(BackwardMode::Tape);
        assert_eq!(rec.fallbacks, 0, "healthy solve must not trip the watchdog");
        let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1e-300);
        assert!(rel(rec.dy0[0], tape.dy0[0]) < 1e-10);
        assert!(rel(rec.dtheta[0], tape.dtheta[0]) < 1e-10);
        assert!(rel(rec.dtheta[1], tape.dtheta[1]) < 1e-10);
        assert_eq!(rec.terminal, tape.terminal, "forward passes must be identical");
    }
}
