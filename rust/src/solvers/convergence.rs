//! Strong/weak convergence-order estimation (Appendix D.4, Figures 5 & 6).
//!
//! The protocol follows the paper: integrate the scalar anharmonic
//! oscillator `dy = sin(y) dt + dW` over `[0, 1]` with step `h = T/N`, and
//! compare against a reference solution computed by Heun's method on the
//! *same Brownian sample paths* at a 10× finer step. Report
//!
//! ```text
//! S_N = sqrt( E[ |Y_N - Y^fine| ] )          (strong error estimator)
//! E_N = | E[Y_N]  - E[Y^fine]  |             (weak, first moment)
//! V_N = | E[Y_N²] - E[(Y^fine)²] |           (weak, second moment)
//! ```
//!
//! Shared paths across all step sizes come from [`FineBrownianGrid`]: `f64`
//! increments generated once on the finest grid and *summed* for coarser
//! steps, so every solver/step-size sees the same underlying path.

use super::{FixedStepSolver, NoiseF64, Sde};
use crate::brownian::{splitmix64, SplitPrng};
use crate::util::stats;

/// Brownian increments pre-generated on a uniform fine grid in `f64`.
pub struct FineBrownianGrid {
    dim: usize,
    fine_steps: usize,
    t1: f64,
    /// increments, `[fine_steps][dim]` flattened.
    inc: Vec<f64>,
}

impl FineBrownianGrid {
    /// Generate `fine_steps` iid `N(0, T/fine_steps)` increments per channel.
    pub fn new(dim: usize, fine_steps: usize, t1: f64, seed: u64) -> Self {
        let dt = t1 / fine_steps as f64;
        let sd = dt.sqrt();
        let mut rng = SplitPrng::new(splitmix64(seed));
        let mut inc = Vec::with_capacity(fine_steps * dim);
        let mut pending: Option<f64> = None;
        for _ in 0..fine_steps * dim {
            let v = match pending.take() {
                Some(v) => v,
                None => {
                    let (a, b) = rng.next_normal_pair();
                    pending = Some(b);
                    a
                }
            };
            inc.push(v * sd);
        }
        Self { dim, fine_steps, t1, inc }
    }

    /// Number of fine steps.
    pub fn fine_steps(&self) -> usize {
        self.fine_steps
    }
}

impl NoiseF64 for FineBrownianGrid {
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]) {
        let dt = self.t1 / self.fine_steps as f64;
        let ks = ((s / dt).round() as usize).min(self.fine_steps);
        let kt = ((t / dt).round() as usize).min(self.fine_steps);
        assert!(kt > ks, "coarse step must cover >= 1 fine step (s={s}, t={t})");
        out.fill(0.0);
        for k in ks..kt {
            let row = &self.inc[k * self.dim..(k + 1) * self.dim];
            for i in 0..self.dim {
                out[i] += row[i];
            }
        }
    }
}

/// Errors measured at one step size.
#[derive(Clone, Copy, Debug)]
pub struct ErrorPoint {
    /// Step size `h = T / n`.
    pub h: f64,
    /// Strong error estimator `S_N` (see module docs).
    pub strong: f64,
    /// Weak first-moment error `E_N`.
    pub weak_mean: f64,
    /// Weak second-moment error `V_N`.
    pub weak_second: f64,
}

/// A full convergence study for one solver.
#[derive(Clone, Debug)]
pub struct ConvergenceReport {
    /// Solver label.
    pub solver: String,
    /// Per-step-size error estimators.
    pub points: Vec<ErrorPoint>,
    /// Fitted strong order (slope of `log2 S_N²` vs `log2 h`, i.e. of the
    /// mean absolute error — matching how the paper plots orders).
    pub strong_order: f64,
    /// Fitted weak order (slope of `log2 E_N` vs `log2 h`).
    pub weak_order: f64,
}

/// Integrate to `t1` and return the terminal scalar value (dim-1 systems).
fn terminal<S: Sde, M: FixedStepSolver>(
    sde: &S,
    solver: &mut M,
    noise: &mut FineBrownianGrid,
    y0: f64,
    t1: f64,
    n_steps: usize,
) -> f64 {
    let mut y = [y0];
    let mut dw = [0.0f64];
    let dt = t1 / n_steps as f64;
    for k in 0..n_steps {
        let s = k as f64 * dt;
        let t = (k + 1) as f64 * dt;
        noise.increment(s, t, &mut dw);
        solver.step(sde, s, dt, &dw, &mut y);
    }
    y[0]
}

/// Compute the paper's `(S_N, E_N, V_N)` estimators for one solver at the
/// given step counts, over `n_paths` Monte-Carlo sample paths.
///
/// `mk_solver` builds a fresh stepper per path/step-size; the reference is
/// Heun at `10 × max(step_counts)` steps on the same path.
pub fn strong_weak_errors<S, M, F>(
    sde: &S,
    mk_solver: F,
    step_counts: &[usize],
    n_paths: usize,
    y0: f64,
    t1: f64,
    seed: u64,
) -> Vec<ErrorPoint>
where
    S: Sde,
    M: FixedStepSolver,
    F: Fn(&S, f64, &[f64]) -> M,
{
    let max_n = *step_counts.iter().max().unwrap();
    let fine_n = 10 * max_n;
    let mut abs_err = vec![0.0f64; step_counts.len()];
    let mut mean_coarse = vec![0.0f64; step_counts.len()];
    let mut sq_coarse = vec![0.0f64; step_counts.len()];
    let mut mean_fine = 0.0f64;
    let mut sq_fine = 0.0f64;

    for p in 0..n_paths {
        let mut grid = FineBrownianGrid::new(1, fine_n, t1, seed.wrapping_add(p as u64));
        // Reference: standard Heun on the fine grid (as in the paper).
        let mut heun = super::Heun::new(1, 1);
        let y_fine = terminal(sde, &mut heun, &mut grid, y0, t1, fine_n);
        mean_fine += y_fine;
        sq_fine += y_fine * y_fine;
        for (i, &n) in step_counts.iter().enumerate() {
            let mut solver = mk_solver(sde, 0.0, &[y0]);
            let y_n = terminal(sde, &mut solver, &mut grid, y0, t1, n);
            abs_err[i] += (y_n - y_fine).abs();
            mean_coarse[i] += y_n;
            sq_coarse[i] += y_n * y_n;
        }
    }

    let np = n_paths as f64;
    mean_fine /= np;
    sq_fine /= np;
    step_counts
        .iter()
        .enumerate()
        .map(|(i, &n)| ErrorPoint {
            h: t1 / n as f64,
            strong: (abs_err[i] / np).sqrt(),
            weak_mean: (mean_coarse[i] / np - mean_fine).abs(),
            weak_second: (sq_coarse[i] / np - sq_fine).abs(),
        })
        .collect()
}

/// Fit convergence orders from error points.
pub fn estimate_orders(solver: &str, points: Vec<ErrorPoint>) -> ConvergenceReport {
    let xs: Vec<f64> = points.iter().map(|p| p.h.log2()).collect();
    // S_N = sqrt(E|err|): E|err| ~ h^q  =>  log2 S_N² = q log2 h + c.
    let ys_strong: Vec<f64> = points.iter().map(|p| (p.strong * p.strong).log2()).collect();
    let ys_weak: Vec<f64> = points.iter().map(|p| p.weak_mean.max(1e-300).log2()).collect();
    let (_, strong_order) = stats::linear_fit(&xs, &ys_strong);
    let (_, weak_order) = stats::linear_fit(&xs, &ys_weak);
    ConvergenceReport { solver: solver.to_string(), points, strong_order, weak_order }
}

#[cfg(test)]
mod tests {
    use super::super::systems::Anharmonic;
    use super::super::{Heun, ReversibleHeun};
    use super::*;

    #[test]
    fn fine_grid_increments_sum_consistently() {
        let mut g = FineBrownianGrid::new(2, 100, 1.0, 3);
        let mut whole = [0.0f64; 2];
        g.increment(0.0, 1.0, &mut whole);
        let mut acc = [0.0f64; 2];
        let mut part = [0.0f64; 2];
        for k in 0..10 {
            g.increment(k as f64 / 10.0, (k + 1) as f64 / 10.0, &mut part);
            acc[0] += part[0];
            acc[1] += part[1];
        }
        assert!((whole[0] - acc[0]).abs() < 1e-12);
        assert!((whole[1] - acc[1]).abs() < 1e-12);
    }

    #[test]
    fn fine_grid_variance() {
        let mut g = FineBrownianGrid::new(20_000, 64, 1.0, 11);
        let mut w = vec![0.0f64; 20_000];
        g.increment(0.0, 1.0, &mut w);
        let var = w.iter().map(|x| x * x).sum::<f64>() / w.len() as f64;
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    // The full-size order studies live in `examples/convergence.rs` and the
    // fig5 bench; here we sanity-check with a small budget.
    #[test]
    fn revheun_additive_noise_strong_order_near_one() {
        let sde = Anharmonic { sigma: 1.0 };
        let pts = strong_weak_errors(
            &sde,
            |s, t0, y0| ReversibleHeun::new(s, t0, y0),
            &[8, 16, 32, 64],
            400,
            1.0,
            1.0,
            42,
        );
        let rep = estimate_orders("revheun", pts);
        assert!(
            rep.strong_order > 0.75 && rep.strong_order < 1.4,
            "strong order {}",
            rep.strong_order
        );
    }

    #[test]
    fn heun_additive_noise_strong_order_near_one() {
        let sde = Anharmonic { sigma: 1.0 };
        let pts = strong_weak_errors(
            &sde,
            |_s, _t0, _y0| Heun::new(1, 1),
            &[8, 16, 32, 64],
            400,
            1.0,
            1.0,
            43,
        );
        let rep = estimate_orders("heun", pts);
        assert!(
            rep.strong_order > 0.75 && rep.strong_order < 1.4,
            "strong order {}",
            rep.strong_order
        );
    }
}
