//! Batched structure-of-arrays (SoA) solve engine — precision-generic.
//!
//! The paper's headline numbers are measured on *batched* solves — SDE-GAN
//! and Latent SDE training integrate 1024+ sample paths per step — while the
//! per-path [`super::integrate`] loop advances one `Vec<f64>` at a time.
//! This module makes the pure-Rust hot path batch-native:
//!
//! * [`BatchSde`] — vector fields evaluated over a whole `[dim × batch]`
//!   SoA state in one call, with a blanket adapter from every per-path
//!   [`Sde`] (so existing systems work unchanged) and a **diagonal-noise
//!   fast path** that skips the dense `e×d` mat-vec when the diffusion is
//!   diagonal (the dominant case in the paper's models);
//! * [`BatchEulerMaruyama`] / [`BatchMidpoint`] / [`BatchHeun`] /
//!   [`BatchReversibleHeun`] — SoA steppers whose per-path arithmetic
//!   mirrors the scalar steppers operation-for-operation, so batched and
//!   per-path integration agree bit-for-bit (and the batched reversible
//!   Heun keeps its algebraic reversibility per path);
//! * [`integrate_batched`] — a chunked `std::thread` worker pool fanning
//!   fixed-size path chunks across cores with work-stealing deques, so
//!   skewed per-chunk costs rebalance. Each path's noise and arithmetic
//!   are independent of the partition, so results are **deterministic and
//!   identical for any thread count or steal schedule**;
//! * [`CounterGridNoise`] — O(1)-memory, random-access per-path Gaussian
//!   grid noise built on [`crate::brownian::normal_at`], with a
//!   [`PathNoiseF64`] adapter exposing any single path's stream to the
//!   per-path solvers (the equivalence tests rest on it).
//!
//! SoA layout conventions: state `y[i * batch + p]` (component `i`, path
//! `p`), noise `dw[j * batch + p]`, dense diffusion
//! `g[(i * noise_dim + j) * batch + p]`, diagonal diffusion `g[i * batch + p]`.
//!
//! # Precision-generic lanes
//!
//! Every trait and stepper here is generic over the sealed element type
//! [`Lane`] (`f64`, the default everywhere, or `f32`): the per-component
//! inner loops run on the unit-stride kernels of [`super::simd`], 4-wide
//! for `f64` and **8-wide for `f32`** — double the SIMD lane width and half
//! the memory bandwidth for workloads that tolerate single precision. The
//! time grid stays `f64` in both instantiations (grid arithmetic is not a
//! lane quantity); only lane data changes type, with `Δt` rounded once per
//! step through [`Lane::from_f64`] (the identity for `f64`, so the `f64`
//! path's bits are exactly the historical ones). Vectorisation is across
//! paths only, so batched results stay bit-for-bit equal to per-path
//! integration *at the same precision* (see the kernel module's docs for
//! the exact invariants).

use super::guard::{self, FaultCause, GuardConfig, GuardedSolve, SolveError, SolveFault};
use super::pool;
use super::simd::{self, Lane};
use super::{NoiseF64, Sde};
use crate::brownian::{normal_at, splitmix64, BrownianSource};
use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// A batched SDE over structure-of-arrays state of element type `T` (see
/// module docs for the layout conventions). `Sync` so chunks can be solved
/// on worker threads. Per-path systems adapt automatically at `f64`; native
/// hand-batched systems additionally implement `BatchSde<f32>` to run on
/// the 8-wide lanes.
pub trait BatchSde<T: Lane = f64>: Sync {
    /// State dimension `e` per path.
    fn state_dim(&self) -> usize;
    /// Brownian dimension `d` per path.
    fn brownian_dim(&self) -> usize;
    /// True when the diffusion is diagonal (`d == e`, off-diagonal zero):
    /// steppers then call [`diffusion_diag_batch`](Self::diffusion_diag_batch)
    /// and replace the dense mat-vec by an elementwise product.
    fn diagonal_noise(&self) -> bool {
        false
    }
    /// Batched drift into `out` (`[dim * batch]`, SoA).
    fn drift_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize);
    /// Batched dense diffusion into `out` (`[dim * noise_dim * batch]`, SoA).
    fn diffusion_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize);
    /// Batched diagonal diffusion into `out` (`[dim * batch]`, SoA). Only
    /// called when [`diagonal_noise`](Self::diagonal_noise) is true.
    fn diffusion_diag_batch(&self, t: f64, y: &[T], out: &mut [T], batch: usize) {
        let _ = (t, y, out, batch);
        unimplemented!("diffusion_diag_batch called on a non-diagonal BatchSde");
    }
}

/// Blanket adapter: every per-path [`Sde`] is a [`BatchSde`] (at `f64`) by
/// gather → per-path evaluation → scatter. Per-path arithmetic is the
/// scalar implementation itself, so adapted batched solves agree with
/// per-path solves bit-for-bit.
impl<S: Sde + Sync> BatchSde for S {
    fn state_dim(&self) -> usize {
        Sde::dim(self)
    }

    fn brownian_dim(&self) -> usize {
        Sde::noise_dim(self)
    }

    fn diagonal_noise(&self) -> bool {
        self.diffusion_is_diagonal()
    }

    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let e = Sde::dim(self);
        let mut yp = vec![0.0; e];
        let mut op = vec![0.0; e];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
            }
            self.drift(t, &yp, &mut op);
            for i in 0..e {
                out[i * batch + p] = op[i];
            }
        }
    }

    fn diffusion_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let e = Sde::dim(self);
        let d = Sde::noise_dim(self);
        let mut yp = vec![0.0; e];
        let mut gp = vec![0.0; e * d];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
            }
            self.diffusion(t, &yp, &mut gp);
            for r in 0..e * d {
                out[r * batch + p] = gp[r];
            }
        }
    }

    fn diffusion_diag_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let e = Sde::dim(self);
        let mut yp = vec![0.0; e];
        let mut gp = vec![0.0; e];
        for p in 0..batch {
            for i in 0..e {
                yp[i] = y[i * batch + p];
            }
            self.diffusion_diag(t, &yp, &mut gp);
            for i in 0..e {
                out[i * batch + p] = gp[i];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Noise
// ---------------------------------------------------------------------------

/// Per-path Brownian grid noise for batched solves over element type `T`.
/// Implementations must be deterministic **per path**: the increment of
/// path `p` at step `k` may not depend on which chunk or thread asks for it.
pub trait BatchNoise<T: Lane = f64>: Sync {
    /// Brownian dimension `d` per path.
    fn brownian_dim(&self) -> usize;
    /// Write the SoA increments for grid step `k` (spanning `[s, t]`) of
    /// paths `p0 .. p0 + chunk` into `out` (`[d * chunk]`):
    /// `out[j * chunk + q]` is channel `j` of path `p0 + q`.
    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [T]);
}

/// Counter-based per-path Gaussian grid noise: O(1) memory, random access,
/// thread-safe. Path `p`'s stream is seeded from `(seed, p)` only, so its
/// increments are identical whether it is solved alone, inside any chunk, or
/// on any thread — the property the engine's determinism guarantee rests on.
///
/// Implements [`BatchNoise`] at both precisions: the `f32` increments are
/// the rounded `f64` samples (same underlying Gaussian draw), so an `f32`
/// solve and an `f64` solve of the same seed see the *same* Brownian sample
/// up to lane rounding — the property the mixed-precision deviation
/// measurements rest on.
pub struct CounterGridNoise {
    base: u64,
    noise_dim: usize,
    t0: f64,
    dt: f64,
    sd: f64,
    n_steps: usize,
}

impl CounterGridNoise {
    /// Noise for `n_steps` uniform intervals over `[t0, t1]`, `noise_dim`
    /// channels per path.
    pub fn new(seed: u64, noise_dim: usize, t0: f64, t1: f64, n_steps: usize) -> Self {
        assert!(t1 > t0 && n_steps >= 1 && noise_dim >= 1);
        let dt = (t1 - t0) / n_steps as f64;
        Self { base: seed, noise_dim, t0, dt, sd: dt.sqrt(), n_steps }
    }

    #[inline]
    fn path_seed(&self, p: usize) -> u64 {
        splitmix64(self.base ^ (p as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// The increment `dW_j` of path `p` at grid step `k`.
    #[inline]
    pub fn value(&self, p: usize, k: usize, j: usize) -> f64 {
        debug_assert!(k < self.n_steps && j < self.noise_dim);
        normal_at(self.path_seed(p), (k * self.noise_dim + j) as u64) * self.sd
    }

    /// The `f32` lane value of the same draw — exactly what the
    /// `BatchNoise<f32>` impl serves (the rounded `f64` sample).
    #[inline]
    pub fn value_f32(&self, p: usize, k: usize, j: usize) -> f32 {
        self.value(p, k, j) as f32
    }

    /// A [`NoiseF64`] view of path `p`'s stream, for driving the per-path
    /// solvers with exactly the noise the batched engine hands that path.
    pub fn path(&self, p: usize) -> PathNoiseF64<'_> {
        PathNoiseF64 { src: self, p }
    }
}

impl CounterGridNoise {
    /// One shared fill body for both precisions: the draw is always the
    /// `f64` sample (`normal_at · √Δt`), rounded through [`Lane::from_f64`]
    /// — the identity at `f64` — so the two [`BatchNoise`] impls cannot
    /// drift apart.
    #[inline]
    fn fill_step_lanes<T: Lane>(
        &self,
        k: usize,
        s: f64,
        t: f64,
        p0: usize,
        chunk: usize,
        out: &mut [T],
    ) {
        debug_assert!((s - (self.t0 + k as f64 * self.dt)).abs() < self.dt * 1e-9);
        debug_assert!(t > s);
        debug_assert_eq!(out.len(), self.noise_dim * chunk);
        let d = self.noise_dim;
        for q in 0..chunk {
            let seed = self.path_seed(p0 + q);
            for j in 0..d {
                out[j * chunk + q] = T::from_f64(normal_at(seed, (k * d + j) as u64) * self.sd);
            }
        }
    }
}

impl BatchNoise for CounterGridNoise {
    fn brownian_dim(&self) -> usize {
        self.noise_dim
    }

    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [f64]) {
        self.fill_step_lanes(k, s, t, p0, chunk, out);
    }
}

impl BatchNoise<f32> for CounterGridNoise {
    fn brownian_dim(&self) -> usize {
        self.noise_dim
    }

    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [f32]) {
        self.fill_step_lanes(k, s, t, p0, chunk, out);
    }
}

/// Single-path [`NoiseF64`] view into a [`CounterGridNoise`].
pub struct PathNoiseF64<'a> {
    src: &'a CounterGridNoise,
    p: usize,
}

impl NoiseF64 for PathNoiseF64<'_> {
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]) {
        let k = ((s - self.src.t0) / self.src.dt).round() as usize;
        debug_assert!(k < self.src.n_steps, "query off the grid: s={s}");
        debug_assert!(
            ((t - s) - self.src.dt).abs() < self.src.dt * 1e-9,
            "PathNoiseF64 serves single grid steps, got [{s}, {t}]"
        );
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.src.value(self.p, k, j);
        }
    }
}

/// Explicitly stored per-step, per-path increments over a uniform grid —
/// the "noise" feed for solves whose driving increments are **data** rather
/// than fresh randomness:
///
/// * the neural-CDE discriminator, whose control increments are the observed
///   (or generated) path's `ΔY` (equation (2) of the paper);
/// * replaying an externally sampled Brownian grid through the batch
///   engine's forward *and* backward sweeps with guaranteed identical bits.
///
/// Storage is SoA at the lane precision `T`: `vals[(k * dim + j) * batch + p]`
/// is channel `j` of path `p` at grid step `k`. Serves any step in any order
/// (the doubly-sequential adjoint access pattern), per path via
/// [`path`](Self::path) or per chunk via [`BatchNoise`].
///
/// The Brownian sources produce `f32` natively, so `StoredBatchNoise<f32>`
/// consumes a [`BrownianSource`] grid **without any widening**
/// ([`fill_from_source`](Self::fill_from_source) /
/// [`from_f32_grid`](Self::from_f32_grid) — a single transpose pass into
/// the SoA lanes, no intermediate `f64` buffer in either precision).
pub struct StoredBatchNoise<T: Lane = f64> {
    t0: f64,
    dt: f64,
    n_steps: usize,
    dim: usize,
    batch: usize,
    vals: Vec<T>,
    /// Grid times `t0 + k·Δt` for `k = 0..=n_steps`, computed once at
    /// construction — [`fill_from_source`](Self::fill_from_source) hands
    /// them to [`BrownianSource::fill_grid`] on every refill, so refills
    /// allocate nothing.
    ts: Vec<f64>,
}

impl<T: Lane> StoredBatchNoise<T> {
    /// Zero-filled increments for `n_steps` uniform intervals over
    /// `[t0, t1]`, `dim` channels per path.
    pub fn zeros(t0: f64, t1: f64, n_steps: usize, dim: usize, batch: usize) -> Self {
        assert!(t1 > t0 && n_steps >= 1 && dim >= 1 && batch >= 1);
        let dt = (t1 - t0) / n_steps as f64;
        Self {
            t0,
            dt,
            n_steps,
            dim,
            batch,
            vals: vec![T::ZERO; n_steps * dim * batch],
            ts: (0..=n_steps).map(|k| t0 + k as f64 * dt).collect(),
        }
    }

    /// Build from a step-major, path-major `f32` grid buffer — the
    /// `[k][p][j]` layout [`BrownianSource::fill_grid`] (with
    /// `size = batch * dim`) and `StepNoise::fill` produce. One transpose
    /// pass straight into the SoA lanes: no intermediate widened buffer for
    /// `f64` consumers, no conversion at all for `f32` consumers.
    pub fn from_f32_grid(
        t0: f64,
        t1: f64,
        n_steps: usize,
        dim: usize,
        batch: usize,
        grid: &[f32],
    ) -> Self {
        assert_eq!(grid.len(), n_steps * batch * dim, "grid must be [n_steps][batch][dim]");
        let mut out = Self::zeros(t0, t1, n_steps, dim, batch);
        for k in 0..n_steps {
            for p in 0..batch {
                let row = &grid[(k * batch + p) * dim..(k * batch + p + 1) * dim];
                for (j, &v) in row.iter().enumerate() {
                    out.vals[(k * out.dim + j) * out.batch + p] = T::from_f32(v);
                }
            }
        }
        out
    }

    /// Refill in place from a [`BrownianSource`] (`src.size()` must equal
    /// `batch * dim`, channel `c = p * dim + j`): **one** `fill_grid`
    /// descent into the caller's reusable `f32` scratch buffer, then one
    /// transpose pass into the SoA lanes — the hot-path replacement for
    /// per-step [`BrownianSource::increment_vec`] calls, which allocate on
    /// every step.
    pub fn fill_from_source<B: BrownianSource>(&mut self, src: &mut B, scratch: &mut Vec<f32>) {
        let size = src.size();
        assert_eq!(size, self.batch * self.dim, "source size must be batch * dim");
        scratch.clear();
        scratch.resize(self.n_steps * size, 0.0);
        src.fill_grid(&self.ts, scratch);
        for k in 0..self.n_steps {
            for p in 0..self.batch {
                let row = &scratch[(k * self.batch + p) * self.dim..];
                for j in 0..self.dim {
                    self.vals[(k * self.dim + j) * self.batch + p] = T::from_f32(row[j]);
                }
            }
        }
    }

    /// Set channel `j` of path `p` at step `k`.
    #[inline]
    pub fn set(&mut self, k: usize, j: usize, p: usize, v: T) {
        self.vals[(k * self.dim + j) * self.batch + p] = v;
    }

    /// Read channel `j` of path `p` at step `k`.
    #[inline]
    pub fn get(&self, k: usize, j: usize, p: usize) -> T {
        self.vals[(k * self.dim + j) * self.batch + p]
    }

    /// The full SoA value buffer (tests perturb it for finite differences).
    pub fn values_mut(&mut self) -> &mut [T] {
        &mut self.vals
    }

    /// A [`NoiseF64`] view of path `p`'s stream (widening at query time for
    /// `f32` storage).
    pub fn path(&self, p: usize) -> StoredPathNoise<'_, T> {
        assert!(p < self.batch);
        StoredPathNoise { src: self, p }
    }
}

impl<T: Lane> BatchNoise<T> for StoredBatchNoise<T> {
    fn brownian_dim(&self) -> usize {
        self.dim
    }

    fn fill_step(&self, k: usize, s: f64, t: f64, p0: usize, chunk: usize, out: &mut [T]) {
        debug_assert!((s - (self.t0 + k as f64 * self.dt)).abs() < self.dt * 1e-9);
        debug_assert!(t > s && p0 + chunk <= self.batch);
        debug_assert_eq!(out.len(), self.dim * chunk);
        for j in 0..self.dim {
            let src = &self.vals[(k * self.dim + j) * self.batch + p0..];
            out[j * chunk..(j + 1) * chunk].copy_from_slice(&src[..chunk]);
        }
    }
}

/// Single-path [`NoiseF64`] view into a [`StoredBatchNoise`].
pub struct StoredPathNoise<'a, T: Lane = f64> {
    src: &'a StoredBatchNoise<T>,
    p: usize,
}

impl<T: Lane> NoiseF64 for StoredPathNoise<'_, T> {
    fn increment(&mut self, s: f64, t: f64, out: &mut [f64]) {
        let k = ((s - self.src.t0) / self.src.dt).round() as usize;
        debug_assert!(k < self.src.n_steps, "query off the grid: s={s}");
        debug_assert!(
            ((t - s) - self.src.dt).abs() < self.src.dt * 1e-9,
            "StoredPathNoise serves single grid steps, got [{s}, {t}]"
        );
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.src.get(k, j, self.p).to_f64();
        }
    }
}

// ---------------------------------------------------------------------------
// Steppers
// ---------------------------------------------------------------------------

/// A batched fixed-step solver over SoA state of element type
/// [`Elem`](Self::Elem). Mirrors [`super::FixedStepSolver`]; constructed per
/// chunk so worker threads never share mutable scratch.
pub trait BatchStepper: Sized {
    /// Lane element type the stepper advances (`f64` on the default 4-wide
    /// kernels, `f32` on the 8-wide ones).
    type Elem: Lane;

    /// Vector-field evaluations per step (as in the scalar counterpart).
    const FIELD_EVALS_PER_STEP: usize;

    /// Build a stepper for one chunk, initialised at `(t0, y0)` (`y0` is the
    /// chunk's SoA state, `[dim * batch]`).
    fn for_chunk<S: BatchSde<Self::Elem>>(
        sde: &S,
        t0: f64,
        y0: &[Self::Elem],
        batch: usize,
    ) -> Self;

    /// Re-initialise an existing stepper at `(t0, y0)` for a (possibly
    /// differently sized) chunk, **reusing its scratch buffers** — the
    /// persistent-worker hot path ([`super::serve`]) holds one stepper per
    /// worker forever and `reinit`s it per chunk instead of paying
    /// [`for_chunk`](Self::for_chunk)'s allocations per call.
    ///
    /// Contract: after `reinit`, the stepper's state and subsequent
    /// [`step`](Self::step) results are bit-identical to a freshly
    /// `for_chunk`-constructed stepper's, and — once the stepper has been
    /// warmed at some chunk size — re-initialising at any equal-or-smaller
    /// `batch` performs no allocation. The default delegates to
    /// `for_chunk` (correct but allocating); the in-tree steppers all
    /// override it.
    fn reinit<S: BatchSde<Self::Elem>>(
        &mut self,
        sde: &S,
        t0: f64,
        y0: &[Self::Elem],
        batch: usize,
    ) {
        *self = Self::for_chunk(sde, t0, y0, batch);
    }

    /// Advance the chunk's SoA state `y` in place from `t` to `t + dt` using
    /// the SoA increments `dw`.
    fn step<S: BatchSde<Self::Elem>>(
        &mut self,
        sde: &S,
        t: f64,
        dt: f64,
        dw: &[Self::Elem],
        y: &mut [Self::Elem],
        batch: usize,
    );
}

/// Evaluate the diffusion into `g`, choosing the diagonal fast path when the
/// SDE advertises one. Returns true when `g` holds the diagonal layout.
fn eval_diffusion<T: Lane, S: BatchSde<T>>(
    sde: &S,
    t: f64,
    y: &[T],
    g: &mut Vec<T>,
    batch: usize,
) -> bool {
    let e = sde.state_dim();
    let d = sde.brownian_dim();
    if sde.diagonal_noise() {
        debug_assert_eq!(e, d, "diagonal noise requires noise_dim == dim");
        g.resize(e * batch, T::ZERO);
        sde.diffusion_diag_batch(t, y, g, batch);
        true
    } else {
        g.resize(e * d * batch, T::ZERO);
        sde.diffusion_batch(t, y, g, batch);
        false
    }
}

/// `y += g · dw` per path — the batched mirror of
/// [`super::apply_diffusion`]: the inner accumulation runs over `j` in the
/// same order as the scalar mat-vec, so per-path results are bit-identical.
fn add_matvec<T: Lane>(
    g: &[T],
    diag: bool,
    dw: &[T],
    y: &mut [T],
    e: usize,
    d: usize,
    batch: usize,
) {
    if diag {
        // Diagonal: `d == e`, one fused elementwise pass over all lanes.
        simd::mul_add(&g[..e * batch], &dw[..e * batch], &mut y[..e * batch]);
    } else {
        for i in 0..e {
            simd::matvec_row(
                &g[i * d * batch..(i + 1) * d * batch],
                dw,
                &mut y[i * batch..(i + 1) * batch],
                d,
            );
        }
    }
}

/// Batched Euler–Maruyama (Itô), mirroring [`super::EulerMaruyama`].
pub struct BatchEulerMaruyama<T: Lane = f64> {
    f: Vec<T>,
    g: Vec<T>,
}

impl<T: Lane> BatchStepper for BatchEulerMaruyama<T> {
    type Elem = T;

    const FIELD_EVALS_PER_STEP: usize = 1;

    fn for_chunk<S: BatchSde<T>>(_sde: &S, _t0: f64, _y0: &[T], _batch: usize) -> Self {
        Self { f: Vec::new(), g: Vec::new() }
    }

    /// The scratch-only steppers carry no cross-step state (`for_chunk`
    /// ignores `y0`; `step` sizes the scratch), so re-initialisation keeps
    /// the warmed buffers and does nothing — every scratch lane is fully
    /// overwritten by the vector-field evaluations each step.
    fn reinit<S: BatchSde<T>>(&mut self, _sde: &S, _t0: f64, _y0: &[T], _batch: usize) {}

    fn step<S: BatchSde<T>>(
        &mut self,
        sde: &S,
        t: f64,
        dt: f64,
        dw: &[T],
        y: &mut [T],
        batch: usize,
    ) {
        let e = sde.state_dim();
        let d = sde.brownian_dim();
        self.f.resize(e * batch, T::ZERO);
        sde.drift_batch(t, y, &mut self.f, batch);
        let diag = eval_diffusion(sde, t, y, &mut self.g, batch);
        simd::axpy(T::from_f64(dt), &self.f, y);
        add_matvec(&self.g, diag, dw, y, e, d, batch);
    }
}

/// Batched midpoint method (Stratonovich), mirroring [`super::Midpoint`].
pub struct BatchMidpoint<T: Lane = f64> {
    f: Vec<T>,
    g: Vec<T>,
    mid: Vec<T>,
    half_dw: Vec<T>,
}

impl<T: Lane> BatchStepper for BatchMidpoint<T> {
    type Elem = T;

    const FIELD_EVALS_PER_STEP: usize = 2;

    fn for_chunk<S: BatchSde<T>>(_sde: &S, _t0: f64, _y0: &[T], _batch: usize) -> Self {
        Self { f: Vec::new(), g: Vec::new(), mid: Vec::new(), half_dw: Vec::new() }
    }

    /// The scratch-only steppers carry no cross-step state (`for_chunk`
    /// ignores `y0`; `step` sizes the scratch), so re-initialisation keeps
    /// the warmed buffers and does nothing — every scratch lane is fully
    /// overwritten by the vector-field evaluations each step.
    fn reinit<S: BatchSde<T>>(&mut self, _sde: &S, _t0: f64, _y0: &[T], _batch: usize) {}

    fn step<S: BatchSde<T>>(
        &mut self,
        sde: &S,
        t: f64,
        dt: f64,
        dw: &[T],
        y: &mut [T],
        batch: usize,
    ) {
        let e = sde.state_dim();
        let d = sde.brownian_dim();
        self.f.resize(e * batch, T::ZERO);
        self.mid.resize(e * batch, T::ZERO);
        self.half_dw.resize(d * batch, T::ZERO);
        // Half step.
        sde.drift_batch(t, y, &mut self.f, batch);
        let diag = eval_diffusion(sde, t, y, &mut self.g, batch);
        self.mid.copy_from_slice(y);
        simd::axpy_half(T::from_f64(dt), &self.f, &mut self.mid);
        simd::scale_half(dw, &mut self.half_dw);
        add_matvec(&self.g, diag, &self.half_dw, &mut self.mid, e, d, batch);
        // Full step with midpoint fields.
        sde.drift_batch(t + 0.5 * dt, &self.mid, &mut self.f, batch);
        let diag = eval_diffusion(sde, t + 0.5 * dt, &self.mid, &mut self.g, batch);
        simd::axpy(T::from_f64(dt), &self.f, y);
        add_matvec(&self.g, diag, dw, y, e, d, batch);
    }
}

/// Batched Heun / trapezoidal rule (Stratonovich), mirroring [`super::Heun`].
pub struct BatchHeun<T: Lane = f64> {
    f0: Vec<T>,
    g0: Vec<T>,
    f1: Vec<T>,
    g1: Vec<T>,
    pred: Vec<T>,
}

impl<T: Lane> BatchStepper for BatchHeun<T> {
    type Elem = T;

    const FIELD_EVALS_PER_STEP: usize = 2;

    fn for_chunk<S: BatchSde<T>>(_sde: &S, _t0: f64, _y0: &[T], _batch: usize) -> Self {
        Self {
            f0: Vec::new(),
            g0: Vec::new(),
            f1: Vec::new(),
            g1: Vec::new(),
            pred: Vec::new(),
        }
    }

    /// The scratch-only steppers carry no cross-step state (`for_chunk`
    /// ignores `y0`; `step` sizes the scratch), so re-initialisation keeps
    /// the warmed buffers and does nothing — every scratch lane is fully
    /// overwritten by the vector-field evaluations each step.
    fn reinit<S: BatchSde<T>>(&mut self, _sde: &S, _t0: f64, _y0: &[T], _batch: usize) {}

    fn step<S: BatchSde<T>>(
        &mut self,
        sde: &S,
        t: f64,
        dt: f64,
        dw: &[T],
        y: &mut [T],
        batch: usize,
    ) {
        let e = sde.state_dim();
        let d = sde.brownian_dim();
        self.f0.resize(e * batch, T::ZERO);
        self.f1.resize(e * batch, T::ZERO);
        self.pred.resize(e * batch, T::ZERO);
        sde.drift_batch(t, y, &mut self.f0, batch);
        let diag0 = eval_diffusion(sde, t, y, &mut self.g0, batch);
        // Euler predictor.
        self.pred.copy_from_slice(y);
        simd::axpy(T::from_f64(dt), &self.f0, &mut self.pred);
        add_matvec(&self.g0, diag0, dw, &mut self.pred, e, d, batch);
        // Trapezoidal corrector.
        sde.drift_batch(t + dt, &self.pred, &mut self.f1, batch);
        let diag1 = eval_diffusion(sde, t + dt, &self.pred, &mut self.g1, batch);
        debug_assert_eq!(diag0, diag1);
        simd::avg_axpy(&self.f0, &self.f1, T::from_f64(dt), y);
        if diag0 {
            simd::avg_mul_add(&self.g0, &self.g1, &dw[..e * batch], &mut y[..e * batch]);
        } else {
            for i in 0..e {
                simd::matvec_row_avg(
                    &self.g0[i * d * batch..(i + 1) * d * batch],
                    &self.g1[i * d * batch..(i + 1) * d * batch],
                    dw,
                    &mut y[i * batch..(i + 1) * batch],
                    d,
                );
            }
        }
    }
}

/// Batched reversible Heun (paper Section 3, Algorithms 1 and 2) over SoA
/// state, mirroring [`super::ReversibleHeun`] per path — including the
/// closed-form [`reverse_step`](Self::reverse_step), so algebraic
/// reversibility holds path-wise in the batched engine too. The adjoint
/// engine ([`super::adjoint`]) drives `reverse_step` in lockstep with its
/// cotangent recursion to reconstruct the forward trajectory in O(1)
/// memory.
pub struct BatchReversibleHeun<T: Lane = f64> {
    dim: usize,
    noise_dim: usize,
    batch: usize,
    diag: bool,
    z: Vec<T>,
    zh: Vec<T>,
    mu: Vec<T>,
    sigma: Vec<T>,
    s_zh: Vec<T>,
    s_mu: Vec<T>,
    s_sigma: Vec<T>,
}

impl<T: Lane> BatchReversibleHeun<T> {
    /// Solution estimates `z` (SoA), for inspection/tests.
    pub fn z(&self) -> &[T] {
        &self.z
    }

    /// Auxiliary estimates `ẑ` (SoA).
    pub fn zh(&self) -> &[T] {
        &self.zh
    }

    /// Cached drift evaluations `μ` (SoA).
    pub fn mu(&self) -> &[T] {
        &self.mu
    }

    /// Cached diffusion evaluations `σ` (SoA; diagonal layout when the SDE
    /// advertises diagonal noise, dense otherwise).
    pub fn sigma(&self) -> &[T] {
        &self.sigma
    }

    /// Replace the full `(z, ẑ, μ, σ)` state (all SoA, lengths matching the
    /// construction-time shapes). Used by the adjoint engine's debug-mode
    /// reconstruction-drift check to replay a forward step from a
    /// reconstructed state.
    pub fn set_state(&mut self, z: &[T], zh: &[T], mu: &[T], sigma: &[T]) {
        self.z.copy_from_slice(z);
        self.zh.copy_from_slice(zh);
        self.mu.copy_from_slice(mu);
        self.sigma.copy_from_slice(sigma);
    }

    /// Max-abs difference of the full `(z, ẑ, μ, σ)` state to another
    /// stepper's (for reversibility tests), widened to `f64`.
    pub fn max_abs_state_diff(&self, other: &Self) -> f64 {
        let d = |a: &[T], b: &[T]| {
            a.iter()
                .zip(b)
                .map(|(x, y)| (x.to_f64() - y.to_f64()).abs())
                .fold(0.0f64, f64::max)
        };
        d(&self.z, &other.z)
            .max(d(&self.zh, &other.zh))
            .max(d(&self.mu, &other.mu))
            .max(d(&self.sigma, &other.sigma))
    }

    /// Algorithm 1 per path: advance `(z, ẑ, μ, σ)` from `t` to `t + dt`.
    pub fn forward_step<S: BatchSde<T>>(&mut self, sde: &S, t: f64, dt: f64, dw: &[T]) {
        let (e, d, b) = (self.dim, self.noise_dim, self.batch);
        let dtl = T::from_f64(dt);
        // ẑ_{n+1} = 2 z − ẑ + μ Δt + σ ΔW.
        simd::leapfrog(&self.z, &self.zh, &self.mu, dtl, &mut self.s_zh);
        add_matvec(&self.sigma, self.diag, dw, &mut self.s_zh, e, d, b);
        // μ_{n+1}, σ_{n+1}.
        sde.drift_batch(t + dt, &self.s_zh, &mut self.s_mu, b);
        if self.diag {
            sde.diffusion_diag_batch(t + dt, &self.s_zh, &mut self.s_sigma, b);
        } else {
            sde.diffusion_batch(t + dt, &self.s_zh, &mut self.s_sigma, b);
        }
        // z_{n+1} = z + ½ (μ + μ') Δt + ½ (σ + σ') ΔW.
        simd::avg_axpy(&self.mu, &self.s_mu, dtl, &mut self.z);
        if self.diag {
            simd::avg_mul_add(&self.sigma, &self.s_sigma, dw, &mut self.z);
        } else {
            for i in 0..e {
                simd::matvec_row_avg_seeded(
                    &self.sigma[i * d * b..(i + 1) * d * b],
                    &self.s_sigma[i * d * b..(i + 1) * d * b],
                    dw,
                    &mut self.z[i * b..(i + 1) * b],
                    d,
                );
            }
        }
        std::mem::swap(&mut self.zh, &mut self.s_zh);
        std::mem::swap(&mut self.mu, &mut self.s_mu);
        std::mem::swap(&mut self.sigma, &mut self.s_sigma);
    }

    /// Algorithm 2's reverse step per path: reconstruct the state at `t_n`
    /// from the state at `t_{n+1} = t_n + dt` in closed form. `dw` must be
    /// the same increments the forward step consumed.
    pub fn reverse_step<S: BatchSde<T>>(&mut self, sde: &S, t_next: f64, dt: f64, dw: &[T]) {
        let (e, d, b) = (self.dim, self.noise_dim, self.batch);
        let dtl = T::from_f64(dt);
        // ẑ_n = 2 z' − ẑ' − μ' Δt − σ' ΔW.
        simd::leapfrog_sub(&self.z, &self.zh, &self.mu, dtl, &mut self.s_zh);
        if self.diag {
            simd::mul_sub(&self.sigma, dw, &mut self.s_zh);
        } else {
            for i in 0..e {
                simd::matvec_row_sub_seeded(
                    &self.sigma[i * d * b..(i + 1) * d * b],
                    dw,
                    &mut self.s_zh[i * b..(i + 1) * b],
                    d,
                );
            }
        }
        // μ_n, σ_n at t_n = t_next - dt.
        sde.drift_batch(t_next - dt, &self.s_zh, &mut self.s_mu, b);
        if self.diag {
            sde.diffusion_diag_batch(t_next - dt, &self.s_zh, &mut self.s_sigma, b);
        } else {
            sde.diffusion_batch(t_next - dt, &self.s_zh, &mut self.s_sigma, b);
        }
        // z_n = z' − ½ (μ + μ') Δt − ½ (σ + σ') ΔW.
        simd::avg_axpy_sub(&self.mu, &self.s_mu, dtl, &mut self.z);
        if self.diag {
            simd::avg_mul_sub(&self.sigma, &self.s_sigma, dw, &mut self.z);
        } else {
            for i in 0..e {
                simd::matvec_row_avg_sub_seeded(
                    &self.sigma[i * d * b..(i + 1) * d * b],
                    &self.s_sigma[i * d * b..(i + 1) * d * b],
                    dw,
                    &mut self.z[i * b..(i + 1) * b],
                    d,
                );
            }
        }
        std::mem::swap(&mut self.zh, &mut self.s_zh);
        std::mem::swap(&mut self.mu, &mut self.s_mu);
        std::mem::swap(&mut self.sigma, &mut self.s_sigma);
    }
}

impl<T: Lane> BatchStepper for BatchReversibleHeun<T> {
    type Elem = T;

    const FIELD_EVALS_PER_STEP: usize = 1;

    fn for_chunk<S: BatchSde<T>>(sde: &S, t0: f64, y0: &[T], batch: usize) -> Self {
        let e = sde.state_dim();
        let d = sde.brownian_dim();
        assert_eq!(y0.len(), e * batch);
        let diag = sde.diagonal_noise();
        let sig_len = if diag { e * batch } else { e * d * batch };
        let mut mu = vec![T::ZERO; e * batch];
        let mut sigma = vec![T::ZERO; sig_len];
        sde.drift_batch(t0, y0, &mut mu, batch);
        if diag {
            sde.diffusion_diag_batch(t0, y0, &mut sigma, batch);
        } else {
            sde.diffusion_batch(t0, y0, &mut sigma, batch);
        }
        Self {
            dim: e,
            noise_dim: d,
            batch,
            diag,
            z: y0.to_vec(),
            zh: y0.to_vec(),
            s_zh: vec![T::ZERO; e * batch],
            s_mu: vec![T::ZERO; e * batch],
            s_sigma: vec![T::ZERO; sig_len],
            mu,
            sigma,
        }
    }

    /// In-place re-initialisation: same shapes and arithmetic as
    /// [`for_chunk`](BatchStepper::for_chunk) — `z = ẑ = y0`, `μ`/`σ`
    /// evaluated at `(t0, y0)`, auxiliary scratch zeroed — but reusing
    /// every buffer, so a warmed stepper re-initialises at any
    /// equal-or-smaller chunk size without allocating.
    fn reinit<S: BatchSde<T>>(&mut self, sde: &S, t0: f64, y0: &[T], batch: usize) {
        let e = sde.state_dim();
        let d = sde.brownian_dim();
        assert_eq!(y0.len(), e * batch);
        let diag = sde.diagonal_noise();
        let sig_len = if diag { e * batch } else { e * d * batch };
        self.dim = e;
        self.noise_dim = d;
        self.batch = batch;
        self.diag = diag;
        self.z.clear();
        self.z.extend_from_slice(y0);
        self.zh.clear();
        self.zh.extend_from_slice(y0);
        self.mu.clear();
        self.mu.resize(e * batch, T::ZERO);
        self.sigma.clear();
        self.sigma.resize(sig_len, T::ZERO);
        self.s_zh.clear();
        self.s_zh.resize(e * batch, T::ZERO);
        self.s_mu.clear();
        self.s_mu.resize(e * batch, T::ZERO);
        self.s_sigma.clear();
        self.s_sigma.resize(sig_len, T::ZERO);
        sde.drift_batch(t0, y0, &mut self.mu, batch);
        if diag {
            sde.diffusion_diag_batch(t0, y0, &mut self.sigma, batch);
        } else {
            sde.diffusion_batch(t0, y0, &mut self.sigma, batch);
        }
    }

    fn step<S: BatchSde<T>>(
        &mut self,
        sde: &S,
        t: f64,
        dt: f64,
        dw: &[T],
        y: &mut [T],
        batch: usize,
    ) {
        debug_assert_eq!(batch, self.batch);
        self.forward_step(sde, t, dt, dw);
        y.copy_from_slice(&self.z);
    }
}

// ---------------------------------------------------------------------------
// The batched driver
// ---------------------------------------------------------------------------

/// Work-partitioning knobs for [`integrate_batched`]. Neither affects
/// results — only wall-clock time.
///
/// Scheduling is work-stealing on the process-wide persistent executor
/// ([`super::pool`]): each participant starts with a contiguous run of
/// chunks, pops from the front, and — when its run goes dry — steals from
/// the back of the most-loaded peer. Skewed per-chunk costs
/// (state-dependent vector fields, uneven tail chunks, a worker
/// descheduled by the OS) therefore rebalance instead of serialising the
/// pool, and because every chunk's noise and arithmetic depend only on its
/// path indices, results are identical for every schedule the stealing
/// produces.
#[derive(Clone, Copy, Debug)]
pub struct BatchOptions {
    /// Worker threads (1 = run on the caller's thread).
    pub threads: usize,
    /// Paths per chunk; chunks are the unit of work distribution (and of
    /// stealing). `0` means "derive from the batch width and `threads` at
    /// solve time" (see [`BatchOptions::chunk_for`]) — the [`Self::auto`]
    /// default, so small batches don't underfill the pool with one
    /// oversized chunk. Chunking never affects results, only wall-clock.
    pub chunk: usize,
    /// Fault-tolerance knobs for the fallible entry points: non-finite
    /// sweep cadence and the adjoint's reconstruction-drift watchdog. The
    /// defaults keep all guards on; guards never change fault-free results,
    /// only whether faults are detected. See [`GuardConfig`].
    pub guard: GuardConfig,
}

impl Default for BatchOptions {
    fn default() -> Self {
        Self { threads: 1, chunk: 64, guard: GuardConfig::default() }
    }
}

impl BatchOptions {
    /// Use every available core (results are identical regardless), with
    /// the chunk size derived per solve from the batch width
    /// ([`Self::chunk_for`]) instead of the historical hardcoded 64 —
    /// a 128-path training batch on 8 workers now splits into 4-chunk
    /// work units instead of two 64-path slabs that idle most of the pool.
    pub fn auto() -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self { threads, chunk: 0, guard: GuardConfig::default() }
    }

    /// The effective chunk size for a `batch`-path solve: the explicit
    /// `chunk` when nonzero, otherwise roughly four chunks per worker
    /// (stealing slack for skewed chunk costs) capped at the historical 64
    /// and floored at 1. Every solve entry point routes through this, so
    /// the `chunk: 0` sentinel never reaches the chunking arithmetic.
    pub fn chunk_for(&self, batch: usize) -> usize {
        if self.chunk > 0 {
            return self.chunk;
        }
        let parts = self.threads.max(1) * 4;
        ((batch + parts - 1) / parts).clamp(1, 64)
    }
}

/// Map `run` over the chunk indices `0..n_chunks` on up to `threads`
/// work-stealing participants of the process-wide persistent executor
/// ([`super::pool`]), returning the results **keyed by chunk index** — the
/// shared scheduler behind [`integrate_batched`] and
/// [`super::adjoint_solve_batched`]. Already element-type agnostic: the
/// chunk payload is whatever `run` returns, so the same pool fans out `f64`
/// and `f32` solves.
///
/// Each participant starts with a contiguous run of chunks (cache-friendly
/// starts), pops from the front, and — when its run goes dry — steals from
/// the back of the most-loaded peer, so skewed per-chunk costs rebalance
/// instead of serialising the pool. Because the output is keyed by chunk
/// index, the (nondeterministic) schedule cannot affect a deterministic
/// `run`'s results: callers whose chunks depend only on their own index get
/// bit-identical output for every `threads` value. Unlike the pre-PR-10
/// scheduler there is no per-call thread spawn/join: workers are spawned
/// once per process and parked between dispatches.
pub fn map_chunks<R, F>(n_chunks: usize, threads: usize, run: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = threads.max(1).min(n_chunks);
    if threads <= 1 {
        return (0..n_chunks).map(run).collect();
    }
    let mut slots: Vec<Option<R>> = (0..n_chunks).map(|_| None).collect();
    {
        // Shared-pointer shim so concurrent tasks can each fill their own
        // slot. Safety: task `c` writes only `slots[c]`, every index in
        // `0..n_chunks` runs exactly once (the pool's contract), and
        // `run_tasks` returns only after all tasks completed — with the
        // pool mutex providing the happens-before edge for the writes.
        struct SlotsPtr<R>(*mut Option<R>);
        unsafe impl<R: Send> Send for SlotsPtr<R> {}
        unsafe impl<R: Send> Sync for SlotsPtr<R> {}
        let out = SlotsPtr(slots.as_mut_ptr());
        // Propagates a panicking `run` to the caller (after the sibling
        // chunks finish) — raw `map_chunks` keeps the historical panic
        // semantics. The fallible engines route through
        // `map_chunks_isolated`, whose `run` never panics, so this is
        // unreachable from the guarded hot path.
        pool::run_tasks(threads, n_chunks, &|c| {
            let r = run(c);
            unsafe { *out.0.add(c) = Some(r) };
        });
    }
    // Unreachable by construction: every index 0..n_chunks is dispatched
    // exactly once and writes its own slot.
    slots.into_iter().map(|o| o.expect("chunk result missing")).collect()
}

/// A chunk worker panic captured by [`map_chunks_isolated`].
#[derive(Clone, Debug)]
pub struct ChunkPanic {
    /// Chunk index whose `run` panicked.
    pub chunk: usize,
    /// Stringified panic payload.
    pub payload: String,
}

/// [`map_chunks`] with panic isolation: each chunk's `run` executes inside
/// `catch_unwind`, so one poisoned chunk (a panicking vector field, a
/// corrupted noise source) yields an `Err(ChunkPanic)` in its slot instead
/// of tearing down the whole pool — every other chunk still completes and
/// returns its result. Scheduling, keying, and determinism guarantees are
/// exactly [`map_chunks`]'s.
///
/// The default panic hook still prints to stderr when a chunk panics;
/// callers that expect panics (fault-injection tests) should install a
/// silent hook around the call.
pub fn map_chunks_isolated<R, F>(
    n_chunks: usize,
    threads: usize,
    run: F,
) -> Vec<Result<R, ChunkPanic>>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    map_chunks(n_chunks, threads, |c| {
        catch_unwind(AssertUnwindSafe(|| run(c)))
            .map_err(|e| ChunkPanic { chunk: c, payload: guard::panic_message(e) })
    })
}

/// Integrate `batch` paths of `sde` from the SoA state `y0` over
/// `[t0, t1]` in `n_steps` fixed steps with stepper `M`, fanning fixed-size
/// path chunks across `opts.threads` work-stealing workers. The element
/// type follows the stepper (`M::Elem`): `BatchEulerMaruyama` runs the
/// historical `f64` path, `BatchEulerMaruyama<f32>` the 8-wide `f32` path,
/// and likewise for the other steppers.
///
/// Returns the SoA trajectory `[(n_steps + 1) * dim * batch]`: time point
/// `k`'s state block starts at `k * dim * batch`.
///
/// Determinism: each path's noise comes from [`BatchNoise`] keyed by the
/// path index and each path's arithmetic touches only its own SoA lane, so
/// the result is bit-identical for every `threads`/`chunk` setting — and,
/// at `f64`, bit-identical to `batch` separate [`super::integrate`] runs
/// driven by [`CounterGridNoise::path`] (at `f32`, to `batch` separate
/// single-path batched runs on the same noise).
///
/// Fault handling: the solve is **strict** — any detected fault (a
/// non-finite lane caught by the `opts.guard.check_every` sweeps, or a
/// panicking vector field / noise source) aborts with a [`SolveError`]
/// carrying exact `(step, path, component)` coordinates for every faulted
/// path. Use [`integrate_batched_guarded`] to quarantine faulted lanes and
/// keep the surviving paths instead.
#[allow(clippy::too_many_arguments)] // mirrors the historical positional API
pub fn integrate_batched<M, S, N>(
    sde: &S,
    noise: &N,
    y0: &[M::Elem],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    opts: &BatchOptions,
) -> Result<Vec<M::Elem>, SolveError>
where
    M: BatchStepper,
    S: BatchSde<M::Elem>,
    N: BatchNoise<M::Elem>,
{
    let gs = integrate_batched_guarded::<M, S, N>(sde, noise, y0, batch, t0, t1, n_steps, opts, None)?;
    if gs.faults.is_empty() {
        Ok(gs.traj)
    } else {
        Err(SolveError::new("integrate_batched", gs.faults))
    }
}

/// [`integrate_batched`] with a **quarantine policy**: faulted paths are
/// reported (not fatal) and their lanes replaced, while every surviving
/// path's lane stays bit-identical to an uninjected solve with the same
/// lane assignment — faults never propagate across paths because no stepper
/// mixes lanes (the same isolation the batched ≡ per-path invariant rests
/// on).
///
/// Detection:
/// * non-finite lanes — cheap blockwise sweeps every
///   `opts.guard.check_every` steps mark a chunk dirty; a dirty chunk is
///   re-run (bit-identically) with a per-step sweep to localise each faulted
///   path's first `(step, component)` exactly;
/// * panics — a panicking chunk is re-run path by path under
///   `catch_unwind`, so only the offending path reports a
///   [`FaultCause::VectorFieldPanic`] (with the last-started step) and its
///   chunk-mates complete normally.
///
/// Replacement: `refill(p, lane)` may fill a `[(n_steps + 1) * dim]`
/// single-path trajectory (layout `lane[k * dim + i]`, e.g. a fresh solve
/// from a [`crate::brownian::BrownianInterval::reseed`] seed) and return
/// true; on `None`/false the path's initial state is held constant — a
/// finite, deterministic placeholder. Errors only when *every* path
/// faulted.
#[allow(clippy::too_many_arguments)] // mirrors the historical positional API
pub fn integrate_batched_guarded<M, S, N>(
    sde: &S,
    noise: &N,
    y0: &[M::Elem],
    batch: usize,
    t0: f64,
    t1: f64,
    n_steps: usize,
    opts: &BatchOptions,
    refill: Option<&dyn Fn(usize, &mut [M::Elem]) -> bool>,
) -> Result<GuardedSolve<M::Elem>, SolveError>
where
    M: BatchStepper,
    S: BatchSde<M::Elem>,
    N: BatchNoise<M::Elem>,
{
    let zero = <M::Elem as Lane>::ZERO;
    let dim = sde.state_dim();
    let nd = sde.brownian_dim();
    assert_eq!(y0.len(), dim * batch, "y0 must be SoA [dim * batch]");
    assert_eq!(noise.brownian_dim(), nd, "noise/sde Brownian dimension mismatch");
    assert!(n_steps >= 1 && batch >= 1);
    let chunk = opts.chunk_for(batch);
    let n_chunks = (batch + chunk - 1) / chunk;
    let dt = (t1 - t0) / n_steps as f64;
    // One canonical copy of the guard knobs; all cadence decisions go
    // through its helpers (`GuardConfig::normalised` docs the 0/1/MAX edge
    // semantics both fields share).
    let gcfg = opts.guard.normalised();

    let run_chunk = |c: usize| -> (Vec<M::Elem>, Vec<SolveFault>) {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        // Gather this chunk's SoA lanes.
        let mut y = vec![zero; dim * cl];
        for i in 0..dim {
            for q in 0..cl {
                y[i * cl + q] = y0[i * batch + p0 + q];
            }
        }
        let mut stepper = M::for_chunk(sde, t0, &y, cl);
        let mut dw = vec![zero; nd * cl];
        let mut traj = Vec::with_capacity((n_steps + 1) * dim * cl);
        traj.extend_from_slice(&y);
        let mut dirty = false;
        for k in 0..n_steps {
            // Same grid arithmetic as `integrate`, so per-path time points
            // (and hence field evaluations) are bit-identical.
            let s = t0 + k as f64 * dt;
            let t = t0 + (k + 1) as f64 * dt;
            noise.fill_step(k, s, t, p0, cl, &mut dw);
            stepper.step(sde, s, t - s, &dw, &mut y, cl);
            traj.extend_from_slice(&y);
            // Blockwise sweep at the guard cadence (and at the terminal
            // step, so nothing escapes detection). Detection only — the
            // solve always completes, so surviving lanes are whole.
            if gcfg.sweep_due(k + 1, n_steps) && guard::any_nonfinite(&y) {
                dirty = true;
            }
        }
        if !dirty {
            return (traj, Vec::new());
        }
        // Localise: re-run the chunk (bit-identically — same noise, same
        // arithmetic) with a per-step, per-path sweep to pin each faulted
        // path's first non-finite `(step, component)` exactly. The first
        // pass's trajectory stays valid for surviving lanes.
        let mut y = vec![zero; dim * cl];
        for i in 0..dim {
            for q in 0..cl {
                y[i * cl + q] = y0[i * batch + p0 + q];
            }
        }
        let mut stepper = M::for_chunk(sde, t0, &y, cl);
        let mut firsts: Vec<Option<SolveFault>> = vec![None; cl];
        for k in 0..n_steps {
            let s = t0 + k as f64 * dt;
            let t = t0 + (k + 1) as f64 * dt;
            noise.fill_step(k, s, t, p0, cl, &mut dw);
            stepper.step(sde, s, t - s, &dw, &mut y, cl);
            for (q, slot) in firsts.iter_mut().enumerate() {
                if slot.is_some() {
                    continue;
                }
                for i in 0..dim {
                    if !y[i * cl + q].to_f64().is_finite() {
                        *slot = Some(SolveFault {
                            step: k,
                            path: p0 + q,
                            component: i,
                            cause: FaultCause::NonFinite,
                        });
                        break;
                    }
                }
            }
        }
        (traj, firsts.into_iter().flatten().collect())
    };

    // Single-path fallback for panicked chunks: bit-identical to the lane it
    // replaces (batch = 1 is just the chunk engine at chunk length 1), with
    // a progress marker so a panic reports its last-started step.
    let run_single = |p: usize, progress: &Cell<usize>| -> (Vec<M::Elem>, Option<SolveFault>) {
        let mut y = vec![zero; dim];
        for i in 0..dim {
            y[i] = y0[i * batch + p];
        }
        let mut stepper = M::for_chunk(sde, t0, &y, 1);
        let mut dw = vec![zero; nd];
        let mut traj = Vec::with_capacity((n_steps + 1) * dim);
        traj.extend_from_slice(&y);
        let mut fault = None;
        for k in 0..n_steps {
            progress.set(k);
            let s = t0 + k as f64 * dt;
            let t = t0 + (k + 1) as f64 * dt;
            noise.fill_step(k, s, t, p, 1, &mut dw);
            stepper.step(sde, s, t - s, &dw, &mut y, 1);
            traj.extend_from_slice(&y);
            if fault.is_none() {
                if let Some((i, _)) = guard::first_nonfinite(&y, dim, 1) {
                    fault = Some(SolveFault {
                        step: k,
                        path: p,
                        component: i,
                        cause: FaultCause::NonFinite,
                    });
                }
            }
        }
        (traj, fault)
    };

    let chunk_results = map_chunks_isolated(n_chunks, opts.threads, run_chunk);

    // Scatter chunk lanes back into the full SoA trajectory, collecting
    // faults (and re-running panicked chunks path by path).
    let mut traj = vec![zero; (n_steps + 1) * dim * batch];
    let mut faults = Vec::new();
    let mut quarantined = Vec::new();
    let scatter_lane = |traj: &mut Vec<M::Elem>, p: usize, lane: &[M::Elem]| {
        for k in 0..=n_steps {
            for i in 0..dim {
                traj[k * dim * batch + i * batch + p] = lane[k * dim + i];
            }
        }
    };
    for (c, res) in chunk_results.into_iter().enumerate() {
        let p0 = c * chunk;
        let cl = chunk.min(batch - p0);
        match res {
            Ok((ct, chunk_faults)) => {
                for k in 0..=n_steps {
                    for i in 0..dim {
                        let src = &ct[(k * dim + i) * cl..(k * dim + i) * cl + cl];
                        let base = k * dim * batch + i * batch + p0;
                        traj[base..base + cl].copy_from_slice(src);
                    }
                }
                for f in &chunk_faults {
                    quarantined.push(f.path);
                }
                faults.extend(chunk_faults);
            }
            // The chunk-level payload is superseded by the per-path re-run,
            // which reproduces the panic deterministically with exact
            // coordinates.
            Err(_chunk_panic) => {
                for q in 0..cl {
                    let p = p0 + q;
                    let progress = Cell::new(0usize);
                    match catch_unwind(AssertUnwindSafe(|| run_single(p, &progress))) {
                        Ok((lane, fault)) => {
                            scatter_lane(&mut traj, p, &lane);
                            if let Some(f) = fault {
                                quarantined.push(p);
                                faults.push(f);
                            }
                        }
                        Err(payload) => {
                            quarantined.push(p);
                            faults.push(SolveFault {
                                step: progress.get(),
                                path: p,
                                component: 0,
                                cause: FaultCause::VectorFieldPanic {
                                    payload: guard::panic_message(payload),
                                },
                            });
                        }
                    }
                }
            }
        }
    }

    if !quarantined.is_empty() && quarantined.len() == batch {
        return Err(SolveError::new("integrate_batched_guarded: every path faulted", faults));
    }

    // Replace quarantined lanes: refilled trajectory, or the initial state
    // held constant (finite, deterministic).
    let mut lane = vec![zero; (n_steps + 1) * dim];
    for &p in &quarantined {
        for v in lane.iter_mut() {
            *v = zero;
        }
        let refilled = refill.map(|f| f(p, &mut lane)).unwrap_or(false);
        if !refilled {
            for k in 0..=n_steps {
                for i in 0..dim {
                    lane[k * dim + i] = y0[i * batch + p];
                }
            }
        }
        scatter_lane(&mut traj, p, &lane);
    }

    Ok(GuardedSolve { traj, faults, quarantined })
}

// ---------------------------------------------------------------------------
// Layout helpers
// ---------------------------------------------------------------------------

/// Repack array-of-structures state `[batch][dim]` (path-major, as the
/// per-path API uses) into SoA `[dim * batch]`.
pub fn aos_to_soa<T: Lane>(aos: &[T], dim: usize, batch: usize) -> Vec<T> {
    assert_eq!(aos.len(), dim * batch);
    let mut soa = vec![T::ZERO; dim * batch];
    for p in 0..batch {
        for i in 0..dim {
            soa[i * batch + p] = aos[p * dim + i];
        }
    }
    soa
}

/// The final grid point of a batched trajectory — the SoA `[dim * batch]`
/// slice at `t1` of the `[(n_steps + 1) * dim * batch]` buffer
/// [`integrate_batched`] (and the serving engine) returns. Borrowed, not
/// copied: the Monte-Carlo pricing path reads 10⁶ terminal states through
/// this without an allocation.
pub fn terminal_states<T: Lane>(traj: &[T], dim: usize, batch: usize) -> &[T] {
    let frame = dim * batch;
    assert!(frame > 0, "need dim >= 1 and batch >= 1");
    assert!(
        !traj.is_empty() && traj.len() % frame == 0,
        "trajectory length {} is not a multiple of dim * batch = {}",
        traj.len(),
        frame
    );
    &traj[traj.len() - frame..]
}

/// Inverse of [`aos_to_soa`].
pub fn soa_to_aos<T: Lane>(soa: &[T], dim: usize, batch: usize) -> Vec<T> {
    assert_eq!(soa.len(), dim * batch);
    let mut aos = vec![T::ZERO; dim * batch];
    for p in 0..batch {
        for i in 0..dim {
            aos[p * dim + i] = soa[i * batch + p];
        }
    }
    aos
}

#[cfg(test)]
mod tests {
    use super::super::systems::{Anharmonic, TanhDiagonal};
    use super::super::{integrate, EulerMaruyama, Sde};
    use super::*;

    #[test]
    fn layout_helpers_roundtrip() {
        let aos: Vec<f64> = (0..12).map(|x| x as f64).collect();
        let soa = aos_to_soa(&aos, 3, 4);
        assert_eq!(soa[1], aos[3]); // component 0 of path 1
        assert_eq!(soa_to_aos(&soa, 3, 4), aos);
    }

    #[test]
    fn counter_noise_is_partition_independent() {
        let noise = CounterGridNoise::new(7, 3, 0.0, 1.0, 8);
        // Fill paths 0..10 in one call and in two uneven calls.
        let mut whole = vec![0.0f64; 3 * 10];
        noise.fill_step(2, 0.25, 0.375, 0, 10, &mut whole);
        let mut left = vec![0.0f64; 3 * 4];
        let mut right = vec![0.0f64; 3 * 6];
        noise.fill_step(2, 0.25, 0.375, 0, 4, &mut left);
        noise.fill_step(2, 0.25, 0.375, 4, 6, &mut right);
        for j in 0..3 {
            for q in 0..4 {
                assert_eq!(whole[j * 10 + q], left[j * 4 + q]);
            }
            for q in 0..6 {
                assert_eq!(whole[j * 10 + 4 + q], right[j * 6 + q]);
            }
        }
        // And matches the per-path adapter.
        let mut pn = noise.path(5);
        let mut dw = [0.0f64; 3];
        crate::solvers::NoiseF64::increment(&mut pn, 0.25, 0.375, &mut dw);
        for j in 0..3 {
            assert_eq!(dw[j], whole[j * 10 + 5]);
        }
    }

    #[test]
    fn counter_noise_f32_is_the_rounded_f64_sample() {
        let noise = CounterGridNoise::new(19, 2, 0.0, 1.0, 6);
        let mut w64 = vec![0.0f64; 2 * 5];
        let mut w32 = vec![0.0f32; 2 * 5];
        BatchNoise::<f64>::fill_step(&noise, 3, 0.5, 0.5 + 1.0 / 6.0, 1, 5, &mut w64);
        BatchNoise::<f32>::fill_step(&noise, 3, 0.5, 0.5 + 1.0 / 6.0, 1, 5, &mut w32);
        for (a, b) in w64.iter().zip(&w32) {
            assert_eq!(*a as f32, *b);
        }
        assert_eq!(noise.value_f32(1, 3, 0), noise.value(1, 3, 0) as f32);
    }

    #[test]
    fn map_chunks_keys_results_by_index_for_every_thread_count() {
        let run = |c: usize| c * c + 1;
        let reference: Vec<usize> = (0..13).map(run).collect();
        for threads in [1usize, 2, 3, 8, 32] {
            assert_eq!(map_chunks(13, threads, run), reference, "threads={threads}");
        }
        // Degenerate sizes.
        assert_eq!(map_chunks(0, 4, run), Vec::<usize>::new());
        assert_eq!(map_chunks(1, 4, run), vec![1]);
    }

    #[test]
    fn map_chunks_isolated_contains_a_panicking_chunk() {
        // Silence the default panic hook for the planned panic.
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let out = map_chunks_isolated(5, 2, |c| {
            if c == 3 {
                panic!("chunk {c} poisoned");
            }
            c * 10
        });
        std::panic::set_hook(prev);
        for (c, r) in out.iter().enumerate() {
            match r {
                Ok(v) => {
                    assert_ne!(c, 3);
                    assert_eq!(*v, c * 10);
                }
                Err(p) => {
                    assert_eq!(c, 3);
                    assert_eq!(p.chunk, 3);
                    assert!(p.payload.contains("poisoned"), "{}", p.payload);
                }
            }
        }
    }

    #[test]
    fn map_chunks_supports_nested_submission() {
        // A chunk's `run` may itself fan out (a solve inside a solve);
        // the persistent executor must complete both levels without
        // deadlocking its fixed-size worker set.
        let out = map_chunks(6, 4, |outer| map_chunks(5, 4, move |inner| outer * 100 + inner));
        for (o, row) in out.iter().enumerate() {
            let want: Vec<usize> = (0..5).map(|i| o * 100 + i).collect();
            assert_eq!(*row, want, "outer chunk {o}");
        }
    }

    #[test]
    fn auto_chunk_derivation_is_bounded_and_bit_neutral() {
        // `chunk: 0` derives from batch width and worker count: never 0,
        // never above the historical 64, explicit values untouched.
        let auto = BatchOptions { threads: 4, chunk: 0, ..Default::default() };
        assert_eq!(auto.chunk_for(1), 1);
        assert_eq!(auto.chunk_for(16), 1);
        assert_eq!(auto.chunk_for(128), 8);
        assert_eq!(auto.chunk_for(1 << 20), 64);
        let explicit = BatchOptions { threads: 4, chunk: 7, ..Default::default() };
        assert_eq!(explicit.chunk_for(1 << 20), 7);
        assert_eq!(BatchOptions::auto().chunk, 0, "auto() opts into derivation");

        // Chunking is bit-invariant, so the derived chunk must reproduce
        // the explicit-chunk solve exactly.
        let sde = TanhDiagonal::new(3, 11);
        let batch = 23;
        let n = 10;
        let y0: Vec<f64> = (0..3 * batch).map(|x| 0.01 * x as f64 - 0.2).collect();
        let noise = CounterGridNoise::new(5, 3, 0.0, 1.0, n);
        let solve = |opts: &BatchOptions| {
            integrate_batched::<BatchEulerMaruyama, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, opts,
            )
            .expect("fault-free by construction")
        };
        let reference = solve(&BatchOptions { threads: 1, chunk: 64, ..Default::default() });
        for (threads, chunk) in [(2usize, 0usize), (4, 0), (3, 5)] {
            let opts = BatchOptions { threads, chunk, ..Default::default() };
            assert_eq!(solve(&opts), reference, "threads={threads} chunk={chunk}");
        }
    }

    #[test]
    fn stored_noise_serves_chunks_and_paths_identically() {
        let mut sn = StoredBatchNoise::zeros(0.0, 1.0, 4, 2, 5);
        for k in 0..4 {
            for j in 0..2 {
                for p in 0..5 {
                    sn.set(k, j, p, (100 * k + 10 * j + p) as f64);
                }
            }
        }
        // Chunked fill matches direct reads.
        let mut out = vec![0.0; 2 * 3];
        sn.fill_step(2, 0.5, 0.75, 1, 3, &mut out);
        for j in 0..2 {
            for q in 0..3 {
                assert_eq!(out[j * 3 + q], sn.get(2, j, 1 + q));
            }
        }
        // Per-path view serves steps in any order (the adjoint pattern).
        let mut pn = sn.path(4);
        let mut dw = [0.0f64; 2];
        for &k in &[3usize, 0, 2, 1] {
            let (s, t) = (0.25 * k as f64, 0.25 * (k + 1) as f64);
            crate::solvers::NoiseF64::increment(&mut pn, s, t, &mut dw);
            assert_eq!(dw, [sn.get(k, 0, 4), sn.get(k, 1, 4)]);
        }
    }

    #[test]
    fn stored_noise_from_f32_grid_both_precisions() {
        // [k][p][j] grid of distinct values.
        let (n, b, w) = (3usize, 4usize, 2usize);
        let grid: Vec<f32> = (0..n * b * w).map(|x| x as f32 * 0.5 - 3.0).collect();
        let s64: StoredBatchNoise<f64> = StoredBatchNoise::from_f32_grid(0.0, 1.0, n, w, b, &grid);
        let s32: StoredBatchNoise<f32> = StoredBatchNoise::from_f32_grid(0.0, 1.0, n, w, b, &grid);
        for k in 0..n {
            for p in 0..b {
                for j in 0..w {
                    let v = grid[(k * b + p) * w + j];
                    assert_eq!(s64.get(k, j, p), v as f64);
                    assert_eq!(s32.get(k, j, p), v);
                }
            }
        }
    }

    #[test]
    fn stored_noise_fill_from_source_matches_per_step_queries() {
        use crate::brownian::BrownianInterval;
        let (n, b, w) = (4usize, 3usize, 2usize);
        let mut sn: StoredBatchNoise<f32> = StoredBatchNoise::zeros(0.0, 1.0, n, w, b);
        let mut scratch = Vec::new();
        let mut src = BrownianInterval::new(0.0, 1.0, b * w, 11);
        sn.fill_from_source(&mut src, &mut scratch);
        // Per-step queries of a fresh, same-seed source give the same bits.
        let mut fresh = BrownianInterval::new(0.0, 1.0, b * w, 11);
        let mut step = vec![0.0f32; b * w];
        for k in 0..n {
            fresh.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, &mut step);
            for p in 0..b {
                for j in 0..w {
                    assert_eq!(sn.get(k, j, p), step[p * w + j], "k={k} p={p} j={j}");
                }
            }
        }
    }

    #[test]
    fn adapter_reports_diagonality() {
        let diag = TanhDiagonal::new(4, 1);
        assert!(BatchSde::diagonal_noise(&diag));
        let scalar = Anharmonic { sigma: 1.0 };
        assert!(BatchSde::diagonal_noise(&scalar));
    }

    #[test]
    fn batched_euler_matches_per_path_small() {
        let sde = TanhDiagonal::new(3, 11);
        let batch = 5;
        let n = 12;
        let aos: Vec<f64> = (0..batch * 3).map(|x| 0.02 * x as f64 - 0.1).collect();
        let y0 = aos_to_soa(&aos, 3, batch);
        let noise = CounterGridNoise::new(21, 3, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 2, ..Default::default() };
        let traj = integrate_batched::<BatchEulerMaruyama, _, _>(
            &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        for p in 0..batch {
            let mut pn = noise.path(p);
            let mut solver = EulerMaruyama::new(Sde::dim(&sde), Sde::noise_dim(&sde));
            let y0p = &aos[p * 3..(p + 1) * 3];
            let tp = integrate(&sde, &mut solver, &mut pn, y0p, 0.0, 1.0, n);
            for k in 0..=n {
                for i in 0..3 {
                    assert_eq!(
                        traj[k * 3 * batch + i * batch + p],
                        tp[k * 3 + i],
                        "path {p} step {k} component {i}"
                    );
                }
            }
        }
    }
}
