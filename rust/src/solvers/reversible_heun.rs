//! The reversible Heun method (paper Section 3, Algorithms 1 and 2).
//!
//! State is the 4-tuple `(z, ẑ, μ, σ)`; a step costs a **single** evaluation
//! of each vector field (half the cost of midpoint/Heun), and the update is
//! *algebraically invertible*: [`ReversibleHeun::reverse_step`] reconstructs
//! the previous state from the next one in closed form. That reversibility
//! is what makes the continuous-adjoint gradients exactly equal to the
//! discretise-then-optimise gradients of the forward pass (the paper's
//! headline Figure 2) — the native adjoint engine ([`super::adjoint`])
//! drives `reverse_step` in lockstep with its cotangent recursion, and
//! `examples/gradient_error.rs` reproduces the machine-precision claim on
//! it end to end.

use super::{apply_diffusion, FixedStepSolver, Sde};

/// Full reversible-Heun solver state `(z, ẑ, μ, σ)`.
#[derive(Clone, Debug, PartialEq)]
pub struct RevHeunState {
    /// The solution estimate `z_n ≈ Z_{t_n}`.
    pub z: Vec<f64>,
    /// The auxiliary estimate `ẑ_n` (propagated by a leapfrog/midpoint rule).
    pub zh: Vec<f64>,
    /// Cached drift evaluation `μ_n = μ(t_n, ẑ_n)`.
    pub mu: Vec<f64>,
    /// Cached diffusion evaluation `σ_n = σ(t_n, ẑ_n)` (row-major `e×d`).
    pub sigma: Vec<f64>,
}

impl RevHeunState {
    /// Initial state per Algorithm 1: `z_0 = ẑ_0 = y0`, `μ_0 = μ(t0, y0)`,
    /// `σ_0 = σ(t0, y0)`.
    pub fn init<S: Sde>(sde: &S, t0: f64, y0: &[f64]) -> Self {
        let mut mu = vec![0.0; sde.dim()];
        let mut sigma = vec![0.0; sde.dim() * sde.noise_dim()];
        sde.drift(t0, y0, &mut mu);
        sde.diffusion(t0, y0, &mut sigma);
        Self { z: y0.to_vec(), zh: y0.to_vec(), mu, sigma }
    }

    /// Max-abs difference to another state (for reversibility tests).
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        let d = |a: &[f64], b: &[f64]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max)
        };
        d(&self.z, &other.z)
            .max(d(&self.zh, &other.zh))
            .max(d(&self.mu, &other.mu))
            .max(d(&self.sigma, &other.sigma))
    }
}

/// The reversible Heun stepper.
pub struct ReversibleHeun {
    state: RevHeunState,
    scratch_zh: Vec<f64>,
    scratch_mu: Vec<f64>,
    scratch_sigma: Vec<f64>,
}

impl ReversibleHeun {
    /// Initialise at `(t0, y0)`.
    pub fn new<S: Sde>(sde: &S, t0: f64, y0: &[f64]) -> Self {
        let state = RevHeunState::init(sde, t0, y0);
        let e = sde.dim();
        let d = sde.noise_dim();
        Self {
            state,
            scratch_zh: vec![0.0; e],
            scratch_mu: vec![0.0; e],
            scratch_sigma: vec![0.0; e * d],
        }
    }

    /// Borrow the current full state.
    pub fn state(&self) -> &RevHeunState {
        &self.state
    }

    /// Replace the full state (used when starting a backward pass from the
    /// retained terminal state).
    pub fn set_state(&mut self, state: RevHeunState) {
        self.state = state;
    }

    /// Re-initialise the `(z, ẑ, μ, σ)` state at `(t, y)`.
    ///
    /// [`FixedStepSolver::step`] trusts the internal state to track the
    /// driver's `y` (which holds whenever `y` is only advanced through
    /// `step` from the `y0` this solver was constructed with). A driver
    /// that mutates `y` externally must call `resync` before stepping
    /// again — the old implicit `state.z != y` detection cost an O(dim)
    /// vector compare on every step of the hot loop.
    pub fn resync<S: Sde>(&mut self, sde: &S, t: f64, y: &[f64]) {
        self.state = RevHeunState::init(sde, t, y);
    }

    /// Algorithm 1: advance `(z, ẑ, μ, σ)` from `t_n` to `t_{n+1}`.
    ///
    /// ```text
    /// ẑ' = 2 z − ẑ + μ Δt + σ ΔW
    /// μ' = μ(t', ẑ'),  σ' = σ(t', ẑ')
    /// z' = z + ½ (μ + μ') Δt + ½ (σ + σ') ΔW
    /// ```
    pub fn forward_step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64]) {
        let st = &mut self.state;
        let e = st.z.len();
        // ẑ_{n+1}
        for i in 0..e {
            self.scratch_zh[i] = 2.0 * st.z[i] - st.zh[i] + st.mu[i] * dt;
        }
        apply_diffusion(&st.sigma, dw, &mut self.scratch_zh);
        // μ_{n+1}, σ_{n+1}
        sde.drift(t + dt, &self.scratch_zh, &mut self.scratch_mu);
        sde.diffusion(t + dt, &self.scratch_zh, &mut self.scratch_sigma);
        // z_{n+1}
        let d = dw.len();
        for i in 0..e {
            let mut acc = st.z[i] + 0.5 * (st.mu[i] + self.scratch_mu[i]) * dt;
            for j in 0..d {
                acc += 0.5 * (st.sigma[i * d + j] + self.scratch_sigma[i * d + j]) * dw[j];
            }
            st.z[i] = acc;
        }
        std::mem::swap(&mut st.zh, &mut self.scratch_zh);
        std::mem::swap(&mut st.mu, &mut self.scratch_mu);
        std::mem::swap(&mut st.sigma, &mut self.scratch_sigma);
    }

    /// Algorithm 2's "reverse step": reconstruct the state at `t_n` from the
    /// state at `t_{n+1} = t_n + dt`, in closed form:
    ///
    /// ```text
    /// ẑ  = 2 z' − ẑ' − μ' Δt − σ' ΔW
    /// μ  = μ(t, ẑ),  σ = σ(t, ẑ)
    /// z  = z' − ½ (μ + μ') Δt − ½ (σ + σ') ΔW
    /// ```
    ///
    /// `dw` must be the same Brownian increment used by the forward step —
    /// supplied by the deterministic Brownian Interval.
    pub fn reverse_step<S: Sde>(&mut self, sde: &S, t_next: f64, dt: f64, dw: &[f64]) {
        let st = &mut self.state;
        let e = st.z.len();
        let d = dw.len();
        // ẑ_n
        for i in 0..e {
            let mut acc = 2.0 * st.z[i] - st.zh[i] - st.mu[i] * dt;
            for j in 0..d {
                acc -= st.sigma[i * d + j] * dw[j];
            }
            self.scratch_zh[i] = acc;
        }
        // μ_n, σ_n at t_n = t_next - dt.
        sde.drift(t_next - dt, &self.scratch_zh, &mut self.scratch_mu);
        sde.diffusion(t_next - dt, &self.scratch_zh, &mut self.scratch_sigma);
        // z_n
        for i in 0..e {
            let mut acc = st.z[i] - 0.5 * (st.mu[i] + self.scratch_mu[i]) * dt;
            for j in 0..d {
                acc -= 0.5 * (st.sigma[i * d + j] + self.scratch_sigma[i * d + j]) * dw[j];
            }
            st.z[i] = acc;
        }
        std::mem::swap(&mut st.zh, &mut self.scratch_zh);
        std::mem::swap(&mut st.mu, &mut self.scratch_mu);
        std::mem::swap(&mut st.sigma, &mut self.scratch_sigma);
    }
}

impl FixedStepSolver for ReversibleHeun {
    const FIELD_EVALS_PER_STEP: usize = 1;

    fn step<S: Sde>(&mut self, sde: &S, t: f64, dt: f64, dw: &[f64], y: &mut [f64]) {
        // The state is authoritative: `new`/`resync`/`set_state` establish
        // it and each step advances it, so the driver loop pays no per-step
        // O(dim) comparison. Callers that mutate `y` between steps must
        // `resync` (see that method's docs).
        self.forward_step(sde, t, dt, dw);
        y.copy_from_slice(&self.state.z);
    }
}

#[cfg(test)]
mod tests {
    use super::super::systems::{Anharmonic, ScalarLinear, TanhDiagonal};
    use super::super::{integrate, FineBrownianGrid, NoiseF64};
    use super::*;

    #[test]
    fn ode_accuracy_second_order() {
        // With σ = 0 the method is a (leapfrog/trapezoidal) second-order ODE
        // integrator: halving dt should cut error ~4x.
        let sde = ScalarLinear { a: 1.0, b: 0.0 };
        let mut err = Vec::new();
        for n in [64usize, 128, 256] {
            let mut solver = ReversibleHeun::new(&sde, 0.0, &[1.0]);
            let mut noise = FineBrownianGrid::new(1, 1024, 1.0, 1);
            let traj = integrate(&sde, &mut solver, &mut noise, &[1.0], 0.0, 1.0, n);
            err.push((traj[traj.len() - 1] - 1.0f64.exp()).abs());
        }
        assert!(err[0] / err[1] > 3.0, "ratios: {err:?}");
        assert!(err[1] / err[2] > 3.0, "ratios: {err:?}");
    }

    #[test]
    fn algebraic_reversibility_bit_tight() {
        // Forward N steps then reverse N steps recovers the initial state to
        // floating-point roundoff — the property gradient exactness rests on.
        let sde = Anharmonic { sigma: 1.0 };
        let n = 200;
        let dt = 1.0 / n as f64;
        let mut noise = FineBrownianGrid::new(1, 4096, 1.0, 33);
        let mut dws = Vec::new();
        let mut dw = [0.0f64];
        let mut solver = ReversibleHeun::new(&sde, 0.0, &[1.0]);
        let init = solver.state().clone();
        for k in 0..n {
            let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
            noise.increment(s, t, &mut dw);
            dws.push(dw[0]);
            solver.forward_step(&sde, s, dt, &dw);
        }
        for k in (0..n).rev() {
            let t_next = (k + 1) as f64 * dt;
            solver.reverse_step(&sde, t_next, dt, &[dws[k]]);
        }
        let diff = solver.state().max_abs_diff(&init);
        assert!(diff < 1e-10, "round-trip error {diff}");
    }

    #[test]
    fn reversibility_multidimensional() {
        let sde = TanhDiagonal::new(10, 99);
        let n = 100;
        let dt = 1.0 / n as f64;
        let y0 = vec![0.1; 10];
        let mut solver = ReversibleHeun::new(&sde, 0.0, &y0);
        let init = solver.state().clone();
        let mut noise = FineBrownianGrid::new(10, 2048, 1.0, 5);
        let mut dws = vec![vec![0.0f64; 10]; n];
        for k in 0..n {
            let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
            noise.increment(s, t, &mut dws[k]);
            solver.forward_step(&sde, s, dt, &dws[k]);
        }
        for k in (0..n).rev() {
            solver.reverse_step(&sde, (k + 1) as f64 * dt, dt, &dws[k]);
        }
        assert!(solver.state().max_abs_diff(&init) < 1e-9);
    }

    #[test]
    fn matches_heun_to_leading_order_on_sde() {
        let sde = ScalarLinear { a: 0.3, b: 0.5 };
        let n = 1024;
        let mut noise1 = FineBrownianGrid::new(1, 4096, 1.0, 21);
        let mut noise2 = FineBrownianGrid::new(1, 4096, 1.0, 21);
        let mut rh = ReversibleHeun::new(&sde, 0.0, &[1.0]);
        let t1 = integrate(&sde, &mut rh, &mut noise1, &[1.0], 0.0, 1.0, n);
        let mut h = super::super::Heun::new(1, 1);
        let t2 = integrate(&sde, &mut h, &mut noise2, &[1.0], 0.0, 1.0, n);
        let (a, b) = (t1[t1.len() - 1], t2[t2.len() - 1]);
        assert!((a - b).abs() < 1e-2, "revheun {a} vs heun {b}");
    }

    #[test]
    fn resync_restarts_from_external_state() {
        // After the driver mutates y, resync must behave like a fresh solver.
        let sde = Anharmonic { sigma: 0.5 };
        let mut a = ReversibleHeun::new(&sde, 0.0, &[1.0]);
        let dw = [0.02f64];
        a.forward_step(&sde, 0.0, 0.1, &dw);
        // Driver jumps to a new state externally:
        a.resync(&sde, 0.0, &[2.0]);
        let mut fresh = ReversibleHeun::new(&sde, 0.0, &[2.0]);
        a.forward_step(&sde, 0.0, 0.1, &dw);
        fresh.forward_step(&sde, 0.0, 0.1, &dw);
        assert_eq!(a.state().max_abs_diff(fresh.state()), 0.0);
    }

    #[test]
    fn z_and_zh_stay_close() {
        // Theorem D.6: E||Y_n - Z_n||_4 = O(sqrt(h)).
        let sde = Anharmonic { sigma: 0.5 };
        let n = 512;
        let dt = 1.0 / n as f64;
        let mut solver = ReversibleHeun::new(&sde, 0.0, &[1.0]);
        let mut noise = FineBrownianGrid::new(1, 4096, 1.0, 8);
        let mut dw = [0.0f64];
        let mut max_gap = 0.0f64;
        for k in 0..n {
            let (s, t) = (k as f64 * dt, (k + 1) as f64 * dt);
            noise.increment(s, t, &mut dw);
            solver.forward_step(&sde, s, dt, &dw);
            let st = solver.state();
            max_gap = max_gap.max((st.z[0] - st.zh[0]).abs());
        }
        assert!(max_gap < 0.5, "z and ẑ diverged: {max_gap}");
    }
}
