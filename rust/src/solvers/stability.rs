//! Absolute stability of the reversible Heun method in the ODE setting
//! (Appendix D.5).
//!
//! Theorem D.19: applied to the linear test equation `y' = λy` with
//! `Re(λ) ≤ 0`, the iterates `{Y_n, Z_n}` are bounded **iff** `λh ∈ [-i, i]`
//! — the same region as the (reversible) asynchronous leapfrog integrator
//! of Zhuang et al. (2021). [`revheun_stability_bounded`] checks
//! boundedness empirically for a given `λh`; tests map the region.

/// Minimal complex arithmetic (kept local — no external deps).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Complex {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl Complex {
    /// Construct from parts.
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// Modulus.
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

impl std::ops::Add for Complex {
    type Output = Complex;
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

impl std::ops::Sub for Complex {
    type Output = Complex;
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

impl std::ops::Mul for Complex {
    type Output = Complex;
    fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }
}

impl std::ops::Mul<f64> for Complex {
    type Output = Complex;
    fn mul(self, s: f64) -> Complex {
        Complex::new(self.re * s, self.im * s)
    }
}

/// Run the reversible Heun method on `y' = λy` for `n_steps` with the given
/// `λh`, reporting whether `max(|Y_n|, |Z_n|)` stayed below `bound`.
///
/// Per Theorem D.19 this returns `true` iff `λh` lies on the imaginary
/// segment `[-i, i]` (up to the finite horizon and tolerance of the check).
pub fn revheun_stability_bounded(lambda_h: Complex, n_steps: usize, bound: f64) -> bool {
    // Reversible Heun on an autonomous linear ODE, dt absorbed into λh:
    //   ẑ' = 2z − ẑ + λh ẑ
    //   z' = z + ½ λh (ẑ + ẑ')
    let mut z = Complex::new(1.0, 0.0);
    let mut zh = Complex::new(1.0, 0.0);
    for _ in 0..n_steps {
        let zh_next = z * 2.0 - zh + lambda_h * zh;
        let z_next = z + lambda_h * (zh + zh_next) * 0.5;
        z = z_next;
        zh = zh_next;
        if z.abs() > bound || zh.abs() > bound {
            return false;
        }
        if !z.re.is_finite() || !zh.re.is_finite() {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 20_000;
    const BOUND: f64 = 1e4;

    #[test]
    fn stable_on_imaginary_segment() {
        for im in [0.0, 0.1, 0.5, 0.9, 0.99] {
            assert!(
                revheun_stability_bounded(Complex::new(0.0, im), N, BOUND),
                "λh = {im}i should be stable"
            );
            assert!(
                revheun_stability_bounded(Complex::new(0.0, -im), N, BOUND),
                "λh = -{im}i should be stable"
            );
        }
    }

    #[test]
    fn unstable_beyond_unit_imaginary() {
        for im in [1.05, 1.5, 2.0] {
            assert!(
                !revheun_stability_bounded(Complex::new(0.0, im), N, BOUND),
                "λh = {im}i should be unstable"
            );
        }
    }

    #[test]
    fn unstable_off_axis_negative_real() {
        // Not A-stable (Remark D.20): negative real parts blow up.
        for (re, im) in [(-0.5, 0.0), (-0.2, 0.5), (-1.0, 0.0), (-0.05, 0.9)] {
            assert!(
                !revheun_stability_bounded(Complex::new(re, im), N, BOUND),
                "λh = {re}+{im}i should be unstable"
            );
        }
    }

    #[test]
    fn region_boundary_matches_theorem() {
        // Sweep a grid over [-1.2, 0.2] x [-1.3, 1.3]; the stable set should
        // be exactly the points with |re| ~ 0 and |im| <= 1.
        let mut mismatches = 0;
        for i in 0..25 {
            for j in 0..27 {
                let re = -1.2 + 1.4 * (i as f64) / 24.0;
                let im = -1.3 + 2.6 * (j as f64) / 26.0;
                let expected = re.abs() < 1e-9 && im.abs() <= 1.0 + 1e-9;
                let got = revheun_stability_bounded(Complex::new(re, im), 5_000, BOUND);
                if got != expected {
                    mismatches += 1;
                }
            }
        }
        // Allow a couple of borderline grid points (|λh| = 1 exactly etc.).
        assert!(mismatches <= 3, "{mismatches} grid points disagree with Theorem D.19");
    }
}
