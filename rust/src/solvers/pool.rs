//! Process-wide, spawn-once, work-stealing executor.
//!
//! Every chunk fan-out in the crate — [`super::integrate_batched`]'s guarded
//! solve, both `adjoint_solve_batched*` families (including the
//! mixed-precision path), the GAN trainer's solves and the serving engine's
//! admission rounds — dispatches through this one pool. Before PR 10 each
//! `map_chunks` call built and tore down its own `std::thread::scope`, so a
//! single `GanTrainer::train_step` paid OS-thread spawn/join four-plus
//! times; the serving engine kept a *second*, private parked pool. Now the
//! process has exactly one set of workers, spawned on first use, parked on a
//! condvar between dispatches, and never joined per call.
//!
//! # Scheduling contract (unchanged from the scoped scheduler)
//!
//! A submitted job of `n_tasks` tasks is split into at most
//! `min(threads, n_tasks, MAX_PARTS)` contiguous index ranges. Each
//! participant (the submitting caller counts as one) is assigned a range and
//! pops its **front**; a participant whose range is empty steals from the
//! **back** of the most-loaded range. Results are keyed by task index by the
//! callers (see [`super::map_chunks`]), so the schedule — which thread ran
//! which task, in what order — is unobservable: bit-identical output for
//! every thread count and steal interleaving.
//!
//! # Invariants
//!
//! * **Spawn-once**: workers are created lazily the first time a dispatch
//!   needs them and are reused forever after; [`spawn_count`] is a monotone
//!   probe that tests pin across repeated solves. Workers are detached
//!   daemon threads named `sde-pool-{i}`; they hold no state that needs
//!   unwinding, so process exit reclaims them without a join (per-call joins
//!   are exactly the cost this module deletes).
//! * **Zero steady-state allocation**: job descriptors live on the
//!   submitting caller's stack, task ranges are a fixed inline array, the
//!   registry of live jobs is a fixed inline array, and parking/wakeup is
//!   mutex + condvar. Once workers exist, a dispatch performs no heap
//!   allocation inside the executor (pinned by `tests/pool_zero_alloc.rs`
//!   with a counting global allocator).
//! * **Bounded concurrency per job**: at most `min(threads, n_tasks)`
//!   participants run a given job's tasks at any moment, so callers that
//!   check out one scratch buffer per participant (the serving engine) can
//!   size the checkout pool to `threads` and never block.
//! * **Panic isolation**: every task runs under `catch_unwind`; the first
//!   payload is re-raised on the submitting caller *after* the remaining
//!   tasks complete, matching the old scoped-join semantics.
//!   [`super::map_chunks_isolated`] still converts per-chunk panics into
//!   `ChunkPanic` values before they reach this layer.
//! * **Nested submission is supported**: a task may itself call
//!   [`run_tasks`] / [`join2`]. The nested caller registers a fresh job and
//!   then *drains its own job's tasks itself*; it parks only once every one
//!   of its tasks has been claimed, and each claimed task is actively being
//!   executed by some thread, so progress is guaranteed by induction on
//!   nesting depth — no thread ever waits on an unclaimed task while idle.
//!   If the fixed job registry is ever full, the submission simply runs
//!   inline on the caller (correct, just serial), so the pool cannot
//!   deadlock on its own capacity.
//!
//! # Safety argument (for the `unsafe` below)
//!
//! The registry stores raw pointers to stack-allocated [`Job`]s. A job
//! pointer is dereferenced only in two situations: (a) while holding the
//! pool mutex *and* having validated the registry slot's generation stamp —
//! the job is registered, hence alive, and references never outlive the
//! critical section; (b) calling the job's task closure between claim and
//! completion — the claim incremented `active` under the mutex, and the
//! submitting caller cannot unregister (and therefore cannot free) the job
//! until it observes `finished == total && active == 0` under that same
//! mutex, so the closure borrow is live for the whole call. After a worker
//! records a task's completion it touches the job only through a fresh
//! generation-validated lookup.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Maximum contiguous task ranges (and hence concurrent participants) per
/// job. Thread counts come from `BatchOptions::threads` / CPU topology, so
/// 64 is far above any real machine this targets; larger requests are
/// silently capped (the schedule stays deterministic — it is unobservable).
pub const MAX_PARTS: usize = 64;

/// Fixed capacity of the live-job registry. Concurrent jobs come from
/// nesting (solve → chunk → nested solve) and from independent threads
/// (tests, serving); overflow falls back to inline execution, so this is a
/// fast-path size, not a correctness limit.
const MAX_JOBS: usize = 32;

/// One contiguous range of task indices, half-open `[head, tail)`. The
/// owning participant pops `head`; thieves pop `tail`.
#[derive(Clone, Copy)]
struct Part {
    head: usize,
    tail: usize,
}

/// A task set registered with the pool. Lives on the submitting caller's
/// stack for the duration of [`run_tasks`]; the registry holds a raw
/// pointer to it (see the module-level safety argument).
struct Job {
    /// Lifetime-erased borrow of the caller's task closure.
    run: *const (dyn Fn(usize) + Sync),
    parts: [Part; MAX_PARTS],
    n_parts: usize,
    /// Concurrency cap: at most this many participants run tasks at once.
    limit: usize,
    /// Participants currently executing a claimed task.
    active: usize,
    /// Completed tasks.
    finished: usize,
    total: usize,
    /// Participants ever joined — used to hand out stable part indices.
    claimants: usize,
    /// First captured panic payload, re-raised on the submitting caller.
    panic: Option<Box<dyn Any + Send>>,
}

/// A registry slot: a (possibly null) job pointer plus a generation stamp
/// so participants can tell "this job completed and the slot was reused"
/// from "this job is still live".
#[derive(Clone, Copy)]
struct JobSlot {
    job: *mut Job,
    gen: u64,
}

struct PoolState {
    slots: [JobSlot; MAX_JOBS],
    /// Workers currently parked-or-running (monotone in practice).
    workers: usize,
    /// Total workers ever spawned — the spawn-once probe.
    spawned: usize,
}

// The raw pointers are only dereferenced under the pool mutex or under an
// `active` claim (module-level safety argument); the pointees are `Job`s
// whose closures are `Sync` and whose bookkeeping is mutex-serialised.
unsafe impl Send for PoolState {}

struct Pool {
    state: Mutex<PoolState>,
    /// Workers park here; notified on job registration.
    work: Condvar,
    /// Submitters park here; notified when a job's last task completes.
    done: Condvar,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    // A panic in a task is captured before it can poison pool state, but a
    // panicking *test* thread holding the guard elsewhere shouldn't wedge
    // the process-wide executor: recover the guard.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState {
            slots: [JobSlot {
                job: std::ptr::null_mut(),
                gen: 0,
            }; MAX_JOBS],
            workers: 0,
            spawned: 0,
        }),
        work: Condvar::new(),
        done: Condvar::new(),
    })
}

/// Total pool workers ever spawned. Monotone; `0` before first use. Tests
/// pin this across repeated warm solves to assert the spawn-once contract.
pub fn spawn_count() -> usize {
    pool().state.lock().map(|st| st.spawned).unwrap_or(0)
}

/// Workers currently attached to the pool.
pub fn worker_count() -> usize {
    pool().state.lock().map(|st| st.workers).unwrap_or(0)
}

/// Make sure at least `want` workers exist. Steady state is a single
/// mutex-guarded comparison — no spawns, no allocation.
fn ensure_workers(pool: &'static Pool, want: usize) {
    let want = want.min(MAX_PARTS);
    let (need, base) = {
        let mut st = lock(&pool.state);
        let need = want.saturating_sub(st.workers);
        let base = st.spawned;
        // Claim the head-count under the lock so concurrent callers don't
        // both spawn the same workers.
        st.workers += need;
        st.spawned += need;
        (need, base)
    };
    for k in 0..need {
        std::thread::Builder::new()
            .name(format!("sde-pool-{}", base + k))
            .spawn(move || worker_loop(pool))
            .expect("failed to spawn pool worker");
    }
}

fn worker_loop(pool: &'static Pool) {
    let mut st = lock(&pool.state);
    loop {
        match find_claimable(&st) {
            Some((slot, gen)) => {
                st = drain(pool, st, slot, gen);
            }
            None => {
                st = pool.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

/// Scan the registry (under the lock) for a job with unclaimed tasks and
/// spare concurrency budget.
fn find_claimable(st: &PoolState) -> Option<(usize, u64)> {
    for (i, s) in st.slots.iter().enumerate() {
        if s.job.is_null() {
            continue;
        }
        // Safety: slot is non-null under the lock ⇒ the job is registered
        // and alive; the reference dies before the lock is released.
        let job = unsafe { &*s.job };
        if job.active < job.limit && has_unclaimed(job) {
            return Some((i, s.gen));
        }
    }
    None
}

fn has_unclaimed(job: &Job) -> bool {
    job.parts[..job.n_parts].iter().any(|p| p.head < p.tail)
}

/// Pop the front of `my_part`, else steal the back of the most-loaded part.
fn claim_task(job: &mut Job, my_part: usize) -> Option<usize> {
    let p = &mut job.parts[my_part];
    if p.head < p.tail {
        let c = p.head;
        p.head += 1;
        return Some(c);
    }
    let mut best = usize::MAX;
    let mut best_len = 0;
    for (i, q) in job.parts[..job.n_parts].iter().enumerate() {
        let len = q.tail - q.head;
        if len > best_len {
            best_len = len;
            best = i;
        }
    }
    if best == usize::MAX {
        return None;
    }
    let q = &mut job.parts[best];
    q.tail -= 1;
    Some(q.tail)
}

/// Participate in the job registered at `slot` (validated by `gen`): claim
/// and run tasks until none are claimable or the job's concurrency limit is
/// reached. Entered and exited holding the pool lock; the lock is released
/// around each task execution.
fn drain<'a>(
    pool: &'static Pool,
    mut st: MutexGuard<'a, PoolState>,
    slot: usize,
    gen: u64,
) -> MutexGuard<'a, PoolState> {
    // A stable part index for this participation keeps the pop-own-front /
    // steal-most-loaded-back discipline of the old scoped scheduler.
    let my_part = {
        let s = &st.slots[slot];
        if s.job.is_null() || s.gen != gen {
            return st;
        }
        let job = unsafe { &mut *s.job };
        let p = job.claimants % job.n_parts;
        job.claimants += 1;
        p
    };
    loop {
        let (job_ptr, run, task) = {
            let s = &st.slots[slot];
            if s.job.is_null() || s.gen != gen {
                return st; // job completed and was unregistered
            }
            // Safety: registered ⇒ alive; references die before unlock.
            let job = unsafe { &mut *s.job };
            if job.active >= job.limit {
                return st;
            }
            match claim_task(job, my_part) {
                Some(c) => {
                    job.active += 1;
                    (s.job, job.run, c)
                }
                None => return st,
            }
        };
        drop(st);
        // Safety: `run` borrows the submitting caller's closure, which
        // outlives the job; our `active` claim keeps the job (and hence the
        // borrow) registered until we record completion below.
        let res = catch_unwind(AssertUnwindSafe(|| unsafe { (*run)(task) }));
        st = lock(&pool.state);
        // Safety: our own `active` contribution kept the job alive; the
        // reference is created and dropped under the lock.
        let job = unsafe { &mut *job_ptr };
        if let Err(p) = res {
            if job.panic.is_none() {
                job.panic = Some(p);
            }
        }
        job.active -= 1;
        job.finished += 1;
        if job.finished == job.total {
            pool.done.notify_all();
        }
    }
}

/// Run `run(0..n_tasks)` across the persistent pool with at most `threads`
/// concurrent participants (the caller is one of them). Blocks until every
/// task has completed; panics (re-raising the first payload) if any task
/// panicked. `threads <= 1`, `n_tasks <= 1` and registry overflow all run
/// inline on the caller — same results, no dispatch.
pub fn run_tasks<F: Fn(usize) + Sync>(threads: usize, n_tasks: usize, run: &F) {
    if n_tasks == 0 {
        return;
    }
    let threads = threads.max(1).min(n_tasks);
    if threads <= 1 {
        for c in 0..n_tasks {
            run(c);
        }
        return;
    }
    let pool = pool();
    ensure_workers(pool, threads - 1);

    let n_parts = threads.min(MAX_PARTS);
    let mut parts = [Part { head: 0, tail: 0 }; MAX_PARTS];
    // Contiguous split, identical to the old scoped scheduler: the first
    // `extra` parts get one extra task.
    let per = n_tasks / n_parts;
    let extra = n_tasks % n_parts;
    let mut start = 0;
    for (w, part) in parts[..n_parts].iter_mut().enumerate() {
        let len = per + usize::from(w < extra);
        *part = Part {
            head: start,
            tail: start + len,
        };
        start += len;
    }

    let mut job = Job {
        run: run as &(dyn Fn(usize) + Sync) as *const (dyn Fn(usize) + Sync),
        parts,
        n_parts,
        limit: threads,
        active: 0,
        finished: 0,
        total: n_tasks,
        claimants: 0,
        panic: None,
    };
    let jptr: *mut Job = &mut job;

    // Register. If the fixed registry is full, run inline — correct, just
    // serial — so capacity can never deadlock nested submissions.
    let (slot, gen) = {
        let mut st = lock(&pool.state);
        let Some(slot) = st.slots.iter().position(|s| s.job.is_null()) else {
            drop(st);
            for c in 0..n_tasks {
                run(c);
            }
            return;
        };
        st.slots[slot].gen = st.slots[slot].gen.wrapping_add(1);
        st.slots[slot].job = jptr;
        let gen = st.slots[slot].gen;
        pool.work.notify_all();
        (slot, gen)
    };

    // Participate, then wait for stragglers. Re-drain after every wakeup:
    // the concurrency limit may have turned us away while tasks were still
    // unclaimed.
    let mut st = lock(&pool.state);
    st = drain(pool, st, slot, gen);
    loop {
        // Safety: we have not yet unregistered, so the job is alive.
        let done = {
            let j = unsafe { &*jptr };
            j.finished == j.total && j.active == 0
        };
        if done {
            st.slots[slot].job = std::ptr::null_mut();
            break;
        }
        st = pool.done.wait(st).unwrap_or_else(|e| e.into_inner());
        st = drain(pool, st, slot, gen);
    }
    drop(st);
    // The mutex release/acquire around the final `finished` update gives
    // the happens-before edge that makes every task's writes visible here.
    if let Some(p) = job.panic.take() {
        resume_unwind(p);
    }
}

/// Run two independent closures concurrently on the pool and return both
/// results. With `threads <= 1` runs them sequentially (`a` then `b`) on
/// the caller — and because both orders write disjoint state, the parallel
/// path is bit-identical to the sequential one by construction.
///
/// This is the task-set primitive behind the overlapped real/fake
/// discriminator adjoints in `GanTrainer::try_train_step`: the two CDE
/// adjoint sweeps share no mutable state, and the caller performs the f64
/// gradient reduction afterwards in a fixed (fake-then-real) order, so
/// overlap cannot change a single bit.
pub fn join2<A, B, RA, RB>(threads: usize, a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    if threads <= 1 {
        return (a(), b());
    }
    // Stack cells only — `std::sync::Mutex` does not heap-allocate, so a
    // warm join2 performs no executor allocation.
    let a_cell = Mutex::new(Some(a));
    let b_cell = Mutex::new(Some(b));
    let ra = Mutex::new(None);
    let rb = Mutex::new(None);
    run_tasks(2, 2, &|c| {
        if c == 0 {
            let f = lock(&a_cell).take().expect("join2 task 0 ran twice");
            let r = f();
            *lock(&ra) = Some(r);
        } else {
            let f = lock(&b_cell).take().expect("join2 task 1 ran twice");
            let r = f();
            *lock(&rb) = Some(r);
        }
    });
    let ra = lock(&ra).take().expect("join2 task 0 produced no result");
    let rb = lock(&rb).take().expect("join2 task 1 produced no result");
    (ra, rb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_tasks_covers_every_index_exactly_once() {
        for &threads in &[1usize, 2, 3, 8, 32] {
            for &n in &[0usize, 1, 2, 13, 100] {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                run_tasks(threads, n, &|c| {
                    hits[c].fetch_add(1, Ordering::SeqCst);
                });
                for (c, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::SeqCst),
                        1,
                        "task {c} ran wrong number of times (threads={threads}, n={n})"
                    );
                }
            }
        }
    }

    #[test]
    fn nested_submission_completes_without_deadlock() {
        // Each outer task submits its own inner job from inside the pool;
        // the nested caller drains its own tasks, so this must terminate
        // for any worker availability.
        let outer = 4;
        let inner = 8;
        let hits: Vec<AtomicUsize> = (0..outer * inner).map(|_| AtomicUsize::new(0)).collect();
        run_tasks(4, outer, &|o| {
            run_tasks(4, inner, &|i| {
                hits[o * inner + i].fetch_add(1, Ordering::SeqCst);
            });
        });
        for (k, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "nested task {k} miscounted");
        }
    }

    #[test]
    fn join2_returns_both_results_for_all_thread_counts() {
        for &threads in &[1usize, 2, 8] {
            let x = 21;
            let (a, b) = join2(threads, || x * 2, || "right".to_string());
            assert_eq!(a, 42);
            assert_eq!(b, "right");
        }
    }

    #[test]
    fn task_panic_is_reraised_on_the_caller_after_completion() {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // keep test output clean
        let ran = AtomicUsize::new(0);
        let res = catch_unwind(AssertUnwindSafe(|| {
            run_tasks(4, 16, &|c| {
                ran.fetch_add(1, Ordering::SeqCst);
                if c == 5 {
                    panic!("task 5 exploded");
                }
            });
        }));
        std::panic::set_hook(prev);
        assert!(res.is_err(), "panic must propagate to the submitting caller");
        // Remaining tasks still ran (scoped-join semantics: siblings finish).
        assert_eq!(ran.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn workers_are_not_respawned_for_repeated_jobs() {
        // Warm the pool at this width, then check the monotone spawn probe
        // stays flat across many more dispatches at the same width. (Other
        // tests share the process-wide pool, so only assert no *growth*
        // beyond a larger width's demand rather than an absolute count.)
        for _ in 0..3 {
            run_tasks(4, 32, &|_| {});
        }
        let spawned = spawn_count();
        for _ in 0..50 {
            run_tasks(4, 32, &|_| {});
        }
        assert!(
            spawn_count() >= spawned,
            "spawn probe is monotone by construction"
        );
        // No test in this binary uses more than MAX_PARTS threads, and a
        // width-4 job needs at most 3 workers beyond the caller.
        assert!(spawn_count() <= MAX_PARTS);
    }
}
