//! Test SDE systems used across experiments and benchmarks.

use super::Sde;
use crate::brownian::SplitPrng;

/// Scalar linear Stratonovich SDE `dy = a y dt + b y ∘ dW` with the exact
/// solution `y_t = y_0 exp(a t + b W_t)` — the workhorse for strong-error
/// checks against ground truth.
pub struct ScalarLinear {
    /// Drift coefficient.
    pub a: f64,
    /// Diffusion coefficient.
    pub b: f64,
}

impl Sde for ScalarLinear {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.a * y[0];
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
}

/// The scalar anharmonic oscillator of Appendix D.4, equation (28):
/// `dy = sin(y) dt + σ dW` (additive noise) — the test problem for the
/// Figure-5/6 convergence study (the paper uses σ = 1, y₀ = 1, T = 1).
pub struct Anharmonic {
    /// Noise level (paper: 1.0).
    pub sigma: f64,
}

impl Sde for Anharmonic {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = y[0].sin();
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
}

/// The Table-10 benchmark SDE (Appendix F.6): Itô with diagonal noise,
///
/// ```text
/// dX^i = tanh((A X)^i) dt + tanh((B X)^i) dW^i
/// ```
///
/// with random matrices `A, B ∈ R^{d×d}`.
pub struct TanhDiagonal {
    d: usize,
    a: Vec<f64>,
    b: Vec<f64>,
    /// Scratch for the matrix–vector products.
    // (interior mutability avoided: scratch allocated per call is fine for a
    // benchmark-workload definition; the solve loop dominates.)
    _priv: (),
}

impl TanhDiagonal {
    /// Random system of dimension `d` (entries `N(0, 1/d)`).
    pub fn new(d: usize, seed: u64) -> Self {
        let mut rng = SplitPrng::new(seed);
        let scale = 1.0 / (d as f64).sqrt();
        let mut mk = |n: usize| -> Vec<f64> {
            (0..n)
                .map(|_| {
                    let (a, _) = rng.next_normal_pair();
                    a * scale
                })
                .collect()
        };
        let a = mk(d * d);
        let b = mk(d * d);
        Self { d, a, b, _priv: () }
    }

    fn matvec(m: &[f64], x: &[f64], out: &mut [f64]) {
        let d = x.len();
        for i in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                acc += m[i * d + j] * x[j];
            }
            out[i] = acc;
        }
    }
}

impl Sde for TanhDiagonal {
    fn dim(&self) -> usize {
        self.d
    }
    fn noise_dim(&self) -> usize {
        self.d
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        Self::matvec(&self.a, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // Diagonal: out is d x d, zero off-diagonal.
        let d = self.d;
        let mut diag = vec![0.0; d];
        Self::matvec(&self.b, y, &mut diag);
        out.fill(0.0);
        for i in 0..d {
            out[i * d + i] = diag[i].tanh();
        }
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // The batched fast path: the diagonal only, straight into `out` —
        // no d×d zero-fill, no per-call scratch allocation.
        Self::matvec(&self.b, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
}

/// The time-dependent Ornstein–Uhlenbeck process of Appendix F.7:
/// `dY = (ρ t − κ Y) dt + χ dW` (the SDE-GAN training dataset).
pub struct TimeDependentOu {
    /// Linear-in-time drift coefficient (paper: 0.02).
    pub rho: f64,
    /// Mean reversion (paper: 0.1).
    pub kappa: f64,
    /// Noise level (paper: 0.4).
    pub chi: f64,
}

impl Default for TimeDependentOu {
    fn default() -> Self {
        Self { rho: 0.02, kappa: 0.1, chi: 0.4 }
    }
}

impl Sde for TimeDependentOu {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.rho * t - self.kappa * y[0];
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_linear_fields() {
        let sde = ScalarLinear { a: 2.0, b: 3.0 };
        let mut f = [0.0];
        let mut g = [0.0];
        sde.drift(0.0, &[1.5], &mut f);
        sde.diffusion(0.0, &[1.5], &mut g);
        assert_eq!(f[0], 3.0);
        assert_eq!(g[0], 4.5);
    }

    #[test]
    fn tanh_diagonal_diffusion_is_diagonal() {
        let sde = TanhDiagonal::new(4, 1);
        let mut g = vec![0.0; 16];
        sde.diffusion(0.0, &[0.5, -0.5, 1.0, 0.0], &mut g);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(g[i * 4 + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn diffusion_diag_matches_dense_diagonal() {
        let sde = TanhDiagonal::new(5, 3);
        let y: Vec<f64> = (0..5).map(|i| 0.3 * i as f64 - 0.6).collect();
        let mut dense = vec![0.0; 25];
        let mut diag = vec![0.0; 5];
        sde.diffusion(0.0, &y, &mut dense);
        sde.diffusion_diag(0.0, &y, &mut diag);
        for i in 0..5 {
            assert_eq!(dense[i * 5 + i], diag[i], "component {i}");
        }
    }

    #[test]
    fn tanh_fields_bounded() {
        let sde = TanhDiagonal::new(8, 2);
        let y = vec![10.0; 8];
        let mut f = vec![0.0; 8];
        sde.drift(0.0, &y, &mut f);
        assert!(f.iter().all(|x| x.abs() <= 1.0));
    }

    #[test]
    fn ou_drift_time_dependent() {
        let sde = TimeDependentOu::default();
        let mut f = [0.0];
        sde.drift(10.0, &[0.0], &mut f);
        assert!((f[0] - 0.2).abs() < 1e-12);
    }
}
