//! Test SDE systems used across experiments and benchmarks.
//!
//! Each benchmark system comes in two forms: the per-path [`Sde`] (which the
//! batch engine can drive through its blanket gather/scatter adapter) and,
//! for the batched hot paths, a **native hand-batched** [`BatchSde`]
//! ([`TanhDiagonalBatch`], [`DenseCoupledBatch`]) whose vector fields are
//! evaluated directly over the SoA lanes — vectorised across paths on the
//! [`super::simd`] kernels, with the per-path arithmetic order preserved so
//! native and adapted solves agree bit-for-bit.

use super::adjoint::{BatchSdeVjp, SdeVjp};
use super::simd::Lane;
use super::{simd, BatchSde, Sde};
use crate::brownian::SplitPrng;

/// Scalar linear Stratonovich SDE `dy = a y dt + b y ∘ dW` with the exact
/// solution `y_t = y_0 exp(a t + b W_t)` — the workhorse for strong-error
/// checks against ground truth.
pub struct ScalarLinear {
    /// Drift coefficient.
    pub a: f64,
    /// Diffusion coefficient.
    pub b: f64,
}

impl Sde for ScalarLinear {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.a * y[0];
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
}

/// VJPs for `θ = [a, b]`: `∂f/∂y = a`, `∂f/∂a = y`; `∂(g·dw)/∂y = b·dw`,
/// `∂(g·dw)/∂b = y·dw`.
impl SdeVjp for ScalarLinear {
    fn param_len(&self) -> usize {
        2
    }
    fn drift_vjp(&self, _t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]) {
        gy[0] += self.a * wf[0];
        gth[0] += y[0] * wf[0];
    }
    fn diffusion_vjp(
        &self,
        _t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
    ) {
        gy[0] += self.b * dw[0] * v[0];
        gth[1] += y[0] * dw[0] * v[0];
    }
}

/// The scalar anharmonic oscillator of Appendix D.4, equation (28):
/// `dy = sin(y) dt + σ dW` (additive noise) — the test problem for the
/// Figure-5/6 convergence study (the paper uses σ = 1, y₀ = 1, T = 1).
pub struct Anharmonic {
    /// Noise level (paper: 1.0).
    pub sigma: f64,
}

impl Sde for Anharmonic {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = y[0].sin();
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
}

/// VJPs for `θ = [σ]`: `∂f/∂y = cos(y)`; the additive noise contributes
/// only `∂(g·dw)/∂σ = dw`.
impl SdeVjp for Anharmonic {
    fn param_len(&self) -> usize {
        1
    }
    fn drift_vjp(&self, _t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], _gth: &mut [f64]) {
        gy[0] += y[0].cos() * wf[0];
    }
    fn diffusion_vjp(
        &self,
        _t: f64,
        _y: &[f64],
        v: &[f64],
        dw: &[f64],
        _gy: &mut [f64],
        gth: &mut [f64],
    ) {
        gth[0] += dw[0] * v[0];
    }
}

/// The Table-10 benchmark SDE (Appendix F.6): Itô with diagonal noise,
///
/// ```text
/// dX^i = tanh((A X)^i) dt + tanh((B X)^i) dW^i
/// ```
///
/// with random matrices `A, B ∈ R^{d×d}`.
pub struct TanhDiagonal {
    d: usize,
    a: Vec<f64>,
    b: Vec<f64>,
    /// Scratch for the matrix–vector products.
    // (interior mutability avoided: scratch allocated per call is fine for a
    // benchmark-workload definition; the solve loop dominates.)
    _priv: (),
}

impl TanhDiagonal {
    /// Random system of dimension `d` (entries `N(0, 1/d)`).
    pub fn new(d: usize, seed: u64) -> Self {
        let mut rng = SplitPrng::new(seed);
        let scale = 1.0 / (d as f64).sqrt();
        let mut mk = |n: usize| -> Vec<f64> {
            (0..n)
                .map(|_| {
                    let (a, _) = rng.next_normal_pair();
                    a * scale
                })
                .collect()
        };
        let a = mk(d * d);
        let b = mk(d * d);
        Self { d, a, b, _priv: () }
    }

    /// System with explicit matrices (row-major `d×d` each) — the
    /// constructor finite-difference gradient checks rebuild perturbed
    /// systems through.
    pub fn from_matrices(d: usize, a: Vec<f64>, b: Vec<f64>) -> Self {
        assert_eq!(a.len(), d * d);
        assert_eq!(b.len(), d * d);
        Self { d, a, b, _priv: () }
    }

    /// The flat parameter vector `θ = [A row-major, B row-major]` — the
    /// layout of the [`SdeVjp`] θ-gradient.
    pub fn params_flat(&self) -> Vec<f64> {
        let mut p = self.a.clone();
        p.extend_from_slice(&self.b);
        p
    }

    fn matvec(m: &[f64], x: &[f64], out: &mut [f64]) {
        let d = x.len();
        for i in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                acc += m[i * d + j] * x[j];
            }
            out[i] = acc;
        }
    }
}

impl Sde for TanhDiagonal {
    fn dim(&self) -> usize {
        self.d
    }
    fn noise_dim(&self) -> usize {
        self.d
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        Self::matvec(&self.a, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // Diagonal: out is d x d, zero off-diagonal.
        let d = self.d;
        let mut diag = vec![0.0; d];
        Self::matvec(&self.b, y, &mut diag);
        out.fill(0.0);
        for i in 0..d {
            out[i * d + i] = diag[i].tanh();
        }
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // The batched fast path: the diagonal only, straight into `out` —
        // no d×d zero-fill, no per-call scratch allocation.
        Self::matvec(&self.b, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
}

/// VJP weight through `tanh`: `s[i] = w[i] * (1 − tanh(u[i])²)` in place
/// (`s` holds the pre-activation `u` on entry). One shared token form for
/// the per-path and batched impls, so their bits agree lane-for-lane.
fn tanh_vjp_weight(u_then_s: &mut [f64], w: &[f64]) {
    for (sv, &wv) in u_then_s.iter_mut().zip(w) {
        let th = sv.tanh();
        *sv = wv * (1.0 - th * th);
    }
}

/// As [`tanh_vjp_weight`] with the factored diffusion cotangent:
/// `s[i] = v[i] * dw[i] * (1 − tanh(u[i])²)`.
fn tanh_vjp_weight_dw(u_then_s: &mut [f64], v: &[f64], dw: &[f64]) {
    for ((sv, &vv), &dv) in u_then_s.iter_mut().zip(v).zip(dw) {
        let th = sv.tanh();
        *sv = vv * dv * (1.0 - th * th);
    }
}

/// VJPs for `θ = [A row-major (d²), B row-major (d²)]`. With
/// `u = M y`, `out_i = tanh(u_i)` and VJP weight `s_i = w_i (1 − tanh²u_i)`:
/// `gy = Mᵀ s` and `∂/∂M_ij = s_i y_j`.
impl SdeVjp for TanhDiagonal {
    fn param_len(&self) -> usize {
        2 * self.d * self.d
    }

    fn drift_vjp(&self, _t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]) {
        let d = self.d;
        let mut s = vec![0.0; d];
        Self::matvec(&self.a, y, &mut s);
        tanh_vjp_weight(&mut s, wf);
        // gy += Aᵀ s, seeded ascending-i — the association the batched
        // strided kernel mirrors.
        for j in 0..d {
            let mut acc = gy[j];
            for i in 0..d {
                acc += self.a[i * d + j] * s[i];
            }
            gy[j] = acc;
        }
        for i in 0..d {
            for j in 0..d {
                gth[i * d + j] += s[i] * y[j];
            }
        }
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
    ) {
        let d = self.d;
        let dd = d * d;
        let mut s = vec![0.0; d];
        Self::matvec(&self.b, y, &mut s);
        tanh_vjp_weight_dw(&mut s, v, dw);
        for j in 0..d {
            let mut acc = gy[j];
            for i in 0..d {
                acc += self.b[i * d + j] * s[i];
            }
            gy[j] = acc;
        }
        for i in 0..d {
            for j in 0..d {
                gth[dd + i * d + j] += s[i] * y[j];
            }
        }
    }
}

/// Native hand-batched twin of [`TanhDiagonal`]: a [`BatchSde`] whose
/// mat-vecs run directly over the SoA lanes ([`simd::broadcast_matvec`] —
/// the matrix entry is broadcast over `LANES` path lanes at a time) instead
/// of gather → per-path mat-vec → scatter through the blanket adapter.
///
/// Same seed ⇒ same matrices ⇒ bit-identical trajectories to driving the
/// per-path [`TanhDiagonal`] through the adapter (the `j` reduction order of
/// the per-path `matvec` is preserved lane-wise).
///
/// Implements [`BatchSde`] at **both precisions**: the `f32` instantiation
/// evaluates the same fields over 8-wide `f32` lanes, using single-precision
/// copies of the matrices rounded once at construction (so an `f32` solve
/// does no per-call narrowing work).
pub struct TanhDiagonalBatch {
    inner: TanhDiagonal,
    a32: Vec<f32>,
    b32: Vec<f32>,
}

impl TanhDiagonalBatch {
    /// Random system of dimension `d`; identical to [`TanhDiagonal::new`]
    /// with the same arguments.
    pub fn new(d: usize, seed: u64) -> Self {
        Self::from_system(TanhDiagonal::new(d, seed))
    }

    /// Wrap an existing per-path system (shares its matrices; the `f32`
    /// copies are rounded here, once).
    pub fn from_system(inner: TanhDiagonal) -> Self {
        let a32 = inner.a.iter().map(|&v| v as f32).collect();
        let b32 = inner.b.iter().map(|&v| v as f32).collect();
        Self { inner, a32, b32 }
    }

    /// The wrapped per-path system.
    pub fn system(&self) -> &TanhDiagonal {
        &self.inner
    }
}

/// One field row over all path lanes: `row[p] = tanh(Σ_j m_row[j] * y[j*b+p])`
/// — the lane arithmetic every `TanhDiagonalBatch` field shares, kept in one
/// place because it is the bit-identity-sensitive part. Generic over the
/// lane element type: both precisions run the same token stream, so each
/// instantiation's association matches its own per-path reference.
fn tanh_matvec_row<T: Lane>(m_row: &[T], y: &[T], row: &mut [T]) {
    simd::broadcast_matvec(m_row, y, row);
    for o in row.iter_mut() {
        *o = o.lane_tanh();
    }
}

impl BatchSde for TanhDiagonalBatch {
    fn state_dim(&self) -> usize {
        self.inner.d
    }

    fn brownian_dim(&self) -> usize {
        self.inner.d
    }

    fn diagonal_noise(&self) -> bool {
        true
    }

    fn drift_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.inner.a[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        // Dense layout (only taken when a caller bypasses the diagonal fast
        // path): diagonal entries, zero elsewhere.
        let d = self.inner.d;
        out.fill(0.0);
        for i in 0..d {
            let row = &mut out[(i * d + i) * batch..(i * d + i + 1) * batch];
            tanh_matvec_row(&self.inner.b[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_diag_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.inner.b[i * d..(i + 1) * d], y, row);
        }
    }
}

/// The 8-wide `f32` instantiation: same fields, same lane discipline, over
/// the construction-time `f32` matrix copies. Bit-identical per path to a
/// single-path `f32` batched solve (the `f32` twin of the `f64` guarantee).
impl BatchSde<f32> for TanhDiagonalBatch {
    fn state_dim(&self) -> usize {
        self.inner.d
    }

    fn brownian_dim(&self) -> usize {
        self.inner.d
    }

    fn diagonal_noise(&self) -> bool {
        true
    }

    fn drift_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.a32[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let d = self.inner.d;
        out.fill(0.0);
        for i in 0..d {
            let row = &mut out[(i * d + i) * batch..(i * d + i + 1) * batch];
            tanh_matvec_row(&self.b32[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_diag_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.b32[i * d..(i + 1) * d], y, row);
        }
    }
}

/// Native SoA VJPs sharing [`TanhDiagonal`]'s matrices: the forward's
/// broadcast mat-vec reappears for `u = M y`, its transpose runs on
/// [`simd::broadcast_matvec_strided_seeded`] (one matrix *column* broadcast
/// across path lanes), and the rank-one `∂/∂M_ij = s_i y_j` update is a
/// lane-wise [`simd::mul_add`] into the per-path θ lanes. Per-path
/// association is preserved throughout, so gradients are bit-identical to
/// driving the per-path [`SdeVjp`] through the blanket adapter.
impl BatchSdeVjp for TanhDiagonalBatch {
    fn param_len(&self) -> usize {
        2 * self.inner.d * self.inner.d
    }

    fn drift_vjp_batch(
        &self,
        _t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let d = self.inner.d;
        let mut s = vec![0.0; d * batch];
        for i in 0..d {
            simd::broadcast_matvec(
                &self.inner.a[i * d..(i + 1) * d],
                y,
                &mut s[i * batch..(i + 1) * batch],
            );
        }
        tanh_vjp_weight(&mut s, wf);
        for j in 0..d {
            simd::broadcast_matvec_strided_seeded(
                &self.inner.a[j..],
                d,
                &s,
                &mut gy[j * batch..(j + 1) * batch],
            );
        }
        for i in 0..d {
            for j in 0..d {
                simd::mul_add(
                    &s[i * batch..(i + 1) * batch],
                    &y[j * batch..(j + 1) * batch],
                    &mut gth[(i * d + j) * batch..(i * d + j + 1) * batch],
                );
            }
        }
    }

    fn diffusion_vjp_batch(
        &self,
        _t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        gth: &mut [f64],
        batch: usize,
    ) {
        let d = self.inner.d;
        let dd = d * d;
        let mut s = vec![0.0; d * batch];
        for i in 0..d {
            simd::broadcast_matvec(
                &self.inner.b[i * d..(i + 1) * d],
                y,
                &mut s[i * batch..(i + 1) * batch],
            );
        }
        tanh_vjp_weight_dw(&mut s, v, dw);
        for j in 0..d {
            simd::broadcast_matvec_strided_seeded(
                &self.inner.b[j..],
                d,
                &s,
                &mut gy[j * batch..(j + 1) * batch],
            );
        }
        for i in 0..d {
            for j in 0..d {
                simd::mul_add(
                    &s[i * batch..(i + 1) * batch],
                    &y[j * batch..(j + 1) * batch],
                    &mut gth[(dd + i * d + j) * batch..(dd + i * d + j + 1) * batch],
                );
            }
        }
    }
}

/// Dense-noise benchmark system: `e = 2` states driven by `d = 3` Brownian
/// channels through a full, state-dependent 2×3 diffusion matrix. Exercises
/// the dense `e×d` mat-vec path that diagonal systems skip (promoted from
/// the batch-engine test suite so benches and tests share one definition).
pub struct DenseCoupled;

impl Sde for DenseCoupled {
    fn dim(&self) -> usize {
        2
    }
    fn noise_dim(&self) -> usize {
        3
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = (0.2 * y[1]).sin() - 0.1 * y[0];
        out[1] = 0.05 * t + 0.3 * y[0].cos();
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = 0.1 + 0.05 * y[0];
        out[1] = 0.2 * y[1];
        out[2] = -0.1;
        out[3] = 0.3;
        out[4] = 0.02 * y[0] * y[1];
        out[5] = 0.15;
    }
}

/// Parameter-free VJPs (the coefficients are fixtures, not weights):
/// hand-differentiated dense `2×3` diffusion, exercising the
/// dense-cotangent path the diagonal systems skip.
impl SdeVjp for DenseCoupled {
    fn param_len(&self) -> usize {
        0
    }

    fn drift_vjp(&self, _t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], _gth: &mut [f64]) {
        gy[0] += -0.1 * wf[0] - 0.3 * y[0].sin() * wf[1];
        gy[1] += 0.2 * (0.2 * y[1]).cos() * wf[0];
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        _gth: &mut [f64],
    ) {
        gy[0] += 0.05 * dw[0] * v[0] + 0.02 * y[1] * dw[1] * v[1];
        gy[1] += 0.2 * dw[1] * v[0] + 0.02 * y[0] * dw[1] * v[1];
    }
}

/// Native hand-batched twin of [`DenseCoupled`]: vector fields written
/// directly over the SoA lanes (unit-stride sweeps across paths, the same
/// per-path expressions), bit-identical to the blanket adapter.
pub struct DenseCoupledBatch;

impl BatchSde for DenseCoupledBatch {
    fn state_dim(&self) -> usize {
        2
    }

    fn brownian_dim(&self) -> usize {
        3
    }

    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let (y0, y1) = y.split_at(batch);
        let (o0, o1) = out.split_at_mut(batch);
        for p in 0..batch {
            o0[p] = (0.2 * y1[p]).sin() - 0.1 * y0[p];
        }
        for p in 0..batch {
            o1[p] = 0.05 * t + 0.3 * y0[p].cos();
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let (y0, y1) = y.split_at(batch);
        for p in 0..batch {
            out[p] = 0.1 + 0.05 * y0[p];
        }
        for p in 0..batch {
            out[batch + p] = 0.2 * y1[p];
        }
        out[2 * batch..3 * batch].fill(-0.1);
        out[3 * batch..4 * batch].fill(0.3);
        for p in 0..batch {
            out[4 * batch + p] = 0.02 * y0[p] * y1[p];
        }
        out[5 * batch..6 * batch].fill(0.15);
    }
}

/// The 8-wide `f32` instantiation of [`DenseCoupledBatch`]: the same
/// per-path expressions with the fixture constants rounded to `f32`,
/// exercising the dense `e×d` mat-vec path on `f32` lanes.
impl BatchSde<f32> for DenseCoupledBatch {
    fn state_dim(&self) -> usize {
        2
    }

    fn brownian_dim(&self) -> usize {
        3
    }

    fn drift_batch(&self, t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let t = t as f32;
        let (y0, y1) = y.split_at(batch);
        let (o0, o1) = out.split_at_mut(batch);
        for p in 0..batch {
            o0[p] = (0.2 * y1[p]).sin() - 0.1 * y0[p];
        }
        for p in 0..batch {
            o1[p] = 0.05 * t + 0.3 * y0[p].cos();
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let (y0, y1) = y.split_at(batch);
        for p in 0..batch {
            out[p] = 0.1 + 0.05 * y0[p];
        }
        for p in 0..batch {
            out[batch + p] = 0.2 * y1[p];
        }
        out[2 * batch..3 * batch].fill(-0.1);
        out[3 * batch..4 * batch].fill(0.3);
        for p in 0..batch {
            out[4 * batch + p] = 0.02 * y0[p] * y1[p];
        }
        out[5 * batch..6 * batch].fill(0.15);
    }
}

/// Native SoA twin of [`DenseCoupled`]'s VJPs: the same per-path
/// expressions swept unit-stride across path lanes.
impl BatchSdeVjp for DenseCoupledBatch {
    fn param_len(&self) -> usize {
        0
    }

    fn drift_vjp_batch(
        &self,
        _t: f64,
        y: &[f64],
        wf: &[f64],
        gy: &mut [f64],
        _gth: &mut [f64],
        batch: usize,
    ) {
        let (y0l, y1l) = y.split_at(batch);
        let (w0, w1) = wf.split_at(batch);
        let (g0, g1) = gy.split_at_mut(batch);
        for p in 0..batch {
            g0[p] += -0.1 * w0[p] - 0.3 * y0l[p].sin() * w1[p];
        }
        for p in 0..batch {
            g1[p] += 0.2 * (0.2 * y1l[p]).cos() * w0[p];
        }
    }

    fn diffusion_vjp_batch(
        &self,
        _t: f64,
        y: &[f64],
        v: &[f64],
        dw: &[f64],
        gy: &mut [f64],
        _gth: &mut [f64],
        batch: usize,
    ) {
        let (y0l, y1l) = y.split_at(batch);
        let (v0, v1) = v.split_at(batch);
        let dw0 = &dw[..batch];
        let dw1 = &dw[batch..2 * batch];
        let (g0, g1) = gy.split_at_mut(batch);
        for p in 0..batch {
            g0[p] += 0.05 * dw0[p] * v0[p] + 0.02 * y1l[p] * dw1[p] * v1[p];
        }
        for p in 0..batch {
            g1[p] += 0.2 * dw1[p] * v0[p] + 0.02 * y0l[p] * dw1[p] * v1[p];
        }
    }
}

/// The time-dependent Ornstein–Uhlenbeck process of Appendix F.7:
/// `dY = (ρ t − κ Y) dt + χ dW` (the SDE-GAN training dataset).
pub struct TimeDependentOu {
    /// Linear-in-time drift coefficient (paper: 0.02).
    pub rho: f64,
    /// Mean reversion (paper: 0.1).
    pub kappa: f64,
    /// Noise level (paper: 0.4).
    pub chi: f64,
}

impl Default for TimeDependentOu {
    fn default() -> Self {
        Self { rho: 0.02, kappa: 0.1, chi: 0.4 }
    }
}

impl Sde for TimeDependentOu {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.rho * t - self.kappa * y[0];
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
}

/// VJPs for `θ = [ρ, κ, χ]`: `∂f/∂y = −κ`, `∂f/∂ρ = t`, `∂f/∂κ = −y`;
/// the additive noise contributes only `∂(g·dw)/∂χ = dw`. The closed-form
/// machine-precision gradient tests run on this system.
impl SdeVjp for TimeDependentOu {
    fn param_len(&self) -> usize {
        3
    }

    fn drift_vjp(&self, t: f64, y: &[f64], wf: &[f64], gy: &mut [f64], gth: &mut [f64]) {
        gy[0] += -self.kappa * wf[0];
        gth[0] += t * wf[0];
        gth[1] += -y[0] * wf[0];
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        _y: &[f64],
        v: &[f64],
        dw: &[f64],
        _gy: &mut [f64],
        gth: &mut [f64],
    ) {
        gth[2] += dw[0] * v[0];
    }
}

/// One asset row of the market model's fields, over all path lanes.
/// Diagonal drift: `out[p] = κ (μ − y[p])`; sigmoid local volatility:
/// `out[p] = ν σ(a + b y[p])` — smooth, bounded and strictly positive.
/// Generic over the lane element type so both precisions run the same
/// token stream (the bit-identity-sensitive part, as for
/// [`tanh_matvec_row`]).
fn ou_drift_row<T: Lane>(kappa: T, mu: T, y: &[T], out: &mut [T]) {
    for (o, &yv) in out.iter_mut().zip(y.iter()) {
        *o = kappa * (mu - yv);
    }
}

fn sigmoid_vol_row<T: Lane>(nu: T, a: T, b: T, y: &[T], out: &mut [T]) {
    for (o, &yv) in out.iter_mut().zip(y.iter()) {
        *o = nu * (a + b * yv).lane_sigmoid();
    }
}

/// The diagonal-noise Monte-Carlo market model of the serving workload
/// (the *Neural SDEs as Infinite-Dimensional GANs* production shape:
/// diagonal σ, huge path counts): `d` assets, each
///
/// `dX_i = κ_i (μ_i − X_i) dt + ν_i σ(a_i + b_i X_i) dW_i`
///
/// with σ the logistic sigmoid — a mean-reverting OU backbone under a
/// smooth, bounded, strictly positive state-dependent local volatility.
/// Parameters are drawn deterministically from `seed`.
///
/// A **native hand-batched** [`BatchSde`] at both precisions (`f32` runs
/// the 8-wide lanes over single-precision parameter copies rounded once at
/// construction). Reports [`diagonal_noise`](BatchSde::diagonal_noise) so
/// batched solves take the PR-1 elementwise fast path; the
/// [`dense_control`](Self::dense_control) toggle opts a copy back into the
/// dense `e×d` mat-vec as the measurable baseline for the
/// `diag_fast_path` bench rows.
///
/// Deliberately *not* a per-path [`Sde`] (that would shadow this native
/// impl through the blanket batch adapter); the per-path reference for
/// bitwise pins is a width-1 batched solve.
pub struct MarketModel {
    d: usize,
    kappa: Vec<f64>,
    mu: Vec<f64>,
    nu: Vec<f64>,
    va: Vec<f64>,
    vb: Vec<f64>,
    kappa32: Vec<f32>,
    mu32: Vec<f32>,
    nu32: Vec<f32>,
    va32: Vec<f32>,
    vb32: Vec<f32>,
    martingale: bool,
    dense_control: bool,
}

impl MarketModel {
    /// Random `d`-asset market with seed-derived parameters:
    /// κ ∈ [0.5, 1.5], μ ∈ [0.9, 1.1], ν ∈ [0.1, 0.4], and vol shape
    /// a ∈ [−0.5, 0.5], b ∈ [0.5, 1.5].
    pub fn new(d: usize, seed: u64) -> Self {
        assert!(d >= 1);
        let mut rng = SplitPrng::new(seed);
        let mut draw = |lo: f64, hi: f64| -> Vec<f64> {
            (0..d).map(|_| lo + (hi - lo) * rng.next_uniform()).collect()
        };
        let kappa = draw(0.5, 1.5);
        let mu = draw(0.9, 1.1);
        let nu = draw(0.1, 0.4);
        let va = draw(-0.5, 0.5);
        let vb = draw(0.5, 1.5);
        let f32s = |v: &[f64]| v.iter().map(|&x| x as f32).collect::<Vec<f32>>();
        Self {
            d,
            kappa32: f32s(&kappa),
            mu32: f32s(&mu),
            nu32: f32s(&nu),
            va32: f32s(&va),
            vb32: f32s(&vb),
            kappa,
            mu,
            nu,
            va,
            vb,
            martingale: false,
            dense_control: false,
        }
    }

    /// Zero-drift (martingale) variant: prices discount to expectations of
    /// the terminal payoff, the Monte-Carlo pricing shape. The volatility
    /// surface is unchanged.
    pub fn martingale(mut self) -> Self {
        self.martingale = true;
        self
    }

    /// Report dense (non-diagonal) noise so the batch engine runs the full
    /// `e×d` mat-vec over the same fields — the measured baseline the
    /// `diag_fast_path/*` bench rows divide by. Bits aside (zero
    /// off-diagonal terms still enter the mat-vec sum), the dynamics are
    /// identical.
    pub fn dense_control(mut self) -> Self {
        self.dense_control = true;
        self
    }

    /// Number of assets (state dimension = Brownian dimension).
    pub fn assets(&self) -> usize {
        self.d
    }
}

impl BatchSde for MarketModel {
    fn state_dim(&self) -> usize {
        self.d
    }

    fn brownian_dim(&self) -> usize {
        self.d
    }

    fn diagonal_noise(&self) -> bool {
        !self.dense_control
    }

    fn drift_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        if self.martingale {
            out[..self.d * batch].fill(0.0);
            return;
        }
        for i in 0..self.d {
            let row = &mut out[i * batch..(i + 1) * batch];
            ou_drift_row(self.kappa[i], self.mu[i], &y[i * batch..(i + 1) * batch], row);
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let d = self.d;
        out[..d * d * batch].fill(0.0);
        for i in 0..d {
            let row = &mut out[(i * d + i) * batch..(i * d + i + 1) * batch];
            sigmoid_vol_row(self.nu[i], self.va[i], self.vb[i], &y[i * batch..(i + 1) * batch], row);
        }
    }

    fn diffusion_diag_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        for i in 0..self.d {
            let row = &mut out[i * batch..(i + 1) * batch];
            sigmoid_vol_row(self.nu[i], self.va[i], self.vb[i], &y[i * batch..(i + 1) * batch], row);
        }
    }
}

/// The 8-wide `f32` instantiation over the construction-time parameter
/// copies — the serving fast path's element type.
impl BatchSde<f32> for MarketModel {
    fn state_dim(&self) -> usize {
        self.d
    }

    fn brownian_dim(&self) -> usize {
        self.d
    }

    fn diagonal_noise(&self) -> bool {
        !self.dense_control
    }

    fn drift_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        if self.martingale {
            out[..self.d * batch].fill(0.0);
            return;
        }
        for i in 0..self.d {
            let row = &mut out[i * batch..(i + 1) * batch];
            ou_drift_row(self.kappa32[i], self.mu32[i], &y[i * batch..(i + 1) * batch], row);
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        let d = self.d;
        out[..d * d * batch].fill(0.0);
        for i in 0..d {
            let row = &mut out[(i * d + i) * batch..(i * d + i + 1) * batch];
            sigmoid_vol_row(
                self.nu32[i],
                self.va32[i],
                self.vb32[i],
                &y[i * batch..(i + 1) * batch],
                row,
            );
        }
    }

    fn diffusion_diag_batch(&self, _t: f64, y: &[f32], out: &mut [f32], batch: usize) {
        for i in 0..self.d {
            let row = &mut out[i * batch..(i + 1) * batch];
            sigmoid_vol_row(
                self.nu32[i],
                self.va32[i],
                self.vb32[i],
                &y[i * batch..(i + 1) * batch],
                row,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_linear_fields() {
        let sde = ScalarLinear { a: 2.0, b: 3.0 };
        let mut f = [0.0];
        let mut g = [0.0];
        sde.drift(0.0, &[1.5], &mut f);
        sde.diffusion(0.0, &[1.5], &mut g);
        assert_eq!(f[0], 3.0);
        assert_eq!(g[0], 4.5);
    }

    #[test]
    fn tanh_diagonal_diffusion_is_diagonal() {
        let sde = TanhDiagonal::new(4, 1);
        let mut g = vec![0.0; 16];
        sde.diffusion(0.0, &[0.5, -0.5, 1.0, 0.0], &mut g);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(g[i * 4 + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn diffusion_diag_matches_dense_diagonal() {
        let sde = TanhDiagonal::new(5, 3);
        let y: Vec<f64> = (0..5).map(|i| 0.3 * i as f64 - 0.6).collect();
        let mut dense = vec![0.0; 25];
        let mut diag = vec![0.0; 5];
        sde.diffusion(0.0, &y, &mut dense);
        sde.diffusion_diag(0.0, &y, &mut diag);
        for i in 0..5 {
            assert_eq!(dense[i * 5 + i], diag[i], "component {i}");
        }
    }

    #[test]
    fn tanh_fields_bounded() {
        let sde = TanhDiagonal::new(8, 2);
        let y = vec![10.0; 8];
        let mut f = vec![0.0; 8];
        sde.drift(0.0, &y, &mut f);
        assert!(f.iter().all(|x| x.abs() <= 1.0));
    }

    #[test]
    fn ou_drift_time_dependent() {
        let sde = TimeDependentOu::default();
        let mut f = [0.0];
        sde.drift(10.0, &[0.0], &mut f);
        assert!((f[0] - 0.2).abs() < 1e-12);
    }

    #[test]
    fn market_model_field_contracts() {
        let d = 3;
        let batch = 5;
        let y: Vec<f64> = (0..d * batch).map(|i| 0.8 + 0.05 * i as f64).collect();
        let mm = MarketModel::new(d, 2024);
        assert!(BatchSde::<f64>::diagonal_noise(&mm));
        // Dense diffusion: the diagonal matches the fast path, off-diagonal
        // entries are exactly zero.
        let mut dense = vec![1.0; d * d * batch];
        let mut diag = vec![0.0; d * batch];
        BatchSde::<f64>::diffusion_batch(&mm, 0.0, &y, &mut dense, batch);
        BatchSde::<f64>::diffusion_diag_batch(&mm, 0.0, &y, &mut diag, batch);
        for i in 0..d {
            for j in 0..d {
                for p in 0..batch {
                    let got = dense[(i * d + j) * batch + p];
                    let want = if i == j { diag[i * batch + p] } else { 0.0 };
                    assert_eq!(got, want, "entry ({i},{j}) path {p}");
                }
            }
        }
        // Volatility is strictly positive; the drift mean-reverts.
        assert!(diag.iter().all(|&v| v > 0.0));
        let mut f = vec![0.0; d * batch];
        BatchSde::<f64>::drift_batch(&mm, 0.0, &y, &mut f, batch);
        assert!(f.iter().any(|&v| v != 0.0));
        // The martingale toggle zeroes the drift without touching the vol.
        let mart = MarketModel::new(d, 2024).martingale();
        let mut f0 = vec![1.0; d * batch];
        BatchSde::<f64>::drift_batch(&mart, 0.0, &y, &mut f0, batch);
        assert!(f0.iter().all(|&v| v == 0.0));
        let mut diag2 = vec![0.0; d * batch];
        BatchSde::<f64>::diffusion_diag_batch(&mart, 0.0, &y, &mut diag2, batch);
        assert_eq!(diag, diag2);
        // The dense-control copy reports dense noise with the same surface.
        let ctl = MarketModel::new(d, 2024).dense_control();
        assert!(!BatchSde::<f64>::diagonal_noise(&ctl));
        assert!(!BatchSde::<f32>::diagonal_noise(&ctl));
        // f32 parameters are the rounded f64 ones: same fields to ~1e-6.
        let y32: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let mut diag32 = vec![0.0f32; d * batch];
        BatchSde::<f32>::diffusion_diag_batch(&mm, 0.0, &y32, &mut diag32, batch);
        for (a, &b) in diag.iter().zip(diag32.iter()) {
            assert!((a - b as f64).abs() < 1e-5);
        }
    }

    #[test]
    fn from_matrices_round_trips_params_flat() {
        // The FD gradient checks rebuild perturbed systems through this
        // pair; full VJP-vs-FD validation of every impl lives in
        // `tests/adjoint_gradients.rs` (one source of truth).
        let base = TanhDiagonal::new(3, 13);
        let theta = base.params_flat();
        let rebuilt = TanhDiagonal::from_matrices(3, theta[..9].to_vec(), theta[9..].to_vec());
        assert_eq!(rebuilt.params_flat(), theta);
        let y = [0.2, -0.1, 0.3];
        let mut fa = [0.0; 3];
        let mut fb = [0.0; 3];
        base.drift(0.0, &y, &mut fa);
        rebuilt.drift(0.0, &y, &mut fb);
        assert_eq!(fa, fb);
    }
}
