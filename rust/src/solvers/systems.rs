//! Test SDE systems used across experiments and benchmarks.
//!
//! Each benchmark system comes in two forms: the per-path [`Sde`] (which the
//! batch engine can drive through its blanket gather/scatter adapter) and,
//! for the batched hot paths, a **native hand-batched** [`BatchSde`]
//! ([`TanhDiagonalBatch`], [`DenseCoupledBatch`]) whose vector fields are
//! evaluated directly over the SoA lanes — vectorised across paths on the
//! [`super::simd`] kernels, with the per-path arithmetic order preserved so
//! native and adapted solves agree bit-for-bit.

use super::{simd, BatchSde, Sde};
use crate::brownian::SplitPrng;

/// Scalar linear Stratonovich SDE `dy = a y dt + b y ∘ dW` with the exact
/// solution `y_t = y_0 exp(a t + b W_t)` — the workhorse for strong-error
/// checks against ground truth.
pub struct ScalarLinear {
    /// Drift coefficient.
    pub a: f64,
    /// Diffusion coefficient.
    pub b: f64,
}

impl Sde for ScalarLinear {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.a * y[0];
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.b * y[0];
    }
}

/// The scalar anharmonic oscillator of Appendix D.4, equation (28):
/// `dy = sin(y) dt + σ dW` (additive noise) — the test problem for the
/// Figure-5/6 convergence study (the paper uses σ = 1, y₀ = 1, T = 1).
pub struct Anharmonic {
    /// Noise level (paper: 1.0).
    pub sigma: f64,
}

impl Sde for Anharmonic {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = y[0].sin();
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.sigma;
    }
}

/// The Table-10 benchmark SDE (Appendix F.6): Itô with diagonal noise,
///
/// ```text
/// dX^i = tanh((A X)^i) dt + tanh((B X)^i) dW^i
/// ```
///
/// with random matrices `A, B ∈ R^{d×d}`.
pub struct TanhDiagonal {
    d: usize,
    a: Vec<f64>,
    b: Vec<f64>,
    /// Scratch for the matrix–vector products.
    // (interior mutability avoided: scratch allocated per call is fine for a
    // benchmark-workload definition; the solve loop dominates.)
    _priv: (),
}

impl TanhDiagonal {
    /// Random system of dimension `d` (entries `N(0, 1/d)`).
    pub fn new(d: usize, seed: u64) -> Self {
        let mut rng = SplitPrng::new(seed);
        let scale = 1.0 / (d as f64).sqrt();
        let mut mk = |n: usize| -> Vec<f64> {
            (0..n)
                .map(|_| {
                    let (a, _) = rng.next_normal_pair();
                    a * scale
                })
                .collect()
        };
        let a = mk(d * d);
        let b = mk(d * d);
        Self { d, a, b, _priv: () }
    }

    fn matvec(m: &[f64], x: &[f64], out: &mut [f64]) {
        let d = x.len();
        for i in 0..d {
            let mut acc = 0.0;
            for j in 0..d {
                acc += m[i * d + j] * x[j];
            }
            out[i] = acc;
        }
    }
}

impl Sde for TanhDiagonal {
    fn dim(&self) -> usize {
        self.d
    }
    fn noise_dim(&self) -> usize {
        self.d
    }
    fn drift(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        Self::matvec(&self.a, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // Diagonal: out is d x d, zero off-diagonal.
        let d = self.d;
        let mut diag = vec![0.0; d];
        Self::matvec(&self.b, y, &mut diag);
        out.fill(0.0);
        for i in 0..d {
            out[i * d + i] = diag[i].tanh();
        }
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true
    }
    fn diffusion_diag(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        // The batched fast path: the diagonal only, straight into `out` —
        // no d×d zero-fill, no per-call scratch allocation.
        Self::matvec(&self.b, y, out);
        for o in out.iter_mut() {
            *o = o.tanh();
        }
    }
}

/// Native hand-batched twin of [`TanhDiagonal`]: a [`BatchSde`] whose
/// mat-vecs run directly over the SoA lanes ([`simd::broadcast_matvec`] —
/// the matrix entry is broadcast over four path lanes at a time) instead of
/// gather → per-path mat-vec → scatter through the blanket adapter.
///
/// Same seed ⇒ same matrices ⇒ bit-identical trajectories to driving the
/// per-path [`TanhDiagonal`] through the adapter (the `j` reduction order of
/// the per-path `matvec` is preserved lane-wise).
pub struct TanhDiagonalBatch {
    inner: TanhDiagonal,
}

impl TanhDiagonalBatch {
    /// Random system of dimension `d`; identical to [`TanhDiagonal::new`]
    /// with the same arguments.
    pub fn new(d: usize, seed: u64) -> Self {
        Self { inner: TanhDiagonal::new(d, seed) }
    }

    /// Wrap an existing per-path system (shares its matrices).
    pub fn from_system(inner: TanhDiagonal) -> Self {
        Self { inner }
    }

    /// The wrapped per-path system.
    pub fn system(&self) -> &TanhDiagonal {
        &self.inner
    }
}

/// One field row over all path lanes: `row[p] = tanh(Σ_j m_row[j] * y[j*b+p])`
/// — the lane arithmetic every `TanhDiagonalBatch` field shares, kept in one
/// place because it is the bit-identity-sensitive part.
fn tanh_matvec_row(m_row: &[f64], y: &[f64], row: &mut [f64]) {
    simd::broadcast_matvec(m_row, y, row);
    for o in row.iter_mut() {
        *o = o.tanh();
    }
}

impl BatchSde for TanhDiagonalBatch {
    fn state_dim(&self) -> usize {
        self.inner.d
    }

    fn brownian_dim(&self) -> usize {
        self.inner.d
    }

    fn diagonal_noise(&self) -> bool {
        true
    }

    fn drift_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.inner.a[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        // Dense layout (only taken when a caller bypasses the diagonal fast
        // path): diagonal entries, zero elsewhere.
        let d = self.inner.d;
        out.fill(0.0);
        for i in 0..d {
            let row = &mut out[(i * d + i) * batch..(i * d + i + 1) * batch];
            tanh_matvec_row(&self.inner.b[i * d..(i + 1) * d], y, row);
        }
    }

    fn diffusion_diag_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let d = self.inner.d;
        for i in 0..d {
            let row = &mut out[i * batch..(i + 1) * batch];
            tanh_matvec_row(&self.inner.b[i * d..(i + 1) * d], y, row);
        }
    }
}

/// Dense-noise benchmark system: `e = 2` states driven by `d = 3` Brownian
/// channels through a full, state-dependent 2×3 diffusion matrix. Exercises
/// the dense `e×d` mat-vec path that diagonal systems skip (promoted from
/// the batch-engine test suite so benches and tests share one definition).
pub struct DenseCoupled;

impl Sde for DenseCoupled {
    fn dim(&self) -> usize {
        2
    }
    fn noise_dim(&self) -> usize {
        3
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = (0.2 * y[1]).sin() - 0.1 * y[0];
        out[1] = 0.05 * t + 0.3 * y[0].cos();
    }
    fn diffusion(&self, _t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = 0.1 + 0.05 * y[0];
        out[1] = 0.2 * y[1];
        out[2] = -0.1;
        out[3] = 0.3;
        out[4] = 0.02 * y[0] * y[1];
        out[5] = 0.15;
    }
}

/// Native hand-batched twin of [`DenseCoupled`]: vector fields written
/// directly over the SoA lanes (unit-stride sweeps across paths, the same
/// per-path expressions), bit-identical to the blanket adapter.
pub struct DenseCoupledBatch;

impl BatchSde for DenseCoupledBatch {
    fn state_dim(&self) -> usize {
        2
    }

    fn brownian_dim(&self) -> usize {
        3
    }

    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let (y0, y1) = y.split_at(batch);
        let (o0, o1) = out.split_at_mut(batch);
        for p in 0..batch {
            o0[p] = (0.2 * y1[p]).sin() - 0.1 * y0[p];
        }
        for p in 0..batch {
            o1[p] = 0.05 * t + 0.3 * y0[p].cos();
        }
    }

    fn diffusion_batch(&self, _t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        let (y0, y1) = y.split_at(batch);
        for p in 0..batch {
            out[p] = 0.1 + 0.05 * y0[p];
        }
        for p in 0..batch {
            out[batch + p] = 0.2 * y1[p];
        }
        out[2 * batch..3 * batch].fill(-0.1);
        out[3 * batch..4 * batch].fill(0.3);
        for p in 0..batch {
            out[4 * batch + p] = 0.02 * y0[p] * y1[p];
        }
        out[5 * batch..6 * batch].fill(0.15);
    }
}

/// The time-dependent Ornstein–Uhlenbeck process of Appendix F.7:
/// `dY = (ρ t − κ Y) dt + χ dW` (the SDE-GAN training dataset).
pub struct TimeDependentOu {
    /// Linear-in-time drift coefficient (paper: 0.02).
    pub rho: f64,
    /// Mean reversion (paper: 0.1).
    pub kappa: f64,
    /// Noise level (paper: 0.4).
    pub chi: f64,
}

impl Default for TimeDependentOu {
    fn default() -> Self {
        Self { rho: 0.02, kappa: 0.1, chi: 0.4 }
    }
}

impl Sde for TimeDependentOu {
    fn dim(&self) -> usize {
        1
    }
    fn noise_dim(&self) -> usize {
        1
    }
    fn drift(&self, t: f64, y: &[f64], out: &mut [f64]) {
        out[0] = self.rho * t - self.kappa * y[0];
    }
    fn diffusion(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
    fn diffusion_is_diagonal(&self) -> bool {
        true // 1×1: trivially diagonal
    }
    fn diffusion_diag(&self, _t: f64, _y: &[f64], out: &mut [f64]) {
        out[0] = self.chi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_linear_fields() {
        let sde = ScalarLinear { a: 2.0, b: 3.0 };
        let mut f = [0.0];
        let mut g = [0.0];
        sde.drift(0.0, &[1.5], &mut f);
        sde.diffusion(0.0, &[1.5], &mut g);
        assert_eq!(f[0], 3.0);
        assert_eq!(g[0], 4.5);
    }

    #[test]
    fn tanh_diagonal_diffusion_is_diagonal() {
        let sde = TanhDiagonal::new(4, 1);
        let mut g = vec![0.0; 16];
        sde.diffusion(0.0, &[0.5, -0.5, 1.0, 0.0], &mut g);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(g[i * 4 + j], 0.0);
                }
            }
        }
    }

    #[test]
    fn diffusion_diag_matches_dense_diagonal() {
        let sde = TanhDiagonal::new(5, 3);
        let y: Vec<f64> = (0..5).map(|i| 0.3 * i as f64 - 0.6).collect();
        let mut dense = vec![0.0; 25];
        let mut diag = vec![0.0; 5];
        sde.diffusion(0.0, &y, &mut dense);
        sde.diffusion_diag(0.0, &y, &mut diag);
        for i in 0..5 {
            assert_eq!(dense[i * 5 + i], diag[i], "component {i}");
        }
    }

    #[test]
    fn tanh_fields_bounded() {
        let sde = TanhDiagonal::new(8, 2);
        let y = vec![10.0; 8];
        let mut f = vec![0.0; 8];
        sde.drift(0.0, &y, &mut f);
        assert!(f.iter().all(|x| x.abs() <= 1.0));
    }

    #[test]
    fn ou_drift_time_dependent() {
        let sde = TimeDependentOu::default();
        let mut f = [0.0];
        sde.drift(10.0, &[0.0], &mut f);
        assert!((f[0] - 0.2).abs() < 1e-12);
    }
}
