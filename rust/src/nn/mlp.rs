//! Native LipSwish-MLP forward passes and analytic VJPs.
//!
//! This is the in-Rust twin of `python/compile/kernels/ref.py`'s
//! `mlp2_lipswish`: a two-layer MLP with the paper's LipSwish hidden
//! activation (Section 5 — 1-Lipschitz, so weight clipping alone bounds the
//! whole network's Lipschitz constant) and an optional bounded final
//! nonlinearity. Parameters live inside the **flat `f32`/`f64` vectors** the
//! training loop owns, addressed through a [`Mlp`] descriptor derived from a
//! [`ParamLayout`] (`w1 [in, h]`, `b1 [h]`, `w2 [h, out]`, `b2 [out]`,
//! contiguous, row-major — the `nets.add_mlp` contract).
//!
//! Every entry point comes in a per-path and an SoA-batched form, and the
//! batched form follows the batch engine's association rule — the matrix
//! reductions run on the broadcast kernels of [`crate::solvers::simd`]
//! (ascending index order, matrix entry broadcast across path lanes) and the
//! nonlinearities are the *same scalar functions* applied lane-wise — so
//! batched evaluation and batched VJPs are **bit-for-bit equal** to the
//! per-path forms. The neural vector fields in [`crate::solvers::neural`]
//! inherit their batched-≡-per-path guarantee directly from this module.
//!
//! The VJP recomputes the forward activations at the evaluation point
//! (the adjoint engine only retains solver states, not MLP internals), and
//! accumulates `∂L/∂θ` with `+=` into the full flat gradient vector at the
//! descriptor's offsets; the input gradient is written zero-seeded.

use crate::nn::{ParamKind, ParamLayout};
use crate::solvers::simd::{self, Lane};

/// LipSwish scale: `ρ(x) = 0.909 · x · sigmoid(x)` has Lipschitz constant
/// exactly 1 (Chen et al. 2019) — the paper's Section-5 activation.
pub const LIPSWISH_SCALE: f64 = 0.909;

/// Numerically standard sigmoid.
#[inline]
pub fn sigmoid(u: f64) -> f64 {
    1.0 / (1.0 + (-u).exp())
}

/// LipSwish activation `ρ(u) = 0.909 · u · σ(u)` (1-Lipschitz, smooth).
#[inline]
pub fn lipswish(u: f64) -> f64 {
    LIPSWISH_SCALE * u * sigmoid(u)
}

/// Derivative `ρ'(u) = 0.909 · (σ(u) + u σ(u)(1 − σ(u)))`; its maximum is
/// `0.909 · 1.0998… < 1`, which is the slope bound the Lipschitz argument
/// needs.
#[inline]
pub fn dlipswish(u: f64) -> f64 {
    let s = sigmoid(u);
    LIPSWISH_SCALE * (s + u * s * (1.0 - s))
}

// Precision-generic twins of the scalar activations, written token-for-token
// as the `f64` forms ([`Lane::from_f64`] is the identity on `f64`, and
// `lane_sigmoid` is the same literal expression as [`sigmoid`]), so the
// generic layers below keep the historical `f64` bits exactly while the
// `f32` instantiation runs the same association at single precision.

#[inline]
fn lipswish_t<T: Lane>(u: T) -> T {
    T::from_f64(LIPSWISH_SCALE) * u * u.lane_sigmoid()
}

#[inline]
fn dlipswish_t<T: Lane>(u: T) -> T {
    let s = u.lane_sigmoid();
    T::from_f64(LIPSWISH_SCALE) * (s + u * s * (T::from_f64(1.0) - s))
}

/// Final nonlinearity of a [`Mlp`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    /// No output nonlinearity (the generator drift `μ_θ`, `ζ`, `ξ`).
    Identity,
    /// `tanh` (the diffusions `σ_θ`, and the CDE fields `f_φ`, `g_φ` — keeps
    /// them bounded).
    Tanh,
    /// `sigmoid` (the Figure-2 gradient-error test problem's fields).
    Sigmoid,
}

#[inline]
fn apply_final<T: Lane>(act: Activation, u: T) -> T {
    match act {
        Activation::Identity => u,
        Activation::Tanh => u.lane_tanh(),
        Activation::Sigmoid => u.lane_sigmoid(),
    }
}

/// Derivative factor of the final nonlinearity at pre-activation `u`.
#[inline]
fn dfinal<T: Lane>(act: Activation, u: T) -> T {
    match act {
        Activation::Identity => T::from_f64(1.0),
        Activation::Tanh => {
            let th = u.lane_tanh();
            T::from_f64(1.0) - th * th
        }
        Activation::Sigmoid => {
            let s = u.lane_sigmoid();
            s * (T::from_f64(1.0) - s)
        }
    }
}

/// Descriptor of one two-layer LipSwish MLP inside a flat parameter vector:
/// `w1 [in, h]` row-major at `offset`, then `b1 [h]`, `w2 [h, out]`
/// row-major, `b2 [out]`, all contiguous.
#[derive(Clone, Copy, Debug)]
pub struct Mlp {
    /// Input width.
    pub in_dim: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Output width.
    pub out_dim: usize,
    /// Offset of `w1` within the flat parameter vector.
    pub offset: usize,
    /// Output nonlinearity.
    pub final_act: Activation,
}

impl Mlp {
    /// Describe the MLP registered as `{prefix}.w1 / .b1 / .w2 / .b2` in a
    /// [`ParamLayout`], validating shapes and contiguity.
    pub fn from_layout(
        layout: &ParamLayout,
        prefix: &str,
        final_act: Activation,
    ) -> anyhow::Result<Self> {
        let get = |suffix: &str| {
            layout
                .find(&format!("{prefix}.{suffix}"))
                .ok_or_else(|| anyhow::anyhow!("layout missing {prefix}.{suffix}"))
        };
        let w1 = get("w1")?;
        let b1 = get("b1")?;
        let w2 = get("w2")?;
        let b2 = get("b2")?;
        anyhow::ensure!(w1.shape.len() == 2 && w2.shape.len() == 2, "{prefix}: w1/w2 not 2-D");
        let (in_dim, hidden) = (w1.shape[0], w1.shape[1]);
        let out_dim = w2.shape[1];
        anyhow::ensure!(w2.shape[0] == hidden, "{prefix}: w2 rows != hidden");
        anyhow::ensure!(b1.shape == [hidden] && b2.shape == [out_dim], "{prefix}: bias shapes");
        anyhow::ensure!(
            b1.offset == w1.offset + in_dim * hidden
                && w2.offset == b1.offset + hidden
                && b2.offset == w2.offset + hidden * out_dim,
            "{prefix}: tensors not contiguous"
        );
        Ok(Self { in_dim, hidden, out_dim, offset: w1.offset, final_act })
    }

    /// Number of scalars the MLP owns in the flat vector.
    pub fn param_len(&self) -> usize {
        self.in_dim * self.hidden + self.hidden + self.hidden * self.out_dim + self.out_dim
    }

    #[inline]
    fn offsets(&self) -> (usize, usize, usize, usize) {
        let w1 = self.offset;
        let b1 = w1 + self.in_dim * self.hidden;
        let w2 = b1 + self.hidden;
        let b2 = w2 + self.hidden * self.out_dim;
        (w1, b1, w2, b2)
    }

    /// Per-path forward: `out = final(lipswish(x·w1 + b1)·w2 + b2)`,
    /// generic over the [`Lane`] element type (`f64` keeps the historical
    /// bits; `f32` runs the same token stream at single precision).
    ///
    /// The reductions run over the input index in ascending order with the
    /// bias as the seed — the association the batched form reproduces
    /// lane-for-lane.
    pub fn forward<T: Lane>(&self, params: &[T], x: &[T], out: &mut [T]) {
        let (h, o) = (self.hidden, self.out_dim);
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(out.len(), o);
        let (w1o, b1o, w2o, b2o) = self.offsets();
        let mut a1 = vec![T::ZERO; h];
        for j in 0..h {
            let mut acc = params[b1o + j];
            for i in 0..self.in_dim {
                acc += params[w1o + i * h + j] * x[i];
            }
            a1[j] = lipswish_t(acc);
        }
        for k in 0..o {
            let mut acc = params[b2o + k];
            for j in 0..h {
                acc += params[w2o + j * o + k] * a1[j];
            }
            out[k] = apply_final(self.final_act, acc);
        }
    }

    /// Batched-SoA forward over `[in_dim × batch]` lanes into
    /// `[out_dim × batch]` lanes — bit-identical per path to [`forward`]
    /// (bias-seeded strided reductions on
    /// [`simd::broadcast_matvec_strided_seeded`], then the same scalar
    /// nonlinearities lane-wise).
    ///
    /// [`forward`]: Self::forward
    pub fn forward_batch<T: Lane>(&self, params: &[T], x: &[T], out: &mut [T], batch: usize) {
        let (h, o, b) = (self.hidden, self.out_dim, batch);
        debug_assert_eq!(x.len(), self.in_dim * b);
        debug_assert_eq!(out.len(), o * b);
        let (w1o, b1o, w2o, b2o) = self.offsets();
        let w1 = &params[w1o..w1o + self.in_dim * h];
        let w2 = &params[w2o..w2o + h * o];
        let mut a1 = vec![T::ZERO; h * b];
        for j in 0..h {
            let lane = &mut a1[j * b..(j + 1) * b];
            lane.fill(params[b1o + j]);
            simd::broadcast_matvec_strided_seeded(&w1[j..], h, x, lane);
        }
        for v in a1.iter_mut() {
            *v = lipswish_t(*v);
        }
        for k in 0..o {
            let lane = &mut out[k * b..(k + 1) * b];
            lane.fill(params[b2o + k]);
            simd::broadcast_matvec_strided_seeded(&w2[k..], o, &a1, lane);
        }
        for v in out.iter_mut() {
            *v = apply_final(self.final_act, *v);
        }
    }

    /// Per-path VJP: given the output cotangent `wout`, accumulate
    /// `∂L/∂θ` (`+=`) into the flat gradient `gth` at this MLP's offsets and
    /// write the input gradient into `gx` (overwritten, zero-seeded). The
    /// forward activations are recomputed from `x`.
    pub fn vjp<T: Lane>(&self, params: &[T], x: &[T], wout: &[T], gx: &mut [T], gth: &mut [T]) {
        let (h, o) = (self.hidden, self.out_dim);
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(wout.len(), o);
        debug_assert_eq!(gx.len(), self.in_dim);
        let (w1o, b1o, w2o, b2o) = self.offsets();
        // Recompute pre-activations and hidden activations.
        let mut u1 = vec![T::ZERO; h];
        let mut a1 = vec![T::ZERO; h];
        for j in 0..h {
            let mut acc = params[b1o + j];
            for i in 0..self.in_dim {
                acc += params[w1o + i * h + j] * x[i];
            }
            u1[j] = acc;
            a1[j] = lipswish_t(acc);
        }
        let mut u2 = vec![T::ZERO; o];
        for k in 0..o {
            let mut acc = params[b2o + k];
            for j in 0..h {
                acc += params[w2o + j * o + k] * a1[j];
            }
            u2[k] = acc;
        }
        // Backward through the final nonlinearity and the second layer.
        let mut s2 = vec![T::ZERO; o];
        for k in 0..o {
            s2[k] = wout[k] * dfinal(self.final_act, u2[k]);
        }
        for k in 0..o {
            gth[b2o + k] += s2[k];
        }
        for j in 0..h {
            for k in 0..o {
                gth[w2o + j * o + k] += a1[j] * s2[k];
            }
        }
        let mut s1 = vec![T::ZERO; h];
        for j in 0..h {
            let mut acc = T::ZERO;
            for k in 0..o {
                acc += params[w2o + j * o + k] * s2[k];
            }
            s1[j] = acc * dlipswish_t(u1[j]);
        }
        // First layer.
        for j in 0..h {
            gth[b1o + j] += s1[j];
        }
        for i in 0..self.in_dim {
            for j in 0..h {
                gth[w1o + i * h + j] += x[i] * s1[j];
            }
        }
        for i in 0..self.in_dim {
            let mut acc = T::ZERO;
            for j in 0..h {
                acc += params[w1o + i * h + j] * s1[j];
            }
            gx[i] = acc;
        }
    }

    /// Batched-SoA VJP, bit-identical per path to [`vjp`]: `gth` holds
    /// **per-path θ lanes** of the full flat vector
    /// (`gth[(offset + m) * batch + p]`, the [`BatchSdeVjp`] convention), and
    /// `gx` (`[in_dim × batch]`) is overwritten zero-seeded.
    ///
    /// [`vjp`]: Self::vjp
    /// [`BatchSdeVjp`]: crate::solvers::BatchSdeVjp
    pub fn vjp_batch<T: Lane>(
        &self,
        params: &[T],
        x: &[T],
        wout: &[T],
        gx: &mut [T],
        gth: &mut [T],
        batch: usize,
    ) {
        let (h, o, b) = (self.hidden, self.out_dim, batch);
        debug_assert_eq!(x.len(), self.in_dim * b);
        debug_assert_eq!(wout.len(), o * b);
        debug_assert_eq!(gx.len(), self.in_dim * b);
        let (w1o, b1o, w2o, b2o) = self.offsets();
        let w1 = &params[w1o..w1o + self.in_dim * h];
        let w2 = &params[w2o..w2o + h * o];
        // Recompute pre-activations (u1 kept for ρ', a1 for the rank-one
        // weight updates) — same bias-seeded strided reductions as forward.
        let mut u1 = vec![T::ZERO; h * b];
        for j in 0..h {
            let lane = &mut u1[j * b..(j + 1) * b];
            lane.fill(params[b1o + j]);
            simd::broadcast_matvec_strided_seeded(&w1[j..], h, x, lane);
        }
        let mut a1 = vec![T::ZERO; h * b];
        for (av, &uv) in a1.iter_mut().zip(u1.iter()) {
            *av = lipswish_t(uv);
        }
        let mut u2 = vec![T::ZERO; o * b];
        for k in 0..o {
            let lane = &mut u2[k * b..(k + 1) * b];
            lane.fill(params[b2o + k]);
            simd::broadcast_matvec_strided_seeded(&w2[k..], o, &a1, lane);
        }
        // s2 = wout ⊙ final'(u2).
        let mut s2 = vec![T::ZERO; o * b];
        for idx in 0..o * b {
            s2[idx] = wout[idx] * dfinal(self.final_act, u2[idx]);
        }
        for k in 0..o {
            simd::add(&s2[k * b..(k + 1) * b], &mut gth[(b2o + k) * b..(b2o + k + 1) * b]);
        }
        for j in 0..h {
            for k in 0..o {
                let slot = w2o + j * o + k;
                simd::mul_add(
                    &a1[j * b..(j + 1) * b],
                    &s2[k * b..(k + 1) * b],
                    &mut gth[slot * b..(slot + 1) * b],
                );
            }
        }
        // s1 = (w2 s2) ⊙ ρ'(u1): row j of w2 is contiguous, so the hidden
        // cotangent is a zero-seeded broadcast reduction (scalar order).
        let mut s1 = vec![T::ZERO; h * b];
        for j in 0..h {
            simd::broadcast_matvec(&w2[j * o..(j + 1) * o], &s2, &mut s1[j * b..(j + 1) * b]);
        }
        for (sv, &uv) in s1.iter_mut().zip(u1.iter()) {
            *sv = *sv * dlipswish_t(uv);
        }
        for j in 0..h {
            simd::add(&s1[j * b..(j + 1) * b], &mut gth[(b1o + j) * b..(b1o + j + 1) * b]);
        }
        for i in 0..self.in_dim {
            for j in 0..h {
                let slot = w1o + i * h + j;
                simd::mul_add(
                    &x[i * b..(i + 1) * b],
                    &s1[j * b..(j + 1) * b],
                    &mut gth[slot * b..(slot + 1) * b],
                );
            }
        }
        for i in 0..self.in_dim {
            simd::broadcast_matvec(
                &w1[i * h..(i + 1) * h],
                &s1,
                &mut gx[i * b..(i + 1) * b],
            );
        }
    }
}

/// True when every weight tensor selected by `filter` is entrywise inside
/// `[-1/fan_in, 1/fan_in]` — the post-[`clip_lipschitz`] invariant the
/// Lipschitz bound rests on.
///
/// [`clip_lipschitz`]: ParamLayout::clip_lipschitz
pub fn weights_clipped<F: Fn(&str) -> bool>(
    layout: &ParamLayout,
    params: &[f32],
    filter: F,
) -> bool {
    layout.tensors.iter().all(|t| {
        if t.kind != ParamKind::Weight || !filter(&t.name) {
            return true;
        }
        let bound = 1.0 / t.fan_in.max(1) as f32 + 1e-7;
        params[t.offset..t.offset + t.len()].iter().all(|v| v.abs() <= bound)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::SplitPrng;
    use crate::nn::layout_from_specs;
    use crate::util::stats::central_gradient;

    fn demo_mlp(final_act: Activation) -> (Mlp, Vec<f64>) {
        let layout = layout_from_specs(&[
            ("t.w1", vec![3, 5], 3, ParamKind::Weight),
            ("t.b1", vec![5], 3, ParamKind::Bias),
            ("t.w2", vec![5, 2], 5, ParamKind::Weight),
            ("t.b2", vec![2], 5, ParamKind::Bias),
        ]);
        let mlp = Mlp::from_layout(&layout, "t", final_act).unwrap();
        let mut rng = SplitPrng::new(11);
        let params: Vec<f64> =
            (0..layout.total).map(|_| rng.next_normal_pair().0 * 0.4).collect();
        (mlp, params)
    }

    #[test]
    fn from_layout_reads_dims_and_offsets() {
        let (mlp, params) = demo_mlp(Activation::Tanh);
        assert_eq!((mlp.in_dim, mlp.hidden, mlp.out_dim), (3, 5, 2));
        assert_eq!(mlp.offset, 0);
        assert_eq!(mlp.param_len(), params.len());
    }

    #[test]
    fn lipswish_matches_reference_values() {
        // ρ(0) = 0, ρ(u) → 0.909·u for large u, ρ(−u) small.
        assert_eq!(lipswish(0.0), 0.0);
        assert!((lipswish(10.0) - 0.909 * 10.0 * sigmoid(10.0)).abs() < 1e-15);
        // Derivative against central differences.
        for &u in &[-3.0, -0.7, 0.0, 0.4, 2.5] {
            let h = 1e-6;
            let fd = (lipswish(u + h) - lipswish(u - h)) / (2.0 * h);
            assert!((dlipswish(u) - fd).abs() < 1e-8, "u={u}");
        }
    }

    #[test]
    fn forward_batch_bit_identical_to_per_path() {
        for act in [Activation::Identity, Activation::Tanh, Activation::Sigmoid] {
            let (mlp, params) = demo_mlp(act);
            for &b in &[1usize, 3, 4, 7, 8, 33] {
                let mut rng = SplitPrng::new(b as u64);
                let x_soa: Vec<f64> =
                    (0..3 * b).map(|_| rng.next_normal_pair().0 * 0.5).collect();
                let mut out_soa = vec![0.0; 2 * b];
                mlp.forward_batch(&params, &x_soa, &mut out_soa, b);
                for p in 0..b {
                    let xp: Vec<f64> = (0..3).map(|i| x_soa[i * b + p]).collect();
                    let mut op = [0.0; 2];
                    mlp.forward(&params, &xp, &mut op);
                    for k in 0..2 {
                        assert_eq!(out_soa[k * b + p], op[k], "act {act:?} b={b} p={p} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn vjp_batch_bit_identical_to_per_path() {
        for act in [Activation::Identity, Activation::Tanh] {
            let (mlp, params) = demo_mlp(act);
            let total = params.len();
            for &b in &[1usize, 4, 7, 33] {
                let mut rng = SplitPrng::new(100 + b as u64);
                let x_soa: Vec<f64> =
                    (0..3 * b).map(|_| rng.next_normal_pair().0 * 0.5).collect();
                let w_soa: Vec<f64> =
                    (0..2 * b).map(|_| rng.next_normal_pair().0).collect();
                let mut gx_soa = vec![0.0; 3 * b];
                let mut gth_lanes = vec![0.0; total * b];
                mlp.vjp_batch(&params, &x_soa, &w_soa, &mut gx_soa, &mut gth_lanes, b);
                for p in 0..b {
                    let xp: Vec<f64> = (0..3).map(|i| x_soa[i * b + p]).collect();
                    let wp: Vec<f64> = (0..2).map(|k| w_soa[k * b + p]).collect();
                    let mut gx = vec![0.0; 3];
                    let mut gth = vec![0.0; total];
                    mlp.vjp(&params, &xp, &wp, &mut gx, &mut gth);
                    for i in 0..3 {
                        assert_eq!(gx_soa[i * b + p], gx[i], "gx act {act:?} b={b} p={p}");
                    }
                    for m in 0..total {
                        assert_eq!(
                            gth_lanes[m * b + p],
                            gth[m],
                            "gth act {act:?} b={b} p={p} m={m}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn forward_and_vjp_f32_batch_bit_identical_to_per_path() {
        // The 8-wide f32 instantiation: batched ≡ per-path at the same
        // element precision, on batches straddling the 8-wide unroll.
        for act in [Activation::Identity, Activation::Tanh, Activation::Sigmoid] {
            let (mlp, params) = demo_mlp(act);
            let params32: Vec<f32> = params.iter().map(|&v| v as f32).collect();
            let total = params.len();
            for &b in &[1usize, 3, 4, 7, 8, 33] {
                let mut rng = SplitPrng::new(200 + b as u64);
                let x_soa: Vec<f32> =
                    (0..3 * b).map(|_| rng.next_normal_pair().0 as f32 * 0.5).collect();
                let w_soa: Vec<f32> =
                    (0..2 * b).map(|_| rng.next_normal_pair().0 as f32).collect();
                let mut out_soa = vec![0.0f32; 2 * b];
                mlp.forward_batch(&params32, &x_soa, &mut out_soa, b);
                let mut gx_soa = vec![0.0f32; 3 * b];
                let mut gth_lanes = vec![0.0f32; total * b];
                mlp.vjp_batch(&params32, &x_soa, &w_soa, &mut gx_soa, &mut gth_lanes, b);
                for p in 0..b {
                    let xp: Vec<f32> = (0..3).map(|i| x_soa[i * b + p]).collect();
                    let wp: Vec<f32> = (0..2).map(|k| w_soa[k * b + p]).collect();
                    let mut op = [0.0f32; 2];
                    mlp.forward(&params32, &xp, &mut op);
                    for k in 0..2 {
                        assert_eq!(
                            out_soa[k * b + p],
                            op[k],
                            "f32 fwd act {act:?} b={b} p={p} k={k}"
                        );
                    }
                    let mut gx = vec![0.0f32; 3];
                    let mut gth = vec![0.0f32; total];
                    mlp.vjp(&params32, &xp, &wp, &mut gx, &mut gth);
                    for i in 0..3 {
                        assert_eq!(gx_soa[i * b + p], gx[i], "f32 gx act {act:?} b={b} p={p}");
                    }
                    for m in 0..total {
                        assert_eq!(
                            gth_lanes[m * b + p],
                            gth[m],
                            "f32 gth act {act:?} b={b} p={p} m={m}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn f32_forward_tracks_f64_forward() {
        // Narrowed parameters and inputs produce outputs within single-
        // precision rounding of the f64 reference — the deviation budget the
        // mixed-precision training route inherits.
        let (mlp, params) = demo_mlp(Activation::Tanh);
        let params32: Vec<f32> = params.iter().map(|&v| v as f32).collect();
        let x = [0.3f64, -0.5, 0.8];
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let mut out = [0.0f64; 2];
        mlp.forward(&params, &x, &mut out);
        let mut out32 = [0.0f32; 2];
        mlp.forward(&params32, &x32, &mut out32);
        for k in 0..2 {
            assert!(
                (out32[k] as f64 - out[k]).abs() < 1e-5 * (1.0 + out[k].abs()),
                "k={k}: {} vs {}",
                out32[k],
                out[k]
            );
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        for act in [Activation::Identity, Activation::Tanh, Activation::Sigmoid] {
            let (mlp, params) = demo_mlp(act);
            let x = [0.3, -0.5, 0.8];
            let wout = [0.7, -1.1];
            let obs = |pp: &[f64], xx: &[f64]| -> f64 {
                let mut out = [0.0; 2];
                mlp.forward(pp, xx, &mut out);
                out.iter().zip(&wout).map(|(o, w)| o * w).sum()
            };
            let mut gx = vec![0.0; 3];
            let mut gth = vec![0.0; params.len()];
            mlp.vjp(&params, &x, &wout, &mut gx, &mut gth);
            let fd_x = central_gradient(|xx| obs(&params, xx), &x, 1e-6);
            for i in 0..3 {
                assert!((gx[i] - fd_x[i]).abs() < 1e-8, "act {act:?} gx[{i}]");
            }
            let fd_th = central_gradient(|pp| obs(pp, &x), &params, 1e-6);
            for m in 0..params.len() {
                assert!((gth[m] - fd_th[m]).abs() < 1e-8, "act {act:?} gth[{m}]");
            }
        }
    }

    #[test]
    fn weights_clipped_detects_violations() {
        let layout = layout_from_specs(&[
            ("f.w1", vec![4, 2], 4, ParamKind::Weight),
            ("f.b1", vec![2], 4, ParamKind::Bias),
        ]);
        let mut p = vec![2.0f32; layout.total];
        assert!(!weights_clipped(&layout, &p, |n| n.starts_with("f.")));
        layout.clip_lipschitz(&mut p, |n| n.starts_with("f."));
        assert!(weights_clipped(&layout, &p, |n| n.starts_with("f.")));
        // Biases are exempt, and unfiltered tensors are ignored.
        assert!(weights_clipped(&layout, &p, |_| false));
    }
}
