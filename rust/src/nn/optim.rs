//! Optimisers over flat parameter vectors.
//!
//! The paper trains Latent SDEs with Adam and SDE-GANs with Adadelta
//! (Appendix F.2, following Kidger et al. 2021), applies per-parameter-group
//! learning rates, and stabilises GAN training with stochastic weight
//! averaging over the last 50% of steps. All of that is implemented here,
//! operating on the flat `f32` vectors that flow into the PJRT executables.

/// A first-order optimiser over a flat parameter vector.
pub trait Optimizer {
    /// Apply one update given the gradient (same length as `params`).
    fn step(&mut self, params: &mut [f32], grad: &[f32]);

    /// Number of updates applied so far.
    fn steps_taken(&self) -> u64;
}

/// Plain SGD (used by the in-Rust metric models, and as a baseline).
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
    steps: u64,
}

impl Sgd {
    /// New SGD optimiser.
    pub fn new(lr: f32) -> Self {
        Self { lr, steps: 0 }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        for (p, g) in params.iter_mut().zip(grad) {
            *p -= self.lr * g;
        }
        self.steps += 1;
    }

    fn steps_taken(&self) -> u64 {
        self.steps
    }
}

/// Adam (Kingma & Ba 2015) with optional per-index learning-rate scaling —
/// the paper gives `ζ_θ`/`ξ_φ` a different learning rate from the vector
/// fields (Appendix F.3/F.4), which we express as `lr_scale` over the flat
/// vector.
pub struct Adam {
    /// Base learning rate.
    pub lr: f32,
    /// First-moment decay (default 0.9).
    pub beta1: f32,
    /// Second-moment decay (default 0.999).
    pub beta2: f32,
    /// Numerical fuzz (default 1e-8).
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    /// Optional per-index multiplier on `lr` (empty = all ones).
    pub lr_scale: Vec<f32>,
    steps: u64,
}

impl Adam {
    /// New Adam state for `n` parameters.
    pub fn new(lr: f32, n: usize) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            v: vec![0.0; n],
            lr_scale: Vec::new(),
            steps: 0,
        }
    }

    /// Set a per-index learning-rate multiplier.
    pub fn with_lr_scale(mut self, scale: Vec<f32>) -> Self {
        assert_eq!(scale.len(), self.m.len());
        self.lr_scale = scale;
        self
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(params.len(), grad.len());
        self.steps += 1;
        let t = self.steps as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            let scale = self.lr_scale.get(i).copied().unwrap_or(1.0);
            params[i] -= self.lr * scale * mhat / (vhat.sqrt() + self.eps);
        }
    }

    fn steps_taken(&self) -> u64 {
        self.steps
    }
}

/// Adadelta (Zeiler 2012): the optimiser Kidger et al. use for SDE-GANs.
/// `Clone` so the GAN training watchdog can snapshot the accumulator state
/// and roll a diverged step back.
#[derive(Clone)]
pub struct Adadelta {
    /// Learning rate (PyTorch calls this `lr`; torchsde GANs use ~1.0×
    /// group-specific scaling).
    pub lr: f32,
    /// Decay of the squared-gradient/update accumulators (default 0.9).
    pub rho: f32,
    /// Numerical fuzz (default 1e-6).
    pub eps: f32,
    acc_grad: Vec<f32>,
    acc_update: Vec<f32>,
    /// Optional per-index multiplier on `lr` (empty = all ones).
    pub lr_scale: Vec<f32>,
    steps: u64,
}

impl Adadelta {
    /// New Adadelta state for `n` parameters.
    pub fn new(lr: f32, n: usize) -> Self {
        Self {
            lr,
            rho: 0.9,
            eps: 1e-6,
            acc_grad: vec![0.0; n],
            acc_update: vec![0.0; n],
            lr_scale: Vec::new(),
            steps: 0,
        }
    }

    /// Set a per-index learning-rate multiplier.
    pub fn with_lr_scale(mut self, scale: Vec<f32>) -> Self {
        assert_eq!(scale.len(), self.acc_grad.len());
        self.lr_scale = scale;
        self
    }
}

impl Optimizer for Adadelta {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.acc_grad.len());
        assert_eq!(params.len(), grad.len());
        for i in 0..params.len() {
            let g = grad[i];
            self.acc_grad[i] = self.rho * self.acc_grad[i] + (1.0 - self.rho) * g * g;
            let update = (self.acc_update[i] + self.eps).sqrt()
                / (self.acc_grad[i] + self.eps).sqrt()
                * g;
            self.acc_update[i] =
                self.rho * self.acc_update[i] + (1.0 - self.rho) * update * update;
            let scale = self.lr_scale.get(i).copied().unwrap_or(1.0);
            params[i] -= self.lr * scale * update;
        }
        self.steps += 1;
    }

    fn steps_taken(&self) -> u64 {
        self.steps
    }
}

/// Apply one optimiser step from an `f64` gradient — the bridge from the
/// native adjoint engine (`solvers::adjoint` produces flat `f64` gradients,
/// `dy0`/`dtheta`) to the `f32` parameter vectors the optimisers drive.
/// Values are narrowed with a plain `as f32` cast (non-finite values pass
/// through so divergence stays visible rather than being masked).
pub fn step_f64<O: Optimizer>(opt: &mut O, params: &mut [f32], grad: &[f64]) {
    assert_eq!(params.len(), grad.len());
    let g32: Vec<f32> = grad.iter().map(|&g| g as f32).collect();
    opt.step(params, &g32);
}

/// Stochastic weight averaging (Appendix F.2): a Cesàro mean of generator
/// weights over the latter part of training, used as the final model.
/// `Clone` so the GAN training watchdog can snapshot and roll back the
/// running average together with the weights it averages.
#[derive(Clone)]
pub struct StochasticWeightAverage {
    sum: Vec<f32>,
    count: u64,
}

impl StochasticWeightAverage {
    /// New accumulator for `n` parameters.
    pub fn new(n: usize) -> Self {
        Self { sum: vec![0.0; n], count: 0 }
    }

    /// Accumulate a snapshot.
    pub fn update(&mut self, params: &[f32]) {
        assert_eq!(params.len(), self.sum.len());
        for (s, &p) in self.sum.iter_mut().zip(params) {
            *s += p;
        }
        self.count += 1;
    }

    /// Number of snapshots accumulated.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The averaged weights (panics if no snapshots were taken).
    pub fn average(&self) -> Vec<f32> {
        assert!(self.count > 0, "SWA average of zero snapshots");
        let inv = 1.0 / self.count as f32;
        self.sum.iter().map(|&s| s * inv).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic bowl: grad = p - target.
    fn converges<O: Optimizer>(mut opt: O, iters: usize, tol: f32) -> bool {
        let target = [1.0f32, -2.0, 0.5];
        let mut p = [0.0f32; 3];
        for _ in 0..iters {
            let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
            opt.step(&mut p, &g);
        }
        p.iter().zip(&target).all(|(a, b)| (a - b).abs() < tol)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(converges(Sgd::new(0.1), 200, 1e-3));
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(converges(Adam::new(0.05, 3), 500, 1e-2));
    }

    #[test]
    fn adadelta_converges_on_quadratic() {
        assert!(converges(Adadelta::new(1.0, 3), 4000, 0.05));
    }

    #[test]
    fn adam_matches_reference_first_step() {
        // Hand-computed: with g = 1, lr = 0.1, the first Adam update is
        // -lr * g/(|g| + eps) ≈ -0.1.
        let mut opt = Adam::new(0.1, 1);
        let mut p = [0.0f32];
        opt.step(&mut p, &[1.0]);
        assert!((p[0] + 0.1).abs() < 1e-4, "p={}", p[0]);
    }

    #[test]
    fn lr_scale_freezes_parameters() {
        let mut opt = Adam::new(0.1, 2).with_lr_scale(vec![1.0, 0.0]);
        let mut p = [0.0f32, 0.0];
        for _ in 0..10 {
            opt.step(&mut p, &[1.0, 1.0]);
        }
        assert!(p[0] < -0.5);
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn step_f64_matches_pre_narrowed_gradient() {
        let mut a = Adam::new(0.05, 3);
        let mut b = Adam::new(0.05, 3);
        let mut pa = [0.1f32, -0.2, 0.3];
        let mut pb = pa;
        let g64 = [0.5f64, -1.25, 2.0];
        let g32: Vec<f32> = g64.iter().map(|&g| g as f32).collect();
        step_f64(&mut a, &mut pa, &g64);
        b.step(&mut pb, &g32);
        assert_eq!(pa, pb);
    }

    #[test]
    fn swa_averages() {
        let mut swa = StochasticWeightAverage::new(2);
        swa.update(&[1.0, 2.0]);
        swa.update(&[3.0, 4.0]);
        assert_eq!(swa.average(), vec![2.0, 3.0]);
        assert_eq!(swa.count(), 2);
    }

    #[test]
    #[should_panic(expected = "zero snapshots")]
    fn swa_empty_panics() {
        StochasticWeightAverage::new(1).average();
    }
}
