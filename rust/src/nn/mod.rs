//! Neural-network parameter management on the Rust side.
//!
//! Training state lives in Rust as **flat `f32` vectors**; the JAX side
//! (Layer 2) unflattens them inside the AOT-compiled executables. The
//! contract between the two is the parameter layout recorded in
//! `artifacts/manifest.json` by `python/compile/aot.py`: an ordered list of
//! `(name, shape, offset, fan_in, kind)` entries. This module parses that
//! layout, initialises parameters to match the JAX reference initialisation,
//! and implements the optimisers the paper trains with (Adam for Latent
//! SDEs, Adadelta for SDE-GANs — Appendix F.2) plus the paper's third
//! contribution: **hard Lipschitz enforcement by weight clipping**
//! (Section 5) and stochastic weight averaging.

pub mod mlp;
mod optim;

pub use mlp::{lipswish, weights_clipped, Activation, Mlp};
pub use optim::{step_f64, Adadelta, Adam, Optimizer, Sgd, StochasticWeightAverage};

use crate::brownian::SplitPrng;
use crate::util::json::Json;

/// Kind of a parameter tensor — decides initialisation and clipping.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// A linear-layer weight matrix (clipped in Lipschitz-constrained nets).
    Weight,
    /// A bias vector (never clipped: adding a bias is 1-Lipschitz).
    Bias,
    /// Anything else (readout vectors, initial values, ...).
    Other,
}

/// One tensor inside a flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamTensor {
    /// Dotted path, e.g. `"disc.f.layers.0.w"`.
    pub name: String,
    /// Shape (row-major).
    pub shape: Vec<usize>,
    /// Offset into the flat vector.
    pub offset: usize,
    /// Fan-in of the linear map this tensor belongs to (for init/clipping).
    pub fan_in: usize,
    /// Tensor kind.
    pub kind: ParamKind,
}

impl ParamTensor {
    /// Number of elements.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True if the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The layout of a flat parameter vector.
#[derive(Clone, Debug, Default)]
pub struct ParamLayout {
    /// Ordered tensors; offsets are contiguous and ascending.
    pub tensors: Vec<ParamTensor>,
    /// Total number of scalars.
    pub total: usize,
}

impl ParamLayout {
    /// Parse from the manifest JSON produced by `aot.py`:
    /// `[{"name": ..., "shape": [...], "offset": n, "fan_in": n,
    ///    "kind": "weight"|"bias"|"other"}, ...]`.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("layout: expected array"))?;
        let mut tensors = Vec::with_capacity(arr.len());
        let mut total = 0usize;
        for item in arr {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("layout entry missing name"))?
                .to_string();
            let shape: Vec<usize> = item
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("{name}: missing shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let offset = item
                .get("offset")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("{name}: missing offset"))?;
            let fan_in = item.get("fan_in").and_then(Json::as_usize).unwrap_or(1);
            let kind = match item.get("kind").and_then(Json::as_str) {
                Some("weight") => ParamKind::Weight,
                Some("bias") => ParamKind::Bias,
                _ => ParamKind::Other,
            };
            let t = ParamTensor { name, shape, offset, fan_in, kind };
            anyhow::ensure!(t.offset == total, "{}: non-contiguous offset", t.name);
            total += t.len();
            tensors.push(t);
        }
        Ok(Self { tensors, total })
    }

    /// Look up a tensor by name.
    pub fn find(&self, name: &str) -> Option<&ParamTensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Initialise a flat parameter vector:
    /// weights `~ U(-1/√fan_in, 1/√fan_in)` (PyTorch `nn.Linear` default,
    /// which the paper's torchsde implementation uses), biases likewise,
    /// `Other` tensors to zero. `scale(name) -> f32` multiplies each
    /// tensor's draw — this is the paper's α/β initialisation-scaling
    /// hyperparameter (Appendix F.2, equation (33)).
    pub fn init<F: Fn(&str) -> f32>(&self, seed: u64, scale: F) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total];
        let mut rng = SplitPrng::new(seed);
        for t in &self.tensors {
            let s = scale(&t.name);
            let bound = 1.0 / (t.fan_in.max(1) as f64).sqrt();
            let dst = &mut out[t.offset..t.offset + t.len()];
            match t.kind {
                ParamKind::Weight | ParamKind::Bias => {
                    for v in dst.iter_mut() {
                        let u = rng.next_uniform() * 2.0 - 1.0;
                        *v = (u * bound) as f32 * s;
                    }
                }
                ParamKind::Other => {
                    for v in dst.iter_mut() {
                        let u = rng.next_uniform() * 2.0 - 1.0;
                        *v = (u * 0.1) as f32 * s;
                    }
                }
            }
        }
        out
    }

    /// The paper's hard Lipschitz constraint (Section 5, "Clipping"):
    /// after each optimiser step, clip every **weight** tensor entry to
    /// `[-1/fan_in, 1/fan_in]`, which enforces `‖Ax‖∞ ≤ ‖x‖∞` per linear
    /// map and hence vector fields of Lipschitz constant ≤ 1 (with
    /// 1-Lipschitz activations such as LipSwish).
    ///
    /// `filter` selects which tensors participate (the discriminator's
    /// vector fields `f_φ`, `g_φ` — the generator is unconstrained).
    pub fn clip_lipschitz<F: Fn(&str) -> bool>(&self, params: &mut [f32], filter: F) {
        for t in &self.tensors {
            if t.kind != ParamKind::Weight || !filter(&t.name) {
                continue;
            }
            let bound = 1.0 / t.fan_in.max(1) as f32;
            for v in &mut params[t.offset..t.offset + t.len()] {
                *v = v.clamp(-bound, bound);
            }
        }
    }
}

/// SDE-GAN network dimensions (the scaled-down Appendix-F.7 defaults of
/// `python/compile/nets.py::GanSpec`), with **native layout constructors**:
/// the pure-Rust training path builds its [`ParamLayout`]s from this spec —
/// same tensor names, shapes, fan-ins and ordering as the JAX
/// `LayoutBuilder` — so no `artifacts/manifest.json` is required. The
/// manifest lookup survives only as the `pjrt` runtime path's source of the
/// same layouts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GanNetSpec {
    /// Data channels `y`.
    pub data_dim: usize,
    /// Generator SDE state dimension `x`.
    pub state: usize,
    /// MLP hidden width `h` (shared by generator and discriminator nets).
    pub hidden: usize,
    /// Brownian dimension `w` driving the generator.
    pub noise: usize,
    /// Initial-noise dimension `v` feeding `ζ_θ`.
    pub init_noise: usize,
    /// Discriminator CDE state dimension `dh`.
    pub disc_state: usize,
    /// Discriminator hidden width `dhh`.
    pub disc_hidden: usize,
}

impl GanNetSpec {
    /// The paper-scaled defaults for `y` data channels.
    pub fn for_data_dim(data_dim: usize) -> Self {
        Self {
            data_dim,
            state: 16,
            hidden: 32,
            noise: 4,
            init_noise: 4,
            disc_state: 16,
            disc_hidden: 32,
        }
    }

    /// Generator layout: `ζ_θ : V → X₀`, vector fields `μ_θ(t, X)`,
    /// `σ_θ(t, X)` (output `x·w`), affine readout `ℓ_θ : X → Y`.
    pub fn gen_layout(&self) -> ParamLayout {
        let (y, x, h, w, v) = (self.data_dim, self.state, self.hidden, self.noise, self.init_noise);
        layout_from_specs(&[
            ("zeta.w1", vec![v, h], v, ParamKind::Weight),
            ("zeta.b1", vec![h], v, ParamKind::Bias),
            ("zeta.w2", vec![h, x], h, ParamKind::Weight),
            ("zeta.b2", vec![x], h, ParamKind::Bias),
            ("mu.w1", vec![1 + x, h], 1 + x, ParamKind::Weight),
            ("mu.b1", vec![h], 1 + x, ParamKind::Bias),
            ("mu.w2", vec![h, x], h, ParamKind::Weight),
            ("mu.b2", vec![x], h, ParamKind::Bias),
            ("sigma.w1", vec![1 + x, h], 1 + x, ParamKind::Weight),
            ("sigma.b1", vec![h], 1 + x, ParamKind::Bias),
            ("sigma.w2", vec![h, x * w], h, ParamKind::Weight),
            ("sigma.b2", vec![x * w], h, ParamKind::Bias),
            ("ell.w", vec![x, y], x, ParamKind::Weight),
            ("ell.b", vec![y], x, ParamKind::Bias),
        ])
    }

    /// Discriminator layout: initial map `ξ_φ(t₀, Y₀)`, CDE vector fields
    /// `f_φ(t, H)`, `g_φ(t, H)` (output `dh·y`), readout vector `m_φ`.
    pub fn disc_layout(&self) -> ParamLayout {
        let (y, dh, dhh) = (self.data_dim, self.disc_state, self.disc_hidden);
        layout_from_specs(&[
            ("xi.w1", vec![1 + y, dhh], 1 + y, ParamKind::Weight),
            ("xi.b1", vec![dhh], 1 + y, ParamKind::Bias),
            ("xi.w2", vec![dhh, dh], dhh, ParamKind::Weight),
            ("xi.b2", vec![dh], dhh, ParamKind::Bias),
            ("f.w1", vec![1 + dh, dhh], 1 + dh, ParamKind::Weight),
            ("f.b1", vec![dhh], 1 + dh, ParamKind::Bias),
            ("f.w2", vec![dhh, dh], dhh, ParamKind::Weight),
            ("f.b2", vec![dh], dhh, ParamKind::Bias),
            ("g.w1", vec![1 + dh, dhh], 1 + dh, ParamKind::Weight),
            ("g.b1", vec![dhh], 1 + dh, ParamKind::Bias),
            ("g.w2", vec![dhh, dh * y], dhh, ParamKind::Weight),
            ("g.b2", vec![dh * y], dhh, ParamKind::Bias),
            ("m", vec![dh], dh, ParamKind::Other),
        ])
    }
}

/// Build a layout programmatically (used by tests and the pure-Rust
/// experiment paths that don't go through the JAX manifest).
pub fn layout_from_specs(specs: &[(&str, Vec<usize>, usize, ParamKind)]) -> ParamLayout {
    let mut tensors = Vec::new();
    let mut total = 0;
    for (name, shape, fan_in, kind) in specs {
        let t = ParamTensor {
            name: name.to_string(),
            shape: shape.clone(),
            offset: total,
            fan_in: *fan_in,
            kind: *kind,
        };
        total += t.len();
        tensors.push(t);
    }
    ParamLayout { tensors, total }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_layout() -> ParamLayout {
        layout_from_specs(&[
            ("f.w1", vec![4, 8], 4, ParamKind::Weight),
            ("f.b1", vec![8], 4, ParamKind::Bias),
            ("f.w2", vec![8, 2], 8, ParamKind::Weight),
            ("readout", vec![2], 1, ParamKind::Other),
        ])
    }

    #[test]
    fn layout_offsets_contiguous() {
        let l = demo_layout();
        assert_eq!(l.total, 32 + 8 + 16 + 2);
        assert_eq!(l.find("f.w2").unwrap().offset, 40);
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"[
            {"name": "a.w", "shape": [2, 3], "offset": 0, "fan_in": 2, "kind": "weight"},
            {"name": "a.b", "shape": [3], "offset": 6, "fan_in": 2, "kind": "bias"}
        ]"#;
        let l = ParamLayout::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(l.total, 9);
        assert_eq!(l.tensors[0].kind, ParamKind::Weight);
        assert_eq!(l.tensors[1].kind, ParamKind::Bias);
    }

    #[test]
    fn json_rejects_gaps() {
        let src = r#"[
            {"name": "a.w", "shape": [2], "offset": 1, "fan_in": 1, "kind": "weight"}
        ]"#;
        assert!(ParamLayout::from_json(&Json::parse(src).unwrap()).is_err());
    }

    #[test]
    fn init_respects_bounds_and_scale() {
        let l = demo_layout();
        let p = l.init(42, |name| if name.starts_with("f.w1") { 2.0 } else { 1.0 });
        let w1 = &p[0..32];
        let bound1 = 2.0 / (4.0f32).sqrt();
        assert!(w1.iter().all(|v| v.abs() <= bound1));
        assert!(w1.iter().any(|v| v.abs() > 0.5 / (4.0f32).sqrt()));
        let w2 = &p[40..56];
        assert!(w2.iter().all(|v| v.abs() <= 1.0 / (8.0f32).sqrt()));
    }

    #[test]
    fn init_deterministic() {
        let l = demo_layout();
        assert_eq!(l.init(7, |_| 1.0), l.init(7, |_| 1.0));
        assert_ne!(l.init(7, |_| 1.0), l.init(8, |_| 1.0));
    }

    #[test]
    fn clipping_bounds_weights_only() {
        let l = demo_layout();
        let mut p = vec![10.0f32; l.total];
        l.clip_lipschitz(&mut p, |name| name.starts_with("f."));
        // f.w1 clipped to 1/4, f.b1 untouched, f.w2 clipped to 1/8,
        // readout untouched.
        assert!(p[0..32].iter().all(|&v| v == 0.25));
        assert!(p[32..40].iter().all(|&v| v == 10.0));
        assert!(p[40..56].iter().all(|&v| v == 0.125));
        assert!(p[56..58].iter().all(|&v| v == 10.0));
    }

    #[test]
    fn gan_net_spec_layouts_match_the_jax_builder() {
        // Mirrors nets.py::GanSpec at the paper-scaled defaults: same tensor
        // order, shapes and fan-ins, so the flat vectors are interchangeable
        // with the manifest layouts.
        let spec = GanNetSpec::for_data_dim(1);
        let gl = spec.gen_layout();
        // zeta: 4*32+32+32*16+16, mu: 17*32+32+32*16+16,
        // sigma: 17*32+32+32*64+64, ell: 16+1.
        assert_eq!(gl.total, 688 + 1104 + 2688 + 17);
        assert_eq!(gl.find("mu.w1").unwrap().shape, vec![17, 32]);
        assert_eq!(gl.find("sigma.w2").unwrap().shape, vec![32, 64]);
        assert_eq!(gl.find("ell.w").unwrap().fan_in, 16);
        let dl = spec.disc_layout();
        // xi: 2*32+32+32*16+16, f: 17*32+32+32*16+16, g: same (y = 1), m: 16.
        assert_eq!(dl.total, 624 + 1104 + 1104 + 16);
        assert_eq!(dl.find("m").unwrap().kind, ParamKind::Other);
        // Every MLP resolves through the descriptor used by the native
        // vector fields.
        for (layout, prefix) in
            [(&gl, "zeta"), (&gl, "mu"), (&gl, "sigma"), (&dl, "xi"), (&dl, "f"), (&dl, "g")]
        {
            assert!(
                Mlp::from_layout(layout, prefix, Activation::Identity).is_ok(),
                "{prefix} should resolve"
            );
        }
    }

    #[test]
    fn clipping_enforces_inf_norm_contraction() {
        // ‖Ax‖∞ ≤ ‖x‖∞ after clipping, for the worst-case x = sign pattern.
        let l = layout_from_specs(&[("w", vec![6, 5], 6, ParamKind::Weight)]);
        let mut p: Vec<f32> = (0..30).map(|i| (i as f32 - 15.0) * 0.3).collect();
        l.clip_lipschitz(&mut p, |_| true);
        // Worst-case output coordinate: sum of |entries| down a column
        // (x multiplies along fan-in = rows here; row-major [in=6, out=5]).
        for j in 0..5 {
            let col_abs_sum: f32 = (0..6).map(|i| p[i * 5 + j].abs()).sum();
            assert!(col_abs_sum <= 1.0 + 1e-6, "column {j}: {col_abs_sum}");
        }
    }
}
