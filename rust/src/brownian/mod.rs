//! Brownian-motion sampling and reconstruction.
//!
//! This module implements the paper's second contribution — the **Brownian
//! Interval** (Section 4): a fast, memory-efficient, *exact* way of sampling
//! and reconstructing Brownian motion, built around a binary tree of
//! `(interval, seed)` pairs, a splittable PRNG, and an LRU cache of computed
//! increments.
//!
//! It also implements the two baselines the paper compares against:
//!
//! * [`VirtualBrownianTree`] — the approximate, `O(log(1/eps))`-per-query
//!   dyadic tree of Li et al. (2020), reimplemented from its description so
//!   that the comparison is Rust-vs-Rust;
//! * [`StoredPath`] — the naive `O(T)`-memory approach that stores every
//!   increment on a fixed grid.
//!
//! All sources implement the [`BrownianSource`] trait, which is what the SDE
//! solvers in [`crate::solvers`] and the training coordinator consume. Every
//! source is deterministic given its seed: re-running the same query sequence
//! reproduces bit-identical noise, which is what makes the backward
//! (adjoint) pass see *exactly* the forward pass's Brownian sample. The
//! native adjoint engine leans on this directly — it either re-queries the
//! source right-to-left, or pulls the whole grid in one
//! [`BrownianSource::fill_grid`] descent and replays it in reverse
//! (`solvers::GridReplayNoise`); both produce the forward pass's exact bits.

mod interval;
mod levy;
mod lru;
mod prng;
mod stored;
mod virtual_tree;

pub use interval::{BrownianInterval, IntervalOptions, QueryStats};
pub use levy::{
    davie_levy_area, space_time_levy_area, space_time_levy_area_into, BrownianWithLevy,
};
pub use lru::LruCache;
pub use prng::{box_muller_fill, normal_at, split_seed, splitmix64, SplitPrng};
pub use stored::StoredPath;
pub use virtual_tree::VirtualBrownianTree;

/// A source of Brownian increments over a fixed time horizon.
///
/// `size` independent scalar Brownian motions are simulated simultaneously
/// (in practice `size = batch * noise_channels`). Increments over the same
/// `(s, t)` are deterministic: querying twice returns identical values, and
/// `W(s, u) == W(s, t) + W(t, u)` holds (exactly for [`BrownianInterval`]
/// and [`StoredPath`]; up to the tolerance `eps` for
/// [`VirtualBrownianTree`]).
pub trait BrownianSource {
    /// Number of independent Brownian channels.
    fn size(&self) -> usize;

    /// Time horizon `[t0, t1]` this source is defined over.
    fn span(&self) -> (f64, f64);

    /// Write `W(t) - W(s)` for each channel into `out` (length `size()`).
    ///
    /// Requires `t0 <= s < t <= t1`.
    fn increment(&mut self, s: f64, t: f64, out: &mut [f32]);

    /// Convenience wrapper allocating the output vector.
    ///
    /// **Not for hot paths**: this allocates on every call. Solve and
    /// training loops should query [`increment`](Self::increment) into a
    /// reusable buffer, or better, pull the whole grid in one
    /// [`fill_grid`](Self::fill_grid) descent
    /// (`solvers::StoredBatchNoise::fill_from_source` /
    /// `solvers::GridReplayNoise::from_source` wrap exactly that pattern).
    fn increment_vec(&mut self, s: f64, t: f64) -> Vec<f32> {
        let mut out = vec![0.0; self.size()];
        self.increment(s, t, &mut out);
        out
    }

    /// Bulk fill: write the increment over every consecutive interval of the
    /// strictly-increasing observation grid `ts` into `out`, step-major
    /// (`out[k * size() .. (k + 1) * size()]` holds `W(ts[k+1]) - W(ts[k])`).
    ///
    /// Equivalent to `ts.len() - 1` sequential [`increment`](Self::increment)
    /// calls (bit-identically so), but sources may override it to walk the
    /// grid in a single traversal — [`BrownianInterval`] skips per-query
    /// revalidation, [`VirtualBrownianTree`] halves its tree descents by
    /// evaluating each grid point once.
    fn fill_grid(&mut self, ts: &[f64], out: &mut [f32]) {
        let n = ts.len().saturating_sub(1);
        let size = self.size();
        assert_eq!(out.len(), n * size, "fill_grid: need {} values", n * size);
        for k in 0..n {
            self.increment(ts[k], ts[k + 1], &mut out[k * size..(k + 1) * size]);
        }
    }
}

/// Validates a query interval against a source's span; panics on misuse.
///
/// Kept as a free function so all three sources report identical errors.
pub(crate) fn check_interval(span: (f64, f64), s: f64, t: f64) {
    assert!(
        s < t,
        "Brownian increment requires s < t, got s={s}, t={t}"
    );
    assert!(
        s >= span.0 - 1e-12 && t <= span.1 + 1e-12,
        "query [{s}, {t}] outside Brownian span [{}, {}]",
        span.0,
        span.1
    );
}
