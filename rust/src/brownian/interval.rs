//! The Brownian Interval (paper Section 4, Appendix E).
//!
//! A binary tree whose nodes are `(interval, seed)` pairs. The tree starts
//! as a stump holding the global interval `[t0, t1]` and a root seed; leaf
//! nodes are created lazily as queries are made, so the tree's shape encodes
//! the conditional structure of the queries actually performed. Node values
//! (the Brownian increments `W_{a,b}`) are *not* stored in the tree — they
//! are recomputed on demand from the seeds via Lévy's Brownian-bridge
//! formula, with a fixed-size LRU cache over computed increments making the
//! common sequential access pattern `O(1)` per query.
//!
//! Compared to the paper's Algorithm 3/4 pseudocode:
//! * the tree is an index arena (`Vec<Node>`), not pointer-linked — queries
//!   are iterative with an explicit stack, so deep trees cannot overflow the
//!   call stack (the paper's "trampolining" remark);
//! * the bridge sample at a split point is always drawn from the **left**
//!   child's seed, whichever child is being queried — this is what makes
//!   `W_left + W_right == W_parent` hold *exactly* (bit-equal), which the
//!   paper's pseudocode leaves implicit;
//! * `bisect` creates both children at once, so sibling seeds always exist.

use super::lru::LruCache;
use super::prng::{box_muller_fill, split_seed};
use super::{check_interval, BrownianSource};

const NIL: u32 = u32::MAX;

#[derive(Clone, Copy, Debug)]
struct Node {
    a: f64,
    b: f64,
    seed: u64,
    parent: u32,
    left: u32,
    right: u32,
}

impl Node {
    #[inline]
    fn is_leaf(&self) -> bool {
        self.left == NIL
    }
}

/// Counters describing how a [`BrownianInterval`] has been exercised.
///
/// Used by the Table-2/7/8/9 benchmark harness to report cache behaviour and
/// by tests asserting the access-pattern properties from Appendix E.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueryStats {
    /// Total `increment` queries served.
    pub queries: u64,
    /// Tree nodes created (excluding the root).
    pub nodes_created: u64,
    /// Bridge samples actually computed (cache misses resolved).
    pub bridges_sampled: u64,
    /// Nodes popped during tree descents (`traverse` and the bulk
    /// `fill_grid` descent) — the traversal-work metric the grid-fill
    /// optimisation is measured by.
    pub node_visits: u64,
    /// Longest ancestor walk needed to find a cached value.
    pub max_recompute_depth: u32,
    /// LRU cache hits.
    pub cache_hits: u64,
    /// LRU cache misses.
    pub cache_misses: u64,
}

/// Tunables for [`BrownianInterval::with_options`].
#[derive(Clone, Copy, Debug)]
pub struct IntervalOptions {
    /// LRU capacity, in cached increments. Each entry costs `size * 4`
    /// bytes. Must be `>= 1` ([`BrownianInterval::with_options`] rejects 0
    /// — there is no silent clamping); the constructed interval reports the
    /// capacity actually in effect via
    /// [`BrownianInterval::cache_capacity`]. Capacity 1 is valid and
    /// bit-exact (the tree descent only ever re-reads the most recently
    /// cached parent), just slow: every ancestor value is recomputed on
    /// each query.
    pub cache_capacity: usize,
    /// Pre-build a balanced dyadic tree of this depth (Appendix E,
    /// "Backward pass"): guarantees `O(log)` worst-case recompute cost when
    /// the backward pass crosses out of the cached window. Depth `d` creates
    /// `2^(d+1) - 1` nodes. `0` disables pre-seeding.
    pub preseed_depth: u32,
}

impl Default for IntervalOptions {
    fn default() -> Self {
        Self { cache_capacity: 128, preseed_depth: 0 }
    }
}

/// Exact, `O(1)`-GPU-memory Brownian motion sampling (paper Section 4).
pub struct BrownianInterval {
    t0: f64,
    t1: f64,
    size: usize,
    nodes: Vec<Node>,
    cache: LruCache<u32, Vec<f32>>,
    /// Recycled value buffers (evicted cache entries) — keeps the hot path
    /// allocation-free once warm.
    free: Vec<Vec<f32>>,
    /// Most recent node touched; traversals start here (Appendix E,
    /// "Search hints").
    hint: u32,
    /// Scratch stacks, retained across queries.
    up_stack: Vec<u32>,
    walk_stack: Vec<(u32, f64, f64)>,
    out_nodes: Vec<u32>,
    /// Scratch for the bulk grid descent: pending `(node, span, step range)`
    /// work items and the resulting `(node, step)` partition.
    grid_stack: Vec<(u32, f64, f64, usize, usize)>,
    grid_parts: Vec<(u32, usize)>,
    stats: QueryStats,
    /// Endpoint snap tolerance (absolute, in time units).
    tol: f64,
}

impl BrownianInterval {
    /// Brownian motion over `[t0, t1]` with `size` channels and default
    /// options.
    pub fn new(t0: f64, t1: f64, size: usize, seed: u64) -> Self {
        Self::with_options(t0, t1, size, seed, IntervalOptions::default())
    }

    /// Brownian motion with explicit cache capacity / dyadic pre-seeding.
    pub fn with_options(
        t0: f64,
        t1: f64,
        size: usize,
        seed: u64,
        opts: IntervalOptions,
    ) -> Self {
        assert!(t1 > t0, "need t1 > t0");
        assert!(size >= 1, "need at least one channel");
        // Honour the requested capacity exactly (historically 0 and 1 were
        // silently clamped to 2, while the LRU's own constructor asserts
        // `>= 1` — a confusing split). Capacity only affects speed, never
        // bits: see `cache_size_does_not_change_the_path`.
        assert!(
            opts.cache_capacity >= 1,
            "IntervalOptions::cache_capacity must be >= 1 (capacity only trades \
             recompute cost for memory; there is no meaningful zero-capacity cache)"
        );
        let root = Node { a: t0, b: t1, seed, parent: NIL, left: NIL, right: NIL };
        let mut bi = Self {
            t0,
            t1,
            size,
            nodes: vec![root],
            cache: LruCache::new(opts.cache_capacity),
            free: Vec::new(),
            hint: 0,
            up_stack: Vec::new(),
            walk_stack: Vec::new(),
            out_nodes: Vec::new(),
            grid_stack: Vec::new(),
            grid_parts: Vec::new(),
            stats: QueryStats::default(),
            tol: (t1 - t0) * 1e-12,
        };
        if opts.preseed_depth > 0 {
            bi.preseed(0, opts.preseed_depth);
        }
        bi
    }

    /// Query statistics accumulated so far.
    pub fn stats(&self) -> QueryStats {
        let (h, m) = self.cache.stats();
        QueryStats { cache_hits: h, cache_misses: m, ..self.stats }
    }

    /// Number of tree nodes currently allocated (CPU-side metadata).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The LRU capacity actually in effect — always exactly the
    /// `cache_capacity` this interval was constructed with (construction
    /// rejects 0 instead of clamping).
    pub fn cache_capacity(&self) -> usize {
        self.cache.capacity()
    }

    /// Re-seed in place: draw a fresh Brownian sample while **keeping the
    /// node arena, the LRU slot arena and the recycled value buffers**.
    ///
    /// The tree's *shape* encodes the query pattern, which for a training
    /// loop is the same fixed grid every step — so instead of rebuilding the
    /// tree (and reallocating every buffer) per step, the trainer holds one
    /// persistent interval and calls `reseed(seed)` between steps. Node
    /// seeds are recomputed from the new root seed in one forward pass
    /// (children always live at larger arena indices than their parent),
    /// cached values are invalidated with their buffers recycled, and the
    /// search hint is reset. Queries after `reseed(s)` return bit-identical
    /// values to a fresh `BrownianInterval` seeded with `s` and driven with
    /// the same query sequence that built this tree's shape.
    pub fn reseed(&mut self, seed: u64) {
        self.nodes[0].seed = seed;
        for idx in 0..self.nodes.len() {
            let node = self.nodes[idx];
            if !node.is_leaf() {
                let (sl, sr) = split_seed(node.seed);
                self.nodes[node.left as usize].seed = sl;
                self.nodes[node.right as usize].seed = sr;
            }
        }
        let recycled = self.cache.take_values();
        self.free.extend(recycled);
        self.hint = 0;
    }

    fn preseed(&mut self, idx: u32, depth: u32) {
        if depth == 0 {
            return;
        }
        let (a, b) = {
            let n = &self.nodes[idx as usize];
            (n.a, n.b)
        };
        let mid = 0.5 * (a + b);
        let (l, r) = self.bisect(idx, mid);
        self.preseed(l, depth - 1);
        self.preseed(r, depth - 1);
    }

    /// Split leaf `idx` at `x`, creating both children. Returns their ids.
    fn bisect(&mut self, idx: u32, x: f64) -> (u32, u32) {
        let node = self.nodes[idx as usize];
        debug_assert!(node.is_leaf(), "bisect called on internal node");
        debug_assert!(x > node.a && x < node.b, "split point outside node");
        let (sl, sr) = split_seed(node.seed);
        let l = self.nodes.len() as u32;
        let r = l + 1;
        self.nodes.push(Node { a: node.a, b: x, seed: sl, parent: idx, left: NIL, right: NIL });
        self.nodes.push(Node { a: x, b: node.b, seed: sr, parent: idx, left: NIL, right: NIL });
        self.nodes[idx as usize].left = l;
        self.nodes[idx as usize].right = r;
        self.stats.nodes_created += 2;
        (l, r)
    }

    #[inline]
    fn close(&self, x: f64, y: f64) -> bool {
        (x - y).abs() <= self.tol
    }

    fn grab_buf(&mut self) -> Vec<f32> {
        self.free.pop().unwrap_or_else(|| vec![0.0f32; self.size])
    }

    /// Ensure node `idx`'s increment is in the cache; returns nothing, the
    /// caller re-reads through the cache (split to appease the borrow
    /// checker without cloning values).
    fn materialise(&mut self, idx: u32) {
        if self.cache.peek(&idx).is_some() {
            return;
        }
        // Walk up until we find a cached ancestor (or the root).
        self.up_stack.clear();
        let mut cur = idx;
        loop {
            if self.cache.peek(&cur).is_some() {
                break;
            }
            self.up_stack.push(cur);
            let parent = self.nodes[cur as usize].parent;
            if parent == NIL {
                break;
            }
            cur = parent;
        }
        self.stats.max_recompute_depth =
            self.stats.max_recompute_depth.max(self.up_stack.len() as u32);

        // If we stopped at the (uncached) root, sample it: W_{t0,t1} ~
        // N(0, (t1 - t0) I) from the root seed.
        if self.up_stack.last() == Some(&0) && self.cache.peek(&0).is_none() {
            self.up_stack.pop();
            let mut buf = self.grab_buf();
            let scale = (self.t1 - self.t0).sqrt();
            box_muller_fill(self.nodes[0].seed, scale, &mut buf);
            self.stats.bridges_sampled += 1;
            if let Some((_, old)) = self.cache.put(0, buf) {
                self.free.push(old);
            }
        }

        // Walk back down, bridging at every level. For a parent [a, b] split
        // at x, the bridge W_{a,x} | W_{a,b} = N( (x-a)/(b-a) W_{a,b},
        // (b-x)(x-a)/(b-a) I ) is *always* drawn from the left child's seed;
        // the right child is the exact complement W_{a,b} - W_{a,x}.
        while let Some(child) = self.up_stack.pop() {
            let node = self.nodes[child as usize];
            let parent = self.nodes[node.parent as usize];
            let (left_id, right_id) = (parent.left, parent.right);
            let left = self.nodes[left_id as usize];
            let (a, b, x) = (parent.a, parent.b, left.b);
            let frac = (x - a) / (b - a);
            let sd = (((b - x) * (x - a)) / (b - a)).sqrt();

            let mut wl = self.grab_buf();
            box_muller_fill(left.seed, sd, &mut wl);
            self.stats.bridges_sampled += 1;
            {
                let wp = self
                    .cache
                    .peek(&node.parent)
                    .expect("parent increment must be cached during descent");
                if child == left_id {
                    for i in 0..self.size {
                        wl[i] += (frac as f32) * wp[i];
                    }
                    // wl now holds W_left.
                } else {
                    for i in 0..self.size {
                        wl[i] = wp[i] - (wl[i] + (frac as f32) * wp[i]);
                    }
                    // wl now holds W_right = W_parent - W_left.
                }
            }
            let store_id = if child == left_id { left_id } else { right_id };
            if let Some((_, old)) = self.cache.put(store_id, wl) {
                self.free.push(old);
            }
        }
    }

    /// Find-or-create the list of nodes whose intervals partition `[s, t]`
    /// (paper Algorithm 4), starting the search from the hint node.
    fn traverse(&mut self, s: f64, t: f64) {
        self.out_nodes.clear();
        // Ascend from the hint until the query is contained.
        let mut start = self.hint;
        loop {
            let n = &self.nodes[start as usize];
            if (s >= n.a - self.tol && t <= n.b + self.tol) || n.parent == NIL {
                break;
            }
            start = n.parent;
        }
        // Descend with an explicit stack. Intervals are processed
        // left-to-right so `out_nodes` is ordered.
        self.walk_stack.clear();
        self.walk_stack.push((start, s, t));
        while let Some((idx, c, d)) = self.walk_stack.pop() {
            self.stats.node_visits += 1;
            let node = self.nodes[idx as usize];
            let c = if self.close(c, node.a) { node.a } else { c };
            let d = if self.close(d, node.b) { node.b } else { d };
            if c == node.a && d == node.b {
                self.out_nodes.push(idx);
                continue;
            }
            if node.is_leaf() {
                if c == node.a {
                    // Split at d; left child covers [a, d].
                    let (l, _) = self.bisect(idx, d);
                    self.out_nodes.push(l);
                } else {
                    // Split at c; the remainder [c, d] lives in the right
                    // child (possibly needing another split there).
                    let (_, r) = self.bisect(idx, c);
                    self.walk_stack.push((r, c, d));
                }
            } else {
                let m = self.nodes[node.left as usize].b;
                if d <= m {
                    self.walk_stack.push((node.left, c, d));
                } else if c >= m {
                    self.walk_stack.push((node.right, c, d));
                } else {
                    // Straddles the split: left part pushed LAST so it is
                    // processed first (stack is LIFO).
                    self.walk_stack.push((node.right, m, d));
                    self.walk_stack.push((node.left, c, m));
                }
            }
        }
        if let Some(&last) = self.out_nodes.last() {
            self.hint = last;
        }
    }

    /// Partition **every** interval of the grid `ts` in a single tree
    /// descent (the bulk counterpart of [`Self::traverse`]): one DFS from
    /// the root distributes the grid's boundary points down the tree, so
    /// each node on the partition frontier is visited exactly once —
    /// instead of once per covering step via per-step hint-guided
    /// traverses. Fills `grid_parts` with ordered `(node, step)` pairs.
    ///
    /// Splits happen at the same points, in the same left-to-right order,
    /// as `ts.len() - 1` sequential [`Self::traverse`] calls would produce,
    /// so the tree shape (hence every sampled value) is bit-identical to
    /// the per-step path.
    fn traverse_grid(&mut self, ts: &[f64]) {
        let n = ts.len() - 1;
        self.grid_parts.clear();
        self.grid_stack.clear();
        self.grid_stack.push((0, ts[0], ts[n], 0, n));
        while let Some((idx, c, d, lo, hi)) = self.grid_stack.pop() {
            self.stats.node_visits += 1;
            let node = self.nodes[idx as usize];
            let c = if self.close(c, node.a) { node.a } else { c };
            let d = if self.close(d, node.b) { node.b } else { d };
            if hi - lo == 1 {
                // Single grid step left: exactly `traverse`'s logic.
                if c == node.a && d == node.b {
                    self.grid_parts.push((idx, lo));
                    continue;
                }
                if node.is_leaf() {
                    if c == node.a {
                        let (l, _) = self.bisect(idx, d);
                        self.grid_parts.push((l, lo));
                    } else {
                        let (_, r) = self.bisect(idx, c);
                        self.grid_stack.push((r, c, d, lo, hi));
                    }
                } else {
                    let m = self.nodes[node.left as usize].b;
                    if d <= m {
                        self.grid_stack.push((node.left, c, d, lo, hi));
                    } else if c >= m {
                        self.grid_stack.push((node.right, c, d, lo, hi));
                    } else {
                        self.grid_stack.push((node.right, m, d, lo, hi));
                        self.grid_stack.push((node.left, c, m, lo, hi));
                    }
                }
                continue;
            }
            // Multiple steps overlap [c, d]: interior grid boundaries exist
            // (ts[lo+1] .. ts[hi-1] all lie strictly inside), so this node
            // must split even if it covers [c, d] exactly.
            if node.is_leaf() {
                if c > node.a {
                    // Trim the left part that belongs to the previous node.
                    let (_, r) = self.bisect(idx, c);
                    self.grid_stack.push((r, c, d, lo, hi));
                } else {
                    // Split off step `lo` at the first interior boundary —
                    // the same split the sequential step-`lo` query makes.
                    let x = ts[lo + 1];
                    let (l, r) = self.bisect(idx, x);
                    self.grid_stack.push((r, x, d, lo + 1, hi));
                    self.grid_parts.push((l, lo));
                }
            } else {
                let m = self.nodes[node.left as usize].b;
                if d <= m {
                    self.grid_stack.push((node.left, c, d, lo, hi));
                } else if c >= m {
                    self.grid_stack.push((node.right, c, d, lo, hi));
                } else {
                    // The split point falls on step boundary `k` (m snaps to
                    // ts[k]) or strictly inside step `k - 1`; route the
                    // overlapping step ranges to each child accordingly.
                    let rel = ts[lo + 1..hi].partition_point(|&x| x < m - self.tol);
                    let k = lo + 1 + rel;
                    let (left_hi, right_lo) = if k < hi && (ts[k] - m).abs() <= self.tol {
                        // Bit-identity with per-step queries requires grid
                        // points to coincide *exactly* with existing split
                        // points (per-step snapping is node-relative, so a
                        // tol-close-but-unequal point would diverge there
                        // too, sliver by sliver). Reject such grids loudly
                        // in debug builds instead of silently differing.
                        debug_assert!(
                            ts[k] == m,
                            "fill_grid: grid point {} lies within the snap \
                             tolerance of node boundary {} without equalling \
                             it; reuse the exact boundary value",
                            ts[k],
                            m
                        );
                        (k, k)
                    } else {
                        (k, k - 1)
                    };
                    self.grid_stack.push((node.right, m, d, right_lo, hi));
                    self.grid_stack.push((node.left, c, m, lo, left_hi));
                }
            }
        }
        if let Some(&(last, _)) = self.grid_parts.last() {
            self.hint = last;
        }
    }

    /// One validated query: partition `[s, t]`, materialise each part, sum.
    /// Shared by [`BrownianSource::increment`] and the bulk
    /// [`BrownianSource::fill_grid`] override.
    fn query(&mut self, s: f64, t: f64, out: &mut [f32]) {
        self.stats.queries += 1;
        self.traverse(s, t);
        out.fill(0.0);
        // Practically `out_nodes` has one or two elements (Appendix E,
        // "Small intervals") — but arbitrary partitions are handled.
        let parts = std::mem::take(&mut self.out_nodes);
        for &idx in &parts {
            self.materialise(idx);
            let w = self
                .cache
                .get(&idx)
                .expect("materialise() must have cached the node");
            for i in 0..out.len() {
                out[i] += w[i];
            }
        }
        self.out_nodes = parts;
    }
}

impl BrownianSource for BrownianInterval {
    fn size(&self) -> usize {
        self.size
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn increment(&mut self, s: f64, t: f64, out: &mut [f32]) {
        check_interval((self.t0, self.t1), s, t);
        assert_eq!(out.len(), self.size, "output buffer size mismatch");
        self.query(s, t, out);
    }

    /// Bulk fill in **one tree descent**: the whole grid is partitioned by a
    /// single DFS from the root ([`Self::traverse_grid`]) instead of one
    /// hint-guided traverse per step, so each partition-frontier node is
    /// visited once (`2n - 1` pops for an `n`-step comb) rather than re-read
    /// through its ancestors step after step. Values are bit-identical to
    /// `n` sequential [`BrownianSource::increment`] calls — the descent
    /// splits leaves at the same points in the same order. (Precondition,
    /// debug-asserted: grid points must either equal existing split points
    /// exactly or lie further than the snap tolerance from them — true for
    /// any reused `ts` array and for real grid spacings, which dwarf the
    /// `1e-12 · span` tolerance.)
    fn fill_grid(&mut self, ts: &[f64], out: &mut [f32]) {
        let n = ts.len().saturating_sub(1);
        assert_eq!(out.len(), n * self.size, "fill_grid: need {} values", n * self.size);
        if n == 0 {
            return;
        }
        check_interval((self.t0, self.t1), ts[0], ts[n]);
        for k in 0..n {
            assert!(ts[k] < ts[k + 1], "fill_grid: grid must be strictly increasing");
        }
        self.stats.queries += n as u64;
        self.traverse_grid(ts);
        out.fill(0.0);
        let parts = std::mem::take(&mut self.grid_parts);
        for &(idx, k) in &parts {
            self.materialise(idx);
            let w = self
                .cache
                .get(&idx)
                .expect("materialise() must have cached the node");
            let row = &mut out[k * self.size..(k + 1) * self.size];
            for i in 0..self.size {
                row[i] += w[i];
            }
        }
        self.grid_parts = parts;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bi(seed: u64) -> BrownianInterval {
        BrownianInterval::new(0.0, 1.0, 4, seed)
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = bi(7);
        let mut b = bi(7);
        for (s, t) in [(0.0, 0.25), (0.25, 0.5), (0.1, 0.9), (0.5, 1.0)] {
            assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t));
        }
    }

    #[test]
    fn repeat_query_identical() {
        let mut a = bi(9);
        let w1 = a.increment_vec(0.2, 0.7);
        let w2 = a.increment_vec(0.2, 0.7);
        assert_eq!(w1, w2);
    }

    #[test]
    fn chain_consistency_exact() {
        // W(s, u) computed as one query equals the sum of sub-queries,
        // bit-exactly, provided the coarse query comes first (so the fine
        // queries refine its nodes).
        let mut a = bi(11);
        let whole = a.increment_vec(0.0, 1.0);
        let mut sum = vec![0.0f32; 4];
        for k in 0..10 {
            let s = k as f64 / 10.0;
            let t = (k + 1) as f64 / 10.0;
            let w = a.increment_vec(s, t);
            for i in 0..4 {
                sum[i] += w[i];
            }
        }
        for i in 0..4 {
            assert!(
                (whole[i] - sum[i]).abs() < 1e-4,
                "channel {i}: {} vs {}",
                whole[i],
                sum[i]
            );
        }
    }

    #[test]
    fn sibling_sum_is_bit_exact() {
        let mut a = bi(13);
        let parent = a.increment_vec(0.0, 1.0);
        let l = a.increment_vec(0.0, 0.5);
        let r = a.increment_vec(0.5, 1.0);
        for i in 0..4 {
            assert_eq!(parent[i], l[i] + r[i], "channel {i}");
        }
    }

    #[test]
    fn cache_capacity_is_honoured_exactly() {
        // No silent clamping: the effective capacity is the requested one.
        for cap in [1usize, 2, 7, 128] {
            let opts = IntervalOptions { cache_capacity: cap, preseed_depth: 0 };
            let bi = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts);
            assert_eq!(bi.cache_capacity(), cap);
        }
        assert_eq!(BrownianInterval::new(0.0, 1.0, 4, 5).cache_capacity(), 128);
    }

    #[test]
    #[should_panic(expected = "cache_capacity must be >= 1")]
    fn cache_capacity_zero_is_rejected() {
        let opts = IntervalOptions { cache_capacity: 0, preseed_depth: 0 };
        let _ = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts);
    }

    #[test]
    fn capacity_one_is_bit_exact() {
        // The descent only ever re-reads the most recently cached parent,
        // so a single-slot cache still produces the exact sample path —
        // pinned against a cache big enough to never evict, through the
        // doubly-sequential (forward + backward) solver pattern and a
        // reseed.
        let tiny = IntervalOptions { cache_capacity: 1, preseed_depth: 0 };
        let big = IntervalOptions { cache_capacity: 4096, preseed_depth: 0 };
        let mut a = BrownianInterval::with_options(0.0, 1.0, 4, 5, tiny);
        let mut b = BrownianInterval::with_options(0.0, 1.0, 4, 5, big);
        let n = 64;
        for round in 0..2u64 {
            for k in 0..n {
                let (s, t) = (k as f64 / n as f64, (k + 1) as f64 / n as f64);
                assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t), "fwd k={k}");
            }
            for k in (0..n).rev() {
                let (s, t) = (k as f64 / n as f64, (k + 1) as f64 / n as f64);
                assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t), "bwd k={k}");
            }
            a.reseed(round + 9);
            b.reseed(round + 9);
        }
    }

    #[test]
    fn cache_size_does_not_change_the_path() {
        let opts_small = IntervalOptions { cache_capacity: 2, preseed_depth: 0 };
        let opts_big = IntervalOptions { cache_capacity: 4096, preseed_depth: 0 };
        let mut a = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts_small);
        let mut b = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts_big);
        let n = 64;
        // Forward then backward sweep — the doubly-sequential pattern.
        for k in 0..n {
            let (s, t) = (k as f64 / n as f64, (k + 1) as f64 / n as f64);
            assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t));
        }
        for k in (0..n).rev() {
            let (s, t) = (k as f64 / n as f64, (k + 1) as f64 / n as f64);
            assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t));
        }
    }

    #[test]
    fn preseeded_tree_same_law_shape() {
        // Pre-seeding changes the realisation (different tree => different
        // conditionals) but must still be deterministic and consistent.
        let opts = IntervalOptions { cache_capacity: 64, preseed_depth: 4 };
        let mut a = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts);
        let mut b = BrownianInterval::with_options(0.0, 1.0, 4, 5, opts);
        let w1 = a.increment_vec(0.3, 0.6);
        let w2 = b.increment_vec(0.3, 0.6);
        assert_eq!(w1, w2);
        let l = a.increment_vec(0.3, 0.45);
        let r = a.increment_vec(0.45, 0.6);
        for i in 0..4 {
            assert!((w1[i] - (l[i] + r[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn increments_have_brownian_moments() {
        // Var[W(s,t)] = t - s; check over many channels.
        let mut a = BrownianInterval::new(0.0, 1.0, 50_000, 99);
        let w = a.increment_vec(0.2, 0.45);
        let n = w.len() as f64;
        let mean = w.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = w.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 0.25).abs() < 0.01, "var={var}");
    }

    #[test]
    fn bridge_conditional_mean_is_linear() {
        // Conditional on W(0,1), E[W(0,s)] = s * W(0,1). Check empirically
        // across channels (each channel is an independent realisation).
        let mut a = BrownianInterval::new(0.0, 1.0, 100_000, 3);
        let whole = a.increment_vec(0.0, 1.0);
        let part = a.increment_vec(0.0, 0.25);
        // Regress part on whole: slope should be ~0.25.
        let n = whole.len();
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for i in 0..n {
            num += whole[i] as f64 * part[i] as f64;
            den += (whole[i] as f64).powi(2);
        }
        let slope = num / den;
        assert!((slope - 0.25).abs() < 0.01, "slope={slope}");
    }

    #[test]
    fn doubly_sequential_hits_cache() {
        let mut a = BrownianInterval::new(0.0, 1.0, 8, 17);
        let n = 100;
        for k in 0..n {
            let _ = a.increment_vec(k as f64 / n as f64, (k + 1) as f64 / n as f64);
        }
        for k in (0..n).rev() {
            let _ = a.increment_vec(k as f64 / n as f64, (k + 1) as f64 / n as f64);
        }
        let st = a.stats();
        // The backward sweep re-reads nodes created on the forward sweep; the
        // default cache (128) is large enough that most of them still live.
        assert!(st.cache_hits > st.cache_misses, "stats: {st:?}");
    }

    #[test]
    fn reseed_matches_fresh_instance() {
        // A persistent, reseeded interval must reproduce a fresh instance
        // bit-for-bit over the same (grid) query sequence.
        let grid: Vec<(f64, f64)> =
            (0..16).map(|k| (k as f64 / 16.0, (k + 1) as f64 / 16.0)).collect();
        let mut persistent = bi(111);
        for &(s, t) in &grid {
            let _ = persistent.increment_vec(s, t); // build the tree shape
        }
        for new_seed in [222u64, 333, 111] {
            persistent.reseed(new_seed);
            let mut fresh = bi(new_seed);
            for &(s, t) in &grid {
                assert_eq!(
                    persistent.increment_vec(s, t),
                    fresh.increment_vec(s, t),
                    "seed {new_seed} [{s},{t}]"
                );
            }
        }
    }

    #[test]
    fn reseed_keeps_node_arena() {
        let mut a = bi(5);
        for k in 0..32 {
            let _ = a.increment_vec(k as f64 / 32.0, (k + 1) as f64 / 32.0);
        }
        let nodes_before = a.node_count();
        a.reseed(6);
        assert_eq!(a.node_count(), nodes_before, "reseed must keep the arena");
        // Refill over the same grid creates no new nodes.
        for k in 0..32 {
            let _ = a.increment_vec(k as f64 / 32.0, (k + 1) as f64 / 32.0);
        }
        assert_eq!(a.node_count(), nodes_before);
    }

    #[test]
    fn fill_grid_matches_sequential_increments() {
        let ts: Vec<f64> = (0..=20).map(|k| k as f64 / 20.0).collect();
        let mut a = bi(77);
        let mut b = bi(77);
        let mut bulk = vec![0.0f32; 20 * 4];
        a.fill_grid(&ts, &mut bulk);
        for k in 0..20 {
            assert_eq!(
                &bulk[k * 4..(k + 1) * 4],
                b.increment_vec(ts[k], ts[k + 1]).as_slice(),
                "step {k}"
            );
        }
    }

    #[test]
    fn fill_grid_matches_sequential_after_reseed() {
        // The warm-tree path (the training loop's pattern): same shape,
        // fresh seeds — bulk fill must still equal per-step queries bitwise.
        let ts: Vec<f64> = (0..=20).map(|k| k as f64 / 20.0).collect();
        let mut a = bi(55);
        let mut b = bi(55);
        let mut bulk = vec![0.0f32; 20 * 4];
        a.fill_grid(&ts, &mut bulk); // build the shape
        for k in 0..20 {
            let _ = b.increment_vec(ts[k], ts[k + 1]);
        }
        for seed in [56u64, 1234] {
            a.reseed(seed);
            b.reseed(seed);
            a.fill_grid(&ts, &mut bulk);
            for k in 0..20 {
                assert_eq!(
                    &bulk[k * 4..(k + 1) * 4],
                    b.increment_vec(ts[k], ts[k + 1]).as_slice(),
                    "seed {seed} step {k}"
                );
            }
        }
    }

    #[test]
    fn fill_grid_node_visit_counts_pinned() {
        // A uniform n-step grid drives the tree into a right-leaning comb of
        // 2n - 1 nodes. Node pops ("visits") per full grid pass:
        //
        //            cold (building)   warm (reseeded, shape exists)
        //  fill_grid       n                2n - 1   (each node once)
        //  per-step     2n - 1              3n - 2   (ancestors re-popped)
        //
        // Cold fill: the root plus each comb tail is popped once (n pops);
        // bisected-off left children are emitted without a pop. Warm fill:
        // one DFS pops each of the 2n - 1 nodes exactly once. Warm per-step:
        // step 0 pops root + leaf, interior steps pop parent tail + tail +
        // leaf (3 each), the last step pops tail + leaf.
        let n = 16usize;
        let ts: Vec<f64> = (0..=n).map(|k| k as f64 / n as f64).collect();

        let mut bulk_src = BrownianInterval::new(0.0, 1.0, 2, 9);
        let mut out = vec![0.0f32; n * 2];
        bulk_src.fill_grid(&ts, &mut out);
        assert_eq!(bulk_src.node_count(), 2 * n - 1);
        assert_eq!(bulk_src.stats().node_visits, n as u64, "cold bulk fill");
        bulk_src.reseed(10);
        bulk_src.fill_grid(&ts, &mut out);
        assert_eq!(
            bulk_src.stats().node_visits,
            (n + 2 * n - 1) as u64,
            "warm bulk fill must pop each partition node exactly once"
        );

        let mut step_src = BrownianInterval::new(0.0, 1.0, 2, 9);
        for k in 0..n {
            let _ = step_src.increment_vec(ts[k], ts[k + 1]);
        }
        assert_eq!(step_src.stats().node_visits, (2 * n - 1) as u64, "cold per-step");
        step_src.reseed(10);
        for k in 0..n {
            let _ = step_src.increment_vec(ts[k], ts[k + 1]);
        }
        assert_eq!(
            step_src.stats().node_visits,
            (2 * n - 1 + 3 * n - 2) as u64,
            "warm per-step re-pops ancestors every step"
        );

        // The headline: a warm grid fill does strictly less traversal work.
        let warm_fill = 2 * n - 1;
        let warm_steps = 3 * n - 2;
        assert!(warm_fill < warm_steps);
    }

    #[test]
    #[should_panic(expected = "s < t")]
    fn rejects_degenerate_interval() {
        let mut a = bi(1);
        let mut out = vec![0.0; 4];
        a.increment(0.5, 0.5, &mut out);
    }

    #[test]
    #[should_panic(expected = "outside Brownian span")]
    fn rejects_out_of_span() {
        let mut a = bi(1);
        let mut out = vec![0.0; 4];
        a.increment(0.5, 1.5, &mut out);
    }
}
