//! Fixed-capacity least-recently-used cache.
//!
//! The Brownian Interval caches computed increments `W_{s,t}` per tree node
//! (Section 4: "a fixed-size Least Recently Used (LRU) cache on the computed
//! increments"). Capacity is what bounds the structure's *value* memory to
//! `O(1)`; the tree itself stores only `(interval, seed)` metadata.
//!
//! Implementation: a `HashMap<K, slot>` into an arena of doubly-linked slots.
//! All operations are O(1); the hot path (`get` on a hit) performs no
//! allocation.

use std::collections::HashMap;
use std::hash::Hash;

const NIL: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// A fixed-capacity LRU cache.
pub struct LruCache<K, V> {
    map: HashMap<K, usize>,
    slots: Vec<Slot<K, V>>,
    head: usize, // most recently used
    tail: usize, // least recently used
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    /// Create a cache holding at most `capacity` entries (`capacity >= 1`).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "LRU capacity must be >= 1");
        Self {
            map: HashMap::with_capacity(capacity + 1),
            slots: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True if no entries are cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Capacity this cache was created with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// (hits, misses) counters — used by the benchmark harness to report
    /// cache effectiveness, and by tests to verify access patterns.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    fn detach(&mut self, idx: usize) {
        let (prev, next) = (self.slots[idx].prev, self.slots[idx].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.slots[idx].prev = NIL;
        self.slots[idx].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look up `key`, marking it most-recently-used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        match self.map.get(key).copied() {
            Some(idx) => {
                self.hits += 1;
                if self.head != idx {
                    self.detach(idx);
                    self.push_front(idx);
                }
                Some(&self.slots[idx].value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Check for `key` without touching recency or stats.
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|&idx| &self.slots[idx].value)
    }

    /// Insert `key -> value`, evicting the least-recently-used entry when at
    /// capacity. Returns the evicted `(key, value)`, if any.
    pub fn put(&mut self, key: K, value: V) -> Option<(K, V)> {
        if let Some(&idx) = self.map.get(&key) {
            // Overwrite in place, mark as MRU.
            self.slots[idx].value = value;
            if self.head != idx {
                self.detach(idx);
                self.push_front(idx);
            }
            return None;
        }
        if self.map.len() < self.capacity {
            let idx = self.slots.len();
            self.slots.push(Slot { key: key.clone(), value, prev: NIL, next: NIL });
            self.map.insert(key, idx);
            self.push_front(idx);
            None
        } else {
            // Recycle the LRU slot.
            let idx = self.tail;
            self.detach(idx);
            let old_key = std::mem::replace(&mut self.slots[idx].key, key.clone());
            let old_val = std::mem::replace(&mut self.slots[idx].value, value);
            self.map.remove(&old_key);
            self.map.insert(key, idx);
            self.push_front(idx);
            Some((old_key, old_val))
        }
    }

    /// Remove every entry and hand back the owned values.
    ///
    /// Used by [`crate::brownian::BrownianInterval::reseed`] to recycle the
    /// cached increment buffers instead of dropping and reallocating them —
    /// the hot refill path stays allocation-free across training steps.
    pub fn take_values(&mut self) -> Vec<V> {
        self.map.clear();
        self.head = NIL;
        self.tail = NIL;
        self.slots.drain(..).map(|s| s.value).collect()
    }

    /// Drop all entries (keeps allocated slots for reuse).
    pub fn clear(&mut self) {
        self.map.clear();
        self.slots.clear();
        self.head = NIL;
        self.tail = NIL;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_put_get() {
        let mut c = LruCache::new(2);
        c.put(1, "a");
        c.put(2, "b");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.get(&2), Some(&"b"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.get(&1); // 2 is now LRU
        let evicted = c.put(3, 30);
        assert_eq!(evicted, Some((2, 20)));
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.get(&3), Some(&30));
    }

    #[test]
    fn overwrite_updates_value_and_recency() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.put(1, 11); // 1 becomes MRU with new value
        assert_eq!(c.put(3, 30), Some((2, 20)));
        assert_eq!(c.get(&1), Some(&11));
    }

    #[test]
    fn capacity_one_behaves() {
        let mut c = LruCache::new(1);
        assert!(c.put(1, 1).is_none());
        assert_eq!(c.put(2, 2), Some((1, 1)));
        assert_eq!(c.get(&1), None);
        assert_eq!(c.get(&2), Some(&2));
    }

    #[test]
    fn stats_count_hits_and_misses() {
        let mut c = LruCache::new(2);
        c.put(1, 1);
        c.get(&1);
        c.get(&9);
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn peek_does_not_change_recency() {
        let mut c = LruCache::new(2);
        c.put(1, 1);
        c.put(2, 2);
        c.peek(&1);
        // 1 is still LRU despite the peek:
        assert_eq!(c.put(3, 3), Some((1, 1)));
    }

    #[test]
    fn take_values_drains_and_resets() {
        let mut c = LruCache::new(4);
        for i in 0..3 {
            c.put(i, i * 10);
        }
        let mut vals = c.take_values();
        vals.sort_unstable();
        assert_eq!(vals, vec![0, 10, 20]);
        assert!(c.is_empty());
        assert_eq!(c.get(&1), None);
        c.put(5, 50);
        assert_eq!(c.get(&5), Some(&50));
    }

    #[test]
    fn clear_resets() {
        let mut c = LruCache::new(4);
        for i in 0..4 {
            c.put(i, i);
        }
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&0), None);
        c.put(7, 7);
        assert_eq!(c.get(&7), Some(&7));
    }

    #[test]
    fn stress_against_reference_model() {
        // Compare against a simple Vec-based model under a pseudo-random
        // workload.
        let mut c = LruCache::new(8);
        let mut model: Vec<(u32, u32)> = Vec::new(); // MRU-first
        let mut state = 0x12345u64;
        for step in 0..10_000u32 {
            state = crate::brownian::splitmix64(state);
            let key = (state % 24) as u32;
            if state & 1 == 0 {
                // put
                model.retain(|&(k, _)| k != key);
                model.insert(0, (key, step));
                model.truncate(8);
                c.put(key, step);
            } else {
                // get
                let expect = model.iter().position(|&(k, _)| k == key);
                let got = c.get(&key).copied();
                match expect {
                    Some(pos) => {
                        let (k, v) = model.remove(pos);
                        model.insert(0, (k, v));
                        assert_eq!(got, Some(v));
                    }
                    None => assert_eq!(got, None),
                }
            }
        }
    }
}
