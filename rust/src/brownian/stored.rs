//! The naive stored-path baseline: presample every increment on a fixed
//! grid and keep all of them in memory (`O(T)` memory — the cost the paper's
//! Section 4 opens with). Exact on grid-aligned queries; off-grid endpoints
//! are snapped to the nearest grid point.

use super::prng::box_muller_fill;
use super::{check_interval, BrownianSource};

/// Brownian motion stored as cumulative sums on a uniform grid.
pub struct StoredPath {
    t0: f64,
    t1: f64,
    size: usize,
    steps: usize,
    /// `cum[k * size + i]` = W_i(t0 + k*dt) - W_i(t0); length (steps+1)*size.
    cum: Vec<f32>,
}

impl StoredPath {
    /// Presample `steps` uniform increments over `[t0, t1]`.
    pub fn new(t0: f64, t1: f64, size: usize, seed: u64, steps: usize) -> Self {
        assert!(t1 > t0 && steps >= 1 && size >= 1);
        let dt = (t1 - t0) / steps as f64;
        let mut cum = vec![0.0f32; (steps + 1) * size];
        let mut inc = vec![0.0f32; size];
        for k in 0..steps {
            box_muller_fill(seed.wrapping_add(k as u64 * 0x9E37_79B9), dt.sqrt(), &mut inc);
            let (prev, next) = cum.split_at_mut((k + 1) * size);
            let prev_row = &prev[k * size..];
            for i in 0..size {
                next[i] = prev_row[i] + inc[i];
            }
        }
        Self { t0, t1, size, steps, cum }
    }

    /// Memory used by the stored values, in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.cum.len() * std::mem::size_of::<f32>()
    }

    fn grid_index(&self, t: f64) -> usize {
        let dt = (self.t1 - self.t0) / self.steps as f64;
        let k = ((t - self.t0) / dt).round() as i64;
        k.clamp(0, self.steps as i64) as usize
    }
}

impl BrownianSource for StoredPath {
    fn size(&self) -> usize {
        self.size
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn increment(&mut self, s: f64, t: f64, out: &mut [f32]) {
        check_interval((self.t0, self.t1), s, t);
        assert_eq!(out.len(), self.size);
        let (ks, kt) = (self.grid_index(s), self.grid_index(t));
        let a = &self.cum[ks * self.size..(ks + 1) * self.size];
        let b = &self.cum[kt * self.size..(kt + 1) * self.size];
        for i in 0..self.size {
            out[i] = b[i] - a[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_consistency_exact_on_grid() {
        let mut p = StoredPath::new(0.0, 1.0, 3, 42, 100);
        let whole = p.increment_vec(0.0, 1.0);
        let l = p.increment_vec(0.0, 0.37); // snaps to 0.37
        let r = p.increment_vec(0.37, 1.0);
        for i in 0..3 {
            // Subtraction of cumulative sums: exact up to one f32 rounding.
            assert!((whole[i] - (l[i] + r[i])).abs() <= 1e-6 * whole[i].abs().max(1.0));
        }
    }

    #[test]
    fn deterministic() {
        let mut a = StoredPath::new(0.0, 1.0, 3, 5, 64);
        let mut b = StoredPath::new(0.0, 1.0, 3, 5, 64);
        assert_eq!(a.increment_vec(0.25, 0.75), b.increment_vec(0.25, 0.75));
    }

    #[test]
    fn memory_scales_with_steps() {
        let small = StoredPath::new(0.0, 1.0, 2, 1, 10);
        let big = StoredPath::new(0.0, 1.0, 2, 1, 1000);
        assert!(big.memory_bytes() > 50 * small.memory_bytes());
    }

    #[test]
    fn moments() {
        let mut p = StoredPath::new(0.0, 1.0, 50_000, 9, 50);
        let w = p.increment_vec(0.0, 1.0);
        let n = w.len() as f64;
        let var = w.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n;
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}
