//! Space–time Lévy area and approximate second iterated integrals
//! (Appendix E, "Stochastic integrals").
//!
//! Higher-order SDE solvers (Rößler's SRK methods, the log-ODE method)
//! consume, beyond the increment `W_{s,t}`, the *space–time Lévy area*
//!
//! ```text
//! H_{s,t} = (1/(t-s)) ∫_s^t ( W_{s,r} - ((r-s)/(t-s)) W_{s,t} ) dr
//! ```
//!
//! and (approximations to) the second iterated integral `𝕎_{s,t}`. For a
//! single interval, `H_{s,t} ~ N(0, (t-s)/12)` independently of `W_{s,t}`
//! (Lemma D.15 of the paper). Exact joint simulation of `(W, 𝕎)` is only
//! known in dimensions 1–2, so we implement Davie's approximation
//! (paper Appendix E, citing Davie 2014 / Foster 2020):
//!
//! ```text
//! 𝕎̃_{s,t} = ½ W⊗W + H⊗W − W⊗H + λ,   λ antisymmetric,
//!            λ_ij ~ N(0, (t-s)²/12)  for i < j.
//! ```
//!
//! which matches the first two moments of the true Lévy area well enough
//! for the O(1/N) 2-Wasserstein rates cited in the paper.

use super::prng::{box_muller_fill, splitmix64};
use super::{BrownianInterval, BrownianSource};

/// Sample the space–time Lévy area `H_{s,t}` into a caller-supplied buffer
/// (one channel per slot) — the allocation-free primitive the hot-path
/// query methods build on.
///
/// Deterministic in `(seed, s, t, h.len())`; independent of the increment
/// by construction (separate stream).
pub fn space_time_levy_area_into(seed: u64, s: f64, t: f64, h: &mut [f32]) {
    let sd = ((t - s) / 12.0).sqrt();
    box_muller_fill(splitmix64(seed ^ 0x48_4C45_5659), sd, h);
}

/// Allocating convenience over [`space_time_levy_area_into`].
pub fn space_time_levy_area(seed: u64, s: f64, t: f64, dim: usize) -> Vec<f32> {
    let mut h = vec![0.0f32; dim];
    space_time_levy_area_into(seed, s, t, &mut h);
    h
}

/// Davie's approximation to the second iterated (Stratonovich) integral,
/// into caller-supplied buffers — the allocation-free form a solver loop
/// should call per step.
///
/// Writes the `dim x dim` matrix `𝕎̃` row-major into `out` (`d * d` long),
/// built from the increment `w`, the space–time Lévy area `h`, and fresh
/// antisymmetric bridge noise keyed by `seed`. `lam` is reusable scratch
/// for the `λ_ij` draws: it is resized to the strictly-upper-triangle count
/// (at least 1), so a warmed buffer is never reallocated. Bit-identical to
/// [`davie_levy_area`] for the same inputs.
pub fn davie_levy_area_into(
    seed: u64,
    s: f64,
    t: f64,
    w: &[f32],
    h: &[f32],
    lam: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(w.len(), h.len());
    let d = w.len();
    assert_eq!(out.len(), d * d, "out must be dim x dim");
    // λ_ij for i<j, antisymmetric; N(0, (t-s)^2 / 12).
    let n_upper = d * (d - 1) / 2;
    lam.clear();
    lam.resize(n_upper.max(1), 0.0);
    let sd = (((t - s) * (t - s)) / 12.0).sqrt();
    box_muller_fill(splitmix64(seed ^ 0x4441_5649_45), sd, lam);
    let mut k = 0;
    for i in 0..d {
        for j in 0..d {
            let mut v = 0.5 * w[i] * w[j] + h[i] * w[j] - w[i] * h[j];
            if i < j {
                v += lam[k + (j - i - 1)];
            } else if j < i {
                // antisymmetric partner of (j, i)
                let base = upper_index(j, i, d);
                v -= lam[base];
            }
            out[i * d + j] = v;
        }
        if i + 1 < d {
            k += d - i - 1;
        }
    }
}

/// Allocating convenience over [`davie_levy_area_into`].
pub fn davie_levy_area(seed: u64, s: f64, t: f64, w: &[f32], h: &[f32]) -> Vec<f32> {
    let d = w.len();
    let mut out = vec![0.0f32; d * d];
    let mut lam = Vec::new();
    davie_levy_area_into(seed, s, t, w, h, &mut lam, &mut out);
    out
}

/// Flat index of the strictly-upper-triangular entry `(i, j)`, `i < j`.
fn upper_index(i: usize, j: usize, d: usize) -> usize {
    // entries before row i: sum_{r<i} (d - r - 1)
    let before: usize = (0..i).map(|r| d - r - 1).sum();
    before + (j - i - 1)
}

/// A [`BrownianInterval`] augmented with space–time Lévy areas, for
/// higher-order solvers. Increments come from the exact interval structure;
/// `H` is sampled per queried interval from an independent stream keyed by
/// the query endpoints (sufficient for the non-overlapping step queries an
/// SDE solver makes, which is the supported access pattern).
pub struct BrownianWithLevy {
    inner: BrownianInterval,
    seed: u64,
}

impl BrownianWithLevy {
    /// Wrap a Brownian Interval; `seed` keys the Lévy-area stream.
    pub fn new(inner: BrownianInterval, seed: u64) -> Self {
        Self { inner, seed }
    }

    /// Increment and space–time Lévy area over `[s, t]` into caller-supplied
    /// buffers (each `size` long) — the allocation-free form a solver loop
    /// should call per step (the allocating wrappers below cost two `Vec`s
    /// per query).
    pub fn increment_and_levy_into(&mut self, s: f64, t: f64, w: &mut [f32], h: &mut [f32]) {
        self.inner.increment(s, t, w);
        let key = self.seed ^ (s.to_bits().rotate_left(17)) ^ t.to_bits();
        space_time_levy_area_into(key, s, t, h);
    }

    /// Increment and space–time Lévy area over `[s, t]`.
    pub fn increment_and_levy(&mut self, s: f64, t: f64) -> (Vec<f32>, Vec<f32>) {
        let n = self.inner.size();
        let mut w = vec![0.0f32; n];
        let mut h = vec![0.0f32; n];
        self.increment_and_levy_into(s, t, &mut w, &mut h);
        (w, h)
    }

    /// Increment, Lévy area, and Davie second-iterated-integral matrix into
    /// caller-supplied buffers (`w`/`h` each `size` long, `area`
    /// `size * size`, `lam` reusable scratch) — the allocation-free form
    /// for hot solver loops. Bit-identical to
    /// [`increment_levy_and_area`](Self::increment_levy_and_area).
    pub fn increment_levy_and_area_into(
        &mut self,
        s: f64,
        t: f64,
        w: &mut [f32],
        h: &mut [f32],
        lam: &mut Vec<f32>,
        area: &mut [f32],
    ) {
        self.increment_and_levy_into(s, t, w, h);
        let key = self.seed ^ s.to_bits() ^ (t.to_bits().rotate_left(31));
        davie_levy_area_into(key, s, t, w, h, lam, area);
    }

    /// Increment, Lévy area, and Davie second-iterated-integral matrix.
    /// Allocating convenience over
    /// [`increment_levy_and_area_into`](Self::increment_levy_and_area_into)
    /// (three `Vec`s per query — not for hot paths).
    pub fn increment_levy_and_area(
        &mut self,
        s: f64,
        t: f64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = self.inner.size();
        let mut w = vec![0.0f32; n];
        let mut h = vec![0.0f32; n];
        let mut area = vec![0.0f32; n * n];
        let mut lam = Vec::new();
        self.increment_levy_and_area_into(s, t, &mut w, &mut h, &mut lam, &mut area);
        (w, h, area)
    }

    /// Access the underlying interval source.
    pub fn inner_mut(&mut self) -> &mut BrownianInterval {
        &mut self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levy_area_moments() {
        // H ~ N(0, h/12) with h = 0.3.
        let h = space_time_levy_area(42, 0.0, 0.3, 100_000);
        let n = h.len() as f64;
        let var = h.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n;
        assert!((var - 0.3 / 12.0).abs() < 0.002, "var={var}");
    }

    #[test]
    fn levy_area_deterministic() {
        assert_eq!(
            space_time_levy_area(7, 0.1, 0.5, 16),
            space_time_levy_area(7, 0.1, 0.5, 16)
        );
    }

    #[test]
    fn davie_diagonal_is_half_square() {
        // 𝕎̃_ii = ½ W_i² exactly (H and λ cancel on the diagonal).
        let w = vec![1.5f32, -0.5, 2.0];
        let h = vec![0.3f32, 0.1, -0.2];
        let a = davie_levy_area(3, 0.0, 1.0, &w, &h);
        for i in 0..3 {
            assert!((a[i * 3 + i] - 0.5 * w[i] * w[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn davie_satisfies_chen_symmetry() {
        // 𝕎̃_ij + 𝕎̃_ji = W_i W_j (the symmetric part is exact).
        let w = vec![0.7f32, -1.2, 0.4, 2.2];
        let h = vec![0.2f32, 0.05, -0.3, 0.0];
        let a = davie_levy_area(9, 0.0, 0.5, &w, &h);
        for i in 0..4 {
            for j in 0..4 {
                let sym = a[i * 4 + j] + a[j * 4 + i];
                assert!(
                    (sym - w[i] * w[j]).abs() < 1e-5,
                    "({i},{j}): {sym} vs {}",
                    w[i] * w[j]
                );
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_forms_bitwise() {
        let a = davie_levy_area(3, 0.0, 1.0, &[1.5f32, -0.5, 2.0], &[0.3f32, 0.1, -0.2]);
        let mut b = vec![0.0f32; 9];
        let mut lam = Vec::new();
        davie_levy_area_into(3, 0.0, 1.0, &[1.5, -0.5, 2.0], &[0.3, 0.1, -0.2], &mut lam, &mut b);
        assert_eq!(a, b);
        // The scratch is reusable without affecting bits (solver-loop shape).
        davie_levy_area_into(3, 0.0, 1.0, &[1.5, -0.5, 2.0], &[0.3, 0.1, -0.2], &mut lam, &mut b);
        assert_eq!(a, b);

        let mk = || BrownianWithLevy::new(BrownianInterval::new(0.0, 1.0, 4, 11), 13);
        let (w, h, area) = mk().increment_levy_and_area(0.0, 0.25);
        let (mut w2, mut h2, mut a2) = (vec![0.0f32; 4], vec![0.0f32; 4], vec![0.0f32; 16]);
        mk().increment_levy_and_area_into(0.0, 0.25, &mut w2, &mut h2, &mut lam, &mut a2);
        assert_eq!((w, h, area), (w2, h2, a2));
    }

    #[test]
    fn with_levy_wrapper_runs() {
        let bi = BrownianInterval::new(0.0, 1.0, 4, 11);
        let mut bl = BrownianWithLevy::new(bi, 13);
        let (w, h, a) = bl.increment_levy_and_area(0.0, 0.25);
        assert_eq!(w.len(), 4);
        assert_eq!(h.len(), 4);
        assert_eq!(a.len(), 16);
    }
}
