//! Splittable counter-based PRNG.
//!
//! The Brownian Interval requires a *splittable* PRNG (Section 4 of the
//! paper, citing Salmon et al. 2011 and Claessen & Pałka 2013): each tree
//! node carries a seed, and a child's seed is derived deterministically from
//! its parent's, so any node's noise can be regenerated without storing it.
//!
//! We use the SplitMix64 finalizer as the mixing function. It is invertible
//! (hence a bijection on `u64`), passes BigCrush as a stream generator, and
//! is what `rand`'s `SplitMix64` and JAX's internal seeding derive from.
//! Splitting hashes the parent seed with a distinct odd constant per child,
//! which is exactly the "dovetailing" construction of Claessen & Pałka.

/// One round of the SplitMix64 output function (Stafford's Mix13 finalizer).
///
/// Bijective on `u64`; consecutive counters produce decorrelated outputs.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministically derive the two child seeds of `seed`.
///
/// Children of distinct parents never collide in practice: the map
/// `seed -> (left, right)` is built from two independent bijective mixes.
#[inline]
pub fn split_seed(seed: u64) -> (u64, u64) {
    // Hash with two distinct odd multipliers before mixing so that the left
    // and right streams are decorrelated from each other *and* from the
    // parent's own output stream.
    let left = splitmix64(seed ^ 0xA5A5_A5A5_5A5A_5A5A);
    let right = splitmix64(seed ^ 0x3C3C_C3C3_9696_6969);
    (left, right)
}

/// A tiny counter-based stream generator seeded by a node seed.
///
/// `SplitPrng` is *stateless across queries*: output `i` of seed `s` is
/// `splitmix64(splitmix64(s) + i)`, so any slice of the stream can be
/// regenerated on demand — the property the Brownian Interval relies on to
/// keep only `O(1)` memory.
#[derive(Clone, Copy, Debug)]
pub struct SplitPrng {
    base: u64,
    ctr: u64,
}

impl SplitPrng {
    /// Create a generator for the stream of `seed`.
    #[inline]
    pub fn new(seed: u64) -> Self {
        Self { base: splitmix64(seed), ctr: 0 }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let out = splitmix64(self.base.wrapping_add(self.ctr));
        self.ctr = self.ctr.wrapping_add(1);
        out
    }

    /// Uniform in `(0, 1)` (never exactly 0, safe for `ln`).
    #[inline]
    pub fn next_uniform(&mut self) -> f64 {
        // 53 random mantissa bits; add half an ulp to stay strictly positive.
        let bits = self.next_u64() >> 11;
        (bits as f64 + 0.5) * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Standard normal via Box–Muller (uses two uniforms per pair).
    #[inline]
    pub fn next_normal_pair(&mut self) -> (f64, f64) {
        let u1 = self.next_uniform();
        let u2 = self.next_uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        (r * theta.cos(), r * theta.sin())
    }
}

#[inline]
fn uniform_from_bits(word: u64) -> f64 {
    let bits = word >> 11;
    (bits as f64 + 0.5) * (1.0 / 9_007_199_254_740_992.0)
}

/// Random access into a seed's normal stream: the `m`-th standard normal of
/// `SplitPrng::new(seed)` — i.e. exactly `box_muller_fill(seed, 1.0, out)`'s
/// `out[m]` — computed in O(1) without generating the prefix.
///
/// This is what lets the batched solve engine hand each *path* its own
/// deterministic noise stream and fill any `(step, channel)` slice of it
/// from any worker thread, with results independent of the work partition.
#[inline]
pub fn normal_at(seed: u64, m: u64) -> f64 {
    let base = splitmix64(seed);
    let pair = m / 2;
    let u1 = uniform_from_bits(splitmix64(base.wrapping_add(2 * pair)));
    let u2 = uniform_from_bits(splitmix64(base.wrapping_add(2 * pair + 1)));
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = std::f64::consts::TAU * u2;
    if m % 2 == 0 {
        r * theta.cos()
    } else {
        r * theta.sin()
    }
}

/// Fill `out` with iid `N(0, scale^2)` samples from the stream of `seed`.
///
/// This is the single hot allocation-free primitive every Brownian source
/// builds on. Deterministic in `(seed, out.len(), scale)`.
pub fn box_muller_fill(seed: u64, scale: f64, out: &mut [f32]) {
    let mut rng = SplitPrng::new(seed);
    let mut i = 0;
    while i + 1 < out.len() {
        let (a, b) = rng.next_normal_pair();
        out[i] = (a * scale) as f32;
        out[i + 1] = (b * scale) as f32;
        i += 2;
    }
    if i < out.len() {
        let (a, _) = rng.next_normal_pair();
        out[i] = (a * scale) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values_nonzero_and_distinct() {
        let a = splitmix64(0);
        let b = splitmix64(1);
        let c = splitmix64(2);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, 0);
    }

    #[test]
    fn split_children_differ_from_parent_and_each_other() {
        for seed in [0u64, 1, 42, u64::MAX, 0xDEADBEEF] {
            let (l, r) = split_seed(seed);
            assert_ne!(l, r);
            assert_ne!(l, seed);
            assert_ne!(r, seed);
        }
    }

    #[test]
    fn split_is_deterministic() {
        assert_eq!(split_seed(99), split_seed(99));
    }

    #[test]
    fn stream_is_replayable() {
        let mut a = SplitPrng::new(7);
        let mut b = SplitPrng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_open_unit_interval() {
        let mut rng = SplitPrng::new(3);
        for _ in 0..10_000 {
            let u = rng.next_uniform();
            assert!(u > 0.0 && u < 1.0);
        }
    }

    #[test]
    fn normals_have_unit_moments() {
        let mut out = vec![0.0f32; 200_000];
        box_muller_fill(12345, 1.0, &mut out);
        let n = out.len() as f64;
        let mean: f64 = out.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 =
            out.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn box_muller_respects_scale() {
        let mut out = vec![0.0f32; 100_000];
        box_muller_fill(5, 0.5, &mut out);
        let n = out.len() as f64;
        let var: f64 = out.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / n;
        assert!((var - 0.25).abs() < 0.01, "var={var}");
    }

    #[test]
    fn normal_at_matches_box_muller_stream() {
        let mut out = vec![0.0f32; 33]; // odd length: exercises the tail
        box_muller_fill(987, 1.0, &mut out);
        for (m, &v) in out.iter().enumerate() {
            assert_eq!(v, normal_at(987, m as u64) as f32, "index {m}");
        }
    }

    #[test]
    fn different_seeds_give_different_streams() {
        let mut a = vec![0.0f32; 16];
        let mut b = vec![0.0f32; 16];
        box_muller_fill(1, 1.0, &mut a);
        box_muller_fill(2, 1.0, &mut b);
        assert_ne!(a, b);
    }
}
