//! The Virtual Brownian Tree baseline (Li et al. 2020, "Scalable Gradients
//! for Stochastic Differential Equations"; paper Section 4's comparator).
//!
//! The real line is approximated by a *fixed* dyadic tree of depth
//! `ceil(log2((t1 - t0) / eps))`. To evaluate `W(s)` the tree is descended
//! from the root, bridging at each midpoint with noise derived from a
//! splittable seed, until the containing dyadic interval is narrower than
//! `eps`; the value at the nearest dyadic point is returned. Samples are
//! therefore **approximate** (resolution `eps`) and every query costs
//! `O(log(1/eps))` — both in contrast to the Brownian Interval. No state is
//! kept between queries beyond the two endpoint values, which is the
//! structure's selling point (O(1) memory) and its weakness (no reuse).

use super::prng::{box_muller_fill, split_seed, splitmix64};
use super::{check_interval, BrownianSource};

/// Approximate Brownian motion via dyadic bisection to tolerance `eps`.
pub struct VirtualBrownianTree {
    t0: f64,
    t1: f64,
    size: usize,
    seed: u64,
    eps: f64,
    depth: u32,
    /// W(t1) - W(t0), fixed at construction (the root increment).
    w_total: Vec<f32>,
    /// Scratch buffers for the two bridge endpoints during descent.
    scratch_a: Vec<f32>,
    scratch_b: Vec<f32>,
    scratch_mid: Vec<f32>,
    scratch_noise: Vec<f32>,
    /// Endpoint buffers retained across `fill_grid` calls so the per-step
    /// training fill is allocation-free once warm.
    grid_prev: Vec<f32>,
    grid_cur: Vec<f32>,
    /// Number of bridge evaluations performed (for benchmarks).
    pub bridge_count: u64,
}

impl VirtualBrownianTree {
    /// Create a tree over `[t0, t1]` with `size` channels and resolution
    /// `eps` (the paper's experiments use the torchsde default `eps = 1e-5`).
    pub fn new(t0: f64, t1: f64, size: usize, seed: u64, eps: f64) -> Self {
        assert!(t1 > t0 && eps > 0.0);
        let depth = (((t1 - t0) / eps).log2().ceil() as u32).max(1);
        let mut w_total = vec![0.0f32; size];
        box_muller_fill(splitmix64(seed), (t1 - t0).sqrt(), &mut w_total);
        Self {
            t0,
            t1,
            size,
            seed,
            eps,
            depth,
            w_total,
            scratch_a: vec![0.0; size],
            scratch_b: vec![0.0; size],
            scratch_mid: vec![0.0; size],
            scratch_noise: vec![0.0; size],
            grid_prev: vec![0.0; size],
            grid_cur: vec![0.0; size],
            bridge_count: 0,
        }
    }

    /// Resolution of the dyadic discretisation.
    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Re-seed in place, keeping all scratch buffers. Queries afterwards are
    /// bit-identical to a fresh tree built with the new seed (the structure
    /// keeps no per-query state, so only the seed and the root increment
    /// need refreshing).
    pub fn reseed(&mut self, seed: u64) {
        self.seed = seed;
        box_muller_fill(splitmix64(seed), (self.t1 - self.t0).sqrt(), &mut self.w_total);
    }

    /// Evaluate `W(t) - W(t0)` into `out` by descending the dyadic tree.
    fn eval_at(&mut self, t: f64, out: &mut [f32]) {
        // Descend [a, b] halving each level. Invariants: scratch_a = W(a),
        // scratch_b = W(b) (as increments from t0); seed identifies [a, b].
        let (mut a, mut b) = (self.t0, self.t1);
        let mut seed = self.seed;
        self.scratch_a.fill(0.0);
        self.scratch_b.copy_from_slice(&self.w_total);
        for _ in 0..self.depth {
            if b - a <= self.eps {
                break;
            }
            let m = 0.5 * (a + b);
            // Bridge at the midpoint: W(m) | W(a), W(b) =
            //   N( (W(a)+W(b))/2 , (b-a)/4 ).
            let sd = (0.25 * (b - a)).sqrt();
            // Midpoint noise is keyed off this interval's seed so it is
            // identical no matter the query order.
            box_muller_fill(splitmix64(seed ^ 0x5bf0_3635), sd, &mut self.scratch_noise);
            self.bridge_count += 1;
            for i in 0..self.size {
                self.scratch_mid[i] =
                    0.5 * (self.scratch_a[i] + self.scratch_b[i]) + self.scratch_noise[i];
            }
            let (sl, sr) = split_seed(seed);
            if t < m {
                b = m;
                seed = sl;
                self.scratch_b.copy_from_slice(&self.scratch_mid);
            } else {
                a = m;
                seed = sr;
                self.scratch_a.copy_from_slice(&self.scratch_mid);
            }
        }
        // Nearest-endpoint approximation at the leaf (resolution eps).
        if t - a <= b - t {
            out.copy_from_slice(&self.scratch_a);
        } else {
            out.copy_from_slice(&self.scratch_b);
        }
    }
}

impl BrownianSource for VirtualBrownianTree {
    fn size(&self) -> usize {
        self.size
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn increment(&mut self, s: f64, t: f64, out: &mut [f32]) {
        check_interval((self.t0, self.t1), s, t);
        assert_eq!(out.len(), self.size);
        // W(t) - W(s): two full descents per query.
        let mut ws = vec![0.0f32; self.size];
        self.eval_at(s, &mut ws);
        self.eval_at(t, out);
        for i in 0..self.size {
            out[i] -= ws[i];
        }
    }

    /// Grid fill evaluating each grid point **once**: the per-increment
    /// default would descend the dyadic tree twice per step (once for each
    /// endpoint); walking the grid keeps the previous endpoint's value and
    /// halves the descents. Bit-identical to sequential `increment` calls.
    fn fill_grid(&mut self, ts: &[f64], out: &mut [f32]) {
        let n = ts.len().saturating_sub(1);
        assert_eq!(out.len(), n * self.size, "fill_grid: need {} values", n * self.size);
        if n == 0 {
            return;
        }
        check_interval((self.t0, self.t1), ts[0], ts[n]);
        // Take the retained endpoint buffers out of `self` so `eval_at` can
        // borrow `self` mutably; restored below (steady state: zero allocs).
        let mut prev = std::mem::take(&mut self.grid_prev);
        let mut cur = std::mem::take(&mut self.grid_cur);
        prev.resize(self.size, 0.0);
        cur.resize(self.size, 0.0);
        self.eval_at(ts[0], &mut prev);
        for k in 0..n {
            assert!(ts[k] < ts[k + 1], "fill_grid: grid must be strictly increasing");
            self.eval_at(ts[k + 1], &mut cur);
            let row = &mut out[k * self.size..(k + 1) * self.size];
            for i in 0..self.size {
                row[i] = cur[i] - prev[i];
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        self.grid_prev = prev;
        self.grid_cur = cur;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = VirtualBrownianTree::new(0.0, 1.0, 4, 3, 1e-5);
        let mut b = VirtualBrownianTree::new(0.0, 1.0, 4, 3, 1e-5);
        for (s, t) in [(0.0, 0.3), (0.3, 0.6), (0.1, 0.9)] {
            assert_eq!(a.increment_vec(s, t), b.increment_vec(s, t));
        }
    }

    #[test]
    fn query_order_does_not_matter() {
        let mut a = VirtualBrownianTree::new(0.0, 1.0, 4, 3, 1e-6);
        let mut b = VirtualBrownianTree::new(0.0, 1.0, 4, 3, 1e-6);
        let w_a1 = a.increment_vec(0.1, 0.2);
        let w_a2 = a.increment_vec(0.7, 0.8);
        let w_b2 = b.increment_vec(0.7, 0.8);
        let w_b1 = b.increment_vec(0.1, 0.2);
        assert_eq!(w_a1, w_b1);
        assert_eq!(w_a2, w_b2);
    }

    #[test]
    fn chain_consistency_within_tolerance() {
        let mut a = VirtualBrownianTree::new(0.0, 1.0, 4, 5, 1e-7);
        let whole = a.increment_vec(0.0, 1.0);
        let l = a.increment_vec(0.0, 0.5);
        let r = a.increment_vec(0.5, 1.0);
        for i in 0..4 {
            assert!((whole[i] - (l[i] + r[i])).abs() < 1e-2);
        }
    }

    #[test]
    fn increments_have_brownian_moments() {
        let mut a = VirtualBrownianTree::new(0.0, 1.0, 50_000, 7, 1e-5);
        let w = a.increment_vec(0.25, 0.5);
        let n = w.len() as f64;
        let mean = w.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = w.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 0.25).abs() < 0.01, "var={var}");
    }

    #[test]
    fn reseed_matches_fresh_instance() {
        let mut persistent = VirtualBrownianTree::new(0.0, 1.0, 4, 1, 1e-5);
        let _ = persistent.increment_vec(0.2, 0.4);
        persistent.reseed(9);
        let mut fresh = VirtualBrownianTree::new(0.0, 1.0, 4, 9, 1e-5);
        for (s, t) in [(0.0, 0.3), (0.3, 0.6), (0.1, 0.9)] {
            assert_eq!(persistent.increment_vec(s, t), fresh.increment_vec(s, t));
        }
    }

    #[test]
    fn fill_grid_matches_sequential_increments() {
        let ts: Vec<f64> = (0..=10).map(|k| k as f64 / 10.0).collect();
        let mut a = VirtualBrownianTree::new(0.0, 1.0, 3, 8, 1e-5);
        let mut b = VirtualBrownianTree::new(0.0, 1.0, 3, 8, 1e-5);
        let mut bulk = vec![0.0f32; 10 * 3];
        a.fill_grid(&ts, &mut bulk);
        for k in 0..10 {
            assert_eq!(
                &bulk[k * 3..(k + 1) * 3],
                b.increment_vec(ts[k], ts[k + 1]).as_slice(),
                "step {k}"
            );
        }
    }

    #[test]
    fn query_cost_grows_with_resolution() {
        let mut coarse = VirtualBrownianTree::new(0.0, 1.0, 1, 7, 1e-2);
        let mut fine = VirtualBrownianTree::new(0.0, 1.0, 1, 7, 1e-8);
        let _ = coarse.increment_vec(0.4, 0.6);
        let _ = fine.increment_vec(0.4, 0.6);
        assert!(fine.bridge_count > 2 * coarse.bridge_count);
    }
}
