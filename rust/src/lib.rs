//! # neural-sde
//!
//! A Rust + JAX + Pallas reproduction of *Efficient and Accurate Gradients
//! for Neural SDEs* (Kidger, Foster, Li, Lyons — NeurIPS 2021).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * Layer 1 (build time): Pallas kernels for the fused LipSwish-MLP vector
//!   fields and the reversible-Heun state update (`python/compile/kernels/`).
//! * Layer 2 (build time): the Neural SDE / Neural CDE / Latent SDE models
//!   and their optimise-then-discretise adjoints in JAX, AOT-lowered to HLO
//!   text (`python/compile/`).
//! * Layer 3 (this crate, runtime): the paper's coordination contributions —
//!   the [`brownian::BrownianInterval`] noise data structure, the
//!   [`solvers::ReversibleHeun`] algebraically-reversible solver, training
//!   orchestration ([`coordinator`]) driving PJRT executables, optimisers
//!   with the paper's weight-clipping scheme ([`nn`]), datasets ([`data`]),
//!   and evaluation metrics ([`metrics`]).
//!
//! Python never runs on the training path: `make artifacts` lowers the JAX
//! programs once, and the Rust binary is self-contained afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use neuralsde::brownian::{BrownianInterval, BrownianSource};
//!
//! // An exact, O(1)-memory Brownian motion over [0, 1] with 8 channels.
//! let mut bm = BrownianInterval::new(0.0, 1.0, 8, 42);
//! let w = bm.increment_vec(0.0, 0.5); // W(0.5) - W(0.0), exact
//! assert_eq!(w.len(), 8);
//! ```

pub mod brownian;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
