//! # neural-sde
//!
//! A Rust + JAX + Pallas reproduction of *Efficient and Accurate Gradients
//! for Neural SDEs* (Kidger, Foster, Li, Lyons — NeurIPS 2021).
//!
//! The crate is a **four-layer native stack** (the historical JAX/PJRT
//! lowering survives as an optional backend):
//!
//! * Neural layer ([`nn`]): flat-parameter layouts with native constructors
//!   ([`nn::GanNetSpec`] — no manifest JSON required), the LipSwish-MLP
//!   forward + analytic VJP in per-path and SoA-batched form
//!   ([`nn::mlp`]), optimisers, the paper's **weight clipping**
//!   ([`nn::ParamLayout::clip_lipschitz`]) and stochastic weight averaging.
//! * Solver layer ([`solvers`]): the [`solvers::ReversibleHeun`] method and
//!   its batched SoA twin, the multi-threaded batch engine
//!   ([`solvers::integrate_batched`]), and the **neural vector fields** as
//!   native systems ([`solvers::neural`]: the SDE-GAN generator and the
//!   neural-CDE discriminator, per-path and hand-batched).
//! * Adjoint layer ([`solvers::adjoint`]): exact reverse-mode gradients by
//!   backward reconstruction, from terminal losses up to whole-trajectory
//!   losses (per-step cotangent injection) and increment cotangents for
//!   data-driven CDEs.
//! * Coordinator layer ([`coordinator`]): end-to-end **in-Rust SDE-GAN
//!   training** ([`coordinator::GanTrainer`] — generator solve →
//!   discriminator CDE → adjoint gradients → Adadelta + clipping + SWA) on
//!   [`brownian::BrownianInterval`] noise, plus datasets ([`data`]) and
//!   evaluation metrics ([`metrics`]).
//!
//! Python never runs on the training path, and the default build needs no
//! artifacts at all: `cargo run --example sde_gan_ou` trains natively. The
//! AOT/PJRT execution layer (Latent SDE, gradient-penalty baseline,
//! non-reversible training solvers) sits behind the off-by-default `pjrt`
//! cargo feature; the default build substitutes a manifest-only stub
//! runtime so the crate builds and tests offline.
//!
//! ## Performance architecture
//!
//! The paper's headline numbers are all measured on *batched* solves
//! (SDE-GAN / Latent SDE training integrates 1024+ paths per step), so the
//! pure-Rust hot path is batch-native and built as three layers that share
//! one invariant:
//!
//! * **SoA layout** — [`solvers::BatchSde`] evaluates a whole
//!   `[dim × batch]` structure-of-arrays state per call (every per-path
//!   [`solvers::Sde`] adapts automatically; the benchmark systems also ship
//!   native hand-batched twins), and diagonal-noise systems skip the dense
//!   `e×d` mat-vec. Component `i`'s values for all paths are contiguous
//!   (`y[i * batch + p]`), so every inner loop is a unit-stride sweep.
//! * **SIMD kernels** — those sweeps run on the unrolled fused kernels of
//!   [`solvers::simd`], which are **precision-generic** over the sealed
//!   [`solvers::Lane`] element type: `f64` unrolls 4-wide (one AVX2
//!   register), `f32` unrolls **8-wide** with half the memory traffic —
//!   the single-precision solve path for workloads that tolerate it (the
//!   Brownian sources produce `f32` natively, so the `f32` path has zero
//!   widening copies). Vectorisation is *across paths*, never within one
//!   path's arithmetic: each path's expression tree (operand order,
//!   association, reduction order over noise channels) is exactly the
//!   scalar steppers', so batched results are **bit-for-bit equal** to
//!   per-path integration at the same precision — lane width varies with
//!   the element type, the association rule does not. That is the SoA-lane
//!   invariant the whole stack rests on (the `f64` instantiation's bits are
//!   the historical ones).
//! * **Work-stealing fan-out on a persistent executor** —
//!   [`solvers::integrate_batched`] spreads path chunks over the
//!   **process-wide, spawn-once executor** ([`solvers::pool`]): workers are
//!   created lazily on the first dispatch, park on a condvar between jobs,
//!   and are never spawned or joined per call. Each participant owns a
//!   contiguous task range and pops its front; idle participants steal from
//!   the back of the most-loaded range. Per-path noise comes from
//!   counter-based streams ([`solvers::CounterGridNoise`]) keyed by path
//!   index alone, so results are bit-identical for every thread count,
//!   chunk size and steal schedule — the schedule is unobservable. A warm
//!   dispatch performs zero executor allocations and zero thread spawns
//!   (pinned by `tests/pool_zero_alloc.rs`), and independent task sets
//!   ([`solvers::pool::join2`]) overlap the GAN trainer's real/fake
//!   discriminator adjoint sweeps on the same workers.
//!
//! The same discipline applies to noise: the Brownian Interval partitions a
//! whole training grid in one tree descent
//! ([`brownian::BrownianSource::fill_grid`]) while producing the exact bits
//! of per-step queries, and [`brownian::BrownianInterval::reseed`] redraws
//! a persistent tree without reallocating it.
//!
//! ### Adjoint engine
//!
//! Gradients run natively on the same stack ([`solvers::adjoint`]). The
//! reversibility invariant: the reversible-Heun step is algebraically
//! invertible, so the backward pass *reconstructs* the forward trajectory
//! via [`solvers::ReversibleHeun::reverse_step`] in O(1) memory, and the
//! cotangents it accumulates are the exact derivatives of the discrete
//! forward solve — no truncation error, only roundoff (the backward
//! reconstruction is bit-exact up to float inversion, pinned <1e-10 by
//! tests, and debug builds assert every reconstructed state forward-replays
//! onto the pre-reverse state). VJP-kernel association rule: the fused
//! backward kernels in [`solvers::simd`] and the analytic VJPs of
//! [`solvers::SdeVjp`] / [`solvers::BatchSdeVjp`] keep the forward kernels'
//! float association — vectorised across paths, never within one path, with
//! θ-gradients held in per-path lanes and reduced in ascending path order —
//! so [`solvers::adjoint_solve_batched`] is bit-identical to per-path
//! [`solvers::adjoint_solve`] for every batch size, chunk size and thread
//! count. Backward noise is replayed from the same deterministic sources as
//! the forward pass ([`solvers::GridReplayNoise`] pulls a whole grid out of
//! a Brownian source in one `fill_grid` descent and serves it right-to-left
//! — the Brownian Interval's reason for existing).
//!
//! ### Mixed-precision training
//!
//! The adjoint engine itself stays `f64` (gradient accuracy is the paper's
//! point), but the *forward* solves don't have to:
//! [`solvers::adjoint_solve_batched_mixed`] and the full-featured
//! [`solvers::adjoint_solve_batched_steps_mixed`] (per-step cotangent
//! injection, `ddw` increment cotangents, the guard/fallback contract) run
//! the forward trajectory on the 8-wide `f32` lanes and backpropagate
//! exactly in `f64` through the widened tape. The gradients are the exact
//! discretise-then-optimise derivatives *of the `f32` discrete map*, so
//! they deviate from all-`f64` training only by single-precision forward
//! rounding — measured by `coordinator::gradient_error::run_native_mixed`
//! and bounded (< 1e-2 relative) by `tests/neural_gan.rs`. The whole
//! SDE-GAN step rides it via [`config::TrainPrecision`]: `Mixed` routes
//! the generator solve, both adjoint sweeps and sampling through the
//! `f32` path with **zero per-step widening copies** (gradient
//! accumulation and the optimiser chain rules stay `f64`, à la
//! Micikevicius et al.), while the `F64` default keeps every historical
//! bit. Mixed training keeps the fan-out guarantee too: its
//! backward sweeps run in tape mode, whose results are chunk-schedule
//! invariant, so mixed steps are bit-deterministic across every
//! thread/chunk setting.
//!
//! The adjoint extends beyond terminal losses: [`solvers::adjoint_solve_steps`]
//! injects per-step loss cotangents during the backward sweep (a
//! path-dependent discriminator reading the whole trajectory backpropagates
//! exactly) and accumulates increment cotangents `∂L/∂ΔW`
//! ([`solvers::AdjointGrad::ddw`]) so CDEs driven by data increments chain
//! onto the driving path. The neural vector fields ([`solvers::neural`])
//! implement the same VJP traits natively over SoA lanes via the batched
//! LipSwish-MLP kernels ([`nn::mlp`]), preserving batched ≡ per-path
//! bit-identity through the whole GAN training step. Both chunk fan-outs —
//! forward and adjoint — share one work-stealing scheduler
//! ([`solvers::map_chunks`], dispatching on the persistent
//! [`solvers::pool`]), whose results are keyed by chunk index so schedules
//! can never affect bits; the trainer additionally overlaps its two
//! data-independent discriminator adjoint solves (real and fake paths)
//! through [`solvers::pool::join2`], with the f64 gradient reduction kept
//! in a fixed fake-then-real order so the overlap is bit-neutral.
//!
//! ## Quickstart
//!
//! ```no_run
//! use neuralsde::brownian::{BrownianInterval, BrownianSource};
//!
//! // An exact, O(1)-memory Brownian motion over [0, 1] with 8 channels.
//! let mut bm = BrownianInterval::new(0.0, 1.0, 8, 42);
//! let w = bm.increment_vec(0.0, 0.5); // W(0.5) - W(0.0), exact
//! assert_eq!(w.len(), 8);
//!
//! // Batched solve: 256 paths of a 4-dim SDE, SoA state, 2 worker threads.
//! use neuralsde::solvers::{
//!     integrate_batched, systems::TanhDiagonal, BatchOptions, BatchReversibleHeun,
//!     CounterGridNoise,
//! };
//! let sde = TanhDiagonal::new(4, 7);
//! let noise = CounterGridNoise::new(1, 4, 0.0, 1.0, 32);
//! let y0 = vec![0.1; 4 * 256];
//! let opts = BatchOptions { threads: 2, chunk: 64, ..Default::default() };
//! let traj = integrate_batched::<BatchReversibleHeun, _, _>(
//!     &sde, &noise, &y0, 256, 0.0, 1.0, 32, &opts,
//! )
//! .expect("solve faulted"); // structured SolveError on non-finite lanes
//! assert_eq!(traj.len(), 33 * 4 * 256);
//! ```
//!
//! ## Error-handling contract
//!
//! The solve and training stack reports failures as **structured, exactly
//! localised errors** instead of panicking or silently propagating NaNs:
//!
//! * Every fallible entry point — [`solvers::integrate_batched`], the
//!   [`solvers::adjoint`] family, [`coordinator::GanTrainer::train_step`] —
//!   returns a `Result` whose error type ([`solvers::SolveError`]) carries
//!   one [`solvers::SolveFault`] per affected path: the grid **step whose
//!   update first produced the faulty value**, the path index, the state
//!   component, and a cause ([`solvers::FaultCause`]: non-finite lane,
//!   reconstruction drift beyond tolerance, or a vector-field panic).
//! * Detection is cheap: blockwise `is_finite` sweeps every
//!   [`solvers::GuardConfig::check_every`] steps (default 8, <2% overhead —
//!   pinned by the `guard/*` rows of `benches/hotpath_micro.rs`), with a
//!   bit-identical re-run to localise the exact coordinates only on breach.
//! * Guards never change healthy results: the batched ≡ per-path bitwise
//!   invariant holds with guards enabled, and
//!   [`solvers::GuardConfig::disabled`] turns sweeps off entirely.
//! * **Panic isolation**: a vector field that panics poisons neither the
//!   worker pool nor sibling paths — [`solvers::map_chunks_isolated`]
//!   catches the unwind per chunk, and the guarded forward engine
//!   ([`solvers::integrate_batched_guarded`]) quarantines exactly the
//!   offending lanes (optionally refilling them from fresh seeds) while
//!   surviving paths keep their bit-exact trajectories.
//! * **Divergence watchdogs** recover instead of failing where an exact
//!   fallback exists: the adjoint backward sweep checkpoints sparse forward
//!   states and falls back from O(1)-memory reconstruction to the stored
//!   tape for the remaining segment on drift breach (gradients stay exact;
//!   [`solvers::AdjointGrad::fallbacks`] counts the events), and the GAN
//!   trainer rolls a diverged step back to a last-good snapshot (θ/φ,
//!   Adadelta accumulators, SWA) and retries with deterministically
//!   re-drawn noise ([`coordinator::GanStepStats`] reports `retries`).
//! * Fault recovery is **deterministic and testable**:
//!   [`solvers::FaultPlan`] injects NaNs, panics and corrupted gradient
//!   lanes at exact coordinates; `tests/fault_tolerance.rs` drives every
//!   recovery path bit-reproducibly.
//!
//! ## Serving architecture
//!
//! Training solves one big batch; *serving* a trained model solves many
//! small, concurrent sampling requests. [`solvers::serve`] covers that
//! shape with a persistent engine instead of per-call machinery:
//!
//! * **One executor for the whole process** — the engine owns no threads:
//!   admission rounds are driven by whichever caller blocks in
//!   [`solvers::ServeEngine::wait_into`] (or calls `flush`), and their
//!   chunk fan-out runs on the same persistent pool ([`solvers::pool`]) as
//!   every training solve — no serve-private worker set, no per-request
//!   thread spawning, no per-chunk stepper construction
//!   ([`solvers::BatchStepper::reinit`] re-initialises each participant's
//!   checked-out stepper in place).
//! * **Size-aware admission packing** — a request is a set of rows in the
//!   `[component × batch]` SoA state, so admission is *lane assignment*:
//!   queued requests pack into one mega-batch of up to
//!   [`solvers::ServeConfig::max_batch`] lanes under an
//!   [`solvers::AdmitPolicy`]. The default `Packed` policy first-fits
//!   smaller requests into capacity a blocked head cannot use (the head
//!   keeps its queue position — deadline-preserving, no starvation) and
//!   drains a **priority lane** of interactive-width requests
//!   ([`solvers::ServeConfig::priority_width`]) before bulk traffic.
//!   Because SIMD vectorises across paths and never inside one path's
//!   arithmetic, and each request's Brownian sample is fixed by its
//!   submit-time counter, packing order can never change results: the
//!   coalesced solve is **bit-identical** to solving each request alone
//!   (`tests/serve_engine.rs` pins widths 1/3/7/33 across policies and
//!   thread/chunk fan-outs).
//! * **Sharded mega-requests** — a request wider than
//!   [`solvers::ServeConfig::shard_width`] splits into per-shard lane
//!   ranges admitted across consecutive rounds, so a 10⁶-path batch
//!   coexists with width-1 interactive traffic instead of monopolising
//!   the pool (`examples/mc_pricing.rs` prices a basket option this way).
//!   Shard faults quarantine to the owning request with request-relative
//!   coordinates; sibling shards and bystander requests keep their bits.
//! * **Sessions own their noise** — each session holds a persistent
//!   [`brownian::BrownianInterval`] (arenas survive across requests via
//!   `reseed`; sessions wider than a fixed block derive per-block seeds so
//!   arena memory stays bounded at 10⁶ paths), with per-request seeds
//!   derived by [`solvers::request_seed`] from the session seed and
//!   request counter alone — results never depend on lane placement or
//!   unrelated traffic. Above [`solvers::ServeConfig::max_sessions`]
//!   resident sessions, the least-recently-used one's heavy state is
//!   evicted — and sessions idle past the wall-clock
//!   [`solvers::ServeConfig::session_ttl_ms`] expire the same way — then
//!   rebuilt **bit-identically** on the next admission by replaying the
//!   same seed derivations.
//! * **Diagonal-noise fast path at f32** — the engine is generic over the
//!   [`solvers::Lane`] element: instantiated at `f32` (8-wide kernels,
//!   half the memory traffic) a diagonal-noise system like
//!   [`solvers::systems::MarketModel`] serves Monte-Carlo pricing loads at
//!   million-path scale, bit-identical to the single-request f32 solve.
//! * **Zero-allocation steady state** — slots, mega-batch arena, session
//!   grids and worker scratch are preallocated and recycled;
//!   [`solvers::ServeEngine::wait_into`] swaps results into caller-owned
//!   buffers. A warm submit→coalesce→solve→collect round trip performs
//!   zero heap allocations, pinned by a counting global allocator in
//!   `tests/serve_zero_alloc.rs` and by a capacity-signature
//!   `debug_assert` inside the solve loop.
//! * **Per-request quarantine** — faults follow the error-handling
//!   contract above, charged to the owning request with request-relative
//!   coordinates; the faulted request's slot returns to the admission
//!   pool and every other in-flight request keeps its exact bits.
//! * `benches/serve_throughput.rs` drives Poisson open-loop load through
//!   the engine and reports sustained `paths/sec` with p50/p99 latency —
//!   including mixed-size workloads (interactive p50/p99 per size class
//!   under `packed_vs_fifo/*`) and the million-path Monte-Carlo fast path
//!   (`diag_fast_path/*`).

pub mod brownian;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod runtime;
pub mod solvers;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
