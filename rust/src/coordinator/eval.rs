//! Model evaluation: the Appendix-F.1 metric battery.

use crate::data::TimeSeriesDataset;
use crate::metrics;

/// Test metrics for a generative model (Appendix F.1).
#[derive(Clone, Copy, Debug)]
pub struct EvalReport {
    /// Real-vs-fake classification accuracy (0.5 = indistinguishable).
    pub real_fake_acc: f64,
    /// Train-on-synthetic-test-on-real forecasting MSE.
    pub prediction_loss: f64,
    /// Signature-feature MMD.
    pub mmd: f64,
}

impl EvalReport {
    /// Format like a paper table row.
    pub fn row(&self) -> String {
        format!(
            "real/fake acc {:5.1}%   prediction {:8.4}   MMD {:9.4e}",
            100.0 * self.real_fake_acc,
            self.prediction_loss,
            self.mmd
        )
    }
}

/// Score generated data against a held-out real test set.
pub fn evaluate_generator(
    real_test: &TimeSeriesDataset,
    fake: &TimeSeriesDataset,
    seed: u64,
) -> EvalReport {
    EvalReport {
        real_fake_acc: metrics::real_fake_accuracy(real_test, fake, seed),
        prediction_loss: metrics::prediction_loss_tstr(fake, real_test),
        mmd: metrics::signature_mmd(real_test, fake, 3),
    }
}
