//! Noise plumbing between the Brownian sources and the PJRT executables.
//!
//! A training step needs the increment tensor ``dws [N, B, w]`` for the
//! solver grid; this module fills it by querying a [`BrownianSource`]
//! sequentially over the observation intervals — the access pattern the
//! Brownian Interval's hint/cache design targets. The same source (same
//! seed) refilled over the same grid reproduces identical noise, which is
//! how eval reuses training noise when needed.

use crate::brownian::{BrownianInterval, BrownianSource, VirtualBrownianTree};
use crate::brownian::{box_muller_fill, splitmix64};

/// Fill `dws` (`[n_steps][batch * w]` flattened) by sequential queries.
pub fn fill_increments<B: BrownianSource>(src: &mut B, ts: &[f32], dws: &mut [f32]) {
    let n = ts.len() - 1;
    let size = src.size();
    assert_eq!(dws.len(), n * size);
    for k in 0..n {
        src.increment(ts[k] as f64, ts[k + 1] as f64, &mut dws[k * size..(k + 1) * size]);
    }
}

/// Which Brownian backend fills the increments (the Table-10 toggle).
pub enum NoiseBackend {
    /// The paper's Brownian Interval (exact, O(1) amortised).
    Interval,
    /// The Virtual Brownian Tree baseline (approximate, O(log 1/eps)).
    VirtualTree {
        /// Dyadic resolution (torchsde default 1e-5).
        eps: f64,
    },
}

/// Per-step noise generator for a fixed time grid.
pub struct StepNoise {
    backend: NoiseBackend,
    t0: f64,
    t1: f64,
    size: usize,
    counter: u64,
    base_seed: u64,
}

impl StepNoise {
    /// `size = batch * noise_channels`; spans the (normalised) time grid.
    pub fn new(backend: NoiseBackend, t0: f64, t1: f64, size: usize, seed: u64) -> Self {
        Self { backend, t0, t1, size, counter: 0, base_seed: seed }
    }

    /// Fill `dws` for a fresh Brownian sample (new seed each call).
    pub fn fill(&mut self, ts: &[f32], dws: &mut [f32]) {
        let seed = splitmix64(self.base_seed ^ self.counter.wrapping_mul(0x9E37_79B9));
        self.counter += 1;
        match self.backend {
            NoiseBackend::Interval => {
                let mut bi = BrownianInterval::new(self.t0, self.t1, self.size, seed);
                fill_increments(&mut bi, ts, dws);
            }
            NoiseBackend::VirtualTree { eps } => {
                let mut vbt =
                    VirtualBrownianTree::new(self.t0, self.t1, self.size, seed, eps);
                fill_increments(&mut vbt, ts, dws);
            }
        }
    }

    /// Fill a buffer with standard normals (initial noise V, encoder ε).
    pub fn fill_normals(&mut self, out: &mut [f32]) {
        let seed = splitmix64(self.base_seed ^ 0xABCD ^ self.counter.wrapping_mul(31));
        self.counter += 1;
        box_muller_fill(seed, 1.0, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_covers_grid_consistently() {
        let mut bi = BrownianInterval::new(-0.5, 0.5, 3, 7);
        let ts: Vec<f32> = (0..5).map(|k| -0.5 + 0.25 * k as f32).collect();
        let mut dws = vec![0.0f32; 4 * 3];
        fill_increments(&mut bi, &ts, &mut dws);
        // Sum over steps equals the whole increment.
        let whole = bi.increment_vec(-0.5, 0.5);
        for c in 0..3 {
            let sum: f32 = (0..4).map(|k| dws[k * 3 + c]).sum();
            assert!((sum - whole[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn step_noise_fresh_samples_differ() {
        let mut sn = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 1);
        let ts = [0.0f32, 0.5, 1.0];
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        sn.fill(&ts, &mut a);
        sn.fill(&ts, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn step_noise_deterministic_across_instances() {
        let ts = [0.0f32, 0.5, 1.0];
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 9).fill(&ts, &mut a);
        StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 9).fill(&ts, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn vbt_backend_works() {
        let mut sn =
            StepNoise::new(NoiseBackend::VirtualTree { eps: 1e-5 }, 0.0, 1.0, 2, 3);
        let ts = [0.0f32, 0.25, 0.5, 0.75, 1.0];
        let mut dws = vec![0.0f32; 8];
        sn.fill(&ts, &mut dws);
        assert!(dws.iter().any(|&x| x != 0.0));
    }
}
