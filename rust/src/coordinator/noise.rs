//! Noise plumbing between the Brownian sources and the PJRT executables.
//!
//! A training step needs the increment tensor ``dws [N, B, w]`` for the
//! solver grid; this module fills it by bulk-querying a [`BrownianSource`]
//! over the observation grid (`fill_grid`) — the access pattern the
//! Brownian Interval's hint/cache design targets. The same source (same
//! seed) refilled over the same grid reproduces identical noise, which is
//! how eval reuses training noise when needed.
//!
//! [`StepNoise`] holds a **persistent** source: instead of rebuilding a
//! Brownian Interval tree + LRU cache from scratch on every training step
//! (the pre-batch-engine behaviour), it keeps one source alive and
//! [`BrownianInterval::reseed`]s it per step — the node arena, LRU arena
//! and recycled value buffers survive, so the steady-state fill path is
//! allocation-free.

use crate::brownian::{BrownianInterval, BrownianSource, VirtualBrownianTree};
use crate::brownian::{box_muller_fill, splitmix64};

/// Fill `dws` (`[n_steps][batch * w]` flattened) by sequential queries.
pub fn fill_increments<B: BrownianSource>(src: &mut B, ts: &[f32], dws: &mut [f32]) {
    let n = ts.len() - 1;
    let size = src.size();
    assert_eq!(dws.len(), n * size);
    for k in 0..n {
        src.increment(ts[k] as f64, ts[k + 1] as f64, &mut dws[k * size..(k + 1) * size]);
    }
}

/// Which Brownian backend fills the increments (the Table-10 toggle).
pub enum NoiseBackend {
    /// The paper's Brownian Interval (exact, O(1) amortised).
    Interval,
    /// The Virtual Brownian Tree baseline (approximate, O(log 1/eps)).
    VirtualTree {
        /// Dyadic resolution (torchsde default 1e-5).
        eps: f64,
    },
}

/// The persistent source behind [`StepNoise`].
enum Source {
    Interval(BrownianInterval),
    VirtualTree(VirtualBrownianTree),
}

/// Per-step noise generator for a fixed time grid.
pub struct StepNoise {
    src: Source,
    counter: u64,
    base_seed: u64,
    /// Reused f64 copy of the f32 observation grid.
    ts64: Vec<f64>,
}

impl StepNoise {
    /// `size = batch * noise_channels`; spans the (normalised) time grid.
    pub fn new(backend: NoiseBackend, t0: f64, t1: f64, size: usize, seed: u64) -> Self {
        let src = match backend {
            NoiseBackend::Interval => {
                Source::Interval(BrownianInterval::new(t0, t1, size, seed))
            }
            NoiseBackend::VirtualTree { eps } => {
                Source::VirtualTree(VirtualBrownianTree::new(t0, t1, size, seed, eps))
            }
        };
        Self { src, counter: 0, base_seed: seed, ts64: Vec::new() }
    }

    /// Fill `dws` for a fresh Brownian sample (new seed each call).
    ///
    /// The persistent source is reseeded in place and bulk-filled over the
    /// grid; with a fixed grid across calls (the training case) this is
    /// bit-identical to building a fresh source per call, without the
    /// per-step tree/cache/buffer construction.
    pub fn fill(&mut self, ts: &[f32], dws: &mut [f32]) {
        let seed = splitmix64(self.base_seed ^ self.counter.wrapping_mul(0x9E37_79B9));
        self.counter += 1;
        self.ts64.clear();
        self.ts64.extend(ts.iter().map(|&t| t as f64));
        match &mut self.src {
            Source::Interval(bi) => {
                bi.reseed(seed);
                bi.fill_grid(&self.ts64, dws);
            }
            Source::VirtualTree(vbt) => {
                vbt.reseed(seed);
                vbt.fill_grid(&self.ts64, dws);
            }
        }
    }

    /// Fill a buffer with standard normals (initial noise V, encoder ε).
    pub fn fill_normals(&mut self, out: &mut [f32]) {
        let seed = splitmix64(self.base_seed ^ 0xABCD ^ self.counter.wrapping_mul(31));
        self.counter += 1;
        box_muller_fill(seed, 1.0, out);
    }

    /// Rewind the draw counter to 0: the next fills replay the same
    /// deterministic sequence a freshly built `StepNoise` would produce.
    /// Lets callers keep one persistent source (arena, cache and buffers
    /// alive) where they previously rebuilt it per call for reproducibility.
    pub fn reset(&mut self) {
        self.counter = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_covers_grid_consistently() {
        let mut bi = BrownianInterval::new(-0.5, 0.5, 3, 7);
        let ts: Vec<f32> = (0..5).map(|k| -0.5 + 0.25 * k as f32).collect();
        let mut dws = vec![0.0f32; 4 * 3];
        fill_increments(&mut bi, &ts, &mut dws);
        // Sum over steps equals the whole increment.
        let whole = bi.increment_vec(-0.5, 0.5);
        for c in 0..3 {
            let sum: f32 = (0..4).map(|k| dws[k * 3 + c]).sum();
            assert!((sum - whole[c]).abs() < 1e-5);
        }
    }

    #[test]
    fn step_noise_fresh_samples_differ() {
        let mut sn = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 1);
        let ts = [0.0f32, 0.5, 1.0];
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        sn.fill(&ts, &mut a);
        sn.fill(&ts, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn step_noise_deterministic_across_instances() {
        let ts = [0.0f32, 0.5, 1.0];
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 9).fill(&ts, &mut a);
        StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 9).fill(&ts, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn step_noise_persistent_matches_fresh_each_step() {
        // The persistent-source optimisation must not change the noise: the
        // k-th fill of one StepNoise equals the k-th fill of a fresh
        // StepNoise driven to the same counter.
        let ts: Vec<f32> = (0..9).map(|k| k as f32 / 8.0).collect();
        let mut persistent = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 6, 33);
        let mut scratch = vec![0.0f32; 8 * 6];
        let mut third_persistent = vec![0.0f32; 8 * 6];
        persistent.fill(&ts, &mut scratch);
        persistent.fill(&ts, &mut scratch);
        persistent.fill(&ts, &mut third_persistent);
        let mut fresh = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 6, 33);
        let mut third_fresh = vec![0.0f32; 8 * 6];
        fresh.fill(&ts, &mut scratch);
        fresh.fill(&ts, &mut scratch);
        fresh.fill(&ts, &mut third_fresh);
        assert_eq!(third_persistent, third_fresh);
    }

    #[test]
    fn step_noise_reset_replays_from_scratch() {
        let ts = [0.0f32, 0.5, 1.0];
        let mut sn = StepNoise::new(NoiseBackend::Interval, 0.0, 1.0, 4, 17);
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 8];
        let mut na = vec![0.0f32; 6];
        let mut nb = vec![0.0f32; 6];
        sn.fill_normals(&mut na);
        sn.fill(&ts, &mut a);
        sn.fill(&ts, &mut b); // drift the counter further
        sn.reset();
        sn.fill_normals(&mut nb);
        sn.fill(&ts, &mut b);
        assert_eq!(na, nb);
        assert_eq!(a, b);
    }

    #[test]
    fn vbt_backend_works() {
        let mut sn =
            StepNoise::new(NoiseBackend::VirtualTree { eps: 1e-5 }, 0.0, 1.0, 2, 3);
        let ts = [0.0f32, 0.25, 0.5, 0.75, 1.0];
        let mut dws = vec![0.0f32; 8];
        sn.fill(&ts, &mut dws);
        assert!(dws.iter().any(|&x| x != 0.0));
    }
}
