//! The Figure-2 / Table-6 gradient-error experiment.
//!
//! For each solver and step size, run the f64 `graderr_<solver>_n<N>`
//! executable (which computes both the optimise-then-discretise and the
//! discretise-then-optimise gradients of the Appendix-F.5 test problem on
//! identical noise) and report the paper's relative L1 error
//!
//! ```text
//! Σ|δ_otd − δ_dto| / max(Σ|δ_otd|, Σ|δ_dto|)
//! ```
//!
//! over the concatenation of ∂L/∂X₀ and ∂L/∂θ.
//!
//! [`run_native`] produces the same table for the pure-Rust reversible-Heun
//! adjoint engine — no PJRT artifacts required: optimise-then-discretise is
//! the O(1)-memory backward reconstruction ([`BackwardMode::Reconstruct`]),
//! discretise-then-optimise is backprop through the stored forward tape
//! ([`BackwardMode::Tape`]) and, as an independent cross-check, central
//! finite differences of the same discrete solve on identical noise.

use crate::brownian::{box_muller_fill, splitmix64, SplitPrng};
use crate::runtime::Runtime;
use crate::solvers::systems::{TanhDiagonal, TanhDiagonalBatch};
use crate::solvers::{
    adjoint_solve, adjoint_solve_batched, adjoint_solve_batched_mixed, integrate, BackwardMode,
    BatchOptions, CounterGridNoise, ReversibleHeun,
};
use crate::util::stats::central_gradient;
use anyhow::Result;

/// One (solver, step-size) measurement.
#[derive(Clone, Debug)]
pub struct GradErrPoint {
    /// Solver name.
    pub solver: String,
    /// Number of steps over `[0, 1]` (step size `1/n`).
    pub n_steps: usize,
    /// Relative L1 gradient error.
    pub rel_err: f64,
}

/// The paper's relative L1 metric (Appendix F.5).
pub fn relative_l1(otd: &[f64], dto: &[f64]) -> f64 {
    assert_eq!(otd.len(), dto.len());
    let num: f64 = otd.iter().zip(dto).map(|(a, b)| (a - b).abs()).sum();
    let da: f64 = otd.iter().map(|x| x.abs()).sum();
    let db: f64 = dto.iter().map(|x| x.abs()).sum();
    num / da.max(db).max(1e-300)
}

/// Run the experiment for every `graderr_*` executable in the manifest.
pub fn run(rt: &mut Runtime, seed: u64) -> Result<Vec<GradErrPoint>> {
    let spec = rt.manifest.model("graderr")?.clone();
    let hy = |k: &str| -> usize { spec.hyper[k] as usize };
    let (x, w, b, p_total) = (hy("x"), hy("w"), hy("b"), hy("params"));

    // Fixed problem instance, shared across all solvers/step sizes.
    let mut params = vec![0.0f32; p_total];
    // Reuse the f32 initialiser then widen (keeps init identical to training).
    box_muller_fill(splitmix64(seed), 0.2, &mut params);
    let params64: Vec<f64> = params.iter().map(|&v| v as f64).collect();
    let mut rng = SplitPrng::new(seed ^ 0xF16);
    let z0: Vec<f64> = (0..b * x)
        .map(|_| rng.next_normal_pair().0)
        .collect();

    let names: Vec<String> = rt
        .manifest
        .execs
        .keys()
        .filter(|k| k.starts_with("graderr_"))
        .cloned()
        .collect();
    let mut out = Vec::new();
    for name in names {
        // graderr_<solver>_n<N>
        let rest = name.trim_start_matches("graderr_");
        let (solver, n_str) = rest.rsplit_once("_n").unwrap();
        let n: usize = n_str.parse()?;
        let ts: Vec<f64> = (0..=n).map(|k| k as f64 / n as f64).collect();
        // Brownian increments on this grid from the batch engine's per-path
        // counter streams: identical across solvers at the same n (seeded by
        // n only), and path p's noise is independent of the batch layout.
        let noise = CounterGridNoise::new(splitmix64(seed ^ (n as u64)), w, 0.0, 1.0, n);
        let mut dws = vec![0.0f64; n * b * w];
        for k in 0..n {
            for p in 0..b {
                for j in 0..w {
                    dws[(k * b + p) * w + j] = noise.value(p, k, j);
                }
            }
        }
        let res = rt.run_f64(
            &name,
            &[
                (&params64, &[p_total]),
                (&z0, &[b, x]),
                (&ts, &[n + 1]),
                (&dws, &[n, b, w]),
            ],
        )?;
        // Outputs: (otd_gz0, otd_gtheta, dto_gz0, dto_gtheta).
        let mut otd = res[0].clone();
        otd.extend_from_slice(&res[1]);
        let mut dto = res[2].clone();
        dto.extend_from_slice(&res[3]);
        out.push(GradErrPoint {
            solver: solver.to_string(),
            n_steps: n,
            rel_err: relative_l1(&otd, &dto),
        });
    }
    out.sort_by(|a, b| a.solver.cmp(&b.solver).then(a.n_steps.cmp(&b.n_steps)));
    Ok(out)
}

/// The native gradient-error rows: the pure-Rust reversible-Heun adjoint
/// on the Table-10 test SDE (`TanhDiagonal`, here d = 4), loss
/// `L = Σ_i z_N^i`, one path of counter-based grid noise shared across
/// every gradient method at each step count.
///
/// Per step count `n` this emits two rows:
///
/// * `native_revheun_rec_vs_tape` — backward reconstruction vs stored-tape
///   backprop of the *same* discrete solve. Both are exact discrete
///   gradients, so the relative error is pure reconstruction roundoff —
///   the paper's machine-precision claim, and it stays flat in `n`;
/// * `native_revheun_adjoint_vs_fd` — adjoint vs central finite
///   differences (step 1e-5) over `(y₀, θ)`; the error here is the FD
///   truncation floor, orders of magnitude above roundoff but far below
///   any solver-truncation bias.
pub fn run_native(seed: u64) -> Vec<GradErrPoint> {
    let d = 4usize;
    let sde = TanhDiagonal::new(d, seed);
    let theta0 = sde.params_flat();
    let y0: Vec<f64> = (0..d).map(|i| 0.05 * i as f64 + 0.1).collect();
    let mut out = Vec::new();
    for &n in &[8usize, 64, 512] {
        let noise = CounterGridNoise::new(splitmix64(seed ^ n as u64), d, 0.0, 1.0, n);
        // The discrete solve being differentiated, as a scalar loss of
        // (θ, y₀) — rebuilt per FD probe on the identical noise stream.
        let solve_loss = |th: &[f64], y0v: &[f64]| -> f64 {
            let s = TanhDiagonal::from_matrices(d, th[..d * d].to_vec(), th[d * d..].to_vec());
            let mut solver = ReversibleHeun::new(&s, 0.0, y0v);
            let mut pn = noise.path(0);
            let traj = integrate(&s, &mut solver, &mut pn, y0v, 0.0, 1.0, n);
            traj[traj.len() - d..].iter().sum()
        };
        let run_adj = |mode| {
            let mut pn = noise.path(0);
            let g = adjoint_solve(&sde, &y0, 0.0, 1.0, n, &mut pn, mode, |_z, gz| {
                gz.fill(1.0)
            })
            // Benchmark-only unwrap: the Table-10 test SDE is bounded
            // (tanh fields), so the guarded solve cannot fault.
            .expect("graderr solve is fault-free by construction");
            let mut cat = g.dy0.clone();
            cat.extend_from_slice(&g.dtheta);
            cat
        };
        let rec = run_adj(BackwardMode::Reconstruct);
        let tape = run_adj(BackwardMode::Tape);
        out.push(GradErrPoint {
            solver: "native_revheun_rec_vs_tape".to_string(),
            n_steps: n,
            rel_err: relative_l1(&rec, &tape),
        });
        let h = 1e-5;
        let mut fd = central_gradient(|yy| solve_loss(&theta0, yy), &y0, h);
        fd.extend(central_gradient(|th| solve_loss(th, &y0), &theta0, h));
        out.push(GradErrPoint {
            solver: "native_revheun_adjoint_vs_fd".to_string(),
            n_steps: n,
            rel_err: relative_l1(&rec, &fd),
        });
    }
    out
}

/// The mixed-precision rows: per step count, the deviation of the
/// mixed-precision gradient — **forward solved in `f32`** on the 8-wide
/// lanes, exact `f64` tape backward over the widened trajectory
/// ([`adjoint_solve_batched_mixed`]) — from the all-`f64` batched adjoint on
/// the *same* Brownian sample (the `f32` increments are the rounded `f64`
/// draws of the shared [`CounterGridNoise`]).
///
/// Unlike the reconstruction-vs-tape rows, this deviation is **not**
/// roundoff-flat: it is the single-precision truncation of the forward
/// trajectory carried through the chain rule — the accuracy price of the
/// f32 solve path's ~2× bandwidth win, which is exactly what a user trading
/// precision for speed needs to see.
pub fn run_native_mixed(seed: u64) -> Vec<GradErrPoint> {
    let d = 4usize;
    let batch = 8usize;
    let nsde = TanhDiagonalBatch::new(d, seed);
    let y0: Vec<f64> = (0..d * batch).map(|i| 0.04 * (i % 7) as f64 + 0.05).collect();
    let opts = BatchOptions::default();
    let ones = |_p0: usize, _cl: usize, _z: &[f64], g: &mut [f64]| g.fill(1.0);
    let mut out = Vec::new();
    for &n in &[8usize, 64, 512] {
        let noise = CounterGridNoise::new(splitmix64(seed ^ n as u64), d, 0.0, 1.0, n);
        let cat = |g: &crate::solvers::AdjointGrad| {
            let mut c = g.dy0.clone();
            c.extend_from_slice(&g.dtheta);
            c
        };
        let full = adjoint_solve_batched(
            &nsde,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Tape,
            &opts,
            &ones,
        )
        // Benchmark-only unwrap: bounded tanh fields cannot fault.
        .expect("graderr solve is fault-free by construction");
        let mixed = adjoint_solve_batched_mixed(
            &nsde, &nsde, &noise, &y0, batch, 0.0, 1.0, n, &opts, &ones,
        )
        .expect("graderr mixed solve is fault-free by construction");
        out.push(GradErrPoint {
            solver: "native_revheun_f32fwd_vs_f64".to_string(),
            n_steps: n,
            rel_err: relative_l1(&cat(&mixed), &cat(&full)),
        });
    }
    out
}

/// Render the Table-6-style text table.
pub fn render(points: &[GradErrPoint]) -> String {
    let mut s = String::from(
        "\nFigure 2 / Table 6 — relative L1 gradient error (O-t-D vs D-t-O)\n",
    );
    s.push_str(&format!("{:<18} {:>8} {:>14}\n", "solver", "steps", "rel err"));
    for p in points {
        s.push_str(&format!(
            "{:<18} {:>8} {:>14.3e}\n",
            p.solver, p.n_steps, p.rel_err
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_l1_basics() {
        assert_eq!(relative_l1(&[1.0, -1.0], &[1.0, -1.0]), 0.0);
        let e = relative_l1(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((e - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_precision_rows_show_f32_truncation_only() {
        let points = run_native_mixed(77);
        assert_eq!(points.len(), 3);
        for p in &points {
            assert!(
                p.rel_err > 0.0,
                "the f32 forward must actually differ from the f64 one at n={}",
                p.n_steps
            );
            assert!(
                p.rel_err < 1e-2,
                "f32-forward gradient deviation should stay at single-precision \
                 truncation level, got {} at n={}",
                p.rel_err,
                p.n_steps
            );
        }
    }

    #[test]
    fn native_rows_reproduce_the_machine_precision_claim() {
        let points = run_native(2021);
        assert_eq!(points.len(), 6);
        for p in &points {
            match p.solver.as_str() {
                "native_revheun_rec_vs_tape" => assert!(
                    p.rel_err < 1e-9,
                    "reconstruction should be roundoff-exact, got {} at n={}",
                    p.rel_err,
                    p.n_steps
                ),
                _ => assert!(
                    p.rel_err < 1e-5,
                    "adjoint-vs-FD should sit at the FD floor, got {} at n={}",
                    p.rel_err,
                    p.n_steps
                ),
            }
        }
    }
}
