//! Experiment registry (placeholder — filled in with the trainers).

/// Names of the paper experiments the CLI can run.
pub const EXPERIMENTS: &[&str] = &[
    "table2", "table4", "table5", "table11", "fig2", "fig5",
];
