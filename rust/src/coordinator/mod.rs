//! Training orchestration — the Layer-3 event loop.
//!
//! * [`gan::GanTrainer`] — adversarial training of SDE-GANs with Adadelta,
//!   weight clipping (Section 5) or the gradient-penalty baseline, and SWA;
//! * [`latent::LatentTrainer`] — ELBO training of Latent SDEs with Adam;
//! * [`noise`] — Brownian-Interval/Virtual-Tree noise plumbing into the
//!   PJRT executables;
//! * [`gradient_error`] — the Figure-2/Table-6 experiment driver;
//! * [`eval`] — the Appendix-F.1 metric battery over trained models.

pub mod eval;
pub mod gan;
pub mod gradient_error;
pub mod latent;
pub mod noise;

pub use eval::{evaluate_generator, EvalReport};
pub use gan::{GanStepStats, GanTrainer};
pub use latent::LatentTrainer;
