//! Training orchestration — the coordinator layer's event loop.
//!
//! * [`gan::GanTrainer`] — adversarial training of SDE-GANs with Adadelta,
//!   weight clipping (Section 5) and SWA, **natively** on the batch +
//!   adjoint engines (no artifacts); the AOT-executable path and the
//!   gradient-penalty baseline sit behind the `pjrt` feature;
//! * [`latent::LatentTrainer`] — ELBO training of Latent SDEs with Adam
//!   (still runtime-driven);
//! * [`noise`] — Brownian-Interval/Virtual-Tree noise plumbing shared by
//!   both backends;
//! * [`gradient_error`] — the Figure-2/Table-6 experiment driver;
//! * [`eval`] — the Appendix-F.1 metric battery over trained models.

pub mod eval;
pub mod gan;
pub mod gradient_error;
pub mod latent;
pub mod noise;

pub use eval::{evaluate_generator, EvalReport};
pub use gan::{GanStepStats, GanTrainer};
pub use latent::LatentTrainer;
