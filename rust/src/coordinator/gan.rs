//! The SDE-GAN trainer (paper Sections 2.2 and 5).
//!
//! Drives the AOT-compiled generator/discriminator gradient executables
//! with noise from the Brownian Interval, updates both networks with
//! Adadelta (Appendix F.2), enforces the discriminator's Lipschitz
//! constraint by **weight clipping** after every discriminator step
//! (Section 5) — or falls back to the gradient-penalty executable for the
//! Table-11 baseline — and maintains a stochastic weight average of the
//! generator over the latter half of training.

use crate::config::{SolverKind, TrainConfig};
use crate::coordinator::noise::{NoiseBackend, StepNoise};
use crate::data::TimeSeriesDataset;
use crate::nn::{Adadelta, Optimizer, StochasticWeightAverage};
use crate::runtime::Runtime;
use anyhow::Result;

/// Per-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct GanStepStats {
    /// Generator loss `E[F_φ(fake)]`.
    pub loss_g: f32,
    /// Discriminator (negated Wasserstein) loss.
    pub loss_d: f32,
}

/// SDE-GAN training state.
pub struct GanTrainer {
    /// Model name in the manifest (e.g. `"gan_ou"`).
    pub model: String,
    solver: SolverKind,
    clip: bool,
    batch: usize,
    seq_len: usize,
    w: usize,
    v_dim: usize,
    y_dim: usize,
    eval_batch: usize,
    /// Generator parameters (flat).
    pub theta: Vec<f32>,
    /// Discriminator parameters (flat).
    pub phi: Vec<f32>,
    opt_g: Adadelta,
    opt_d: Adadelta,
    swa: StochasticWeightAverage,
    noise: StepNoise,
    ts: Vec<f32>,
    /// Discriminator layout, cached at construction — `train_step` clips
    /// after every discriminator update and must not re-fetch (and clone)
    /// the layout from the manifest each time.
    disc_layout: crate::nn::ParamLayout,
    steps_done: usize,
    total_steps: usize,
}

impl GanTrainer {
    /// Build from a runtime + config; initialises parameters with the
    /// paper's α/β scaling (equation (33)).
    pub fn new(rt: &Runtime, cfg: &TrainConfig, total_steps: usize) -> Result<Self> {
        let model = format!("gan_{}", cfg.dataset.as_str());
        let spec = rt.manifest.model(&model)?;
        let model_name = model.clone();
        let hy = move |k: &str| rt.manifest.hyper(&model_name, k);
        let batch = hy("batch")? as usize;
        let seq_len = hy("seq_len")? as usize;
        let gl = spec.gen_layout.clone();
        let dl = spec.disc_layout.clone();
        let alpha = cfg.alpha;
        let beta = cfg.beta;
        // ζ (and ξ) get α; vector fields get β (Appendix F.2 eq. (33)).
        let theta = gl.init(cfg.seed, |name| {
            if name.starts_with("zeta") { alpha } else { beta }
        });
        let mut phi = dl.init(cfg.seed ^ 0x5555, |name| {
            if name.starts_with("xi") { alpha } else { beta }
        });
        // Start inside the clipped region.
        dl.clip_lipschitz(&mut phi, field_filter);
        // Per-group learning rates via lr_scale over the flat vector.
        let scale_of = |layout: &crate::nn::ParamLayout, init_group: &str| -> Vec<f32> {
            let mut s = vec![1.0f32; layout.total];
            for t in &layout.tensors {
                let is_init = t.name.starts_with(init_group);
                let v = if is_init { 1.0 } else { cfg.lr_field / cfg.lr_init };
                s[t.offset..t.offset + t.len()].fill(v);
            }
            s
        };
        let opt_g = Adadelta::new(cfg.lr_init, gl.total)
            .with_lr_scale(scale_of(&gl, "zeta"));
        let opt_d = Adadelta::new(cfg.lr_init, dl.total)
            .with_lr_scale(scale_of(&dl, "xi"));
        // Times: normalised to mean 0, unit range (Appendix F.2).
        let ts: Vec<f32> = (0..seq_len)
            .map(|k| k as f32 / (seq_len - 1) as f32 - 0.5)
            .collect();
        let backend = if cfg.brownian_interval {
            NoiseBackend::Interval
        } else {
            NoiseBackend::VirtualTree { eps: 1e-5 }
        };
        let w = hy("w")? as usize;
        let noise = StepNoise::new(backend, -0.5, 0.5, batch * w, cfg.seed ^ 0x77);
        Ok(Self {
            model,
            solver: cfg.solver,
            clip: cfg.clip,
            batch,
            seq_len,
            w,
            v_dim: hy("v")? as usize,
            y_dim: hy("y")? as usize,
            eval_batch: hy("eval_batch")? as usize,
            theta,
            phi,
            swa: StochasticWeightAverage::new(gl.total),
            opt_g,
            opt_d,
            noise,
            ts,
            disc_layout: dl,
            steps_done: 0,
            total_steps,
        })
    }

    fn exec_name(&self, kind: &str) -> String {
        format!("{}_{}_{}", self.model, self.solver.as_str(), kind)
    }

    /// One adversarial round: a discriminator step then a generator step.
    pub fn train_step(
        &mut self,
        rt: &mut Runtime,
        data: &TimeSeriesDataset,
        rng: &mut crate::brownian::SplitPrng,
    ) -> Result<GanStepStats> {
        let n = self.seq_len - 1;
        let mut v = vec![0.0f32; self.batch * self.v_dim];
        let mut dws = vec![0.0f32; n * self.batch * self.w];

        // ---- Discriminator step.
        let (y_real, _) = data.sample_batch(self.batch, rng);
        self.noise.fill_normals(&mut v);
        self.noise.fill(&self.ts, &mut dws);
        let disc_exec = if self.clip {
            self.exec_name("disc_grad")
        } else {
            // Gradient-penalty baseline (only lowered for midpoint + OU).
            format!("{}_midpoint_disc_grad_gp", self.model)
        };
        let out = rt.run_f32(
            &disc_exec,
            &[
                (&self.theta, &[self.theta.len()]),
                (&self.phi, &[self.phi.len()]),
                (&v, &[self.batch, self.v_dim]),
                (&self.ts, &[self.seq_len]),
                (&dws, &[n, self.batch, self.w]),
                (&y_real, &[self.batch, self.seq_len, self.y_dim]),
            ],
        )?;
        let loss_d = out[0][0];
        let gphi = &out[1];
        anyhow::ensure!(gphi.len() == self.phi.len(), "disc grad shape");
        self.opt_d.step(&mut self.phi, gphi);
        if self.clip {
            // Section 5: clip the CDE vector fields f_φ, g_φ to Lipschitz ≤ 1
            // (layout cached at construction — no per-step manifest clone).
            self.disc_layout.clip_lipschitz(&mut self.phi, field_filter);
        }

        // ---- Generator step (fresh noise).
        self.noise.fill_normals(&mut v);
        self.noise.fill(&self.ts, &mut dws);
        let out = rt.run_f32(
            &self.exec_name("gen_grad"),
            &[
                (&self.theta, &[self.theta.len()]),
                (&self.phi, &[self.phi.len()]),
                (&v, &[self.batch, self.v_dim]),
                (&self.ts, &[self.seq_len]),
                (&dws, &[n, self.batch, self.w]),
            ],
        )?;
        let loss_g = out[0][0];
        let gtheta = &out[1];
        anyhow::ensure!(gtheta.len() == self.theta.len(), "gen grad shape");
        self.opt_g.step(&mut self.theta, gtheta);
        self.steps_done += 1;
        // SWA over the last 50% of training (Appendix F.2).
        if self.steps_done * 2 >= self.total_steps {
            self.swa.update(&self.theta);
        }
        Ok(GanStepStats { loss_g, loss_d })
    }

    /// Final generator weights: the stochastic weight average if available.
    pub fn final_theta(&self) -> Vec<f32> {
        if self.swa.count() > 0 {
            self.swa.average()
        } else {
            self.theta.clone()
        }
    }

    /// Generate `n_samples` series from the (averaged) generator.
    pub fn sample(&mut self, rt: &mut Runtime, n_samples: usize) -> Result<TimeSeriesDataset> {
        let theta = self.final_theta();
        let n = self.seq_len - 1;
        let eb = self.eval_batch;
        let mut values = Vec::with_capacity(n_samples * self.seq_len * self.y_dim);
        let mut v = vec![0.0f32; eb * self.v_dim];
        let mut dws = vec![0.0f32; n * eb * self.w];
        let mut eval_noise =
            StepNoise::new(NoiseBackend::Interval, -0.5, 0.5, eb * self.w, 0xE7A1);
        let mut produced = 0;
        while produced < n_samples {
            eval_noise.fill_normals(&mut v);
            eval_noise.fill(&self.ts, &mut dws);
            let out = rt.run_f32(
                &self.exec_name("sample"),
                &[
                    (&theta, &[theta.len()]),
                    (&v, &[eb, self.v_dim]),
                    (&self.ts, &[self.seq_len]),
                    (&dws, &[n, eb, self.w]),
                ],
            )?;
            let take = (n_samples - produced).min(eb);
            values.extend_from_slice(&out[0][..take * self.seq_len * self.y_dim]);
            produced += take;
        }
        Ok(TimeSeriesDataset {
            n: n_samples,
            seq_len: self.seq_len,
            channels: self.y_dim,
            values,
            times: self.ts.iter().map(|&t| t as f64).collect(),
            labels: None,
        })
    }
}

/// Clip filter: the discriminator's CDE vector fields (Section 5 applies
/// the Lipschitz constraint to `f_φ` and `g_φ`).
fn field_filter(name: &str) -> bool {
    name.starts_with("f.") || name.starts_with("g.")
}
