//! The SDE-GAN trainer (paper Sections 2.2 and 5) — **native backend**.
//!
//! A full adversarial training step runs in pure Rust on the batch + adjoint
//! engines: generator solve ([`integrate_batched`], reversible Heun over SoA
//! lanes) → affine readout → neural-CDE discriminator
//! ([`NeuralDiscriminatorBatch`] driven by the path's `ΔY` increments) →
//! exact reverse-mode gradients through both solves
//! ([`adjoint_solve_batched_steps`]: terminal cotangent `±m/B` for the CDE,
//! per-step cotangent injection for the generator whose whole trajectory the
//! discriminator read, and `ΔY` cotangents chaining the two) → Adadelta
//! (Appendix F.2) → hard Lipschitz enforcement by **weight clipping**
//! (Section 5) → stochastic weight averaging of the generator.
//!
//! No `artifacts/manifest.json` is needed: hyperparameters come from
//! [`TrainConfig`] and the [`GanNetSpec`] defaults, layouts from the native
//! constructors. The AOT-executable path (which also provides the
//! gradient-penalty baseline and non-reversible solvers) is retained behind
//! the `pjrt` feature as [`GanTrainer::from_runtime`] /
//! [`GanTrainer::train_step_runtime`] / [`GanTrainer::sample_runtime`].
//!
//! Determinism: all noise is drawn from the persistent [`StepNoise`]
//! (Brownian Interval) keyed by the config seed, per-path solve and adjoint
//! arithmetic is bit-identical across batch/chunk/thread settings (the
//! engines' invariant), and every cross-path reduction here (θ-chains,
//! readout gradients, score means) runs in ascending path order — so
//! training losses and parameters are bit-reproducible for any
//! [`BatchOptions`].
//!
//! Precision ([`TrainPrecision`]): with the default `F64` every solve widens
//! θ/φ and the Brownian grid and runs on the 4-wide lanes — bit-for-bit the
//! historical trainer. With `Mixed`, the three SDE solves per adversarial
//! round (generator forward, CDE adjoint, generator adjoint) and the eval
//! [`GanTrainer::sample`] path run their forwards on the **8-wide `f32`
//! lanes** straight from the Brownian sources' native `f32` output — no
//! `widen_params`/`widen_increments` copies on the solve hot path — while
//! every adjoint backpropagates **exactly in `f64`** through the widened
//! tape of the `f32` forward ([`adjoint_solve_batched_steps_mixed`]).
//! Master weights, optimiser accumulators, and the small per-path chains
//! (ζ, ξ, readout ℓ, score means) stay in `f64`/`f32`-master form, so the
//! gradient deviates from the all-`f64` step only by the forward's
//! single-precision rounding, and the Tape-mode mixed adjoints keep the
//! bit-reproducibility guarantee across every [`BatchOptions`] fan-out.
//!
//! Fault tolerance: the solve engines surface structured [`SolveError`]s
//! (non-finite lanes, reconstruction drift, vector-field panics), and
//! [`GanTrainer::train_step`] wraps each adversarial round in a training
//! watchdog — snapshot the trainable state, attempt the round, roll back
//! and retry on divergence with deterministically re-drawn noise — and
//! reports rollbacks/retries through [`GanStepStats`] and
//! [`GanTrainer::watchdog_rollbacks`].

use crate::config::{SolverKind, TrainConfig, TrainPrecision};
use crate::coordinator::noise::{NoiseBackend, StepNoise};
use crate::data::TimeSeriesDataset;
use crate::nn::{
    step_f64, Activation, Adadelta, GanNetSpec, Mlp, ParamLayout, StochasticWeightAverage,
};
#[cfg(feature = "pjrt")]
use crate::nn::Optimizer;
use crate::solvers::neural::{widen_params, NeuralDiscriminatorBatch, NeuralGeneratorBatch};
use crate::solvers::{
    adjoint_solve_batched_steps, adjoint_solve_batched_steps_mixed, integrate_batched,
    AdjointGrad, BackwardMode, BatchOptions, BatchReversibleHeun, FaultCause, SolveError,
    SolveFault, StoredBatchNoise,
};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use anyhow::Result;

/// The normalised training interval (observation times have mean 0 and unit
/// range — Appendix F.2).
const T0: f64 = -0.5;
const T1: f64 = 0.5;

/// Per-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct GanStepStats {
    /// Generator loss `E[F_φ(fake)]`.
    pub loss_g: f32,
    /// Discriminator (negated Wasserstein) loss `E[F(real)] − E[F(fake)]`.
    pub loss_d: f32,
    /// Watchdog retries consumed by this step (0 = clean first attempt).
    pub retries: u32,
}

/// Everything the training watchdog must roll back when a step diverges:
/// parameters, optimiser accumulators, and the SWA running average.
struct TrainerSnapshot {
    theta: Vec<f32>,
    phi: Vec<f32>,
    opt_g: Adadelta,
    opt_d: Adadelta,
    swa: StochasticWeightAverage,
    steps_done: usize,
}

/// SDE-GAN training state.
pub struct GanTrainer {
    /// Model name (e.g. `"gan_ou"`), used for display and artifact lookup.
    pub model: String,
    spec: GanNetSpec,
    solver: SolverKind,
    clip: bool,
    precision: TrainPrecision,
    batch: usize,
    eval_batch: usize,
    seq_len: usize,
    /// Generator parameters (flat).
    pub theta: Vec<f32>,
    /// Discriminator parameters (flat).
    pub phi: Vec<f32>,
    gen_layout: ParamLayout,
    disc_layout: ParamLayout,
    zeta: Mlp,
    xi: Mlp,
    ell_w_off: usize,
    ell_b_off: usize,
    m_off: usize,
    opt_g: Adadelta,
    opt_d: Adadelta,
    swa: StochasticWeightAverage,
    noise: StepNoise,
    /// Cached batch systems — built once, parameters refreshed in place
    /// before each use (the previous per-call `from_f32` rebuilds were two
    /// full layout walks + allocations per training step).
    gen_batch: NeuralGeneratorBatch,
    disc_batch: NeuralDiscriminatorBatch,
    /// Persistent eval-path noise + scratch for [`Self::sample`], reset per
    /// call so sampling stays bit-reproducible call over call.
    eval_noise: StepNoise,
    eval_v32: Vec<f32>,
    eval_dws32: Vec<f32>,
    ts: Vec<f32>,
    opts: BatchOptions,
    steps_done: usize,
    total_steps: usize,
    watchdog_enabled: bool,
    watchdog_max_retries: u32,
    watchdog_rollbacks: u64,
    /// Deterministic fault injection: the next `force_fail` step attempts
    /// fail right after the discriminator update (tests and drills).
    force_fail: u32,
}

impl GanTrainer {
    /// Build the native trainer from the config alone — no runtime, no
    /// manifest. Network dimensions are the [`GanNetSpec`] defaults for the
    /// dataset's channel count; parameters are initialised with the paper's
    /// α/β scaling (equation (33)) and the discriminator starts inside the
    /// clipped region.
    pub fn new(cfg: &TrainConfig, total_steps: usize) -> Result<Self> {
        if !cfg.clip {
            // The flag used to select the Table-11 gradient-penalty
            // executable; natively there is no GP, only no constraint.
            eprintln!(
                "[gan] warning: clip=false on the native backend trains an \
                 UNCONSTRAINED critic (no Lipschitz control); the training \
                 watchdog stays enabled and rolls back diverged steps, but \
                 expect instability. The Table-11 gradient-penalty baseline \
                 needs --features pjrt + artifacts (GanTrainer::from_runtime)"
            );
        }
        let (seq_len, y_dim) = cfg.dataset.shape();
        let spec = GanNetSpec::for_data_dim(y_dim);
        let gl = spec.gen_layout();
        let dl = spec.disc_layout();
        Self::assemble(cfg, spec, seq_len, gl, dl, cfg.batch, cfg.batch, total_steps)
    }

    /// Shared construction over externally supplied layouts (native path:
    /// the [`GanNetSpec`] constructors; `pjrt` path: the manifest's).
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        cfg: &TrainConfig,
        spec: GanNetSpec,
        seq_len: usize,
        gl: ParamLayout,
        dl: ParamLayout,
        batch: usize,
        eval_batch: usize,
        total_steps: usize,
    ) -> Result<Self> {
        let alpha = cfg.alpha;
        let beta = cfg.beta;
        // ζ (and ξ) get α; vector fields get β (Appendix F.2 eq. (33)).
        let theta = gl.init(cfg.seed, |name| {
            if name.starts_with("zeta") { alpha } else { beta }
        });
        let mut phi = dl.init(cfg.seed ^ 0x5555, |name| {
            if name.starts_with("xi") { alpha } else { beta }
        });
        // Start inside the clipped region.
        dl.clip_lipschitz(&mut phi, field_filter);
        // Per-group learning rates via lr_scale over the flat vector.
        let scale_of = |layout: &ParamLayout, init_group: &str| -> Vec<f32> {
            let mut s = vec![1.0f32; layout.total];
            for t in &layout.tensors {
                let is_init = t.name.starts_with(init_group);
                let v = if is_init { 1.0 } else { cfg.lr_field / cfg.lr_init };
                s[t.offset..t.offset + t.len()].fill(v);
            }
            s
        };
        let opt_g = Adadelta::new(cfg.lr_init, gl.total).with_lr_scale(scale_of(&gl, "zeta"));
        let opt_d = Adadelta::new(cfg.lr_init, dl.total).with_lr_scale(scale_of(&dl, "xi"));
        // Times: normalised to mean 0, unit range (Appendix F.2).
        let ts: Vec<f32> =
            (0..seq_len).map(|k| k as f32 / (seq_len - 1) as f32 - 0.5).collect();
        let backend = if cfg.brownian_interval {
            NoiseBackend::Interval
        } else {
            NoiseBackend::VirtualTree { eps: 1e-5 }
        };
        let noise = StepNoise::new(backend, T0, T1, batch * spec.noise, cfg.seed ^ 0x77);
        let gen_batch = NeuralGeneratorBatch::from_f32(&spec, &theta);
        let disc_batch = NeuralDiscriminatorBatch::from_f32(&spec, &phi);
        let eval_noise =
            StepNoise::new(NoiseBackend::Interval, T0, T1, eval_batch * spec.noise, 0xE7A1);
        let eval_v32 = vec![0.0f32; eval_batch * spec.init_noise];
        let eval_dws32 = vec![0.0f32; (seq_len - 1) * eval_batch * spec.noise];
        let zeta = Mlp::from_layout(&gl, "zeta", Activation::Identity)?;
        let xi = Mlp::from_layout(&dl, "xi", Activation::Identity)?;
        let ell_w_off = gl
            .find("ell.w")
            .ok_or_else(|| anyhow::anyhow!("gen layout missing ell.w"))?
            .offset;
        let ell_b_off = gl
            .find("ell.b")
            .ok_or_else(|| anyhow::anyhow!("gen layout missing ell.b"))?
            .offset;
        let m_off = dl
            .find("m")
            .ok_or_else(|| anyhow::anyhow!("disc layout missing m"))?
            .offset;
        Ok(Self {
            model: format!("gan_{}", cfg.dataset.as_str()),
            spec,
            solver: cfg.solver,
            clip: cfg.clip,
            precision: cfg.precision,
            batch,
            eval_batch,
            seq_len,
            swa: StochasticWeightAverage::new(gl.total),
            theta,
            phi,
            gen_layout: gl,
            disc_layout: dl,
            zeta,
            xi,
            ell_w_off,
            ell_b_off,
            m_off,
            opt_g,
            opt_d,
            noise,
            gen_batch,
            disc_batch,
            eval_noise,
            eval_v32,
            eval_dws32,
            ts,
            opts: BatchOptions::auto(),
            steps_done: 0,
            total_steps,
            watchdog_enabled: true,
            watchdog_max_retries: 3,
            watchdog_rollbacks: 0,
            force_fail: 0,
        })
    }

    /// Override the batch-engine fan-out knobs (results are bit-identical
    /// for every setting; only wall-clock changes).
    pub fn with_batch_options(mut self, opts: BatchOptions) -> Self {
        self.opts = opts;
        self
    }

    /// The network dimensions in use.
    pub fn spec(&self) -> &GanNetSpec {
        &self.spec
    }

    /// The discriminator layout (tests assert the clipping invariant on it).
    pub fn disc_layout(&self) -> &ParamLayout {
        &self.disc_layout
    }

    /// Configure the training watchdog (on by default, 3 retries).
    /// `enabled = false` surfaces the first structured error instead of
    /// rolling back.
    pub fn with_watchdog(mut self, enabled: bool, max_retries: u32) -> Self {
        self.watchdog_enabled = enabled;
        self.watchdog_max_retries = max_retries;
        self
    }

    /// Total watchdog rollbacks performed over this trainer's lifetime.
    pub fn watchdog_rollbacks(&self) -> u64 {
        self.watchdog_rollbacks
    }

    /// Deterministic fault injection (tests and recovery drills): the next
    /// `attempts` step attempts fail right after the discriminator update,
    /// so the rollback has a real parameter/optimiser update to undo.
    pub fn inject_training_fault(&mut self, attempts: u32) {
        self.force_fail = attempts;
    }

    fn snapshot(&self) -> TrainerSnapshot {
        TrainerSnapshot {
            theta: self.theta.clone(),
            phi: self.phi.clone(),
            opt_g: self.opt_g.clone(),
            opt_d: self.opt_d.clone(),
            swa: self.swa.clone(),
            steps_done: self.steps_done,
        }
    }

    fn restore(&mut self, snap: TrainerSnapshot) {
        self.theta = snap.theta;
        self.phi = snap.phi;
        self.opt_g = snap.opt_g;
        self.opt_d = snap.opt_d;
        self.swa = snap.swa;
        self.steps_done = snap.steps_done;
    }

    /// One adversarial round — a discriminator step then a generator step —
    /// entirely on the native stack.
    ///
    /// Fault tolerance: each attempt runs against a snapshot of the
    /// trainable state (θ/φ, both Adadelta accumulators, the SWA average).
    /// If the solve engines surface a structured [`SolveError`], or a loss
    /// or gradient lane goes non-finite, the watchdog rolls the state back
    /// and retries — the [`StepNoise`] counter has already advanced past the
    /// faulty draw, so the retry re-solves with fresh *deterministic* noise.
    /// After `watchdog_max_retries` failed attempts (or with the watchdog
    /// disabled) the structured error propagates to the caller.
    pub fn train_step(
        &mut self,
        data: &TimeSeriesDataset,
        rng: &mut crate::brownian::SplitPrng,
    ) -> Result<GanStepStats> {
        anyhow::ensure!(
            self.solver == SolverKind::ReversibleHeun,
            "the native backend trains through the reversible-Heun adjoint; \
             other solvers need the AOT executables (`--features pjrt` + `make artifacts`)"
        );
        let mut retries = 0u32;
        loop {
            let snap = self.snapshot();
            match self.try_train_step(data, rng) {
                Ok((loss_g, loss_d)) => {
                    self.steps_done += 1;
                    // SWA over the last 50% of training (Appendix F.2).
                    if self.steps_done * 2 >= self.total_steps {
                        self.swa.update(&self.theta);
                    }
                    return Ok(GanStepStats {
                        loss_g: loss_g as f32,
                        loss_d: loss_d as f32,
                        retries,
                    });
                }
                Err(err) => {
                    if !self.watchdog_enabled || retries >= self.watchdog_max_retries {
                        return Err(err.into());
                    }
                    self.restore(snap);
                    self.watchdog_rollbacks += 1;
                    retries += 1;
                    eprintln!(
                        "[gan] watchdog: step {} rolled back (retry {}/{}): {}",
                        self.steps_done, retries, self.watchdog_max_retries, err
                    );
                }
            }
        }
    }

    /// One attempt at an adversarial round. Parameter and optimiser updates
    /// happen in place; on `Err` the watchdog loop in [`train_step`] rolls
    /// them back from its snapshot.
    fn try_train_step(
        &mut self,
        data: &TimeSeriesDataset,
        rng: &mut crate::brownian::SplitPrng,
    ) -> Result<(f64, f64), SolveError> {
        // ---- Discriminator step.
        let (y_real, _) = data.sample_batch(self.batch, rng);
        let (loss_d, gphi) = self.disc_grads(&y_real)?;
        check_finite("train_step: discriminator update", self.steps_done, loss_d, &gphi)?;
        step_f64(&mut self.opt_d, &mut self.phi, &gphi);
        if self.clip {
            // Section 5: clip the CDE vector fields f_φ, g_φ to Lipschitz ≤ 1.
            // (With --no-clip the native discriminator is simply
            // unconstrained; the gradient-penalty baseline is pjrt-only.)
            self.disc_layout.clip_lipschitz(&mut self.phi, field_filter);
        }
        if self.force_fail > 0 {
            self.force_fail -= 1;
            return Err(SolveError::new(
                "train_step: injected fault",
                vec![SolveFault {
                    step: self.steps_done,
                    path: 0,
                    component: 0,
                    cause: FaultCause::NonFinite,
                }],
            ));
        }

        // ---- Generator step (fresh noise).
        let (loss_g, gtheta) = self.gen_grads()?;
        check_finite("train_step: generator update", self.steps_done, loss_g, &gtheta)?;
        step_f64(&mut self.opt_g, &mut self.theta, &gtheta);
        Ok((loss_g, loss_d))
    }

    /// Draw one training step's noise in the Brownian sources' native
    /// `f32`: initial normals `V [batch, v]` and the `[n][batch, w]` grid
    /// increments. Precision-specific packing (widening for the `f64`
    /// route, an SoA transpose with no conversion for the `f32` route)
    /// happens at the call site — the mixed route never widens.
    fn draw_noise_raw(&mut self) -> (Vec<f32>, Vec<f32>) {
        let (b, w, v_dim) = (self.batch, self.spec.noise, self.spec.init_noise);
        let n = self.seq_len - 1;
        let mut v32 = vec![0.0f32; b * v_dim];
        self.noise.fill_normals(&mut v32);
        let mut dws32 = vec![0.0f32; n * b * w];
        self.noise.fill(&self.ts, &mut dws32);
        (v32, dws32)
    }

    /// Generator forward solve at the configured precision over `batch`
    /// paths: the trajectory in `f64` lanes (mixed: the **exact** widening
    /// of the `f32` solve the adjoint will re-run) plus the [`GenNoise`]
    /// artefacts that adjoint replays. The caller must have refreshed
    /// `self.gen_batch` with the θ it means to differentiate.
    fn gen_forward(
        &self,
        z0: &[f64],
        dws32: &[f32],
        batch: usize,
    ) -> Result<(Vec<f64>, GenNoise), SolveError> {
        let w = self.spec.noise;
        let n = self.seq_len - 1;
        match self.precision {
            TrainPrecision::F64 => {
                let dws = widen_increments(dws32, n, w, batch);
                let x_traj = integrate_batched::<BatchReversibleHeun, _, _>(
                    &self.gen_batch, &dws, z0, batch, T0, T1, n, &self.opts,
                )?;
                Ok((x_traj, GenNoise::F64(dws)))
            }
            TrainPrecision::Mixed => {
                let dws = StoredBatchNoise::<f32>::from_f32_grid(T0, T1, n, w, batch, dws32);
                let z032: Vec<f32> = z0.iter().map(|&x| x as f32).collect();
                let traj32 = integrate_batched::<BatchReversibleHeun<f32>, _, _>(
                    &self.gen_batch, &dws, &z032, batch, T0, T1, n, &self.opts,
                )?;
                Ok((traj32.iter().map(|&x| x as f64).collect(), GenNoise::F32(dws, z032)))
            }
        }
    }

    /// `ζ_θ(V)` per path, scattered to SoA `[x * batch]` lanes.
    fn initial_state(&self, theta64: &[f64], v: &[f64], batch: usize) -> Vec<f64> {
        let (x, v_dim) = (self.spec.state, self.spec.init_noise);
        let mut z0 = vec![0.0f64; x * batch];
        let mut z0p = vec![0.0f64; x];
        for p in 0..batch {
            self.zeta.forward(theta64, &v[p * v_dim..(p + 1) * v_dim], &mut z0p);
            for i in 0..x {
                z0[i * batch + p] = z0p[i];
            }
        }
        z0
    }

    /// Affine readout `Y = ℓ_θ(X)` over a whole SoA trajectory:
    /// `[(n+1) * x * batch]` lanes → `[(n+1) * y * batch]` lanes.
    fn readout(&self, theta64: &[f64], x_traj: &[f64], batch: usize) -> Vec<f64> {
        let (x, y) = (self.spec.state, self.spec.data_dim);
        let n_pts = x_traj.len() / (x * batch);
        let mut y_path = vec![0.0f64; n_pts * y * batch];
        for k in 0..n_pts {
            for c in 0..y {
                for p in 0..batch {
                    let mut acc = theta64[self.ell_b_off + c];
                    for i in 0..x {
                        acc += theta64[self.ell_w_off + i * y + c]
                            * x_traj[(k * x + i) * batch + p];
                    }
                    y_path[(k * y + c) * batch + p] = acc;
                }
            }
        }
        y_path
    }

    /// Path increments `ΔY_k = Y_{k+1} − Y_k` as the CDE's stored "noise".
    fn path_increments(&self, y_path: &[f64], batch: usize) -> StoredBatchNoise {
        let y = self.spec.data_dim;
        let n = self.seq_len - 1;
        let mut dys = StoredBatchNoise::zeros(T0, T1, n, y, batch);
        for k in 0..n {
            for c in 0..y {
                for p in 0..batch {
                    let hi = y_path[((k + 1) * y + c) * batch + p];
                    let lo = y_path[(k * y + c) * batch + p];
                    dys.set(k, c, p, hi - lo);
                }
            }
        }
        dys
    }

    /// [`Self::path_increments`] narrowed for the mixed route: the CDE's
    /// `f32` forward consumes `ΔY` rounded once to single precision (the
    /// mixed adjoint then backpropagates exactly through that rounded map).
    fn path_increments_f32(&self, y_path: &[f64], batch: usize) -> StoredBatchNoise<f32> {
        let y = self.spec.data_dim;
        let n = self.seq_len - 1;
        let mut dys = StoredBatchNoise::<f32>::zeros(T0, T1, n, y, batch);
        for k in 0..n {
            for c in 0..y {
                for p in 0..batch {
                    let hi = y_path[((k + 1) * y + c) * batch + p];
                    let lo = y_path[(k * y + c) * batch + p];
                    dys.set(k, c, p, (hi - lo) as f32);
                }
            }
        }
        dys
    }

    /// `H₀ = ξ_φ(t₀, Y₀)` per path, scattered to SoA `[dh * batch]` lanes.
    fn cde_initial(&self, phi64: &[f64], y_path: &[f64], batch: usize) -> Vec<f64> {
        let (dh, y) = (self.spec.disc_state, self.spec.data_dim);
        let mut h0 = vec![0.0f64; dh * batch];
        let mut inp = vec![0.0f64; 1 + y];
        let mut h0p = vec![0.0f64; dh];
        for p in 0..batch {
            inp[0] = T0;
            for c in 0..y {
                inp[1 + c] = y_path[c * batch + p];
            }
            self.xi.forward(phi64, &inp, &mut h0p);
            for i in 0..dh {
                h0[i * batch + p] = h0p[i];
            }
        }
        h0
    }

    /// Chain the CDE's `∂L/∂H₀` back through `ξ_φ` (ascending path order):
    /// φ-gradients accumulate into `gphi`, and the `Y₀` input gradient into
    /// `y0_cot` lanes when the caller needs the path cotangent (generator
    /// step).
    fn chain_xi(
        &self,
        phi64: &[f64],
        y_path: &[f64],
        gh0: &[f64],
        batch: usize,
        gphi: &mut [f64],
        mut y0_cot: Option<&mut [f64]>,
    ) {
        let (dh, y) = (self.spec.disc_state, self.spec.data_dim);
        let mut inp = vec![0.0f64; 1 + y];
        let mut gx = vec![0.0f64; 1 + y];
        let mut gh0p = vec![0.0f64; dh];
        for p in 0..batch {
            inp[0] = T0;
            for c in 0..y {
                inp[1 + c] = y_path[c * batch + p];
            }
            for i in 0..dh {
                gh0p[i] = gh0[i * batch + p];
            }
            self.xi.vjp(phi64, &inp, &gh0p, &mut gx, gphi);
            if let Some(yc) = y0_cot.as_deref_mut() {
                for c in 0..y {
                    yc[c * batch + p] += gx[1 + c];
                }
            }
        }
    }

    /// Mean readout score `E_p[m · H_T]` from the CDE adjoint's terminal
    /// lanes (ascending path order).
    fn mean_score(&self, m64: &[f64], g: &AdjointGrad, batch: usize) -> f64 {
        let dh = self.spec.disc_state;
        let mut acc = 0.0f64;
        for p in 0..batch {
            let mut s = 0.0f64;
            for i in 0..dh {
                s += m64[i] * g.terminal[i * batch + p];
            }
            acc += s;
        }
        acc / batch as f64
    }

    /// One discriminator update's loss and φ-gradient:
    /// `loss_d = E[F(real)] − E[F(fake)]`, CDE adjoints on both paths with
    /// terminal cotangents `∓m/B`, `ξ` chain, and the `m`-readout gradient.
    fn disc_grads(&mut self, y_real: &[f32]) -> Result<(f64, Vec<f64>), SolveError> {
        let b = self.batch;
        let (dh, y) = (self.spec.disc_state, self.spec.data_dim);
        let n = self.seq_len - 1;
        let (v32, dws32) = self.draw_noise_raw();
        let v = widen_params(&v32);
        let theta64 = widen_params(&self.theta);
        let phi64 = widen_params(&self.phi);
        let m64 = phi64[self.m_off..self.m_off + dh].to_vec();
        // Refresh the cached batch systems in place (no per-step rebuild).
        self.gen_batch.set_params_f32(&self.theta);
        self.disc_batch.set_params_f32(&self.phi);

        // Fake path (forward only — no generator gradients in this step).
        let z0 = self.initial_state(&theta64, &v, b);
        let (x_traj, _) = self.gen_forward(&z0, &dws32, b)?;
        let y_fake = self.readout(&theta64, &x_traj, b);
        // Real path, repacked [B, L, y] → per-point SoA lanes.
        let stride = self.seq_len * y;
        let mut y_real_lanes = vec![0.0f64; (n + 1) * y * b];
        for k in 0..=n {
            for c in 0..y {
                for p in 0..b {
                    y_real_lanes[(k * y + c) * b + p] = y_real[p * stride + k * y + c] as f64;
                }
            }
        }

        let disc = &self.disc_batch;
        let mixed = self.precision == TrainPrecision::Mixed;
        let run = |y_path: &[f64], sign: f64| -> Result<AdjointGrad, SolveError> {
            let h0 = self.cde_initial(&phi64, y_path, b);
            let m_ref = &m64;
            let inject = |k: usize, _p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
                if k == n {
                    for (i, &mi) in m_ref.iter().enumerate() {
                        let w = sign * mi / b as f64;
                        for q in 0..cl {
                            lz[i * cl + q] += w;
                        }
                    }
                }
            };
            if mixed {
                let dys = self.path_increments_f32(y_path, b);
                let h032: Vec<f32> = h0.iter().map(|&x| x as f32).collect();
                adjoint_solve_batched_steps_mixed(
                    disc,
                    disc,
                    &dys,
                    &h032,
                    b,
                    T0,
                    T1,
                    n,
                    BackwardMode::Tape,
                    false,
                    &self.opts,
                    &inject,
                )
            } else {
                let dys = self.path_increments(y_path, b);
                adjoint_solve_batched_steps(
                    disc,
                    &dys,
                    &h0,
                    b,
                    T0,
                    T1,
                    n,
                    BackwardMode::Reconstruct,
                    false,
                    &self.opts,
                    &inject,
                )
            }
        };
        // The real-path and fake-path CDE adjoints are data-independent —
        // they share only `&self` (immutably) and write disjoint results —
        // so they overlap on the persistent executor. Bits are unchanged by
        // construction: each solve is internally schedule-invariant, and
        // every cross-solve reduction below keeps the fixed fake-then-real
        // f64 accumulation order (pinned by the fan-out determinism tests
        // in `tests/neural_gan.rs`).
        let (gf, gr) = crate::solvers::pool::join2(
            self.opts.threads,
            || run(&y_fake, -1.0),
            || run(&y_real_lanes, 1.0),
        );
        let (gf, gr) = (gf?, gr?);
        let loss_d = self.mean_score(&m64, &gr, b) - self.mean_score(&m64, &gf, b);

        // φ-gradient: CDE solves (fake then real, matching the reference
        // accumulation order), ξ chains, then the m readout.
        let mut gphi = gf.dtheta.clone();
        for (g, &r) in gphi.iter_mut().zip(gr.dtheta.iter()) {
            *g += r;
        }
        self.chain_xi(&phi64, &y_fake, &gf.dy0, b, &mut gphi, None);
        self.chain_xi(&phi64, &y_real_lanes, &gr.dy0, b, &mut gphi, None);
        for i in 0..dh {
            let mut mean_r = 0.0f64;
            let mut mean_f = 0.0f64;
            for p in 0..b {
                mean_r += gr.terminal[i * b + p];
                mean_f += gf.terminal[i * b + p];
            }
            gphi[self.m_off + i] += (mean_r - mean_f) / b as f64;
        }
        Ok((loss_d, gphi))
    }

    /// One generator update's loss and θ-gradient: CDE adjoint with `ΔY`
    /// cotangents, chain onto the generated path (increments + `Y₀` via `ξ`
    /// + readout `ℓ`), then the generator adjoint with per-step cotangent
    /// injection, and the `ζ` chain at the initial condition.
    fn gen_grads(&mut self) -> Result<(f64, Vec<f64>), SolveError> {
        let b = self.batch;
        let (x, y, dh) = (self.spec.state, self.spec.data_dim, self.spec.disc_state);
        let n = self.seq_len - 1;
        let v_dim = self.spec.init_noise;
        let (v32, dws32) = self.draw_noise_raw();
        let v = widen_params(&v32);
        let theta64 = widen_params(&self.theta);
        let phi64 = widen_params(&self.phi);
        let m64 = phi64[self.m_off..self.m_off + dh].to_vec();
        // Refresh the cached batch systems in place (no per-step rebuild).
        self.gen_batch.set_params_f32(&self.theta);
        self.disc_batch.set_params_f32(&self.phi);

        let z0 = self.initial_state(&theta64, &v, b);
        let (x_traj, gn) = self.gen_forward(&z0, &dws32, b)?;
        let y_path = self.readout(&theta64, &x_traj, b);

        // Discriminator response + backward: loss_g = E_p[m · H_T], so the
        // terminal cotangent is +m/B; ddw gives ∂loss/∂ΔY.
        let disc = &self.disc_batch;
        let h0 = self.cde_initial(&phi64, &y_path, b);
        let m_ref = &m64;
        let inject_cde = |k: usize, _p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
            if k == n {
                for (i, &mi) in m_ref.iter().enumerate() {
                    let w = mi / b as f64;
                    for q in 0..cl {
                        lz[i * cl + q] += w;
                    }
                }
            }
        };
        let gcde = if self.precision == TrainPrecision::Mixed {
            let dys = self.path_increments_f32(&y_path, b);
            let h032: Vec<f32> = h0.iter().map(|&x| x as f32).collect();
            adjoint_solve_batched_steps_mixed(
                disc,
                disc,
                &dys,
                &h032,
                b,
                T0,
                T1,
                n,
                BackwardMode::Tape,
                true,
                &self.opts,
                &inject_cde,
            )?
        } else {
            let dys = self.path_increments(&y_path, b);
            adjoint_solve_batched_steps(
                disc,
                &dys,
                &h0,
                b,
                T0,
                T1,
                n,
                BackwardMode::Reconstruct,
                true,
                &self.opts,
                &inject_cde,
            )?
        };
        let loss_g = self.mean_score(&m64, &gcde, b);

        // Path cotangent: ΔY_k = Y_{k+1} − Y_k chains the increment
        // cotangents onto the grid points; Y₀ additionally feeds ξ.
        let mut y_cot = vec![0.0f64; (n + 1) * y * b];
        for k in 0..n {
            for c in 0..y {
                for p in 0..b {
                    let d = gcde.ddw[(k * y + c) * b + p];
                    y_cot[((k + 1) * y + c) * b + p] += d;
                    y_cot[(k * y + c) * b + p] -= d;
                }
            }
        }
        let mut phi_scratch = vec![0.0f64; phi64.len()];
        {
            let (head, _) = y_cot.split_at_mut(y * b);
            self.chain_xi(&phi64, &y_path, &gcde.dy0, b, &mut phi_scratch, Some(head));
        }

        // Through the affine readout ℓ: X-cotangents for the solve, ℓ-grads
        // for θ.
        let mut x_cot = vec![0.0f64; (n + 1) * x * b];
        for k in 0..=n {
            for i in 0..x {
                for c in 0..y {
                    let wic = theta64[self.ell_w_off + i * y + c];
                    for p in 0..b {
                        x_cot[(k * x + i) * b + p] += wic * y_cot[(k * y + c) * b + p];
                    }
                }
            }
        }

        // Generator adjoint: the loss read the whole X trajectory, so the
        // cotangents inject per step during the backward sweep. The mixed
        // route replays the exact f32 forward (same stepper, same noise,
        // same narrowed z₀) and backpropagates in f64 through its tape.
        let x_cot_ref = &x_cot;
        let inject_gen = |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
            let blk = &x_cot_ref[k * x * b..(k + 1) * x * b];
            for i in 0..x {
                for q in 0..cl {
                    lz[i * cl + q] += blk[i * b + p0 + q];
                }
            }
        };
        let ggen = match &gn {
            GenNoise::F64(dws) => adjoint_solve_batched_steps(
                &self.gen_batch,
                dws,
                &z0,
                b,
                T0,
                T1,
                n,
                BackwardMode::Reconstruct,
                false,
                &self.opts,
                &inject_gen,
            )?,
            GenNoise::F32(dws, z032) => adjoint_solve_batched_steps_mixed(
                &self.gen_batch,
                &self.gen_batch,
                dws,
                z032,
                b,
                T0,
                T1,
                n,
                BackwardMode::Tape,
                false,
                &self.opts,
                &inject_gen,
            )?,
        };
        let mut gtheta = ggen.dtheta;

        // ζ chain at the initial condition (ascending path order).
        let mut gv = vec![0.0f64; v_dim];
        let mut dz0p = vec![0.0f64; x];
        for p in 0..b {
            for i in 0..x {
                dz0p[i] = ggen.dy0[i * b + p];
            }
            self.zeta.vjp(&theta64, &v[p * v_dim..(p + 1) * v_dim], &dz0p, &mut gv, &mut gtheta);
        }

        // ℓ gradients: g_w[i][c] = Σ_k Σ_p X·cot, g_b[c] = Σ_k Σ_p cot.
        for k in 0..=n {
            for i in 0..x {
                for c in 0..y {
                    let mut acc = gtheta[self.ell_w_off + i * y + c];
                    for p in 0..b {
                        acc += x_traj[(k * x + i) * b + p] * y_cot[(k * y + c) * b + p];
                    }
                    gtheta[self.ell_w_off + i * y + c] = acc;
                }
            }
            for c in 0..y {
                let mut acc = gtheta[self.ell_b_off + c];
                for p in 0..b {
                    acc += y_cot[(k * y + c) * b + p];
                }
                gtheta[self.ell_b_off + c] = acc;
            }
        }
        Ok((loss_g, gtheta))
    }

    /// Final generator weights: the stochastic weight average if available.
    pub fn final_theta(&self) -> Vec<f32> {
        if self.swa.count() > 0 {
            self.swa.average()
        } else {
            self.theta.clone()
        }
    }

    /// Generate `n_samples` series from the (averaged) generator — native
    /// forward solves (at the configured [`TrainPrecision`]), no runtime
    /// required. Noise and staging buffers are the trainer's persistent
    /// eval scratch; [`StepNoise::reset`] replays the same deterministic
    /// sequence every call, matching the old build-a-fresh-source behaviour
    /// without its per-call tree/cache/buffer construction.
    pub fn sample(&mut self, n_samples: usize) -> Result<TimeSeriesDataset> {
        let theta = self.final_theta();
        let theta64 = widen_params(&theta);
        let y = self.spec.data_dim;
        let eb = self.eval_batch;
        self.gen_batch.set_params_f32(&theta);
        self.eval_noise.reset();
        let mut values = Vec::with_capacity(n_samples * self.seq_len * y);
        let mut produced = 0;
        while produced < n_samples {
            self.eval_noise.fill_normals(&mut self.eval_v32);
            self.eval_noise.fill(&self.ts, &mut self.eval_dws32);
            let v = widen_params(&self.eval_v32);
            let z0 = self.initial_state(&theta64, &v, eb);
            let (x_traj, _) = self.gen_forward(&z0, &self.eval_dws32, eb)?;
            let y_path = self.readout(&theta64, &x_traj, eb);
            let take = (n_samples - produced).min(eb);
            for p in 0..take {
                for k in 0..self.seq_len {
                    for c in 0..y {
                        values.push(y_path[(k * y + c) * eb + p] as f32);
                    }
                }
            }
            produced += take;
        }
        Ok(TimeSeriesDataset {
            n: n_samples,
            seq_len: self.seq_len,
            channels: y,
            values,
            times: self.ts.iter().map(|&t| t as f64).collect(),
            labels: None,
        })
    }
}

/// The AOT-executable training path (PJRT runtime): the Table-11
/// gradient-penalty baseline and the non-reversible solvers live here.
#[cfg(feature = "pjrt")]
impl GanTrainer {
    /// Build from a runtime + manifest (hyperparameters and layouts come
    /// from `artifacts/manifest.json`, as `python/compile/aot.py` records
    /// them).
    pub fn from_runtime(rt: &Runtime, cfg: &TrainConfig, total_steps: usize) -> Result<Self> {
        let model = format!("gan_{}", cfg.dataset.as_str());
        let spec_m = rt.manifest.model(&model)?;
        let gl = spec_m.gen_layout.clone();
        let dl = spec_m.disc_layout.clone();
        let hy = |k: &str| rt.manifest.hyper(&model, k);
        let spec = GanNetSpec {
            data_dim: hy("y")? as usize,
            state: hy("x")? as usize,
            hidden: hy("h")? as usize,
            noise: hy("w")? as usize,
            init_noise: hy("v")? as usize,
            disc_state: hy("dh")? as usize,
            disc_hidden: hy("dhh")? as usize,
        };
        let seq_len = hy("seq_len")? as usize;
        let batch = hy("batch")? as usize;
        let eval_batch = hy("eval_batch")? as usize;
        Self::assemble(cfg, spec, seq_len, gl, dl, batch, eval_batch, total_steps)
    }

    fn exec_name(&self, kind: &str) -> String {
        format!("{}_{}_{}", self.model, self.solver.as_str(), kind)
    }

    /// One adversarial round through the AOT gradient executables.
    pub fn train_step_runtime(
        &mut self,
        rt: &mut Runtime,
        data: &TimeSeriesDataset,
        rng: &mut crate::brownian::SplitPrng,
    ) -> Result<GanStepStats> {
        let n = self.seq_len - 1;
        let w = self.spec.noise;
        let mut v = vec![0.0f32; self.batch * self.spec.init_noise];
        let mut dws = vec![0.0f32; n * self.batch * w];

        // ---- Discriminator step.
        let (y_real, _) = data.sample_batch(self.batch, rng);
        self.noise.fill_normals(&mut v);
        self.noise.fill(&self.ts, &mut dws);
        let disc_exec = if self.clip {
            self.exec_name("disc_grad")
        } else {
            // Gradient-penalty baseline (only lowered for midpoint + OU).
            format!("{}_midpoint_disc_grad_gp", self.model)
        };
        let out = rt.run_f32(
            &disc_exec,
            &[
                (&self.theta, &[self.theta.len()]),
                (&self.phi, &[self.phi.len()]),
                (&v, &[self.batch, self.spec.init_noise]),
                (&self.ts, &[self.seq_len]),
                (&dws, &[n, self.batch, w]),
                (&y_real, &[self.batch, self.seq_len, self.spec.data_dim]),
            ],
        )?;
        let loss_d = out[0][0];
        let gphi = &out[1];
        anyhow::ensure!(gphi.len() == self.phi.len(), "disc grad shape");
        self.opt_d.step(&mut self.phi, gphi);
        if self.clip {
            self.disc_layout.clip_lipschitz(&mut self.phi, field_filter);
        }

        // ---- Generator step (fresh noise).
        self.noise.fill_normals(&mut v);
        self.noise.fill(&self.ts, &mut dws);
        let out = rt.run_f32(
            &self.exec_name("gen_grad"),
            &[
                (&self.theta, &[self.theta.len()]),
                (&self.phi, &[self.phi.len()]),
                (&v, &[self.batch, self.spec.init_noise]),
                (&self.ts, &[self.seq_len]),
                (&dws, &[n, self.batch, w]),
            ],
        )?;
        let loss_g = out[0][0];
        let gtheta = &out[1];
        anyhow::ensure!(gtheta.len() == self.theta.len(), "gen grad shape");
        self.opt_g.step(&mut self.theta, gtheta);
        self.steps_done += 1;
        if self.steps_done * 2 >= self.total_steps {
            self.swa.update(&self.theta);
        }
        Ok(GanStepStats { loss_g, loss_d, retries: 0 })
    }

    /// Generate `n_samples` series through the AOT sampling executable.
    pub fn sample_runtime(
        &mut self,
        rt: &mut Runtime,
        n_samples: usize,
    ) -> Result<TimeSeriesDataset> {
        let theta = self.final_theta();
        let n = self.seq_len - 1;
        let eb = self.eval_batch;
        let (y, w, v_dim) = (self.spec.data_dim, self.spec.noise, self.spec.init_noise);
        let mut values = Vec::with_capacity(n_samples * self.seq_len * y);
        let mut v = vec![0.0f32; eb * v_dim];
        let mut dws = vec![0.0f32; n * eb * w];
        let mut eval_noise = StepNoise::new(NoiseBackend::Interval, T0, T1, eb * w, 0xE7A1);
        let mut produced = 0;
        while produced < n_samples {
            eval_noise.fill_normals(&mut v);
            eval_noise.fill(&self.ts, &mut dws);
            let out = rt.run_f32(
                &self.exec_name("sample"),
                &[
                    (&theta, &[theta.len()]),
                    (&v, &[eb, v_dim]),
                    (&self.ts, &[self.seq_len]),
                    (&dws, &[n, eb, w]),
                ],
            )?;
            let take = (n_samples - produced).min(eb);
            values.extend_from_slice(&out[0][..take * self.seq_len * y]);
            produced += take;
        }
        Ok(TimeSeriesDataset {
            n: n_samples,
            seq_len: self.seq_len,
            channels: y,
            values,
            times: self.ts.iter().map(|&t| t as f64).collect(),
            labels: None,
        })
    }
}

/// The generator forward's solve-precision artefacts: the stored noise
/// (and, on the f32 route, the narrowed `z₀` lanes) the adjoint replays so
/// its internal forward is bit-identical to the trajectory the loss read.
enum GenNoise {
    /// f64 route: widened stored increments.
    F64(StoredBatchNoise),
    /// Mixed route: native-f32 stored increments + narrowed initial state.
    F32(StoredBatchNoise<f32>, Vec<f32>),
}

/// Clip filter: the discriminator's CDE vector fields (Section 5 applies
/// the Lipschitz constraint to `f_φ` and `g_φ`).
pub fn field_filter(name: &str) -> bool {
    name.starts_with("f.") || name.starts_with("g.")
}

/// Watchdog guard on one training update: a non-finite loss or gradient
/// lane becomes a structured [`SolveError`] carrying the offending flat
/// parameter index (`component`) and the training step (`step`).
fn check_finite(
    context: &'static str,
    step: usize,
    loss: f64,
    grad: &[f64],
) -> Result<(), SolveError> {
    let bad = if loss.is_finite() {
        grad.iter().position(|g| !g.is_finite())
    } else {
        Some(0)
    };
    match bad {
        None => Ok(()),
        Some(i) => Err(SolveError::new(
            context,
            vec![SolveFault { step, path: 0, component: i, cause: FaultCause::NonFinite }],
        )),
    }
}

/// Widen a filled `[n][batch, w]` `f32` increment buffer (the
/// [`StepNoise::fill`] layout the AOT executables consume) into the batch
/// engine's stored SoA form over the normalised `[T0, T1]` grid — one
/// transpose pass via [`StoredBatchNoise::from_f32_grid`], no intermediate
/// widened buffer (and none at all once the consumer moves to `f32` lanes).
fn widen_increments(dws32: &[f32], n: usize, w: usize, batch: usize) -> StoredBatchNoise {
    debug_assert_eq!(dws32.len(), n * batch * w);
    StoredBatchNoise::from_f32_grid(T0, T1, n, w, batch, dws32)
}
