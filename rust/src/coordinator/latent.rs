//! The Latent SDE trainer (Li et al. 2020; paper Section 2.2 / Table 5).
//!
//! Joint θ/φ optimisation of the ELBO with Adam (Appendix F.2), driving
//! the `latent_<ds>_<solver>_grad` executable; sampling draws from the
//! learned prior SDE.

use crate::config::{SolverKind, TrainConfig};
use crate::coordinator::noise::{NoiseBackend, StepNoise};
use crate::data::TimeSeriesDataset;
use crate::nn::{Adam, Optimizer};
use crate::runtime::Runtime;
use anyhow::Result;

/// Latent SDE training state.
pub struct LatentTrainer {
    /// Model name in the manifest (e.g. `"latent_air"`).
    pub model: String,
    solver: SolverKind,
    batch: usize,
    seq_len: usize,
    x: usize,
    v_dim: usize,
    y_dim: usize,
    eval_batch: usize,
    /// Joint (θ, φ) parameters, flat.
    pub params: Vec<f32>,
    opt: Adam,
    noise: StepNoise,
    ts: Vec<f32>,
}

impl LatentTrainer {
    /// Build from a runtime + config.
    pub fn new(rt: &Runtime, cfg: &TrainConfig) -> Result<Self> {
        let model = format!("latent_{}", cfg.dataset.as_str());
        let spec = rt.manifest.model(&model)?;
        let model_name = model.clone();
        let hy = move |k: &str| rt.manifest.hyper(&model_name, k);
        let batch = hy("batch")? as usize;
        let seq_len = hy("seq_len")? as usize;
        let lay = spec.gen_layout.clone();
        let alpha = cfg.alpha;
        let beta = cfg.beta;
        let params = lay.init(cfg.seed, |name| {
            if name.starts_with("zeta") || name.starts_with("xi") {
                alpha
            } else {
                beta
            }
        });
        let scale: Vec<f32> = {
            let mut s = vec![1.0f32; lay.total];
            for t in &lay.tensors {
                let is_init = t.name.starts_with("zeta");
                let v = if is_init { 1.0 } else { cfg.lr_field / cfg.lr_init };
                s[t.offset..t.offset + t.len()].fill(v);
            }
            s
        };
        let opt = Adam::new(cfg.lr_init, lay.total).with_lr_scale(scale);
        let ts: Vec<f32> = (0..seq_len)
            .map(|k| k as f32 / (seq_len - 1) as f32 - 0.5)
            .collect();
        let backend = if cfg.brownian_interval {
            NoiseBackend::Interval
        } else {
            NoiseBackend::VirtualTree { eps: 1e-5 }
        };
        let x = hy("x")? as usize;
        Ok(Self {
            model,
            solver: cfg.solver,
            batch,
            seq_len,
            x,
            v_dim: hy("v")? as usize,
            y_dim: hy("y")? as usize,
            eval_batch: hy("eval_batch")? as usize,
            params,
            opt,
            noise: StepNoise::new(backend, -0.5, 0.5, batch * x, cfg.seed ^ 0x99),
            ts,
        })
    }

    /// One ELBO descent step; returns the loss.
    pub fn train_step(
        &mut self,
        rt: &mut Runtime,
        data: &TimeSeriesDataset,
        rng: &mut crate::brownian::SplitPrng,
    ) -> Result<f32> {
        let n = self.seq_len - 1;
        let (y_real, _) = data.sample_batch(self.batch, rng);
        let mut dws = vec![0.0f32; n * self.batch * self.x];
        let mut eps = vec![0.0f32; self.batch * self.v_dim];
        self.noise.fill(&self.ts, &mut dws);
        self.noise.fill_normals(&mut eps);
        let name = format!("{}_{}_grad", self.model, self.solver.as_str());
        let out = rt.run_f32(
            &name,
            &[
                (&self.params, &[self.params.len()]),
                (&self.ts, &[self.seq_len]),
                (&dws, &[n, self.batch, self.x]),
                (&y_real, &[self.batch, self.seq_len, self.y_dim]),
                (&eps, &[self.batch, self.v_dim]),
            ],
        )?;
        let loss = out[0][0];
        anyhow::ensure!(out[1].len() == self.params.len(), "latent grad shape");
        self.opt.step(&mut self.params, &out[1]);
        Ok(loss)
    }

    /// Generate samples from the learned prior SDE.
    pub fn sample(&mut self, rt: &mut Runtime, n_samples: usize) -> Result<TimeSeriesDataset> {
        let n = self.seq_len - 1;
        let eb = self.eval_batch;
        let mut values = Vec::with_capacity(n_samples * self.seq_len * self.y_dim);
        let mut v = vec![0.0f32; eb * self.v_dim];
        let mut dws = vec![0.0f32; n * eb * self.x];
        let mut eval_noise =
            StepNoise::new(NoiseBackend::Interval, -0.5, 0.5, eb * self.x, 0x1A7E);
        let name = format!("{}_{}_sample", self.model, self.solver.as_str());
        let mut produced = 0;
        while produced < n_samples {
            eval_noise.fill_normals(&mut v);
            eval_noise.fill(&self.ts, &mut dws);
            let out = rt.run_f32(
                &name,
                &[
                    (&self.params, &[self.params.len()]),
                    (&v, &[eb, self.v_dim]),
                    (&self.ts, &[self.seq_len]),
                    (&dws, &[n, eb, self.x]),
                ],
            )?;
            let take = (n_samples - produced).min(eb);
            values.extend_from_slice(&out[0][..take * self.seq_len * self.y_dim]);
            produced += take;
        }
        Ok(TimeSeriesDataset {
            n: n_samples,
            seq_len: self.seq_len,
            channels: self.y_dim,
            values,
            times: self.ts.iter().map(|&t| t as f64).collect(),
            labels: None,
        })
    }
}
