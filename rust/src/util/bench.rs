//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Follows the paper's measurement protocol (Appendix F.6): each benchmark
//! is repeated `repeats` times and the **minimum** wall time is reported —
//! "errors in speed benchmarks are one-sided, and so the minimum time
//! represents the least noisy measurement". Mean and standard deviation are
//! also recorded for context.
//!
//! Results print as an aligned table and can be dumped to JSON so the
//! benchmark binaries regenerate the paper's tables as machine-readable
//! artifacts.

use super::json::{obj, Json};
use super::stats;
use std::time::Instant;

/// A single benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Identifier, e.g. `"bi/seq/batch=2560/n=100"`.
    pub name: String,
    /// Minimum over repeats, seconds (headline number, as in the paper).
    pub min_s: f64,
    /// Mean over repeats, seconds.
    pub mean_s: f64,
    /// Standard deviation over repeats, seconds.
    pub std_s: f64,
    /// Number of timed repeats.
    pub repeats: usize,
}

/// A group of measurements forming one results table.
pub struct BenchTable {
    /// Table title (e.g. `"Table 8: doubly sequential access"`).
    pub title: String,
    /// Collected measurements in insertion order.
    pub rows: Vec<Measurement>,
    repeats: usize,
    warmup: usize,
}

impl BenchTable {
    /// New table; `repeats` timed runs per benchmark after `warmup`
    /// untimed runs. The paper uses `repeats = 32`.
    pub fn new(title: &str, repeats: usize, warmup: usize) -> Self {
        Self { title: title.to_string(), rows: Vec::new(), repeats, warmup }
    }

    /// Time `f` (which should perform one complete workload run).
    ///
    /// `f` receives the run index; use it to vary seeds if the workload
    /// must not be trivially cacheable.
    pub fn bench<F: FnMut(usize)>(&mut self, name: &str, f: F) -> &Measurement {
        let reps = self.repeats;
        self.bench_n(name, reps, f)
    }

    /// Like [`bench`](Self::bench) with an explicit repeat count — used to
    /// trim very large workload cells (the paper's 32768-batch columns).
    pub fn bench_n<F: FnMut(usize)>(
        &mut self,
        name: &str,
        repeats: usize,
        mut f: F,
    ) -> &Measurement {
        for i in 0..self.warmup {
            f(i);
        }
        let mut times = Vec::with_capacity(repeats);
        for i in 0..repeats {
            let t0 = Instant::now();
            f(self.warmup + i);
            times.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: name.to_string(),
            min_s: stats::min(&times),
            mean_s: stats::mean(&times),
            std_s: stats::std_dev(&times),
            repeats,
        };
        eprintln!(
            "  {:<44} min {:>10}   mean {:>10} ± {}",
            m.name,
            stats::fmt_seconds(m.min_s),
            stats::fmt_seconds(m.mean_s),
            stats::fmt_seconds(m.std_s),
        );
        self.rows.push(m);
        self.rows.last().unwrap()
    }

    /// Minimum time of a previously-recorded row (panics if absent).
    pub fn min_of(&self, name: &str) -> f64 {
        self.rows
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("no measurement named {name}"))
            .min_s
    }

    /// Render the table with an optional speed-up column computed between
    /// row-name pairs `(baseline, candidate)`.
    pub fn render(&self) -> String {
        let mut out = format!("\n== {} (min over {} runs) ==\n", self.title, self.repeats);
        for r in &self.rows {
            out.push_str(&format!(
                "{:<48} {:>12}\n",
                r.name,
                stats::fmt_seconds(r.min_s)
            ));
        }
        out
    }

    /// Serialise all rows to JSON.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("repeats", Json::Num(self.repeats as f64)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("min_s", Json::Num(r.min_s)),
                                ("mean_s", Json::Num(r.mean_s)),
                                ("std_s", Json::Num(r.std_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Append this table's JSON to `path` (one JSON document per file).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }
}

/// Write one or more tables as a machine-tracked `BENCH_<tag>.json` under
/// `dir`, returning the path written.
///
/// This is the perf-trajectory format committed per PR (e.g.
/// `BENCH_pr1.json` at the repo root): one document per tag holding every
/// table's rows, so regressions are diffable across the PR history. `extra`
/// lets a bench attach derived headline numbers (speedups, thread counts).
pub fn write_bench_json(
    dir: &str,
    tag: &str,
    tables: &[&BenchTable],
    extra: Vec<(&str, Json)>,
) -> std::io::Result<String> {
    let mut fields: Vec<(&str, Json)> = vec![
        ("tag", Json::Str(tag.to_string())),
        (
            "tables",
            Json::Arr(tables.iter().map(|t| t.to_json()).collect()),
        ),
    ];
    fields.extend(extra);
    let path = format!("{}/BENCH_{}.json", dir.trim_end_matches('/'), tag);
    std::fs::write(&path, obj(fields).to_string_pretty())?;
    Ok(path)
}

/// Black-box helper to stop the optimiser deleting benchmark work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_measurements() {
        let mut t = BenchTable::new("test", 3, 1);
        t.bench("sleepless", |_| {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            black_box(s);
        });
        assert_eq!(t.rows.len(), 1);
        assert!(t.rows[0].min_s >= 0.0);
        assert!(t.rows[0].min_s <= t.rows[0].mean_s + 1e-12);
        assert!(t.min_of("sleepless") == t.rows[0].min_s);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = BenchTable::new("test", 2, 0);
        t.bench("a", |_| {});
        let j = t.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str(), Some("test"));
    }

    #[test]
    fn bench_json_document_written() {
        let mut t = BenchTable::new("tab", 2, 0);
        t.bench("row", |_| {});
        let dir = std::env::temp_dir().join("neuralsde_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let dir = dir.to_str().unwrap().to_string();
        let path =
            write_bench_json(&dir, "test", &[&t], vec![("speedup", Json::Num(2.0))]).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("tag").unwrap().as_str(), Some("test"));
        assert_eq!(parsed.get("speedup").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("tables").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }
}
