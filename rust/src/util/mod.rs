//! Small self-contained utilities.
//!
//! The build environment is offline, so instead of pulling `serde_json`,
//! `clap` and `criterion` we implement the slivers of them we need:
//! a JSON value type + parser/writer ([`json`]), a flag-style CLI argument
//! parser ([`cli`]), summary statistics and least-squares fits ([`stats`]),
//! and a minimum-of-`k`-runs micro-benchmark harness ([`bench`]) matching
//! the paper's measurement protocol (Appendix F.6 reports the *minimum*
//! over 32 repeats, "errors in speed benchmarks are one-sided").

pub mod bench;
pub mod cli;
pub mod json;
pub mod stats;
