//! Minimal JSON: enough to read `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) and to write experiment-result files.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs (accepted,
//! replaced with U+FFFD). Numbers parse as `f64`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 (numbers only).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize (non-negative integral numbers only).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialise with two-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Convenience: build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: `Json::Arr` of numbers.
pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .s
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let rest = &self.s[self.i..];
                    let st = std::str::from_utf8(rest).map_err(|_| "invalid utf8")?;
                    let c = st.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("c"), Some(&Json::Null));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj(vec![
            ("nums", num_arr(&[1.0, 2.0, 3.25])),
            ("nested", obj(vec![("k", Json::Str("v".into()))])),
            ("empty", Json::Arr(vec![])),
        ]);
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""aAbé""#).unwrap();
        assert_eq!(v.as_str(), Some("aAbé"));
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
