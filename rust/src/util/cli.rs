//! Tiny CLI argument parser (offline stand-in for `clap`).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [--key=value] ...`.
//! Unknown keys are collected and reported by [`Args::finish`] so typos
//! fail loudly.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// First positional argument, conventionally the subcommand.
    pub subcommand: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator (for tests).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.kv.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.kv.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    /// String option.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.consumed.push(key.to_string());
        self.kv.get(key).cloned()
    }

    /// String option with default.
    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn get_parse_or<T: std::str::FromStr>(&mut self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={s}: {e}")),
        }
    }

    /// Boolean flag (present or absent).
    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any `--key` that no call consumed.
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown arguments: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_kv() {
        let mut a = parse("train --steps 100 --lr=0.01 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_parse_or("steps", 0usize), 100);
        assert_eq!(a.get_parse_or("lr", 0.0f64), 0.01);
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults() {
        let mut a = parse("bench");
        assert_eq!(a.get_or("out", "results.json"), "results.json");
        assert_eq!(a.get_parse_or("batch", 32usize), 32);
        assert!(!a.flag("quick"));
    }

    #[test]
    fn unknown_args_detected() {
        let mut a = parse("train --oops 3");
        let _ = a.get("steps");
        assert!(a.finish().is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("run file1 file2");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
