//! Summary statistics and least-squares helpers used across the
//! experiment harness (convergence-order fits, result tables).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Ordinary least squares `y ≈ a + b x`; returns `(a, b)`.
///
/// Used to estimate convergence orders from log-log error curves
/// (Figures 5 and 6): the slope `b` of `log2(err)` against `log2(h)` is the
/// empirical order.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        den += (x[i] - mx) * (x[i] - mx);
    }
    let b = num / den;
    (my - b * mx, b)
}

/// Pearson correlation coefficient.
pub fn correlation(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..x.len() {
        sxy += (x[i] - mx) * (y[i] - my);
        sxx += (x[i] - mx).powi(2);
        syy += (y[i] - my).powi(2);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Minimum of a slice (NaN-propagating).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().fold(f64::INFINITY, |a, &b| a.min(b))
}

/// Maximum of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
}

/// Format seconds human-readably (`412 µs`, `3.2 ms`, `1.7 s`).
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 3.0 - 0.5 * v).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b + 0.5).abs() < 1e-12);
    }

    #[test]
    fn correlation_of_line_is_one() {
        let x: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.0 * v + 1.0).collect();
        assert!((correlation(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_seconds(2.0), "2.00 s");
        assert!(fmt_seconds(0.002).contains("ms"));
        assert!(fmt_seconds(2e-7).contains("ns"));
    }
}
