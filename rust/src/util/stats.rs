//! Summary statistics and least-squares helpers used across the
//! experiment harness (convergence-order fits, result tables).

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Ordinary least squares `y ≈ a + b x`; returns `(a, b)`.
///
/// Used to estimate convergence orders from log-log error curves
/// (Figures 5 and 6): the slope `b` of `log2(err)` against `log2(h)` is the
/// empirical order.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        den += (x[i] - mx) * (x[i] - mx);
    }
    let b = num / den;
    (my - b * mx, b)
}

/// Pearson correlation coefficient.
pub fn correlation(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..x.len() {
        sxy += (x[i] - mx) * (y[i] - my);
        sxx += (x[i] - mx).powi(2);
        syy += (y[i] - my).powi(2);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Central-difference gradient of a scalar function:
/// `g_i = (f(x + h e_i) − f(x − h e_i)) / 2h`.
///
/// The shared gradient-check harness: every analytic VJP in
/// `solvers::adjoint` is validated against this (the `O(h²)` truncation
/// error means halving `h` should quarter the disagreement until roundoff
/// `~ε/h` takes over — tests probe several `h` to see both regimes). For
/// maps that are *affine* in `x_i` the central difference is exact up to
/// roundoff at any `h`, which is how the closed-form OU problem pins the
/// adjoint to machine precision.
pub fn central_gradient<F: FnMut(&[f64]) -> f64>(mut f: F, x: &[f64], h: f64) -> Vec<f64> {
    assert!(h > 0.0, "finite-difference step must be positive");
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let xi = x[i];
        xp[i] = xi + h;
        let fp = f(&xp);
        xp[i] = xi - h;
        let fm = f(&xp);
        xp[i] = xi;
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

/// Minimum of a slice (NaN-propagating).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().fold(f64::INFINITY, |a, &b| a.min(b))
}

/// Maximum of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b))
}

/// Format seconds human-readably (`412 µs`, `3.2 ms`, `1.7 s`).
pub fn fmt_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.0} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 3.0 - 0.5 * v).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-12);
        assert!((b + 0.5).abs() < 1e-12);
    }

    #[test]
    fn correlation_of_line_is_one() {
        let x: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.0 * v + 1.0).collect();
        assert!((correlation(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_max() {
        let xs = [3.0, -1.0, 2.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn central_gradient_quadratic_and_affine() {
        // f(x) = x0² + 3 x1: ∂f = [2 x0, 3]. The affine component is exact
        // at any h; the quadratic one is exact for central differences too
        // (odd truncation terms vanish, f''' = 0).
        let f = |x: &[f64]| x[0] * x[0] + 3.0 * x[1];
        let g = central_gradient(f, &[1.5, -2.0], 0.5);
        assert!((g[0] - 3.0).abs() < 1e-12, "g0 = {}", g[0]);
        assert!((g[1] - 3.0).abs() < 1e-12, "g1 = {}", g[1]);
        // Cubic term: truncation error shrinks ~h².
        let f3 = |x: &[f64]| x[0] * x[0] * x[0];
        let e1 = (central_gradient(f3, &[1.0], 1e-2)[0] - 3.0).abs();
        let e2 = (central_gradient(f3, &[1.0], 1e-3)[0] - 3.0).abs();
        assert!(e2 < e1 / 10.0, "truncation did not shrink: {e1} -> {e2}");
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_seconds(2.0), "2.00 s");
        assert!(fmt_seconds(0.002).contains("ms"));
        assert!(fmt_seconds(2e-7).contains("ns"));
    }
}
