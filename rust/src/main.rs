//! `neural-sde` — CLI launcher for the Neural SDE reproduction.
//!
//! Subcommands map onto the paper's experiments (see DESIGN.md §3):
//!
//! * `train`          — train an SDE-GAN or Latent SDE (per `--dataset`)
//! * `gradient-error` — Figure 2 / Table 6
//! * `info`           — list loaded artifacts
//!
//! The table/figure *benchmarks* live under `cargo bench`; the runnable
//! experiment drivers under `examples/`.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{DatasetKind, TrainConfig};
use neuralsde::coordinator::{evaluate_generator, gradient_error, GanTrainer, LatentTrainer};
use neuralsde::data::{air, ou, weights};
use neuralsde::runtime::load_runtime;
use neuralsde::util::cli::Args;

const USAGE: &str = "\
neural-sde — Efficient and Accurate Gradients for Neural SDEs (NeurIPS 2021)

USAGE:
  neural-sde <subcommand> [options]

SUBCOMMANDS:
  train            Train a model: --dataset ou|weights|air  --solver
                   reversible_heun|midpoint  --steps N  [--no-clip]
                   [--virtual-brownian-tree] [--seed N]
  gradient-error   Reproduce Figure 2 / Table 6
  info             Show runtime/artifact status
  help             This message
";

fn build_dataset(cfg: &TrainConfig) -> neuralsde::data::TimeSeriesDataset {
    let mut data = match cfg.dataset {
        DatasetKind::Ou => ou::generate(cfg.data_size, cfg.seed, ou::OuParams::default()),
        DatasetKind::Weights => {
            weights::generate(cfg.data_size, cfg.seed, weights::WeightsParams::default())
        }
        DatasetKind::Air => air::generate(cfg.data_size, cfg.seed, air::AirParams::default()),
    };
    data.normalise_initial();
    data
}

fn cmd_train(mut args: Args) -> anyhow::Result<()> {
    let config_path = args.get("config");
    let mut cfg = TrainConfig::load(config_path.as_deref(), &mut args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let data = build_dataset(&cfg);
    let (train, _val, test) = data.split();
    let mut rng = SplitPrng::new(cfg.seed);
    println!(
        "training {} / {} for {} steps (clip={}, noise={})",
        cfg.dataset.as_str(),
        cfg.solver.as_str(),
        cfg.steps,
        cfg.clip,
        if cfg.brownian_interval { "brownian-interval" } else { "virtual-tree" },
    );
    match cfg.dataset {
        DatasetKind::Air => {
            // The Latent SDE still runs through the AOT executables.
            cfg.lr_init = 4e-3;
            let mut rt = load_runtime(&cfg.artifacts_dir)?;
            let mut tr = LatentTrainer::new(&rt, &cfg)?;
            for step in 0..cfg.steps {
                let loss = tr.train_step(&mut rt, &train, &mut rng)?;
                if step % 25 == 0 {
                    println!("step {step:>4}  loss {loss:+.4}");
                }
            }
            let fake = tr.sample(&mut rt, test.n)?;
            println!("{}", evaluate_generator(&test, &fake, 7).row());
        }
        _ => {
            // SDE-GANs train natively (reversible Heun + clipping) — no
            // artifacts required. Non-reversible solvers and the Table-11
            // gradient-penalty baseline (--no-clip) only exist as AOT
            // executables, so those requests route to the pjrt runtime.
            let needs_runtime =
                cfg.solver != neuralsde::config::SolverKind::ReversibleHeun || !cfg.clip;
            if needs_runtime {
                #[cfg(feature = "pjrt")]
                {
                    let mut rt = load_runtime(&cfg.artifacts_dir)?;
                    let mut tr = GanTrainer::from_runtime(&rt, &cfg, cfg.steps)?;
                    for step in 0..cfg.steps {
                        let s = tr.train_step_runtime(&mut rt, &train, &mut rng)?;
                        if step % 25 == 0 {
                            println!(
                                "step {step:>4}  loss_g {:+.4}  loss_d {:+.4}",
                                s.loss_g, s.loss_d
                            );
                        }
                    }
                    let fake = tr.sample_runtime(&mut rt, test.n)?;
                    println!("{}", evaluate_generator(&test, &fake, 7).row());
                    return Ok(());
                }
                #[cfg(not(feature = "pjrt"))]
                anyhow::bail!(
                    "--solver {} with clip={} trains through the AOT executables: \
                     rebuild with --features pjrt and run `make artifacts` (the \
                     native backend covers reversible_heun + clipping)",
                    cfg.solver.as_str(),
                    cfg.clip
                );
            }
            let mut tr = GanTrainer::new(&cfg, cfg.steps)?;
            for step in 0..cfg.steps {
                let s = tr.train_step(&train, &mut rng)?;
                if step % 25 == 0 {
                    println!("step {step:>4}  loss_g {:+.4}  loss_d {:+.4}", s.loss_g, s.loss_d);
                }
            }
            let fake = tr.sample(test.n)?;
            println!("{}", evaluate_generator(&test, &fake, 7).row());
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(args)?,
        Some("gradient-error") => {
            // Native adjoint rows need no artifacts; the PJRT solver
            // comparison additionally needs `make artifacts`.
            let native = gradient_error::run_native(2021);
            println!("{}", gradient_error::render(&native));
            let mixed = gradient_error::run_native_mixed(2021);
            println!("{}", gradient_error::render(&mixed));
            if neuralsde::runtime::Runtime::artifacts_present("artifacts") {
                let mut rt = load_runtime("artifacts")?;
                let points = gradient_error::run(&mut rt, 2021)?;
                println!("{}", gradient_error::render(&points));
            } else {
                println!("PJRT rows skipped (no artifacts; run `make artifacts`)");
            }
        }
        Some("info") => {
            println!("neural-sde v{}", env!("CARGO_PKG_VERSION"));
            if neuralsde::runtime::Runtime::artifacts_present("artifacts") {
                let rt = load_runtime("artifacts")?;
                println!("platform: {}", rt.platform());
                println!("{} executables:", rt.manifest.execs.len());
                for name in rt.manifest.execs.keys() {
                    println!("  {name}");
                }
            } else {
                println!("no artifacts (run `make artifacts`)");
            }
        }
        _ => print!("{USAGE}"),
    }
    Ok(())
}
