//! Truncated path signature (the feature map of Appendix F.1).
//!
//! For a path `X: [0, T] → R^c` the depth-`m` signature is the collection of
//! iterated integrals `∫ dX^{i1} ⋯ dX^{ik}` for `k ≤ m` — a `c + c² + … +
//! c^m`-dimensional feature vector characterising the path up to
//! reparameterisation. For piecewise-linear paths it is computed exactly by
//! Chen's identity: the signature of a concatenation is the tensor product
//! of signatures, and the signature of a straight segment with increment `d`
//! is `exp⊗(d) = (1, d, d⊗d/2!, …)`.

/// Dimension of the depth-`m` signature over `R^c` (levels 1..=m).
pub fn sig_dim(c: usize, depth: usize) -> usize {
    let mut total = 0;
    let mut level = 1;
    for _ in 0..depth {
        level *= c;
        total += level;
    }
    total
}

/// Augment a `[seq_len][channels]` series (f32) with a leading time channel
/// (f64 output, `[seq_len][channels + 1]`).
///
/// Time augmentation makes the signature injective on the actual series
/// values (otherwise it only sees the path's image) and is standard practice
/// — torchcde/signatory do the same.
pub fn time_augment(series: &[f32], seq_len: usize, channels: usize) -> Vec<f64> {
    assert_eq!(series.len(), seq_len * channels);
    let mut out = Vec::with_capacity(seq_len * (channels + 1));
    for k in 0..seq_len {
        out.push(k as f64 / (seq_len.max(2) - 1) as f64);
        for c in 0..channels {
            out.push(series[k * channels + c] as f64);
        }
    }
    out
}

/// Depth-`m` signature of a piecewise-linear path `[seq_len][c]` (f64,
/// row-major). Returns levels 1..=m concatenated (length [`sig_dim`]).
pub fn signature(path: &[f64], seq_len: usize, c: usize, depth: usize) -> Vec<f64> {
    assert!(depth >= 1);
    assert_eq!(path.len(), seq_len * c);
    // sig[k] is the level-(k+1) tensor, flattened (c^(k+1) long).
    let mut sig: Vec<Vec<f64>> = (0..depth).map(|k| vec![0.0; c.pow(k as u32 + 1)]).collect();
    let mut exp: Vec<Vec<f64>> = sig.clone();
    let mut new_sig = sig.clone();
    let mut d = vec![0.0f64; c];
    for step in 1..seq_len {
        for i in 0..c {
            d[i] = path[step * c + i] - path[(step - 1) * c + i];
        }
        // exp levels: e[0] = d, e[k] = e[k-1] ⊗ d / (k+1).
        exp[0].copy_from_slice(&d);
        for k in 1..depth {
            let (lo, hi) = exp.split_at_mut(k);
            let prev = &lo[k - 1];
            let cur = &mut hi[0];
            let inv = 1.0 / (k as f64 + 1.0);
            for (a, &pa) in prev.iter().enumerate() {
                for (b, &db) in d.iter().enumerate() {
                    cur[a * c + b] = pa * db * inv;
                }
            }
        }
        // Chen: new_sig[k] = sig[k] + e[k] + Σ_{j=1}^{k-1} sig[j-1] ⊗ e[k-j-1]
        for k in 0..depth {
            let dst = &mut new_sig[k];
            dst.copy_from_slice(&sig[k]);
            for (x, &e) in dst.iter_mut().zip(&exp[k]) {
                *x += e;
            }
            for j in 0..k {
                // sig level (j+1) ⊗ exp level (k-j-1+1): c^(j+1) x c^(k-j-1+1)
                let a_t = &sig[j];
                let b_t = &exp[k - j - 1];
                let bn = b_t.len();
                for (ai, &av) in a_t.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let base = ai * bn;
                    for (bi, &bv) in b_t.iter().enumerate() {
                        dst[base + bi] += av * bv;
                    }
                }
            }
        }
        std::mem::swap(&mut sig, &mut new_sig);
    }
    sig.concat()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sig_dims() {
        assert_eq!(sig_dim(2, 3), 2 + 4 + 8);
        assert_eq!(sig_dim(3, 2), 3 + 9);
        assert_eq!(sig_dim(1, 4), 4);
    }

    #[test]
    fn straight_line_signature_is_exp() {
        // One segment with increment d: level k = d^{⊗k}/k!.
        let path = [0.0, 0.0, 2.0, 3.0]; // c=2, 2 points, d = (2,3)
        let s = signature(&path, 2, 2, 3);
        // level 1
        assert_eq!(&s[0..2], &[2.0, 3.0]);
        // level 2: outer(d,d)/2
        let l2 = &s[2..6];
        let expect2 = [2.0, 3.0, 3.0, 4.5];
        for (a, b) in l2.iter().zip(expect2) {
            assert!((a - b).abs() < 1e-12);
        }
        // level 3 entry (0,0,0): 8/6
        assert!((s[6] - 8.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn level1_is_total_increment() {
        let path = [0.0, 1.0, -0.5, 2.0, 3.0, 0.0]; // c=2, 3 points
        let s = signature(&path, 3, 2, 2);
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] + 1.0).abs() < 1e-12);
    }

    #[test]
    fn chen_identity_concatenation() {
        // signature(path) computed in one go equals combining the signature
        // over two halves with the tensor (Chen) product.
        let c = 2;
        let depth = 3;
        let pts: Vec<f64> = vec![
            0.0, 0.0, 1.0, 0.5, 0.3, -0.2, 0.8, 0.8, 1.5, 0.1, 2.0, 2.0, 1.0, 2.5,
        ];
        let n = pts.len() / c;
        let full = signature(&pts, n, c, depth);
        let split = 4;
        let first = signature(&pts[..split * c], split, c, depth);
        // Second half shares the boundary point.
        let second = signature(&pts[(split - 1) * c..], n - split + 1, c, depth);
        // Chen combine with levels (including level 0 = 1).
        let levels = |s: &[f64]| -> Vec<Vec<f64>> {
            let mut out = vec![vec![1.0]];
            let mut off = 0;
            for k in 1..=depth {
                let n = c.pow(k as u32);
                out.push(s[off..off + n].to_vec());
                off += n;
            }
            out
        };
        let a = levels(&first);
        let b = levels(&second);
        let mut combined: Vec<f64> = Vec::new();
        for k in 1..=depth {
            let mut lvl = vec![0.0; c.pow(k as u32)];
            for j in 0..=k {
                let (x, y) = (&a[j], &b[k - j]);
                let yn = y.len();
                for (xi, &xv) in x.iter().enumerate() {
                    for (yi, &yv) in y.iter().enumerate() {
                        lvl[xi * yn + yi] += xv * yv;
                    }
                }
            }
            combined.extend(lvl);
        }
        for (f, g) in full.iter().zip(&combined) {
            assert!((f - g).abs() < 1e-10, "{f} vs {g}");
        }
    }

    #[test]
    fn invariant_to_time_reparameterisation() {
        // Inserting a repeated point (zero increment) changes nothing.
        let base = [0.0, 0.0, 1.0, 1.0, 2.0, 0.5];
        let repeated = [0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 2.0, 0.5];
        let a = signature(&base, 3, 2, 3);
        let b = signature(&repeated, 4, 2, 3);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn time_augment_shapes_and_range() {
        let series = [5.0f32, 6.0, 7.0];
        let p = time_augment(&series, 3, 1);
        assert_eq!(p.len(), 6);
        assert_eq!(p[0], 0.0);
        assert_eq!(p[4], 1.0);
        assert_eq!(p[5], 7.0);
    }
}
