//! Evaluation metrics (Appendix F.1).
//!
//! The paper scores generative quality with four metrics; all are
//! implemented here over **signature features** (the same feature family
//! the paper's MMD uses — Appendix F.1 cites Kiraly & Oberhauser):
//!
//! * real-vs-fake classification accuracy (lower = better generator),
//! * label classification accuracy, train-on-synthetic-test-on-real
//!   (higher = better),
//! * prediction (forecasting) loss, train-on-synthetic-test-on-real
//!   (lower = better),
//! * maximum mean discrepancy with a truncated-signature feature map
//!   (lower = better).
//!
//! The paper's TSTR models are Neural CDEs trained for 5000 GPU steps; per
//! DESIGN.md §4 we substitute logistic/ridge models over depth-`m`
//! signature features — same protocol, CPU-trainable in milliseconds.

mod classify;
mod mmd;
mod signature;

pub use classify::{
    label_accuracy_tstr, prediction_loss_tstr, real_fake_accuracy, LogisticRegression,
    RidgeRegression,
};
pub use mmd::{mean_signature, signature_mmd};
pub use signature::{sig_dim, signature, time_augment};

use crate::data::TimeSeriesDataset;

/// Feature vector for one series: truncated signature of the time-augmented
/// path. `depth` 3–4 is plenty for the series lengths here.
pub fn series_features(series: &[f32], seq_len: usize, channels: usize, depth: usize) -> Vec<f64> {
    let path = time_augment(series, seq_len, channels);
    signature(&path, seq_len, channels + 1, depth)
}

/// Feature matrix for a whole dataset, `[n][sig_dim]` flattened.
pub fn dataset_features(ds: &TimeSeriesDataset, depth: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(ds.n * sig_dim(ds.channels + 1, depth));
    for i in 0..ds.n {
        out.extend(series_features(ds.series(i), ds.seq_len, ds.channels, depth));
    }
    out
}
