//! Maximum mean discrepancy with a truncated-signature feature map
//! (Appendix F.1).
//!
//! Given a feature map `ψ` and samples `P_i ~ P`, `Q_i ~ Q`, the estimator
//! is `‖ mean_i ψ(P_i) − mean_j ψ(Q_j) ‖₂`. The paper uses a depth-5
//! signature transform as `ψ`; we default to depth 4 (the series here are
//! short) with per-coordinate standardisation fitted on the real data so no
//! single signature level dominates the norm.

use super::{series_features, sig_dim};
use crate::data::TimeSeriesDataset;

/// Mean signature feature of a dataset (length [`sig_dim`]` (channels+1,
/// depth)`).
pub fn mean_signature(ds: &TimeSeriesDataset, depth: usize) -> Vec<f64> {
    let dim = sig_dim(ds.channels + 1, depth);
    let mut mean = vec![0.0f64; dim];
    for i in 0..ds.n {
        let f = series_features(ds.series(i), ds.seq_len, ds.channels, depth);
        for (m, v) in mean.iter_mut().zip(&f) {
            *m += v;
        }
    }
    for m in &mut mean {
        *m /= ds.n as f64;
    }
    mean
}

/// Signature-feature MMD between two datasets (lower = more similar).
///
/// Coordinates are standardised by the per-coordinate scale of the *real*
/// (first) dataset's features, fitted over its series.
pub fn signature_mmd(real: &TimeSeriesDataset, fake: &TimeSeriesDataset, depth: usize) -> f64 {
    assert_eq!(real.channels, fake.channels, "channel mismatch");
    let dim = sig_dim(real.channels + 1, depth);
    // Fit scale on real features.
    let mut mean = vec![0.0f64; dim];
    let mut sq = vec![0.0f64; dim];
    for i in 0..real.n {
        let f = series_features(real.series(i), real.seq_len, real.channels, depth);
        for k in 0..dim {
            mean[k] += f[k];
            sq[k] += f[k] * f[k];
        }
    }
    let nr = real.n as f64;
    let mut scale = vec![0.0f64; dim];
    for k in 0..dim {
        mean[k] /= nr;
        let var = (sq[k] / nr - mean[k] * mean[k]).max(0.0);
        scale[k] = 1.0 / (var.sqrt() + 1e-8);
    }
    // Mean feature difference, standardised.
    let mf = mean_signature(fake, depth);
    let mut acc = 0.0f64;
    for k in 0..dim {
        let d = (mean[k] - mf[k]) * scale[k];
        acc += d * d;
    }
    (acc / dim as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ou::{self, OuParams};

    #[test]
    fn mmd_zero_for_identical_data() {
        let d = ou::generate(64, 3, OuParams::default());
        let m = signature_mmd(&d, &d, 3);
        assert!(m < 1e-9, "mmd={m}");
    }

    #[test]
    fn mmd_small_for_same_law() {
        let a = ou::generate(800, 3, OuParams::default());
        let b = ou::generate(800, 4, OuParams::default());
        let m = signature_mmd(&a, &b, 3);
        assert!(m < 0.25, "same-law mmd={m}");
    }

    #[test]
    fn mmd_separates_different_laws() {
        let a = ou::generate(400, 3, OuParams::default());
        let mut p = OuParams::default();
        p.chi = 1.2; // much noisier law
        p.kappa = 0.5;
        let b = ou::generate(400, 5, p);
        let same = signature_mmd(&a, &ou::generate(400, 7, OuParams::default()), 3);
        let diff = signature_mmd(&a, &b, 3);
        assert!(diff > 3.0 * same, "same={same}, diff={diff}");
    }

    #[test]
    fn mean_signature_dimension() {
        let d = ou::generate(8, 1, OuParams::default());
        assert_eq!(mean_signature(&d, 4).len(), sig_dim(2, 4));
    }
}
