//! Train-on-synthetic-test-on-real metric models (Appendix F.1) and the
//! real-vs-fake classifier, over signature features.
//!
//! * [`LogisticRegression`] — binary or softmax-multiclass, full-batch
//!   gradient descent with L2 regularisation;
//! * [`RidgeRegression`] — closed-form (Cholesky) ridge, used for the
//!   forecasting metric (predict the last 20% of a series from the
//!   signature of the first 80%).

use super::series_features;
use crate::brownian::SplitPrng;
use crate::data::TimeSeriesDataset;

/// Standardise columns of an `[n][d]` feature matrix in place; returns the
/// `(mean, std)` per column so test features can reuse the fit.
pub fn fit_standardise(x: &mut [f64], n: usize, d: usize) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(d);
    for j in 0..d {
        let mut mean = 0.0;
        for i in 0..n {
            mean += x[i * d + j];
        }
        mean /= n as f64;
        let mut var = 0.0;
        for i in 0..n {
            var += (x[i * d + j] - mean).powi(2);
        }
        let sd = (var / n as f64).sqrt().max(1e-9);
        for i in 0..n {
            x[i * d + j] = (x[i * d + j] - mean) / sd;
        }
        out.push((mean, sd));
    }
    out
}

/// Apply a previously-fitted standardisation.
pub fn apply_standardise(x: &mut [f64], n: usize, d: usize, fit: &[(f64, f64)]) {
    for j in 0..d {
        let (m, s) = fit[j];
        for i in 0..n {
            x[i * d + j] = (x[i * d + j] - m) / s;
        }
    }
}

/// Multinomial logistic regression (binary is the 2-class case).
pub struct LogisticRegression {
    /// Number of classes.
    pub classes: usize,
    /// Feature dimension.
    pub dim: usize,
    /// Weights `[classes][dim]` + biases `[classes]`.
    w: Vec<f64>,
    b: Vec<f64>,
}

impl LogisticRegression {
    /// Train on `[n][d]` features with labels in `0..classes`.
    pub fn train(
        x: &[f64],
        y: &[u32],
        n: usize,
        d: usize,
        classes: usize,
        epochs: usize,
        lr: f64,
        l2: f64,
    ) -> Self {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n);
        let mut w = vec![0.0f64; classes * d];
        let mut b = vec![0.0f64; classes];
        let mut probs = vec![0.0f64; classes];
        let mut gw = vec![0.0f64; classes * d];
        let mut gb = vec![0.0f64; classes];
        for _ in 0..epochs {
            gw.fill(0.0);
            gb.fill(0.0);
            for i in 0..n {
                let xi = &x[i * d..(i + 1) * d];
                softmax_logits(&w, &b, xi, classes, d, &mut probs);
                for c in 0..classes {
                    let err = probs[c] - if y[i] as usize == c { 1.0 } else { 0.0 };
                    gb[c] += err;
                    for j in 0..d {
                        gw[c * d + j] += err * xi[j];
                    }
                }
            }
            let inv = 1.0 / n as f64;
            for k in 0..w.len() {
                w[k] -= lr * (gw[k] * inv + l2 * w[k]);
            }
            for c in 0..classes {
                b[c] -= lr * gb[c] * inv;
            }
        }
        Self { classes, dim: d, w, b }
    }

    /// Predicted class of one feature vector.
    pub fn predict(&self, xi: &[f64]) -> u32 {
        let mut probs = vec![0.0f64; self.classes];
        softmax_logits(&self.w, &self.b, xi, self.classes, self.dim, &mut probs);
        probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32
    }

    /// Accuracy over `[n][d]` test features.
    pub fn accuracy(&self, x: &[f64], y: &[u32], n: usize) -> f64 {
        let d = self.dim;
        let correct = (0..n)
            .filter(|&i| self.predict(&x[i * d..(i + 1) * d]) == y[i])
            .count();
        correct as f64 / n as f64
    }
}

fn softmax_logits(w: &[f64], b: &[f64], xi: &[f64], classes: usize, d: usize, out: &mut [f64]) {
    for c in 0..classes {
        let mut z = b[c];
        let row = &w[c * d..(c + 1) * d];
        for j in 0..d {
            z += row[j] * xi[j];
        }
        out[c] = z;
    }
    let m = out.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for o in out.iter_mut() {
        *o = (*o - m).exp();
        sum += *o;
    }
    for o in out.iter_mut() {
        *o /= sum;
    }
}

/// Ridge regression solved in closed form via Cholesky.
pub struct RidgeRegression {
    /// Feature dimension (including the implicit bias term appended).
    pub dim: usize,
    /// Output dimension.
    pub out_dim: usize,
    /// Weights `[dim + 1][out_dim]` (last row = bias).
    w: Vec<f64>,
}

impl RidgeRegression {
    /// Fit `y ≈ x W` with L2 penalty `lambda` (bias unpenalised).
    pub fn fit(x: &[f64], y: &[f64], n: usize, d: usize, out_dim: usize, lambda: f64) -> Self {
        assert_eq!(x.len(), n * d);
        assert_eq!(y.len(), n * out_dim);
        let da = d + 1; // augmented with bias column
        // Normal equations: (Xᵀ X + λI) W = Xᵀ Y.
        let mut xtx = vec![0.0f64; da * da];
        let mut xty = vec![0.0f64; da * out_dim];
        for i in 0..n {
            let xi = &x[i * d..(i + 1) * d];
            for a in 0..da {
                let va = if a < d { xi[a] } else { 1.0 };
                for b_ in a..da {
                    let vb = if b_ < d { xi[b_] } else { 1.0 };
                    xtx[a * da + b_] += va * vb;
                }
                for o in 0..out_dim {
                    xty[a * out_dim + o] += va * y[i * out_dim + o];
                }
            }
        }
        for a in 0..da {
            for b_ in 0..a {
                xtx[a * da + b_] = xtx[b_ * da + a];
            }
        }
        for a in 0..d {
            xtx[a * da + a] += lambda;
        }
        xtx[(da - 1) * da + (da - 1)] += 1e-9; // keep bias row SPD
        let chol = cholesky(&xtx, da).expect("XtX + λI must be SPD");
        let mut w = vec![0.0f64; da * out_dim];
        let mut rhs = vec![0.0f64; da];
        let mut sol = vec![0.0f64; da];
        for o in 0..out_dim {
            for a in 0..da {
                rhs[a] = xty[a * out_dim + o];
            }
            chol_solve(&chol, da, &rhs, &mut sol);
            for a in 0..da {
                w[a * out_dim + o] = sol[a];
            }
        }
        Self { dim: d, out_dim, w }
    }

    /// Predict outputs for one feature vector.
    pub fn predict(&self, xi: &[f64], out: &mut [f64]) {
        assert_eq!(xi.len(), self.dim);
        assert_eq!(out.len(), self.out_dim);
        let da = self.dim + 1;
        for o in 0..self.out_dim {
            let mut acc = self.w[(da - 1) * self.out_dim + o]; // bias
            for j in 0..self.dim {
                acc += xi[j] * self.w[j * self.out_dim + o];
            }
            out[o] = acc;
        }
    }

    /// Mean squared error over `[n][d]` features / `[n][out]` targets.
    pub fn mse(&self, x: &[f64], y: &[f64], n: usize) -> f64 {
        let mut pred = vec![0.0; self.out_dim];
        let mut acc = 0.0;
        for i in 0..n {
            self.predict(&x[i * self.dim..(i + 1) * self.dim], &mut pred);
            for o in 0..self.out_dim {
                acc += (pred[o] - y[i * self.out_dim + o]).powi(2);
            }
        }
        acc / (n * self.out_dim) as f64
    }
}

/// Lower-triangular Cholesky factor of an SPD matrix (row-major `n×n`).
fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `L Lᵀ x = b` given the Cholesky factor.
fn chol_solve(l: &[f64], n: usize, b: &[f64], x: &mut [f64]) {
    // Forward: L y = b.
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    // Backward: Lᵀ x = y.
    for i in (0..n).rev() {
        let mut sum = x[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
}

// ---------------------------------------------------------------------------
// High-level metric entry points
// ---------------------------------------------------------------------------

const SIG_DEPTH: usize = 3;

/// Real-vs-fake classification accuracy (Appendix F.1).
///
/// Combines real and fake series, takes an 80/20 split, trains a classifier
/// on the 80%, reports accuracy on the 20%. `0.5` means indistinguishable
/// (best possible generator); `1.0` means trivially separable.
pub fn real_fake_accuracy(real: &TimeSeriesDataset, fake: &TimeSeriesDataset, seed: u64) -> f64 {
    assert_eq!(real.channels, fake.channels);
    assert_eq!(real.seq_len, fake.seq_len);
    let d = super::sig_dim(real.channels + 1, SIG_DEPTH);
    let n = real.n + fake.n;
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..real.n {
        x.extend(series_features(real.series(i), real.seq_len, real.channels, SIG_DEPTH));
        y.push(1u32);
    }
    for i in 0..fake.n {
        x.extend(series_features(fake.series(i), fake.seq_len, fake.channels, SIG_DEPTH));
        y.push(0u32);
    }
    // Shuffle.
    let mut rng = SplitPrng::new(seed);
    for i in (1..n).rev() {
        let j = (rng.next_u64() % (i as u64 + 1)) as usize;
        for k in 0..d {
            x.swap(i * d + k, j * d + k);
        }
        y.swap(i, j);
    }
    let n_train = (n * 4) / 5;
    let fit = fit_standardise(&mut x[..n_train * d], n_train, d);
    apply_standardise(&mut x[n_train * d..], n - n_train, d, &fit);
    let model =
        LogisticRegression::train(&x[..n_train * d], &y[..n_train], n_train, d, 2, 300, 0.5, 1e-3);
    model.accuracy(&x[n_train * d..], &y[n_train..], n - n_train)
}

/// Label-classification TSTR accuracy (Appendix F.1): train a classifier on
/// *generated* labelled data, evaluate on *real* test data. Higher = better.
pub fn label_accuracy_tstr(
    fake: &TimeSeriesDataset,
    real_test: &TimeSeriesDataset,
    classes: usize,
) -> f64 {
    let d = super::sig_dim(fake.channels + 1, SIG_DEPTH);
    let yl = fake.labels.as_ref().expect("fake data must carry labels");
    let mut x = Vec::with_capacity(fake.n * d);
    for i in 0..fake.n {
        x.extend(series_features(fake.series(i), fake.seq_len, fake.channels, SIG_DEPTH));
    }
    let fit = fit_standardise(&mut x, fake.n, d);
    let model = LogisticRegression::train(&x, yl, fake.n, d, classes, 400, 0.5, 1e-3);
    let yt = real_test.labels.as_ref().expect("real data must carry labels");
    let mut xt = Vec::with_capacity(real_test.n * d);
    for i in 0..real_test.n {
        xt.extend(series_features(
            real_test.series(i),
            real_test.seq_len,
            real_test.channels,
            SIG_DEPTH,
        ));
    }
    apply_standardise(&mut xt, real_test.n, d, &fit);
    model.accuracy(&xt, yt, real_test.n)
}

/// Prediction TSTR loss (Appendix F.1): fit a forecaster on generated data —
/// signature of the first 80% of each series → values of the last 20% —
/// and evaluate its MSE on real test data. Lower = better.
pub fn prediction_loss_tstr(fake: &TimeSeriesDataset, real_test: &TimeSeriesDataset) -> f64 {
    assert_eq!(fake.channels, real_test.channels);
    assert_eq!(fake.seq_len, real_test.seq_len);
    let head = (fake.seq_len * 4) / 5;
    let tail = fake.seq_len - head;
    let d = super::sig_dim(fake.channels + 1, SIG_DEPTH);
    let out_dim = tail * fake.channels;
    let build = |ds: &TimeSeriesDataset| -> (Vec<f64>, Vec<f64>) {
        let mut x = Vec::with_capacity(ds.n * d);
        let mut y = Vec::with_capacity(ds.n * out_dim);
        for i in 0..ds.n {
            let s = ds.series(i);
            x.extend(series_features(&s[..head * ds.channels], head, ds.channels, SIG_DEPTH));
            for v in &s[head * ds.channels..] {
                y.push(*v as f64);
            }
        }
        (x, y)
    };
    let (mut xf, yf) = build(fake);
    let fit = fit_standardise(&mut xf, fake.n, d);
    let model = RidgeRegression::fit(&xf, &yf, fake.n, d, out_dim, 1e-2);
    let (mut xr, yr) = build(real_test);
    apply_standardise(&mut xr, real_test.n, d, &fit);
    model.mse(&xr, &yr, real_test.n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::air::{self, AirParams};
    use crate::data::ou::{self, OuParams};

    #[test]
    fn cholesky_solves_spd_system() {
        // A = M Mᵀ + I for random-ish M.
        let m = [1.0, 2.0, 0.0, 3.0, 1.0, 4.0, 2.0, 2.0, 5.0];
        let n = 3;
        let mut a = vec![0.0; 9];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    a[i * n + j] += m[i * n + k] * m[j * n + k];
                }
            }
            a[i * n + i] += 1.0;
        }
        let l = cholesky(&a, n).unwrap();
        let b = [1.0, -2.0, 0.5];
        let mut x = [0.0; 3];
        chol_solve(&l, n, &b, &mut x);
        // Check A x = b.
        for i in 0..n {
            let mut acc = 0.0;
            for j in 0..n {
                acc += a[i * n + j] * x[j];
            }
            assert!((acc - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn logistic_separates_linearly_separable() {
        // Two Gaussian blobs.
        let mut rng = SplitPrng::new(3);
        let n = 200;
        let d = 2;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let (a, b) = rng.next_normal_pair();
            let cls = (i % 2) as u32;
            let shift = if cls == 1 { 3.0 } else { -3.0 };
            x.push(a + shift);
            x.push(b);
            y.push(cls);
        }
        let model = LogisticRegression::train(&x, &y, n, d, 2, 200, 0.5, 1e-4);
        assert!(model.accuracy(&x, &y, n) > 0.95);
    }

    #[test]
    fn ridge_recovers_linear_map() {
        let mut rng = SplitPrng::new(5);
        let n = 100;
        let d = 3;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let (a, b) = rng.next_normal_pair();
            let (c, _) = rng.next_normal_pair();
            x.extend([a, b, c]);
            y.push(2.0 * a - b + 0.5 * c + 1.0);
        }
        let model = RidgeRegression::fit(&x, &y, n, d, 1, 1e-6);
        assert!(model.mse(&x, &y, n) < 1e-6);
    }

    #[test]
    fn real_fake_near_half_for_same_law() {
        let a = ou::generate(300, 1, OuParams::default());
        let b = ou::generate(300, 2, OuParams::default());
        let acc = real_fake_accuracy(&a, &b, 7);
        assert!(acc < 0.68, "same-law accuracy {acc}");
    }

    #[test]
    fn real_fake_high_for_different_law() {
        let a = ou::generate(300, 1, OuParams::default());
        let mut p = OuParams::default();
        p.chi = 1.5;
        let b = ou::generate(300, 2, p);
        let acc = real_fake_accuracy(&a, &b, 7);
        assert!(acc > 0.8, "different-law accuracy {acc}");
    }

    #[test]
    fn label_tstr_beats_chance_on_separable_data() {
        let train = air::generate(600, 1, AirParams::default());
        let test = air::generate(240, 2, AirParams::default());
        let acc = label_accuracy_tstr(&train, &test, 12);
        assert!(acc > 0.3, "12-class accuracy {acc} (chance = 0.083)");
    }

    #[test]
    fn prediction_tstr_sane() {
        let train = ou::generate(400, 1, OuParams::default());
        let test = ou::generate(150, 2, OuParams::default());
        let mse = prediction_loss_tstr(&train, &test);
        // OU tails are predictable to within the stationary variance (~0.8).
        assert!(mse < 1.5, "mse={mse}");
        assert!(mse > 0.0);
    }
}
