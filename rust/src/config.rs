//! Experiment configuration.
//!
//! Configurations are JSON files (parsed with the in-tree [`crate::util::json`]
//! module) with CLI overrides applied on top — see `configs/*.json` for the
//! shipped presets matching the paper's experiments. Every trainer in
//! [`crate::coordinator`] is driven by one of these structs.

use crate::solvers::{AdmitPolicy, ServeConfig};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Which solver an experiment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// The paper's reversible Heun method (1 field evaluation / step).
    ReversibleHeun,
    /// The midpoint baseline (2 evaluations / step).
    Midpoint,
    /// Standard Heun (2 evaluations / step).
    Heun,
}

impl SolverKind {
    /// Parse from the manifest/CLI string form.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "reversible_heun" | "revheun" => Ok(Self::ReversibleHeun),
            "midpoint" => Ok(Self::Midpoint),
            "heun" => Ok(Self::Heun),
            other => anyhow::bail!("unknown solver '{other}'"),
        }
    }

    /// String form used in artifact names.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::ReversibleHeun => "reversible_heun",
            Self::Midpoint => "midpoint",
            Self::Heun => "heun",
        }
    }
}

/// Numeric precision of the training step's SDE solves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainPrecision {
    /// Every solve widens θ/φ and the Brownian grid to `f64` and runs on
    /// the 4-wide lanes — the bit-pinned baseline.
    F64,
    /// Forward solves run on the 8-wide `f32` lanes; adjoints backpropagate
    /// exactly (in `f64`) through the widened tape of the `f32` forward
    /// (Micikevicius et al., *Mixed Precision Training*: master weights and
    /// gradient accumulation stay in higher precision).
    Mixed,
}

impl TrainPrecision {
    /// Parse from the manifest/CLI string form.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f64" | "double" => Ok(Self::F64),
            "mixed" | "f32" => Ok(Self::Mixed),
            other => anyhow::bail!("unknown precision '{other}'"),
        }
    }

    /// String form used in artifact names.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::F64 => "f64",
            Self::Mixed => "mixed",
        }
    }
}

/// Which dataset an experiment trains on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Time-dependent Ornstein–Uhlenbeck (Appendix F.7).
    Ou,
    /// SGD weight trajectories (Appendix F.3 substitute).
    Weights,
    /// Air-quality-like bivariate daily series (Appendix F.4 substitute).
    Air,
}

impl DatasetKind {
    /// Parse from string.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "ou" => Ok(Self::Ou),
            "weights" => Ok(Self::Weights),
            "air" => Ok(Self::Air),
            other => anyhow::bail!("unknown dataset '{other}'"),
        }
    }

    /// String form.
    pub fn as_str(&self) -> &'static str {
        match self {
            Self::Ou => "ou",
            Self::Weights => "weights",
            Self::Air => "air",
        }
    }

    /// (seq_len, channels) of the dataset.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            Self::Ou => (32, 1),
            Self::Weights => (50, 1),
            Self::Air => (24, 2),
        }
    }
}

/// Full training configuration (defaults are the scaled-down versions of the
/// paper's hyperparameters — Appendix F — sized for CPU).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Dataset to train on.
    pub dataset: DatasetKind,
    /// SDE solver.
    pub solver: SolverKind,
    /// Training steps (generator steps for GANs).
    pub steps: usize,
    /// Batch size.
    pub batch: usize,
    /// Number of dataset series to generate.
    pub data_size: usize,
    /// Learning rate for the "initial" parameter group (ζ_θ, ξ_φ).
    pub lr_init: f32,
    /// Learning rate for the vector-field parameter group.
    pub lr_field: f32,
    /// Whether the discriminator is Lipschitz-clipped (Section 5). When
    /// false, an R1-style gradient penalty executable is used instead
    /// (the Table-11 baseline).
    pub clip: bool,
    /// RNG seed.
    pub seed: u64,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Per-step Brownian noise via the Brownian Interval (true) or the
    /// Virtual Brownian Tree baseline (false) — the Table-10 toggle.
    pub brownian_interval: bool,
    /// Initialisation scale α for the initial-condition networks (eq. 33).
    pub alpha: f32,
    /// Initialisation scale β for the vector-field networks (eq. 33).
    pub beta: f32,
    /// Solve precision of the training step ([`TrainPrecision::F64`] keeps
    /// every existing bitwise pin; [`TrainPrecision::Mixed`] runs forward
    /// solves on the 8-wide `f32` lanes with exact `f64` adjoints).
    pub precision: TrainPrecision,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Ou,
            solver: SolverKind::ReversibleHeun,
            steps: 300,
            batch: 128,
            data_size: 1024,
            lr_init: 1.6e-3,
            lr_field: 2.0e-4,
            clip: true,
            seed: 42,
            artifacts_dir: "artifacts".to_string(),
            brownian_interval: true,
            alpha: 1.0,
            beta: 0.5,
            precision: TrainPrecision::F64,
        }
    }
}

impl TrainConfig {
    /// Load from a JSON file, then apply CLI overrides.
    pub fn load(path: Option<&str>, args: &mut Args) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("reading config {p}: {e}"))?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))?;
            cfg.apply_json(&j)?;
        }
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    /// Apply fields present in a JSON object.
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(s) = j.get("dataset").and_then(Json::as_str) {
            self.dataset = DatasetKind::parse(s)?;
        }
        if let Some(s) = j.get("solver").and_then(Json::as_str) {
            self.solver = SolverKind::parse(s)?;
        }
        let num = |k: &str, dst: &mut f64| {
            if let Some(v) = j.get(k).and_then(Json::as_f64) {
                *dst = v;
            }
        };
        let mut f = self.steps as f64;
        num("steps", &mut f);
        self.steps = f as usize;
        f = self.batch as f64;
        num("batch", &mut f);
        self.batch = f as usize;
        f = self.data_size as f64;
        num("data_size", &mut f);
        self.data_size = f as usize;
        f = self.lr_init as f64;
        num("lr_init", &mut f);
        self.lr_init = f as f32;
        f = self.lr_field as f64;
        num("lr_field", &mut f);
        self.lr_field = f as f32;
        f = self.seed as f64;
        num("seed", &mut f);
        self.seed = f as u64;
        f = self.alpha as f64;
        num("alpha", &mut f);
        self.alpha = f as f32;
        f = self.beta as f64;
        num("beta", &mut f);
        self.beta = f as f32;
        if let Some(Json::Bool(b)) = j.get("clip") {
            self.clip = *b;
        }
        if let Some(Json::Bool(b)) = j.get("brownian_interval") {
            self.brownian_interval = *b;
        }
        if let Some(s) = j.get("artifacts_dir").and_then(Json::as_str) {
            self.artifacts_dir = s.to_string();
        }
        if let Some(s) = j.get("precision").and_then(Json::as_str) {
            self.precision = TrainPrecision::parse(s)?;
        }
        Ok(())
    }

    /// Apply CLI overrides (`--steps`, `--solver`, ...).
    pub fn apply_args(&mut self, args: &mut Args) -> anyhow::Result<()> {
        if let Some(s) = args.get("dataset") {
            self.dataset = DatasetKind::parse(&s)?;
        }
        if let Some(s) = args.get("solver") {
            self.solver = SolverKind::parse(&s)?;
        }
        self.steps = args.get_parse_or("steps", self.steps);
        self.batch = args.get_parse_or("batch", self.batch);
        self.data_size = args.get_parse_or("data-size", self.data_size);
        self.seed = args.get_parse_or("seed", self.seed);
        self.lr_init = args.get_parse_or("lr-init", self.lr_init);
        self.lr_field = args.get_parse_or("lr-field", self.lr_field);
        if args.flag("no-clip") {
            self.clip = false;
        }
        if args.flag("virtual-brownian-tree") {
            self.brownian_interval = false;
        }
        self.artifacts_dir = args.get_or("artifacts", &self.artifacts_dir);
        self.alpha = args.get_parse_or("alpha", self.alpha);
        self.beta = args.get_parse_or("beta", self.beta);
        if let Some(s) = args.get("precision") {
            self.precision = TrainPrecision::parse(&s)?;
        }
        Ok(())
    }
}

/// Serving-engine tuning knobs shared by the benches, the Monte-Carlo
/// pricing example and serving binaries — the CLI-facing subset of
/// [`ServeConfig`] (the solve grid stays with the caller, it is the
/// model's horizon, not a tuning knob).
#[derive(Clone, Copy, Debug)]
pub struct ServeTuning {
    /// Mega-batch capacity in lanes per admission round.
    pub max_batch: usize,
    /// Worker threads; `0` means one per core.
    pub threads: usize,
    /// Lanes per work unit.
    pub chunk: usize,
    /// Admission-packing policy.
    pub policy: AdmitPolicy,
    /// Per-round lane cap of one request (`0` = `max_batch`).
    pub shard_width: usize,
    /// Priority-lane width.
    pub priority_width: usize,
    /// Resident-session cap (`0` = unlimited).
    pub max_sessions: usize,
    /// Wall-clock idle TTL for session Brownian state, in milliseconds
    /// (`0` = never expire). Expired sessions rebuild bit-identically.
    pub session_ttl_ms: u64,
}

impl Default for ServeTuning {
    fn default() -> Self {
        Self {
            max_batch: 256,
            threads: 0,
            chunk: 64,
            policy: AdmitPolicy::Packed,
            shard_width: 0,
            priority_width: 8,
            max_sessions: 0,
            session_ttl_ms: 0,
        }
    }
}

impl ServeTuning {
    /// Apply CLI overrides (`--max-batch`, `--serve-threads`, `--chunk`,
    /// `--policy`, `--shard-width`, `--priority-width`, `--max-sessions`,
    /// `--session-ttl-ms`).
    pub fn apply_args(&mut self, args: &mut Args) -> anyhow::Result<()> {
        self.max_batch = args.get_parse_or("max-batch", self.max_batch);
        self.threads = args.get_parse_or("serve-threads", self.threads);
        self.chunk = args.get_parse_or("chunk", self.chunk);
        self.shard_width = args.get_parse_or("shard-width", self.shard_width);
        self.priority_width = args.get_parse_or("priority-width", self.priority_width);
        self.max_sessions = args.get_parse_or("max-sessions", self.max_sessions);
        self.session_ttl_ms = args.get_parse_or("session-ttl-ms", self.session_ttl_ms);
        if let Some(s) = args.get("policy") {
            self.policy = match AdmitPolicy::parse(&s) {
                Some(p) => p,
                None => anyhow::bail!("unknown admission policy '{s}'"),
            };
        }
        Ok(())
    }

    /// Build a [`ServeConfig`] over the caller's solve grid with these
    /// knobs applied (`threads == 0` keeps the one-per-core default).
    pub fn build(&self, t0: f64, t1: f64, n_steps: usize) -> ServeConfig {
        let mut cfg = ServeConfig::new(t0, t1, n_steps);
        cfg.max_batch = self.max_batch;
        if self.threads > 0 {
            cfg.threads = self.threads;
        }
        cfg.chunk = self.chunk;
        cfg.policy = self.policy;
        cfg.shard_width = self.shard_width;
        cfg.priority_width = self.priority_width;
        cfg.max_sessions = self.max_sessions;
        cfg.session_ttl_ms = self.session_ttl_ms;
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = TrainConfig::default();
        assert_eq!(c.dataset, DatasetKind::Ou);
        assert_eq!(c.solver, SolverKind::ReversibleHeun);
        assert!(c.clip);
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{"dataset": "air", "solver": "midpoint", "steps": 50, "clip": false}"#,
        )
        .unwrap();
        let mut c = TrainConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.dataset, DatasetKind::Air);
        assert_eq!(c.solver, SolverKind::Midpoint);
        assert_eq!(c.steps, 50);
        assert!(!c.clip);
    }

    #[test]
    fn cli_overrides() {
        let mut args = Args::parse(
            "train --solver heun --steps 9 --no-clip"
                .split_whitespace()
                .map(String::from),
        );
        let mut c = TrainConfig::default();
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.solver, SolverKind::Heun);
        assert_eq!(c.steps, 9);
        assert!(!c.clip);
        assert!(args.finish().is_ok());
    }

    #[test]
    fn precision_knob() {
        assert_eq!(TrainConfig::default().precision, TrainPrecision::F64);
        let j = Json::parse(r#"{"precision": "mixed"}"#).unwrap();
        let mut c = TrainConfig::default();
        c.apply_json(&j).unwrap();
        assert_eq!(c.precision, TrainPrecision::Mixed);
        let mut args = Args::parse(
            "train --precision f64".split_whitespace().map(String::from),
        );
        c.apply_args(&mut args).unwrap();
        assert_eq!(c.precision, TrainPrecision::F64);
        assert!(args.finish().is_ok());
        for p in [TrainPrecision::F64, TrainPrecision::Mixed] {
            assert_eq!(TrainPrecision::parse(p.as_str()).unwrap(), p);
        }
        assert!(TrainPrecision::parse("bf16").is_err());
    }

    #[test]
    fn solver_roundtrip() {
        for s in [SolverKind::ReversibleHeun, SolverKind::Midpoint, SolverKind::Heun] {
            assert_eq!(SolverKind::parse(s.as_str()).unwrap(), s);
        }
        assert!(SolverKind::parse("rk4").is_err());
    }

    #[test]
    fn serve_tuning_cli_and_build() {
        let mut args = Args::parse(
            "serve --max-batch 128 --policy fifo --shard-width 32 --max-sessions 4 \
             --session-ttl-ms 5000"
                .split_whitespace()
                .map(String::from),
        );
        let mut t = ServeTuning::default();
        assert_eq!(t.policy, AdmitPolicy::Packed);
        assert_eq!(t.session_ttl_ms, 0, "TTL is off by default");
        t.apply_args(&mut args).unwrap();
        assert!(args.finish().is_ok());
        assert_eq!(t.max_batch, 128);
        assert_eq!(t.policy, AdmitPolicy::Fifo);
        assert_eq!(t.shard_width, 32);
        assert_eq!(t.max_sessions, 4);
        assert_eq!(t.session_ttl_ms, 5000);
        let cfg = t.build(0.0, 2.0, 16);
        assert_eq!(cfg.max_batch, 128);
        assert_eq!(cfg.policy, AdmitPolicy::Fifo);
        assert_eq!(cfg.shard_width, 32);
        assert_eq!(cfg.max_sessions, 4);
        assert_eq!(cfg.session_ttl_ms, 5000);
        assert_eq!(cfg.n_steps, 16);
        assert!(cfg.threads >= 1, "threads 0 keeps the per-core default");
        // Unknown policies are a structured error, not a silent default.
        let mut bad = Args::parse(
            "serve --policy lifo".split_whitespace().map(String::from),
        );
        assert!(ServeTuning::default().apply_args(&mut bad).is_err());
        for p in [AdmitPolicy::Fifo, AdmitPolicy::Packed] {
            assert_eq!(AdmitPolicy::parse(p.as_str()), Some(p));
        }
    }

    #[test]
    fn dataset_shapes() {
        assert_eq!(DatasetKind::Ou.shape(), (32, 1));
        assert_eq!(DatasetKind::Air.shape(), (24, 2));
        assert_eq!(DatasetKind::Weights.shape(), (50, 1));
    }
}
