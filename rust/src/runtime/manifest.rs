//! The artifact manifest: the contract between `python/compile/aot.py`
//! (which writes it) and the Rust coordinator (which consumes it).
//!
//! ```json
//! {
//!   "version": 1,
//!   "executables": {
//!     "gan_ou_revheun_fwd_step": {
//!       "file": "gan_ou_revheun_fwd_step.hlo.txt",
//!       "inputs":  [{"name": "state_z", "shape": [128, 32], "dtype": "f32"}, ...],
//!       "outputs": [{"name": "state_z", "shape": [128, 32], "dtype": "f32"}, ...]
//!     }, ...
//!   },
//!   "models": {
//!     "gan_ou": {
//!       "gen_layout": [...], "disc_layout": [...],
//!       "hyper": {"hidden": 32, "state": 32, "noise": 4, ...}
//!     }, ...
//!   }
//! }
//! ```

use crate::nn::ParamLayout;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Shape + dtype of one executable input/output.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    /// Argument name (documentation only; order is what matters).
    pub name: String,
    /// Dimensions.
    pub shape: Vec<usize>,
    /// `"f32"` or `"f64"`.
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True for zero-sized tensors.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("<anon>")
                .to_string(),
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
        })
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    /// HLO text file, relative to the artifact dir.
    pub file: String,
    /// Ordered input tensors.
    pub inputs: Vec<TensorSpec>,
    /// Ordered output tensors.
    pub outputs: Vec<TensorSpec>,
}

/// Per-model metadata (parameter layouts + hyperparameters).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Generator parameter layout.
    pub gen_layout: ParamLayout,
    /// Discriminator / auxiliary-network layout (empty for plain models).
    pub disc_layout: ParamLayout,
    /// Free-form numeric hyperparameters recorded at lowering time.
    pub hyper: BTreeMap<String, f64>,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Executables by name.
    pub execs: BTreeMap<String, ExecSpec>,
    /// Models by name.
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Load and parse `path`.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading manifest {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        Self::from_json(&j)
    }

    /// Parse from JSON.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut out = Manifest::default();
        if let Some(execs) = j.get("executables").and_then(Json::as_obj) {
            for (name, spec) in execs {
                let file = spec
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: missing file"))?
                    .to_string();
                let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                    spec.get(key)
                        .and_then(Json::as_arr)
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect()
                };
                out.execs.insert(
                    name.clone(),
                    ExecSpec { file, inputs: parse_specs("inputs")?, outputs: parse_specs("outputs")? },
                );
            }
        }
        if let Some(models) = j.get("models").and_then(Json::as_obj) {
            for (name, spec) in models {
                let gen_layout = match spec.get("gen_layout") {
                    Some(l) => ParamLayout::from_json(l)?,
                    None => ParamLayout::default(),
                };
                let disc_layout = match spec.get("disc_layout") {
                    Some(l) => ParamLayout::from_json(l)?,
                    None => ParamLayout::default(),
                };
                let mut hyper = BTreeMap::new();
                if let Some(h) = spec.get("hyper").and_then(Json::as_obj) {
                    for (k, v) in h {
                        if let Some(x) = v.as_f64() {
                            hyper.insert(k.clone(), x);
                        }
                    }
                }
                out.models.insert(name.clone(), ModelSpec { gen_layout, disc_layout, hyper });
            }
        }
        Ok(out)
    }

    /// Fetch a model spec or error.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("no model '{name}' in manifest"))
    }

    /// Hyperparameter lookup with error context.
    pub fn hyper(&self, model: &str, key: &str) -> Result<f64> {
        self.model(model)?
            .hyper
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("model '{model}': missing hyper '{key}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "executables": {
            "fwd": {
                "file": "fwd.hlo.txt",
                "inputs": [
                    {"name": "z", "shape": [4, 8], "dtype": "f32"},
                    {"name": "params", "shape": [100], "dtype": "f32"}
                ],
                "outputs": [{"name": "z_next", "shape": [4, 8], "dtype": "f32"}]
            }
        },
        "models": {
            "gan_ou": {
                "gen_layout": [
                    {"name": "w", "shape": [2, 3], "offset": 0, "fan_in": 2, "kind": "weight"}
                ],
                "hyper": {"hidden": 32, "dt": 0.03125}
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let e = &m.execs["fwd"];
        assert_eq!(e.file, "fwd.hlo.txt");
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].len(), 32);
        assert_eq!(m.hyper("gan_ou", "hidden").unwrap(), 32.0);
        assert_eq!(m.model("gan_ou").unwrap().gen_layout.total, 6);
        assert!(m.model("nope").is_err());
        assert!(m.hyper("gan_ou", "nope").is_err());
    }

    #[test]
    fn empty_manifest_ok() {
        let m = Manifest::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert!(m.execs.is_empty());
    }
}
