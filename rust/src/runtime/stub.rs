//! Stub runtime backend (default build, no `pjrt` feature).
//!
//! Loads the manifest so metadata-only paths (trainer construction, `info`,
//! layout queries) work, but refuses to execute: running the AOT artifacts
//! needs the XLA/PJRT runtime, which the offline build does not link.

use super::Manifest;
use anyhow::Result;

/// Manifest-only runtime; `run_f32`/`run_f64` always error.
pub struct Runtime {
    /// Parsed manifest.
    pub manifest: Manifest,
}

impl Runtime {
    /// Read `dir/manifest.json`; no PJRT client is created.
    pub fn new(dir: &str) -> Result<Self> {
        let manifest = Manifest::load(&format!("{dir}/manifest.json"))?;
        Ok(Self { manifest })
    }

    /// Platform string (e.g. for logs).
    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// Execution is unavailable in the stub backend.
    pub fn run_f32(
        &mut self,
        name: &str,
        _inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!(
            "cannot execute '{name}': built without the `pjrt` feature \
             (rebuild with `cargo build --features pjrt` and real xla bindings)"
        )
    }

    /// Execution is unavailable in the stub backend.
    pub fn run_f64(
        &mut self,
        name: &str,
        _inputs: &[(&[f64], &[usize])],
    ) -> Result<Vec<Vec<f64>>> {
        anyhow::bail!(
            "cannot execute '{name}': built without the `pjrt` feature \
             (rebuild with `cargo build --features pjrt` and real xla bindings)"
        )
    }

    /// Check whether the artifact directory exists and contains a manifest —
    /// used by binaries to emit a friendly "run `make artifacts`" error.
    pub fn artifacts_present(dir: &str) -> bool {
        std::path::Path::new(dir).join("manifest.json").exists()
    }
}
