//! Runtime backends for the AOT-compiled JAX programs.
//!
//! `python/compile/aot.py` lowers each Layer-2 entry point to **HLO text**
//! (not a serialized proto — jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids) and records
//! an `artifacts/manifest.json` describing every executable's inputs,
//! outputs and parameter layout.
//!
//! Two interchangeable backends provide [`Runtime`]:
//!
//! * **`pjrt` feature enabled** ([`pjrt`]): wraps the `xla` crate —
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//!   `execute`, with compiled executables cached per artifact.
//! * **default build** ([`stub`]): parses the manifest and answers metadata
//!   queries but returns a clear error from `run_f32`/`run_f64`, so the
//!   crate builds and tests offline without linking XLA.

mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use manifest::{ExecSpec, Manifest, TensorSpec};
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use anyhow::{Context, Result};

/// Convenience: load a runtime, with a friendly error if artifacts are
/// missing.
pub fn load_runtime(dir: &str) -> Result<Runtime> {
    anyhow::ensure!(
        Runtime::artifacts_present(dir),
        "no artifacts found in '{dir}' — run `make artifacts` first"
    );
    Runtime::new(dir).context("loading PJRT runtime")
}
