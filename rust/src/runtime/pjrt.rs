//! PJRT runtime backend (`pjrt` feature): loading and executing the
//! AOT-compiled JAX programs through the `xla` crate.

use super::Manifest;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// A loaded PJRT runtime over a directory of HLO-text artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Parsed manifest.
    pub manifest: Manifest,
    dir: std::path::PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and read `dir/manifest.json`.
    pub fn new(dir: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let manifest = Manifest::load(&format!("{dir}/manifest.json"))?;
        Ok(Self { client, manifest, dir: dir.into(), cache: HashMap::new() })
    }

    /// Platform string (e.g. `"cpu"`), for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable named in the manifest.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .execs
                .get(name)
                .ok_or_else(|| anyhow!("no executable '{name}' in manifest"))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute `name` on f32 inputs with the given shapes; returns the
    /// flattened f32 outputs (the executables are lowered with
    /// `return_tuple=True`, so outputs arrive as a tuple).
    ///
    /// Shapes are `[dims...]`; an empty dims list is a scalar.
    pub fn run_f32(
        &mut self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let tuple = out_lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing tuple of {name}: {e:?}"))?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Execute with f64 inputs/outputs (the gradient-error experiment runs
    /// in double precision, matching the paper's Figure-2 error floor).
    pub fn run_f64(
        &mut self,
        name: &str,
        inputs: &[(&[f64], &[usize])],
    ) -> Result<Vec<Vec<f64>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let tuple = out_lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing tuple of {name}: {e:?}"))?;
        tuple
            .into_iter()
            .map(|l| l.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Check whether the artifact directory exists and contains a manifest —
    /// used by binaries to emit a friendly "run `make artifacts`" error.
    pub fn artifacts_present(dir: &str) -> bool {
        std::path::Path::new(dir).join("manifest.json").exists()
    }
}
