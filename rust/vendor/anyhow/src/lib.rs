//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so instead of pulling the
//! real `anyhow` we vendor the sliver of its API this workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Errors are flat strings — no source
//! chains or backtraces — which is all the coordinator ever formats.

use std::fmt;

/// A string-backed error value (stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend context, anyhow-style (`"context: original"`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` (and the
// second `Context` impl below) coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error::msg(err)
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension trait (stand-in for `anyhow::Context`).
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 1 + 1)
    }

    fn checks(x: i32) -> Result<i32> {
        ensure!(x > 0, "x must be positive, got {x}");
        Ok(x)
    }

    fn parses(s: &str) -> Result<i32> {
        let n: i32 = s.parse()?; // exercises From<ParseIntError>
        Ok(n)
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 2");
        assert_eq!(checks(3).unwrap(), 3);
        assert!(checks(-1).is_err());
        let e: Result<()> = fails().context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: boom 2");
        assert!(parses("12").is_ok());
        let e = parses("nope").context("parsing").unwrap_err();
        assert!(e.to_string().starts_with("parsing: "));
        let single = anyhow!(String::from("plain"));
        assert_eq!(single.to_string(), "plain");
    }
}
