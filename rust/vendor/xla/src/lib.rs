//! Compile-only stub of the `xla` PJRT bindings.
//!
//! The offline build environment cannot link the real XLA/PJRT runtime, but
//! the `pjrt` feature of the `neuralsde` crate still has to type-check. This
//! stub mirrors the subset of the real crate's API the runtime layer uses;
//! every entry point returns an [`XlaError`] explaining how to swap in the
//! real bindings. Replace this directory with the actual `xla` crate (or
//! point the `xla` path dependency at it) to execute AOT artifacts.

use std::borrow::Borrow;

const STUB_MSG: &str =
    "stub xla crate: replace rust/vendor/xla with the real xla/PJRT bindings to execute artifacts";

/// Error type matching the real crate's `{e:?}`-formatted usage.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

fn stub_err<T>() -> Result<T, XlaError> {
    Err(XlaError(STUB_MSG.to_string()))
}

/// Element types transferable to/from literals.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// The real crate builds a CPU PJRT client; the stub always errors.
    pub fn cpu() -> Result<Self, XlaError> {
        stub_err()
    }

    /// Platform string for logs.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile an HLO computation (stub: unreachable, `cpu()` errors first).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        stub_err()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        stub_err()
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A host-side literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Self {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        stub_err()
    }

    /// Split a tuple literal into its elements.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, XlaError> {
        stub_err()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        stub_err()
    }
}

/// A device buffer returned by execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer to the host.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        stub_err()
    }
}

/// A compiled, loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on the given argument literals.
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        stub_err()
    }
}
