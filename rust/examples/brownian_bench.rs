//! Table 2 / 7 / 8 / 9 quick-look: Brownian Interval vs Virtual Brownian
//! Tree over the paper's access patterns. (The full criterion-style sweep
//! lives in `cargo bench --bench tab2_brownian_access`; this example is
//! the interactive version.)
//!
//! ```sh
//! cargo run --release --example brownian_bench -- [--batch 2560] [--intervals 100]
//! ```

use neuralsde::brownian::{BrownianInterval, BrownianSource, VirtualBrownianTree};
use neuralsde::util::bench::BenchTable;
use neuralsde::util::cli::Args;

fn sequential<B: BrownianSource>(src: &mut B, n: usize, out: &mut [f32]) {
    for k in 0..n {
        src.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, out);
    }
}

fn doubly_sequential<B: BrownianSource>(src: &mut B, n: usize, out: &mut [f32]) {
    sequential(src, n, out);
    for k in (0..n).rev() {
        src.increment(k as f64 / n as f64, (k + 1) as f64 / n as f64, out);
    }
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let batch: usize = args.get_parse_or("batch", 2560);
    let n: usize = args.get_parse_or("intervals", 100);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut table = BenchTable::new(
        &format!("Brownian access, batch={batch}, {n} subintervals"),
        32,
        3,
    );
    let mut out = vec![0.0f32; batch];

    table.bench("BrownianInterval/sequential", |i| {
        let mut bi = BrownianInterval::new(0.0, 1.0, batch, i as u64);
        sequential(&mut bi, n, &mut out);
    });
    table.bench("VirtualBrownianTree/sequential", |i| {
        let mut vbt = VirtualBrownianTree::new(0.0, 1.0, batch, i as u64, 1e-5);
        sequential(&mut vbt, n, &mut out);
    });
    table.bench("BrownianInterval/doubly_sequential", |i| {
        let mut bi = BrownianInterval::new(0.0, 1.0, batch, i as u64);
        doubly_sequential(&mut bi, n, &mut out);
    });
    table.bench("VirtualBrownianTree/doubly_sequential", |i| {
        let mut vbt = VirtualBrownianTree::new(0.0, 1.0, batch, i as u64, 1e-5);
        doubly_sequential(&mut vbt, n, &mut out);
    });

    println!("{}", table.render());
    let bi = table.min_of("BrownianInterval/doubly_sequential");
    let vbt = table.min_of("VirtualBrownianTree/doubly_sequential");
    println!("doubly-sequential speedup (BI vs VBT): {:.1}x", vbt / bi);
    Ok(())
}
