//! Figures 5 & 6: strong/weak convergence of the reversible Heun method vs
//! standard Heun on the anharmonic oscillator `dy = sin(y) dt + dW`
//! (Appendix D.4, equation (28)), plus the Appendix-D.5 stability map.
//!
//! Expected shape: both methods show strong order ≈ 1.0 and weak order
//! ≈ 2.0 for this additive-noise SDE.
//!
//! ```sh
//! cargo run --release --example convergence -- [--paths 20000] [--stability]
//! ```

use neuralsde::solvers::systems::Anharmonic;
use neuralsde::solvers::{
    estimate_orders, revheun_stability_bounded, strong_weak_errors, Complex, Heun,
    ReversibleHeun,
};
use neuralsde::util::cli::Args;
use neuralsde::util::json::{obj, Json};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let n_paths: usize = args.get_parse_or("paths", 20_000);
    let stability = args.flag("stability");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let sde = Anharmonic { sigma: 1.0 };
    let steps = [4usize, 8, 16, 32, 64, 128];
    println!("anharmonic oscillator dy = sin(y) dt + dW, y0 = 1, T = 1");
    println!("{n_paths} Monte-Carlo paths; reference = Heun at 10x finest\n");

    let mut reports = Vec::new();
    let pts = strong_weak_errors(
        &sde,
        |s, t0, y0| ReversibleHeun::new(s, t0, y0),
        &steps,
        n_paths,
        1.0,
        1.0,
        2021,
    );
    reports.push(estimate_orders("reversible_heun", pts));
    let pts = strong_weak_errors(&sde, |_s, _t, _y| Heun::new(1, 1), &steps,
                                 n_paths, 1.0, 1.0, 2021);
    reports.push(estimate_orders("heun", pts));

    let mut rows = Vec::new();
    for rep in &reports {
        println!(
            "{:<18} strong order {:.2}   weak order {:.2}",
            rep.solver, rep.strong_order, rep.weak_order
        );
        println!("  {:>6} {:>12} {:>12} {:>12}", "h", "S_N", "E_N", "V_N");
        for p in &rep.points {
            println!(
                "  {:>6.4} {:>12.4e} {:>12.4e} {:>12.4e}",
                p.h, p.strong, p.weak_mean, p.weak_second
            );
            rows.push(obj(vec![
                ("solver", Json::Str(rep.solver.clone())),
                ("h", Json::Num(p.h)),
                ("strong", Json::Num(p.strong)),
                ("weak_mean", Json::Num(p.weak_mean)),
                ("weak_second", Json::Num(p.weak_second)),
            ]));
        }
    }
    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig5_fig6_convergence.json",
                   Json::Arr(rows).to_string_pretty())?;
    println!("\nwrote results/fig5_fig6_convergence.json");

    if stability {
        // Appendix D.5: map the absolute-stability region on a small grid.
        println!("\nstability region (S = bounded, . = unbounded); Theorem D.19");
        for j in (0..13).rev() {
            let im = -1.2 + 0.2 * j as f64;
            let mut row = String::new();
            for i in 0..13 {
                let re = -1.0 + 0.1 * i as f64;
                let ok = revheun_stability_bounded(Complex::new(re, im), 5000, 1e4);
                row.push(if ok { 'S' } else { '.' });
            }
            println!("  im={im:+.1}  {row}");
        }
    }
    Ok(())
}
