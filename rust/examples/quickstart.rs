//! Quickstart: the whole three-layer stack in ~60 lines.
//!
//! Loads the AOT artifacts, builds the OU dataset, trains an SDE-GAN with
//! the reversible Heun method for a handful of steps, and scores the
//! samples. Run with:
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::{evaluate_generator, GanTrainer};
use neuralsde::data::ou::{self, OuParams};
use neuralsde::runtime::load_runtime;

fn main() -> anyhow::Result<()> {
    let cfg = TrainConfig::default();
    let mut rt = load_runtime(&cfg.artifacts_dir)?;
    println!("PJRT platform: {}", rt.platform());

    // Data: the paper's time-dependent OU dataset (Appendix F.7).
    let mut data = ou::generate(512, cfg.seed, OuParams::default());
    data.normalise_initial();
    let (train, _val, test) = data.split();
    println!("dataset: {} train / {} test series", train.n, test.n);

    // Train an SDE-GAN (reversible Heun + Lipschitz clipping).
    let steps = 20;
    let mut trainer = GanTrainer::new(&rt, &cfg, steps)?;
    let mut rng = SplitPrng::new(cfg.seed);
    for step in 0..steps {
        let stats = trainer.train_step(&mut rt, &train, &mut rng)?;
        if step % 5 == 0 || step + 1 == steps {
            println!(
                "step {step:>3}  loss_g {:+.4}  loss_d {:+.4}",
                stats.loss_g, stats.loss_d
            );
        }
    }

    // Generate and score samples.
    let fake = trainer.sample(&mut rt, test.n)?;
    let report = evaluate_generator(&test, &fake, 7);
    println!("after {steps} steps: {}", report.row());
    println!("quickstart OK");
    Ok(())
}
