//! Quickstart: the whole native stack in ~50 lines.
//!
//! Builds the OU dataset, trains an SDE-GAN with the reversible Heun method
//! and the pure-Rust adjoint engine for a handful of steps, and scores the
//! samples. No artifacts or PJRT required — this runs on a fresh checkout:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::{evaluate_generator, GanTrainer};
use neuralsde::data::ou::{self, OuParams};

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default();
    cfg.batch = 64;

    // Data: the paper's time-dependent OU dataset (Appendix F.7).
    let mut data = ou::generate(512, cfg.seed, OuParams::default());
    data.normalise_initial();
    let (train, _val, test) = data.split();
    println!("dataset: {} train / {} test series", train.n, test.n);

    // Train an SDE-GAN (reversible Heun + Lipschitz clipping), natively.
    let steps = 20;
    let mut trainer = GanTrainer::new(&cfg, steps)?;
    let mut rng = SplitPrng::new(cfg.seed);
    for step in 0..steps {
        let stats = trainer.train_step(&train, &mut rng)?;
        if step % 5 == 0 || step + 1 == steps {
            println!(
                "step {step:>3}  loss_g {:+.4}  loss_d {:+.4}",
                stats.loss_g, stats.loss_d
            );
        }
    }

    // Generate and score samples.
    let fake = trainer.sample(test.n)?;
    let report = evaluate_generator(&test, &fake, 7);
    println!("after {steps} steps: {}", report.row());
    println!("quickstart OK");
    Ok(())
}
