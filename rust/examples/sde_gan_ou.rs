//! End-to-end validation driver (Tables 3/11, scaled down).
//!
//! Trains an SDE-GAN on the time-dependent OU dataset for a few hundred
//! optimiser steps through the complete stack — Rust data pipeline →
//! Brownian Interval noise → AOT PJRT gradient executables (O-t-D adjoint)
//! → Adadelta + Lipschitz clipping → SWA — logging the Wasserstein loss
//! curve and the Appendix-F.1 test metrics. Results are appended to
//! `results/sde_gan_ou.json` and summarised in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example sde_gan_ou -- [--steps 300] [--solver midpoint] [--no-clip]
//! ```

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::{evaluate_generator, GanTrainer};
use neuralsde::data::ou::{self, OuParams};
use neuralsde::runtime::load_runtime;
use neuralsde::util::cli::Args;
use neuralsde::util::json::{num_arr, obj, Json};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let mut cfg = TrainConfig::default();
    cfg.apply_args(&mut args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let mut rt = load_runtime(&cfg.artifacts_dir)?;

    let mut data = ou::generate(cfg.data_size, cfg.seed, OuParams::default());
    data.normalise_initial();
    let (train, _val, test) = data.split();
    println!(
        "SDE-GAN / OU — solver={} clip={} steps={} batch(from manifest)",
        cfg.solver.as_str(),
        cfg.clip,
        cfg.steps
    );

    let mut trainer = GanTrainer::new(&rt, &cfg, cfg.steps)?;
    let mut rng = SplitPrng::new(cfg.seed);
    let mut losses_g = Vec::new();
    let mut losses_d = Vec::new();
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let stats = trainer.train_step(&mut rt, &train, &mut rng)?;
        losses_g.push(stats.loss_g as f64);
        losses_d.push(stats.loss_d as f64);
        if step % 25 == 0 || step + 1 == cfg.steps {
            println!(
                "step {step:>4}  loss_g {:+.4}  loss_d {:+.4}  ({:.2}s elapsed)",
                stats.loss_g,
                stats.loss_d,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let train_time = t0.elapsed().as_secs_f64();
    let per_step = train_time / cfg.steps as f64;

    let fake = trainer.sample(&mut rt, test.n)?;
    let report = evaluate_generator(&test, &fake, 7);
    println!("\ntraining time: {train_time:.1}s ({per_step:.3}s/step)");
    println!("test metrics: {}", report.row());

    std::fs::create_dir_all("results")?;
    let out = obj(vec![
        ("experiment", Json::Str("sde_gan_ou".into())),
        ("solver", Json::Str(cfg.solver.as_str().into())),
        ("clip", Json::Bool(cfg.clip)),
        ("steps", Json::Num(cfg.steps as f64)),
        ("train_time_s", Json::Num(train_time)),
        ("s_per_step", Json::Num(per_step)),
        ("real_fake_acc", Json::Num(report.real_fake_acc)),
        ("prediction_loss", Json::Num(report.prediction_loss)),
        ("mmd", Json::Num(report.mmd)),
        ("loss_g_curve", num_arr(&losses_g)),
        ("loss_d_curve", num_arr(&losses_d)),
    ]);
    let path = format!(
        "results/sde_gan_ou_{}_{}.json",
        cfg.solver.as_str(),
        if cfg.clip { "clip" } else { "gp" }
    );
    std::fs::write(&path, out.to_string_pretty())?;
    println!("wrote {path}");
    Ok(())
}
