//! End-to-end validation driver (Tables 3/11, scaled down) — **native**.
//!
//! Trains an SDE-GAN on the time-dependent OU dataset for a few hundred
//! optimiser steps through the complete pure-Rust stack — data pipeline →
//! Brownian Interval noise → batched reversible-Heun solves → native
//! reverse-mode adjoint (per-step cotangents through the neural-CDE
//! discriminator) → Adadelta + Lipschitz clipping → SWA — logging the
//! Wasserstein loss curve and the Appendix-F.1 test metrics. Runs out of
//! the box on the default (stub-runtime) build: no `make artifacts`, no
//! PJRT. Results are appended to `results/sde_gan_ou_*.json`.
//!
//! ```sh
//! cargo run --release --example sde_gan_ou -- [--steps 300] [--no-clip] [--smoke]
//! ```
//!
//! `--smoke` is the CI mode: a handful of steps with asserted invariants
//! (finite losses throughout, a discriminator loss that improves on its
//! first value, clipped discriminator weights).

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::{evaluate_generator, GanTrainer};
use neuralsde::data::ou::{self, OuParams};
use neuralsde::nn::weights_clipped;
use neuralsde::util::cli::Args;
use neuralsde::util::json::{num_arr, obj, Json};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let smoke = args.flag("smoke");
    let mut cfg = TrainConfig::default();
    cfg.apply_args(&mut args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    if smoke {
        cfg.steps = cfg.steps.min(12);
        cfg.batch = cfg.batch.min(32);
        cfg.data_size = cfg.data_size.min(128);
    }

    let mut data = ou::generate(cfg.data_size, cfg.seed, OuParams::default());
    data.normalise_initial();
    let (train, _val, test) = data.split();
    println!(
        "SDE-GAN / OU (native) — solver={} precision={} clip={} steps={} batch={}",
        cfg.solver.as_str(),
        cfg.precision.as_str(),
        cfg.clip,
        cfg.steps,
        cfg.batch
    );

    let mut trainer = GanTrainer::new(&cfg, cfg.steps)?;
    let mut rng = SplitPrng::new(cfg.seed);
    let mut losses_g = Vec::new();
    let mut losses_d = Vec::new();
    let mut retries_total = 0u64;
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let stats = trainer.train_step(&train, &mut rng)?;
        losses_g.push(stats.loss_g as f64);
        losses_d.push(stats.loss_d as f64);
        retries_total += stats.retries as u64;
        if step % 25 == 0 || step + 1 == cfg.steps {
            println!(
                "step {step:>4}  loss_g {:+.4}  loss_d {:+.4}  ({:.2}s elapsed)",
                stats.loss_g,
                stats.loss_d,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let train_time = t0.elapsed().as_secs_f64();
    let per_step = train_time / cfg.steps as f64;

    if smoke {
        assert!(
            losses_g.iter().chain(&losses_d).all(|l| l.is_finite()),
            "non-finite loss in the native training loop"
        );
        let first_d = losses_d[0];
        let best_d = losses_d.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            best_d < first_d,
            "discriminator loss never improved on its first value ({first_d} -> best {best_d})"
        );
        if cfg.clip {
            assert!(
                weights_clipped(trainer.disc_layout(), &trainer.phi, |n| {
                    n.starts_with("f.") || n.starts_with("g.")
                }),
                "discriminator weights escaped the Lipschitz clip region"
            );
        }
        println!(
            "smoke OK: finite losses, improving discriminator, clipped weights \
             (watchdog: {} rollback(s), {} retried step(s))",
            trainer.watchdog_rollbacks(),
            retries_total
        );
    }

    let fake = trainer.sample(test.n)?;
    let report = evaluate_generator(&test, &fake, 7);
    println!("\ntraining time: {train_time:.1}s ({per_step:.3}s/step)");
    println!("test metrics: {}", report.row());

    std::fs::create_dir_all("results")?;
    let out = obj(vec![
        ("experiment", Json::Str("sde_gan_ou".into())),
        ("backend", Json::Str("native".into())),
        ("solver", Json::Str(cfg.solver.as_str().into())),
        ("precision", Json::Str(cfg.precision.as_str().into())),
        ("clip", Json::Bool(cfg.clip)),
        ("steps", Json::Num(cfg.steps as f64)),
        ("watchdog_rollbacks", Json::Num(trainer.watchdog_rollbacks() as f64)),
        ("train_time_s", Json::Num(train_time)),
        ("s_per_step", Json::Num(per_step)),
        ("real_fake_acc", Json::Num(report.real_fake_acc)),
        ("prediction_loss", Json::Num(report.prediction_loss)),
        ("mmd", Json::Num(report.mmd)),
        ("loss_g_curve", num_arr(&losses_g)),
        ("loss_d_curve", num_arr(&losses_d)),
    ]);
    // The f64 path keeps its historical filename; mixed runs get their own.
    let precision_suffix = match cfg.precision {
        neuralsde::config::TrainPrecision::F64 => String::new(),
        neuralsde::config::TrainPrecision::Mixed => format!("_{}", cfg.precision.as_str()),
    };
    let path = format!(
        "results/sde_gan_ou_{}_{}{}.json",
        cfg.solver.as_str(),
        if cfg.clip { "clip" } else { "unconstrained" },
        precision_suffix
    );
    std::fs::write(&path, out.to_string_pretty())?;
    println!("wrote {path}");
    Ok(())
}
