//! Monte-Carlo basket-option pricing through the serving engine at 10⁶ paths.
//!
//! Prices a European basket call under the diagonal-noise [`MarketModel`]
//! (martingale dynamics: zero drift, per-asset sigmoid local volatility) by
//! submitting one million paths as a **single sharded mega-request** to
//! [`ServeEngine`] on the f32×8 fast path. While the mega-request drains
//! across admission rounds, width-1 interactive probes ride the priority
//! lane — the example measures their round-trip latency to show that a
//! million-path batch does not head-of-line-block interactive traffic.
//!
//! ```sh
//! cargo run --release --example mc_pricing                 # full 10⁶ paths
//! cargo run --release --example mc_pricing -- --smoke      # CI-sized run
//! cargo run --release --example mc_pricing -- \
//!     --paths 250000 --steps 64 --assets 4 --shard-width 2048
//! ```
//!
//! All [`ServeTuning`] flags (`--max-batch`, `--chunk`, `--policy`,
//! `--shard-width`, `--priority-width`, `--serve-threads`, `--max-sessions`)
//! are accepted; none of them changes the price bits — admission packing,
//! sharding and chunking are bitwise-neutral by construction.

use std::time::Instant;

use neuralsde::config::ServeTuning;
use neuralsde::solvers::systems::MarketModel;
use neuralsde::solvers::{terminal_states, BatchReversibleHeun, ServeEngine};
use neuralsde::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let smoke = args.flag("smoke");
    let n_paths: usize = args.get_parse_or("paths", if smoke { 16_384 } else { 1_000_000 });
    let n_steps: usize = args.get_parse_or("steps", if smoke { 16 } else { 32 });
    let assets: usize = args.get_parse_or("assets", 2);
    let seed: u64 = args.get_parse_or("seed", 2024);
    let strike: f64 = args.get_parse_or("strike", 1.05);
    let mut tuning = ServeTuning {
        max_batch: 8192,
        chunk: 256,
        shard_width: 4096,
        ..ServeTuning::default()
    };
    tuning.apply_args(&mut args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let cfg = tuning.build(0.0, 1.0, n_steps);
    println!(
        "mc_pricing: {n_paths} paths x {n_steps} steps, {assets} assets \
         (policy {}, shard {}, mega-batch {})",
        cfg.policy.as_str(),
        tuning.shard_width,
        tuning.max_batch
    );
    let model = MarketModel::new(assets, seed).martingale();
    let engine = ServeEngine::<BatchReversibleHeun<f32>, _>::new(model, cfg);

    // The mega-request: every asset starts at 1.0 (at-the-money basket).
    let mega = engine.open_session(seed ^ 1, n_paths);
    let y0 = vec![1.0f32; assets * n_paths];
    let t_solve = Instant::now();
    let ticket = engine.submit(mega, &y0);

    // Interactive probes while the mega-request drains shard by shard: a
    // width-1 session rides the priority lane, so each probe completes in
    // the next admission round instead of waiting out the million paths.
    let probe = engine.open_session(seed ^ 2, 1);
    let y0_probe = vec![1.0f32; assets];
    let mut probe_out = Vec::new();
    let mut probe_us: Vec<f64> = Vec::new();
    let mut traj = Vec::new();
    loop {
        if let Some(res) = engine.try_wait_into(ticket, &mut traj) {
            res.map_err(|e| anyhow::anyhow!("mega-request faulted: {e}"))?;
            break;
        }
        let t0 = Instant::now();
        let t = engine.submit(probe, &y0_probe);
        engine
            .wait_into(t, &mut probe_out)
            .map_err(|e| anyhow::anyhow!("interactive probe faulted: {e}"))?;
        probe_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let solve_s = t_solve.elapsed().as_secs_f64();
    // Tiny runs can finish before the first poll; exercise the interactive
    // path regardless so `--smoke` covers it.
    while probe_us.len() < 3 {
        let t0 = Instant::now();
        let t = engine.submit(probe, &y0_probe);
        engine
            .wait_into(t, &mut probe_out)
            .map_err(|e| anyhow::anyhow!("interactive probe faulted: {e}"))?;
        probe_us.push(t0.elapsed().as_secs_f64() * 1e6);
    }

    // Price the basket call from the terminal frame: payoff
    // max(mean_i X_i(T) - K, 0), reported as mean ± standard error.
    let term = terminal_states(&traj, assets, n_paths);
    let mut sum = 0.0f64;
    let mut sumsq = 0.0f64;
    for p in 0..n_paths {
        let mut basket = 0.0f64;
        for i in 0..assets {
            basket += term[i * n_paths + p] as f64;
        }
        basket /= assets as f64;
        let payoff = (basket - strike).max(0.0);
        sum += payoff;
        sumsq += payoff * payoff;
    }
    let mean = sum / n_paths as f64;
    let var = (sumsq / n_paths as f64 - mean * mean).max(0.0);
    let stderr = (var / n_paths as f64).sqrt();

    probe_us.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let p50 = probe_us[probe_us.len() / 2];
    let worst = *probe_us.last().expect("at least three probes ran");
    println!(
        "mega-request solved in {solve_s:.3}s  ({:.0} paths/s)",
        n_paths as f64 / solve_s
    );
    println!("basket call (K = {strike}): price {mean:.6} +/- {stderr:.6}");
    println!(
        "interactive probes during drain: {}  (p50 {p50:.0} us, max {worst:.0} us)",
        probe_us.len()
    );

    if smoke {
        assert_eq!(traj.len(), (n_steps + 1) * assets * n_paths);
        assert!(term.iter().all(|v| v.is_finite()), "non-finite terminal state");
        // Martingale basket at 1.0 with ~0.05–0.2 effective vol: a 1.05
        // call is worth a few percent — comfortably inside these bounds.
        assert!(mean.is_finite() && mean > 0.0 && mean < 1.0, "price {mean} out of range");
        assert!(stderr.is_finite() && stderr < 0.05, "stderr {stderr} out of range");
        assert_eq!(probe_out.len(), (n_steps + 1) * assets);
        println!("mc_pricing smoke OK");
    }
    Ok(())
}
