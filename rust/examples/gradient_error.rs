//! Figure 2 / Table 6: relative L1 gradient error of the continuous
//! adjoint against discretise-then-optimise, per solver and step size.
//!
//! The expected shape (the paper's headline plot): midpoint and Heun
//! errors start around 1e-1…1e-2 and fall polynomially with the step size,
//! while the reversible Heun method sits at floating-point error (~1e-15
//! in f64) for *every* step size.
//!
//! ```sh
//! cargo run --release --example gradient_error
//! ```

use neuralsde::coordinator::gradient_error;
use neuralsde::runtime::load_runtime;
use neuralsde::util::json::{obj, Json};

fn main() -> anyhow::Result<()> {
    // Native rows first: the pure-Rust reversible-Heun adjoint engine needs
    // no AOT artifacts, so this example always has something to show.
    let mut points = gradient_error::run_native(2021);
    println!("{}", gradient_error::render(&points));
    let rec_max = points
        .iter()
        .filter(|p| p.solver == "native_revheun_rec_vs_tape")
        .map(|p| p.rel_err)
        .fold(0.0f64, f64::max);
    println!("native reconstruction-vs-tape worst error: {rec_max:.3e} (pure roundoff)");

    // Mixed-precision rows: f32 forward on the 8-wide lanes, exact f64
    // tape backward — the gradient-accuracy price of the f32 solve path.
    let mixed = gradient_error::run_native_mixed(2021);
    println!("{}", gradient_error::render(&mixed));
    let mixed_max = mixed.iter().map(|p| p.rel_err).fold(0.0f64, f64::max);
    println!("f32-forward vs f64 worst deviation: {mixed_max:.3e} (single-precision truncation)");
    points.extend(mixed);

    // PJRT rows: the JAX-twin solver comparison, when artifacts exist.
    match load_runtime("artifacts") {
        Ok(mut rt) => {
            let pjrt = gradient_error::run(&mut rt, 2021)?;
            println!("{}", gradient_error::render(&pjrt));

            // Sanity summary: the paper's claim, checked numerically.
            let rh_max = pjrt
                .iter()
                .filter(|p| p.solver == "reversible_heun")
                .map(|p| p.rel_err)
                .fold(0.0f64, f64::max);
            let mp_min = pjrt
                .iter()
                .filter(|p| p.solver == "midpoint")
                .map(|p| p.rel_err)
                .fold(f64::INFINITY, f64::min);
            println!("reversible Heun worst error : {rh_max:.3e}");
            println!("midpoint best error         : {mp_min:.3e}");
            println!(
                "separation                  : {:.1e}x",
                mp_min / rh_max.max(1e-300)
            );
            points.extend(pjrt);
        }
        Err(e) => println!("PJRT rows skipped (no artifacts): {e}"),
    }

    std::fs::create_dir_all("results")?;
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            obj(vec![
                ("solver", Json::Str(p.solver.clone())),
                ("n_steps", Json::Num(p.n_steps as f64)),
                ("rel_err", Json::Num(p.rel_err)),
            ])
        })
        .collect();
    std::fs::write(
        "results/fig2_gradient_error.json",
        Json::Arr(rows).to_string_pretty(),
    )?;
    println!("wrote results/fig2_gradient_error.json");
    Ok(())
}
