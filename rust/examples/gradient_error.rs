//! Figure 2 / Table 6: relative L1 gradient error of the continuous
//! adjoint against discretise-then-optimise, per solver and step size.
//!
//! The expected shape (the paper's headline plot): midpoint and Heun
//! errors start around 1e-1…1e-2 and fall polynomially with the step size,
//! while the reversible Heun method sits at floating-point error (~1e-15
//! in f64) for *every* step size.
//!
//! ```sh
//! cargo run --release --example gradient_error
//! ```

use neuralsde::coordinator::gradient_error;
use neuralsde::runtime::load_runtime;
use neuralsde::util::json::{obj, Json};

fn main() -> anyhow::Result<()> {
    let mut rt = load_runtime("artifacts")?;
    let points = gradient_error::run(&mut rt, 2021)?;
    println!("{}", gradient_error::render(&points));

    // Sanity summary: the paper's claim, checked numerically.
    let rh_max = points
        .iter()
        .filter(|p| p.solver == "reversible_heun")
        .map(|p| p.rel_err)
        .fold(0.0f64, f64::max);
    let mp_min = points
        .iter()
        .filter(|p| p.solver == "midpoint")
        .map(|p| p.rel_err)
        .fold(f64::INFINITY, f64::min);
    println!("reversible Heun worst error : {rh_max:.3e}");
    println!("midpoint best error         : {mp_min:.3e}");
    println!(
        "separation                  : {:.1e}x",
        mp_min / rh_max.max(1e-300)
    );

    std::fs::create_dir_all("results")?;
    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            obj(vec![
                ("solver", Json::Str(p.solver.clone())),
                ("n_steps", Json::Num(p.n_steps as f64)),
                ("rel_err", Json::Num(p.rel_err)),
            ])
        })
        .collect();
    std::fs::write(
        "results/fig2_gradient_error.json",
        Json::Arr(rows).to_string_pretty(),
    )?;
    println!("wrote results/fig2_gradient_error.json");
    Ok(())
}
