//! Latent SDE on the air-quality-like dataset (Table 1/5 + Figure 1).
//!
//! Trains the Latent SDE via the ELBO, reports the Appendix-F.1 metrics,
//! and dumps generated-vs-real O₃-channel samples to
//! `results/fig1_samples.csv` (the Figure-1 reproduction).
//!
//! ```sh
//! cargo run --release --example latent_sde_air -- [--steps 200] [--solver midpoint]
//! ```

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{DatasetKind, TrainConfig};
use neuralsde::coordinator::{evaluate_generator, LatentTrainer};
use neuralsde::data::air::{self, AirParams};
use neuralsde::runtime::load_runtime;
use neuralsde::util::cli::Args;
use neuralsde::util::json::{num_arr, obj, Json};
use std::fmt::Write as _;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let mut cfg = TrainConfig::default();
    cfg.dataset = DatasetKind::Air;
    cfg.lr_init = 4e-3;
    cfg.lr_field = 2e-3;
    cfg.apply_args(&mut args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let mut rt = load_runtime(&cfg.artifacts_dir)?;

    let mut data = air::generate(cfg.data_size, cfg.seed, AirParams::default());
    data.normalise_initial();
    let (train, _val, test) = data.split();
    println!("Latent SDE / air — solver={} steps={}", cfg.solver.as_str(), cfg.steps);

    let mut trainer = LatentTrainer::new(&rt, &cfg)?;
    let mut rng = SplitPrng::new(cfg.seed);
    let mut losses = Vec::new();
    let t0 = Instant::now();
    for step in 0..cfg.steps {
        let loss = trainer.train_step(&mut rt, &train, &mut rng)?;
        losses.push(loss as f64);
        if step % 25 == 0 || step + 1 == cfg.steps {
            println!("step {step:>4}  elbo loss {loss:+.4}");
        }
    }
    let train_time = t0.elapsed().as_secs_f64();

    let fake = trainer.sample(&mut rt, test.n)?;
    let report = evaluate_generator(&test, &fake, 7);
    println!("\ntraining time: {train_time:.1}s");
    println!("test metrics: {}", report.row());

    // Figure 1: O3-channel samples, real vs generated, as CSV.
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("kind,series,t,o3\n");
    for i in 0..8.min(test.n) {
        let s = test.series(i);
        for k in 0..test.seq_len {
            writeln!(csv, "real,{i},{k},{}", s[k * 2 + 1])?;
        }
        let f = fake.series(i);
        for k in 0..fake.seq_len {
            writeln!(csv, "generated,{i},{k},{}", f[k * 2 + 1])?;
        }
    }
    std::fs::write("results/fig1_samples.csv", csv)?;

    let out = obj(vec![
        ("experiment", Json::Str("latent_sde_air".into())),
        ("solver", Json::Str(cfg.solver.as_str().into())),
        ("steps", Json::Num(cfg.steps as f64)),
        ("train_time_s", Json::Num(train_time)),
        ("real_fake_acc", Json::Num(report.real_fake_acc)),
        ("prediction_loss", Json::Num(report.prediction_loss)),
        ("mmd", Json::Num(report.mmd)),
        ("loss_curve", num_arr(&losses)),
    ]);
    let path = format!("results/latent_sde_air_{}.json", cfg.solver.as_str());
    std::fs::write(&path, out.to_string_pretty())?;
    println!("wrote {path} and results/fig1_samples.csv");
    Ok(())
}
