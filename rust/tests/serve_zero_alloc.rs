//! The serving engine's zero-allocation contract, pinned with a counting
//! global allocator: once the engine, its slots, its session and the
//! caller's result buffer are warm, a full submit → coalesce → solve →
//! collect round trip performs **zero** heap allocations — across every
//! thread involved (submitter, admission, workers).
//!
//! This lives in its own integration-test binary because the global
//! allocator is process-wide: any concurrently running test would pollute
//! the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use neuralsde::solvers::systems::TanhDiagonalBatch;
use neuralsde::solvers::{BatchReversibleHeun, ServeConfig, ServeEngine};

/// Counts every allocation and reallocation in the process.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_round_trip_allocates_nothing() {
    let dim = 4usize;
    let n_paths = 8usize;
    let mut cfg = ServeConfig::new(0.0, 1.0, 24);
    cfg.max_batch = 16;
    cfg.threads = 2;
    cfg.chunk = 4;
    let engine = ServeEngine::<BatchReversibleHeun, _>::new(TanhDiagonalBatch::new(dim, 42), cfg);
    let sess = engine.open_session(7, n_paths);
    let y0 = vec![0.1f64; dim * n_paths];
    let mut out = Vec::new();

    // Warm everything: the slot's buffers reach their steady capacities on
    // the first two rounds (the result buffer ping-pongs between the slot
    // and the caller, so the pair is fully warmed after round two), the
    // Brownian tree builds its node arena on the first fill, the workers
    // build their scratch at spawn. A few extra rounds for slack.
    for _ in 0..6 {
        let t = engine.submit(sess, &y0);
        engine.wait_into(t, &mut out).expect("warmup request faulted");
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..25 {
        let t = engine.submit(sess, &y0);
        engine.wait_into(t, &mut out).expect("steady-state request faulted");
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state serving must not allocate (saw {} allocations over 25 round trips)",
        after - before
    );
    assert_eq!(out.len(), (24 + 1) * dim * n_paths);

    // Phase two — the steady-state SUBMIT path: several outstanding
    // requests at once exercise the packing queue, the slot pool's reuse
    // (three live slots, LIFO free list) and the multi-round admission of
    // a backlog wider than `max_batch` (24 queued lanes against a 16-lane
    // mega-batch), rather than phase one's single-slot ping-pong. Same
    // contract: zero allocations once warm.
    let mut outs = [Vec::new(), Vec::new(), Vec::new()];
    for _ in 0..3 {
        let ts =
            [engine.submit(sess, &y0), engine.submit(sess, &y0), engine.submit(sess, &y0)];
        for (t, o) in ts.into_iter().zip(outs.iter_mut()) {
            o.clear();
            engine.wait_into(t, o).expect("warmup request faulted");
        }
    }
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..25 {
        let ts =
            [engine.submit(sess, &y0), engine.submit(sess, &y0), engine.submit(sess, &y0)];
        for (t, o) in ts.into_iter().zip(outs.iter_mut()) {
            engine.wait_into(t, o).expect("steady-state request faulted");
        }
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state submit backlog must not allocate (saw {} allocations)",
        after - before
    );
    for o in &outs {
        assert_eq!(o.len(), (24 + 1) * dim * n_paths);
    }
}
