//! The deterministic fault-injection suite: every recovery path of the
//! fault-tolerance stack, exercised bit-reproducibly.
//!
//! * A planned NaN in one noise increment surfaces as a structured
//!   [`SolveError`] with the **exact** `(step, path)` coordinates, both at
//!   per-step sweep cadence and when detected by a sparse sweep (the
//!   localisation re-run pins the step regardless of `check_every`).
//! * A panicking drift evaluation quarantines **only its own lane** —
//!   survivors are bit-identical to an uninjected solve, and the quarantined
//!   lane is either held at its initial state or refilled by the caller.
//! * A forced reconstruction-drift breach degrades the batched adjoint from
//!   `Reconstruct` to `Tape` mid-sweep, and the gradients match an all-`Tape`
//!   run **bitwise** (the fallback is exact, not approximate).
//! * A corrupted cotangent lane is caught by the backward sweep with exact
//!   coordinates at `check_every = 1`.
//! * The GAN training watchdog rolls a failed step back and retries
//!   bit-deterministically; with the watchdog disabled the structured error
//!   surfaces instead.
//! * Quarantine decisions and surviving bits are invariant under the batch
//!   engine's thread/chunk fan-out.

use std::sync::Mutex;

use neuralsde::brownian::SplitPrng;
use neuralsde::config::TrainConfig;
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::ou;
use neuralsde::solvers::systems::TanhDiagonalBatch;
use neuralsde::solvers::{
    adjoint_solve_batched_steps, integrate_batched, integrate_batched_guarded, BackwardMode,
    BatchOptions, BatchReversibleHeun, CounterGridNoise, FaultCause, FaultPlan, FaultyBatchNoise,
    GuardConfig, PanicOnSentinel,
};

/// The panic hook is process-global; tests that suppress it to keep planned
/// panics quiet must not interleave with each other.
static PANIC_HOOK_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the default panic hook replaced by a silent one (planned
/// panics would otherwise spam the test output). Assertions belong outside
/// `f` so their messages stay visible.
fn with_quiet_panics<R>(f: impl FnOnce() -> R) -> R {
    let _guard = PANIC_HOOK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = f();
    std::panic::set_hook(prev);
    out
}

/// Slightly different per-lane initial states so lane mixups would show.
fn soa_start(dim: usize, batch: usize) -> Vec<f64> {
    (0..dim * batch).map(|q| 0.02 * (q % 13) as f64 + 0.05).collect()
}

// ---------------------------------------------------------------------------
// NaN injection → exact coordinates
// ---------------------------------------------------------------------------

#[test]
fn nan_injection_reported_with_exact_coordinates() {
    let (dim, batch, n) = (2usize, 6usize, 10usize);
    let sde = TanhDiagonalBatch::new(dim, 11);
    let inner = CounterGridNoise::new(21, dim, 0.0, 1.0, n);
    let noise = FaultyBatchNoise::new(&inner, FaultPlan::new().inject_nan(5, 3, 1));
    let y0 = soa_start(dim, batch);
    let opts = BatchOptions { threads: 1, chunk: 4, ..Default::default() };
    let err = integrate_batched::<BatchReversibleHeun, _, _>(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect_err("the planned NaN must fault the solve");
    assert_eq!(err.context, "integrate_batched");
    assert_eq!(err.faults.len(), 1, "exactly one path faulted: {err}");
    let f = &err.faults[0];
    assert_eq!(f.step, 5, "step whose update consumed the NaN increment");
    assert_eq!(f.path, 3, "only the injected path");
    assert_eq!(f.cause, FaultCause::NonFinite);
}

#[test]
fn sparse_sweep_still_localizes_the_exact_step() {
    // With check_every = 3 the blockwise sweep only *detects* at steps 3, 6,
    // 9, … — the bit-identical localisation re-run must still pin the fault
    // to the exact step the NaN entered.
    let (dim, batch, n) = (2usize, 6usize, 10usize);
    let sde = TanhDiagonalBatch::new(dim, 11);
    let inner = CounterGridNoise::new(21, dim, 0.0, 1.0, n);
    let noise = FaultyBatchNoise::new(&inner, FaultPlan::new().inject_nan(5, 3, 1));
    let y0 = soa_start(dim, batch);
    let opts = BatchOptions {
        threads: 1,
        chunk: 4,
        guard: GuardConfig { check_every: 3, ..GuardConfig::default() },
    };
    let err = integrate_batched::<BatchReversibleHeun, _, _>(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect_err("the planned NaN must fault the solve");
    assert_eq!(err.faults.len(), 1, "{err}");
    assert_eq!(err.faults[0].step, 5, "sparse detection, exact localisation");
    assert_eq!(err.faults[0].path, 3);
}

// ---------------------------------------------------------------------------
// Panic isolation and quarantine
// ---------------------------------------------------------------------------

#[test]
fn panicking_drift_quarantines_only_its_lane() {
    let (dim, batch, n) = (2usize, 10usize, 8usize);
    let inner = TanhDiagonalBatch::new(dim, 31);
    let sentinel = 777.0f64;
    let sde = PanicOnSentinel::new(&inner, sentinel);
    let noise = CounterGridNoise::new(41, dim, 0.0, 1.0, n);
    let mut y0 = soa_start(dim, batch);
    y0[2] = sentinel; // component 0, path 2
    let opts = BatchOptions { threads: 2, chunk: 4, ..Default::default() };

    let gs = with_quiet_panics(|| {
        integrate_batched_guarded::<BatchReversibleHeun, _, _>(
            &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts, None,
        )
    })
    .expect("survivors exist, so quarantine mode must return Ok");
    assert_eq!(gs.quarantined, vec![2], "exactly the sentinel path");
    assert_eq!(gs.faults.len(), 1);
    assert_eq!(gs.faults[0].path, 2);
    assert!(
        matches!(gs.faults[0].cause, FaultCause::VectorFieldPanic { .. }),
        "cause: {}",
        gs.faults[0].cause
    );

    // Survivors must be bit-identical to an uninjected solve of the same
    // initial state (the bare tanh system handles the sentinel value fine).
    let reference = integrate_batched::<BatchReversibleHeun, _, _>(
        &inner, &noise, &y0, batch, 0.0, 1.0, n, &opts,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    for k in 0..=n {
        for i in 0..dim {
            for p in (0..batch).filter(|&p| p != 2) {
                let idx = (k * dim + i) * batch + p;
                assert_eq!(
                    gs.traj[idx], reference[idx],
                    "surviving path {p} drifted at step {k} component {i}"
                );
            }
        }
    }
    // Without a refill, the quarantined lane is its initial state held
    // constant over the whole grid.
    for k in 0..=n {
        for i in 0..dim {
            assert_eq!(gs.traj[(k * dim + i) * batch + 2], y0[i * batch + 2]);
        }
    }
}

#[test]
fn quarantined_lane_can_be_refilled() {
    let (dim, batch, n) = (2usize, 6usize, 5usize);
    let inner = TanhDiagonalBatch::new(dim, 31);
    let sentinel = 777.0f64;
    let sde = PanicOnSentinel::new(&inner, sentinel);
    let noise = CounterGridNoise::new(41, dim, 0.0, 1.0, n);
    let mut y0 = soa_start(dim, batch);
    y0[batch + 4] = sentinel; // component 1, path 4
    let opts = BatchOptions { threads: 1, chunk: 3, ..Default::default() };
    // Replacement trajectory: a recognisable constant per grid point.
    let refill: &dyn Fn(usize, &mut [f64]) -> bool = &|_p, lane| {
        for (r, v) in lane.iter_mut().enumerate() {
            *v = 0.25 + r as f64;
        }
        true
    };
    let gs = with_quiet_panics(|| {
        integrate_batched_guarded::<BatchReversibleHeun, _, _>(
            &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts, Some(refill),
        )
    })
    .expect("survivors exist");
    assert_eq!(gs.quarantined, vec![4]);
    for k in 0..=n {
        for i in 0..dim {
            assert_eq!(
                gs.traj[(k * dim + i) * batch + 4],
                0.25 + (k * dim + i) as f64,
                "refilled lane layout is lane[k * dim + i]"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Reconstruction-drift watchdog → Tape fallback
// ---------------------------------------------------------------------------

#[test]
fn forced_drift_breach_falls_back_to_tape_bitwise() {
    let (dim, batch, n) = (3usize, 4usize, 16usize);
    let sde = TanhDiagonalBatch::new(dim, 99);
    let noise = CounterGridNoise::new(7, dim, 0.0, 1.0, n);
    let y0 = soa_start(dim, batch);
    let seed = |k: usize, _p0: usize, _cl: usize, _z: &[f64], lz: &mut [f64]| {
        if k == n {
            lz.fill(1.0);
        }
    };
    let base = BatchOptions { threads: 2, chunk: 2, ..Default::default() };
    let tape = adjoint_solve_batched_steps(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, BackwardMode::Tape, false, &base, &seed,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert_eq!(tape.fallbacks, 0, "Tape mode has nothing to fall back from");

    // A negative drift tolerance is the deterministic test hook: the first
    // checkpoint comparison breaches, so the entire backward sweep runs on
    // the rebuilt tape — gradients must equal the all-Tape run bit for bit.
    let forced = BatchOptions {
        threads: 2,
        chunk: 2,
        guard: GuardConfig { checkpoint_every: 1, drift_tol: -1.0, ..GuardConfig::default() },
    };
    let rec = adjoint_solve_batched_steps(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, BackwardMode::Reconstruct, false, &forced, &seed,
    )
    .expect("the fallback recovers; no error surfaces");
    assert!(rec.fallbacks > 0, "the forced breach must trip the watchdog");
    assert_eq!(rec.terminal, tape.terminal, "terminal state");
    assert_eq!(rec.dy0, tape.dy0, "dy0 must match all-Tape bitwise");
    assert_eq!(rec.dtheta, tape.dtheta, "dtheta must match all-Tape bitwise");

    // A healthy reconstruction never trips the watchdog.
    let healthy = adjoint_solve_batched_steps(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, BackwardMode::Reconstruct, false, &base, &seed,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert_eq!(healthy.fallbacks, 0, "healthy solve must not fall back");
}

// ---------------------------------------------------------------------------
// Corrupted gradient lane → exact coordinates
// ---------------------------------------------------------------------------

#[test]
fn corrupted_gradient_lane_reported_with_exact_coordinates() {
    let (dim, batch, n) = (2usize, 4usize, 9usize);
    let sde = TanhDiagonalBatch::new(dim, 55);
    let noise = CounterGridNoise::new(17, dim, 0.0, 1.0, n);
    let y0 = soa_start(dim, batch);
    let plan = FaultPlan::new().corrupt_grad(4, 1, 0);
    let seed = move |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
        if k == n {
            lz.fill(1.0);
        }
        plan.corrupt_grad_lanes(k, p0, cl, lz);
    };
    // check_every = 1 sweeps the cotangents at every backward step, so the
    // corruption is caught exactly where it lands.
    let opts = BatchOptions {
        threads: 1,
        chunk: batch,
        guard: GuardConfig { check_every: 1, ..GuardConfig::default() },
    };
    let err = adjoint_solve_batched_steps(
        &sde, &noise, &y0, batch, 0.0, 1.0, n, BackwardMode::Reconstruct, false, &opts, &seed,
    )
    .expect_err("the corrupted cotangent must fault the sweep");
    assert_eq!(err.context, "adjoint_solve_batched_steps");
    assert_eq!(err.faults.len(), 1, "{err}");
    let f = &err.faults[0];
    assert_eq!(f.step, 4, "backward step the corruption landed on");
    assert_eq!(f.path, 1);
    assert_eq!(f.component, 0);
    assert_eq!(f.cause, FaultCause::NonFinite);
}

// ---------------------------------------------------------------------------
// GAN training watchdog
// ---------------------------------------------------------------------------

fn watchdog_config() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.steps = 1;
    cfg.batch = 8;
    cfg.data_size = 32;
    cfg
}

#[test]
fn training_watchdog_rolls_back_and_retries_deterministically() {
    let cfg = watchdog_config();
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let run = || -> (u32, u64, Vec<f32>, Vec<f32>, f32, f32) {
        let mut tr = GanTrainer::new(&cfg, cfg.steps).expect("trainer");
        tr.inject_training_fault(1);
        let mut rng = SplitPrng::new(9);
        let stats = tr.train_step(&data, &mut rng).expect("watchdog recovers the step");
        (
            stats.retries,
            tr.watchdog_rollbacks(),
            tr.theta.clone(),
            tr.phi.clone(),
            stats.loss_g,
            stats.loss_d,
        )
    };
    let (retries_a, rb_a, theta_a, phi_a, lg_a, ld_a) = run();
    assert_eq!(retries_a, 1, "one injected failure → one retry");
    assert_eq!(rb_a, 1, "one rollback recorded");
    // The whole recovery — snapshot, rollback, fresh noise draw, retry — is
    // deterministic: a second trainer through the same fault lands on
    // bit-identical parameters and losses.
    let (retries_b, rb_b, theta_b, phi_b, lg_b, ld_b) = run();
    assert_eq!(retries_a, retries_b);
    assert_eq!(rb_a, rb_b);
    assert_eq!(theta_a, theta_b, "retried θ must be bit-identical");
    assert_eq!(phi_a, phi_b, "retried φ must be bit-identical");
    assert_eq!((lg_a, ld_a), (lg_b, ld_b), "retried losses must be bit-identical");
}

#[test]
fn disabled_watchdog_surfaces_the_structured_error() {
    let cfg = watchdog_config();
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let mut tr = GanTrainer::new(&cfg, cfg.steps).expect("trainer").with_watchdog(false, 0);
    tr.inject_training_fault(1);
    let mut rng = SplitPrng::new(9);
    let err = tr.train_step(&data, &mut rng).expect_err("no watchdog, no recovery");
    let msg = format!("{err}");
    assert!(msg.contains("injected fault"), "structured context survives anyhow: {msg}");
    assert_eq!(tr.watchdog_rollbacks(), 0);
}

// ---------------------------------------------------------------------------
// Schedule invariance of quarantine decisions
// ---------------------------------------------------------------------------

#[test]
fn quarantine_is_schedule_invariant() {
    let (dim, batch, n) = (2usize, 10usize, 6usize);
    let inner = TanhDiagonalBatch::new(dim, 31);
    let sentinel = 777.0f64;
    let sde = PanicOnSentinel::new(&inner, sentinel);
    let noise = CounterGridNoise::new(41, dim, 0.0, 1.0, n);
    let mut y0 = soa_start(dim, batch);
    y0[2] = sentinel; // component 0, path 2
    let mut first: Option<(Vec<usize>, Vec<f64>)> = None;
    for (threads, chunk) in [(1usize, 10usize), (2, 4), (4, 3)] {
        let opts = BatchOptions { threads, chunk, ..Default::default() };
        let gs = with_quiet_panics(|| {
            integrate_batched_guarded::<BatchReversibleHeun, _, _>(
                &sde, &noise, &y0, batch, 0.0, 1.0, n, &opts, None,
            )
        })
        .expect("survivors exist");
        match &first {
            None => first = Some((gs.quarantined, gs.traj)),
            Some((q, traj)) => {
                assert_eq!(&gs.quarantined, q, "quarantine set at t={threads} c={chunk}");
                assert_eq!(&gs.traj, traj, "bits changed at t={threads} c={chunk}");
            }
        }
    }
}
