//! Property tests for the native neural layer and the in-Rust SDE-GAN
//! training path:
//!
//! * LipSwish slope ≤ 1 and the post-clip MLP ∞-norm contraction — the two
//!   halves of the paper's Section-5 Lipschitz argument;
//! * `max_vjp_fd_error` for the neural `SdeVjp` impls (generator MLP fields
//!   and CDE discriminator fields) at several FD step sizes;
//! * whole-trajectory losses: per-step cotangent injection and the noise
//!   (`ΔW`) cotangents both agree with central finite differences of the
//!   same discrete solve (≤1e-6 relative L1 — the acceptance bound);
//! * the batched neural adjoint (with injection + `ddw`) is **bit-identical**
//!   to the per-path adjoint across the SIMD remainder batches 1/3/4/7/8/33
//!   and every chunk/thread setting, and the native SoA systems match the
//!   blanket gather/scatter adapter bitwise;
//! * the native `GanTrainer`: finite losses, moving parameters, the clip
//!   invariant after every step, bit-determinism across seeds and across
//!   batch-engine fan-out settings, and finite non-degenerate sampling —
//!   all without artifacts or a runtime;
//! * mixed precision: the `f32` batched MLP kernels are bit-identical to the
//!   per-path generic forward/VJP across the SIMD remainder batches, the
//!   mixed adjoint (`f32` forward, exact `f64` backward) deviates from the
//!   all-`f64` gradients by a small but **nonzero** single-precision
//!   rounding term, and `TrainPrecision::Mixed` training is bit-deterministic
//!   across every thread/chunk fan-out while tracking the `f64` step.

use neuralsde::brownian::SplitPrng;
use neuralsde::config::{TrainConfig, TrainPrecision};
use neuralsde::coordinator::gradient_error::relative_l1;
use neuralsde::coordinator::GanTrainer;
use neuralsde::data::ou;
use neuralsde::nn::mlp::{dlipswish, lipswish};
use neuralsde::nn::{weights_clipped, Activation, GanNetSpec, Mlp};
use neuralsde::solvers::neural::{
    widen_params, NeuralDiscriminator, NeuralDiscriminatorBatch, NeuralGenerator,
    NeuralGeneratorBatch,
};
use neuralsde::solvers::{
    adjoint_solve_batched_steps, adjoint_solve_batched_steps_mixed, adjoint_solve_steps,
    aos_to_soa, integrate, max_vjp_fd_error, AdjointGrad, BackwardMode, BatchOptions,
    CounterGridNoise, ReversibleHeun, Sde, StoredBatchNoise,
};
use neuralsde::util::stats::central_gradient;

fn tiny_spec() -> GanNetSpec {
    GanNetSpec {
        data_dim: 1,
        state: 3,
        hidden: 4,
        noise: 2,
        init_noise: 2,
        disc_state: 3,
        disc_hidden: 4,
    }
}

fn random_params(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = SplitPrng::new(seed);
    (0..n).map(|_| rng.next_normal_pair().0 * 0.3).collect()
}

fn field_filter(name: &str) -> bool {
    name.starts_with("f.") || name.starts_with("g.")
}

// ---------------------------------------------------------------------------
// Lipschitz properties (Section 5)
// ---------------------------------------------------------------------------

#[test]
fn lipswish_slope_bounded_by_one() {
    // ρ(x) = 0.909·x·σ(x): its max slope is 0.909·1.0998… < 1. Scan the
    // derivative and check the Lipschitz pair bound on random pairs.
    let mut u = -12.0f64;
    while u <= 12.0 {
        let d = dlipswish(u);
        assert!(d <= 1.0 && d >= -0.2, "slope {d} at u={u}");
        u += 1e-3;
    }
    let mut rng = SplitPrng::new(3);
    for _ in 0..2000 {
        let (a, b) = rng.next_normal_pair();
        let (a, b) = (3.0 * a, 3.0 * b);
        assert!(
            (lipswish(a) - lipswish(b)).abs() <= (a - b).abs() + 1e-12,
            "pair ({a}, {b})"
        );
    }
}

#[test]
fn clipped_mlp_is_inf_norm_contraction() {
    // After clip_lipschitz, every output coordinate of a weight matrix is an
    // absolute-row-sum ≤ 1 map, LipSwish and tanh are 1-Lipschitz and biases
    // shift-invariant — so the whole f_φ MLP contracts in the ∞-norm.
    let spec = GanNetSpec::for_data_dim(1);
    let dl = spec.disc_layout();
    // Init far outside the clip region so the clamp is doing the work.
    let mut phi = dl.init(17, |_| 8.0);
    assert!(!weights_clipped(&dl, &phi, field_filter));
    dl.clip_lipschitz(&mut phi, field_filter);
    assert!(weights_clipped(&dl, &phi, field_filter));
    let phi64 = widen_params(&phi);
    let f = Mlp::from_layout(&dl, "f", Activation::Tanh).unwrap();
    let mut rng = SplitPrng::new(23);
    let dim = 1 + spec.disc_state;
    let mut out_a = vec![0.0; spec.disc_state];
    let mut out_b = vec![0.0; spec.disc_state];
    for trial in 0..50 {
        let xa: Vec<f64> = (0..dim).map(|_| rng.next_normal_pair().0 * 2.0).collect();
        let xb: Vec<f64> = (0..dim).map(|_| rng.next_normal_pair().0 * 2.0).collect();
        f.forward(&phi64, &xa, &mut out_a);
        f.forward(&phi64, &xb, &mut out_b);
        let din = xa
            .iter()
            .zip(&xb)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let dout = out_a
            .iter()
            .zip(&out_b)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(
            dout <= din * (1.0 + 1e-12) + 1e-15,
            "trial {trial}: |Δout|∞ {dout} > |Δin|∞ {din}"
        );
    }
}

// ---------------------------------------------------------------------------
// VJP-vs-FD for the neural fields
// ---------------------------------------------------------------------------

#[test]
fn neural_vjps_match_finite_differences_at_several_tolerances() {
    let spec = tiny_spec();
    let probes = [(1e-3, 1e-4), (1e-4, 1e-6), (1e-5, 1e-8)];
    let gen_theta = random_params(spec.gen_layout().total, 41);
    for &(h, tol) in &probes {
        let err = max_vjp_fd_error(
            |p: &[f64]| NeuralGenerator::new(&spec, p.to_vec()),
            &gen_theta,
            0.2,
            &[0.3, -0.4, 0.5],
            &[0.8, -0.6, 1.1],
            &[0.5, 0.9, -0.7],
            &[0.12, -0.31],
            h,
        );
        assert!(err < tol, "generator VJP-vs-FD error {err:e} at h={h:e}");
    }
    let disc_phi = random_params(spec.disc_layout().total, 43);
    for &(h, tol) in &probes {
        let err = max_vjp_fd_error(
            |p: &[f64]| NeuralDiscriminator::new(&spec, p.to_vec()),
            &disc_phi,
            -0.3,
            &[0.2, 0.6, -0.5],
            &[1.2, -0.4, 0.3],
            &[-0.8, 0.5, 0.6],
            &[0.21],
            h,
        );
        assert!(err < tol, "discriminator VJP-vs-FD error {err:e} at h={h:e}");
    }
}

// ---------------------------------------------------------------------------
// Whole-trajectory losses: per-step cotangents and noise cotangents vs FD
// ---------------------------------------------------------------------------

/// The deterministic per-step loss weights `c[k][i]` shared by the FD loss
/// and the adjoint injection.
fn step_weight(k: usize, i: usize) -> f64 {
    0.2 + (0.37 * k as f64).sin() * 0.1 + 0.05 * i as f64
}

#[test]
fn per_step_cotangent_injection_matches_fd() {
    // L = Σ_k Σ_i c[k][i] · z_k[i] reads the whole trajectory — the
    // path-dependent-discriminator shape. The injected backward must match
    // central differences of the identical discrete solve to ≤1e-6 rel L1.
    let spec = tiny_spec();
    let x = spec.state;
    let n = 12usize;
    let theta0 = random_params(spec.gen_layout().total, 7);
    let y0 = [0.15f64, -0.1, 0.2];
    let noise = CounterGridNoise::new(19, spec.noise, 0.0, 1.0, n);
    let loss = |th: &[f64], y0v: &[f64]| -> f64 {
        let sde = NeuralGenerator::new(&spec, th.to_vec());
        let mut solver = ReversibleHeun::new(&sde, 0.0, y0v);
        let mut pn = noise.path(0);
        let traj = integrate(&sde, &mut solver, &mut pn, y0v, 0.0, 1.0, n);
        let mut acc = 0.0;
        for k in 0..=n {
            for i in 0..x {
                acc += step_weight(k, i) * traj[k * x + i];
            }
        }
        acc
    };
    let sde = NeuralGenerator::new(&spec, theta0.clone());
    let mut pn = noise.path(0);
    let adj = adjoint_solve_steps(
        &sde,
        &y0,
        0.0,
        1.0,
        n,
        &mut pn,
        BackwardMode::Reconstruct,
        false,
        |k, _z, lz| {
            for (i, l) in lz.iter_mut().enumerate() {
                *l += step_weight(k, i);
            }
        },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let mut got = adj.dy0.clone();
    got.extend_from_slice(&adj.dtheta);
    let mut fd = central_gradient(|yy| loss(&theta0, yy), &y0, 1e-5);
    fd.extend(central_gradient(|th| loss(th, &y0), &theta0, 1e-5));
    let rel = relative_l1(&got, &fd);
    assert!(rel <= 1e-6, "per-step-injection adjoint vs FD rel L1 {rel:e}");
    // Reconstruct and Tape agree on the injected loss too.
    let mut pn = noise.path(0);
    let tape = adjoint_solve_steps(
        &sde,
        &y0,
        0.0,
        1.0,
        n,
        &mut pn,
        BackwardMode::Tape,
        false,
        |k, _z, lz| {
            for (i, l) in lz.iter_mut().enumerate() {
                *l += step_weight(k, i);
            }
        },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let mut tp = tape.dy0.clone();
    tp.extend_from_slice(&tape.dtheta);
    assert!(relative_l1(&got, &tp) < 1e-10, "rec vs tape with injection");
}

#[test]
fn noise_cotangents_match_fd() {
    // ∂L/∂ΔW for a terminal loss, against central differences over the
    // stored increment values themselves — validates the ddw recursion the
    // CDE's path cotangents ride on.
    let spec = tiny_spec();
    let (x, w) = (spec.state, spec.noise);
    let n = 8usize;
    let theta = random_params(spec.gen_layout().total, 29);
    let y0 = [0.1f64, 0.05, -0.2];
    // Base increments from the counter stream, owned so FD can perturb.
    let src = CounterGridNoise::new(31, w, 0.0, 1.0, n);
    let base: Vec<f64> = (0..n * w).map(|r| src.value(0, r / w, r % w)).collect();
    let loss = |vals: &[f64]| -> f64 {
        let mut stored = StoredBatchNoise::zeros(0.0, 1.0, n, w, 1);
        stored.values_mut().copy_from_slice(vals);
        let sde = NeuralGenerator::new(&spec, theta.clone());
        let mut solver = ReversibleHeun::new(&sde, 0.0, &y0);
        let mut pn = stored.path(0);
        let traj = integrate(&sde, &mut solver, &mut pn, &y0, 0.0, 1.0, n);
        traj[traj.len() - x..].iter().sum()
    };
    let sde = NeuralGenerator::new(&spec, theta.clone());
    let mut stored = StoredBatchNoise::zeros(0.0, 1.0, n, w, 1);
    stored.values_mut().copy_from_slice(&base);
    let mut pn = stored.path(0);
    let adj = adjoint_solve_steps(
        &sde,
        &y0,
        0.0,
        1.0,
        n,
        &mut pn,
        BackwardMode::Reconstruct,
        true,
        |k, _z, lz| {
            if k == n {
                lz.fill(1.0);
            }
        },
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    assert_eq!(adj.ddw.len(), n * w);
    let fd = central_gradient(loss, &base, 1e-6);
    let rel = relative_l1(&adj.ddw, &fd);
    assert!(rel <= 1e-6, "ddw vs FD rel L1 {rel:e}");
}

// ---------------------------------------------------------------------------
// Batched ≡ per-path, bitwise, for the neural systems
// ---------------------------------------------------------------------------

const REMAINDER_BATCHES: [usize; 6] = [1, 3, 4, 7, 8, 33];

/// Per-path starting states, slightly different per path so lane mixups
/// would be caught.
fn aos_start(dim: usize, batch: usize) -> Vec<f64> {
    (0..batch * dim).map(|q| 0.02 * (q % 17) as f64 - 0.1).collect()
}

/// Per-path + per-component + per-step cotangent (catches any transposition).
fn inject_weight(k: usize, i: usize, p: usize) -> f64 {
    0.1 + 0.03 * i as f64 + 0.001 * p as f64 + 0.01 * (k % 5) as f64
}

/// Per-path reference with injection + ddw: `batch` separate
/// `adjoint_solve_steps` runs, lanes gathered SoA, θ summed ascending.
fn per_path_reference(
    sde: &NeuralGenerator,
    aos: &[f64],
    batch: usize,
    n: usize,
    noise: &CounterGridNoise,
    mode: BackwardMode,
) -> AdjointGrad {
    let dim = Sde::dim(sde);
    let nd = Sde::noise_dim(sde);
    let pl = sde.params_flat().len();
    let mut terminal = vec![0.0; dim * batch];
    let mut dy0 = vec![0.0; dim * batch];
    let mut dtheta = vec![0.0; pl];
    let mut ddw = vec![0.0; n * nd * batch];
    for p in 0..batch {
        let y0p = &aos[p * dim..(p + 1) * dim];
        let mut pn = noise.path(p);
        let g = adjoint_solve_steps(sde, y0p, 0.0, 1.0, n, &mut pn, mode, true, |k, _z, lz| {
            for (i, l) in lz.iter_mut().enumerate() {
                *l += inject_weight(k, i, p);
            }
        })
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        for i in 0..dim {
            terminal[i * batch + p] = g.terminal[i];
            dy0[i * batch + p] = g.dy0[i];
        }
        for m in 0..pl {
            dtheta[m] += g.dtheta[m];
        }
        for r in 0..n * nd {
            ddw[r * batch + p] = g.ddw[r];
        }
    }
    AdjointGrad { terminal, dy0, dtheta, ddw, fallbacks: 0 }
}

#[test]
fn neural_batched_adjoint_bit_identical_to_per_path() {
    let spec = tiny_spec();
    let dim = spec.state;
    let n = 10usize;
    let theta = random_params(spec.gen_layout().total, 13);
    let sde = NeuralGenerator::new(&spec, theta.clone());
    let native = NeuralGeneratorBatch::from_system(NeuralGenerator::new(&spec, theta.clone()));
    for &batch in &REMAINDER_BATCHES {
        let aos = aos_start(dim, batch);
        let y0 = aos_to_soa(&aos, dim, batch);
        let noise = CounterGridNoise::new(77, spec.noise, 0.0, 1.0, n);
        for mode in [BackwardMode::Reconstruct, BackwardMode::Tape] {
            let reference = per_path_reference(&sde, &aos, batch, n, &noise, mode);
            let seed = |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
                for i in 0..dim {
                    for q in 0..cl {
                        lz[i * cl + q] += inject_weight(k, i, p0 + q);
                    }
                }
            };
            for (threads, chunk) in [(1usize, batch), (1, 2), (3, 2), (2, 4), (4, 3)] {
                let opts = BatchOptions { threads, chunk, ..Default::default() };
                let got = adjoint_solve_batched_steps(
                    &native, &noise, &y0, batch, 0.0, 1.0, n, mode, true, &opts, &seed,
                )
                .expect("fault-free by construction"); // test-only unwrap: no injection here
                assert_eq!(
                    got.terminal, reference.terminal,
                    "terminal: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
                assert_eq!(
                    got.dy0, reference.dy0,
                    "dy0: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
                assert_eq!(
                    got.dtheta, reference.dtheta,
                    "dtheta: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
                assert_eq!(
                    got.ddw, reference.ddw,
                    "ddw: batch={batch} mode={mode:?} t={threads} c={chunk}"
                );
            }
        }
    }
}

#[test]
fn neural_native_batch_matches_blanket_adapter_bitwise() {
    // The per-path NeuralGenerator *is* a BatchSdeVjp through the blanket
    // gather/scatter adapter; the hand-batched SoA twin must produce the
    // same bits (forward, backward, injection and ddw).
    let spec = tiny_spec();
    let dim = spec.state;
    let n = 9usize;
    let theta = random_params(spec.gen_layout().total, 51);
    let adapter = NeuralGenerator::new(&spec, theta.clone());
    let native = NeuralGeneratorBatch::from_system(NeuralGenerator::new(&spec, theta));
    let seed = |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
        for i in 0..dim {
            for q in 0..cl {
                lz[i * cl + q] += inject_weight(k, i, p0 + q);
            }
        }
    };
    for &batch in &[1usize, 5, 33] {
        let y0 = aos_to_soa(&aos_start(dim, batch), dim, batch);
        let noise = CounterGridNoise::new(3, spec.noise, 0.0, 1.0, n);
        let opts = BatchOptions { threads: 1, chunk: 16, ..Default::default() };
        let a = adjoint_solve_batched_steps(
            &adapter,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            true,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        let b = adjoint_solve_batched_steps(
            &native,
            &noise,
            &y0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            true,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        assert_eq!(a.terminal, b.terminal, "terminal at batch {batch}");
        assert_eq!(a.dy0, b.dy0, "dy0 at batch {batch}");
        assert_eq!(a.dtheta, b.dtheta, "dtheta at batch {batch}");
        assert_eq!(a.ddw, b.ddw, "ddw at batch {batch}");
    }
}

#[test]
fn cde_batched_adjoint_matches_per_path() {
    // The discriminator CDE: driven by stored ΔY "noise", terminal readout
    // cotangent, ddw wanted (the generator-step path cotangents).
    let spec = tiny_spec();
    let (dh, y) = (spec.disc_state, spec.data_dim);
    let n = 11usize;
    let phi = random_params(spec.disc_layout().total, 61);
    let disc = NeuralDiscriminator::new(&spec, phi.clone());
    let native = NeuralDiscriminatorBatch::from_system(NeuralDiscriminator::new(&spec, phi));
    for &batch in &[1usize, 4, 7, 33] {
        // Deterministic pseudo-ΔY increments, distinct per (k, c, p).
        let mut dys = StoredBatchNoise::zeros(0.0, 1.0, n, y, batch);
        for k in 0..n {
            for c in 0..y {
                for p in 0..batch {
                    dys.set(k, c, p, 0.05 * ((k + 1) as f64 * 0.7).sin() + 0.002 * p as f64
                        - 0.001 * c as f64);
                }
            }
        }
        let aos = aos_start(dh, batch);
        let h0 = aos_to_soa(&aos, dh, batch);
        let seed = |k: usize, _p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
            if k == n {
                for i in 0..dh {
                    for q in 0..cl {
                        lz[i * cl + q] += 1.0 + 0.5 * i as f64;
                    }
                }
            }
        };
        let opts = BatchOptions { threads: 2, chunk: 3, ..Default::default() };
        let got = adjoint_solve_batched_steps(
            &native,
            &dys,
            &h0,
            batch,
            0.0,
            1.0,
            n,
            BackwardMode::Reconstruct,
            true,
            &opts,
            &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        let pl = spec.disc_layout().total;
        let mut dtheta = vec![0.0; pl];
        for p in 0..batch {
            let y0p = &aos[p * dh..(p + 1) * dh];
            let mut pn = dys.path(p);
            let g = adjoint_solve_steps(
                &disc,
                y0p,
                0.0,
                1.0,
                n,
                &mut pn,
                BackwardMode::Reconstruct,
                true,
                |k, _z, lz| {
                    if k == n {
                        for (i, l) in lz.iter_mut().enumerate() {
                            *l += 1.0 + 0.5 * i as f64;
                        }
                    }
                },
            )
            .expect("fault-free by construction"); // test-only unwrap: no injection here
            for i in 0..dh {
                assert_eq!(got.terminal[i * batch + p], g.terminal[i], "terminal p={p}");
                assert_eq!(got.dy0[i * batch + p], g.dy0[i], "dy0 p={p}");
            }
            for r in 0..n * y {
                assert_eq!(got.ddw[r * batch + p], g.ddw[r], "ddw p={p} r={r}");
            }
            for m in 0..pl {
                dtheta[m] += g.dtheta[m];
            }
        }
        assert_eq!(got.dtheta, dtheta, "dtheta at batch {batch}");
    }
}

// ---------------------------------------------------------------------------
// The native trainer end to end
// ---------------------------------------------------------------------------

fn smoke_config() -> TrainConfig {
    let mut cfg = TrainConfig::default();
    cfg.steps = 3;
    cfg.batch = 12;
    cfg.data_size = 64;
    cfg
}

#[test]
fn native_gan_training_steps_are_finite_and_clip() {
    let cfg = smoke_config();
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let mut trainer = GanTrainer::new(&cfg, cfg.steps).expect("trainer");
    let theta0 = trainer.theta.clone();
    let phi0 = trainer.phi.clone();
    let mut rng = SplitPrng::new(1);
    for step in 0..cfg.steps {
        let stats = trainer.train_step(&data, &mut rng).expect("step");
        assert!(stats.loss_g.is_finite(), "step {step} loss_g");
        assert!(stats.loss_d.is_finite(), "step {step} loss_d");
        assert!(
            weights_clipped(trainer.disc_layout(), &trainer.phi, field_filter),
            "step {step}: f/g weights escaped the clip region"
        );
    }
    assert_ne!(trainer.theta, theta0, "generator params should move");
    assert_ne!(trainer.phi, phi0, "discriminator params should move");
}

#[test]
fn native_gan_training_is_bit_deterministic_across_fanout() {
    // Same seed → identical losses, for ANY batch-engine fan-out: the
    // trainer's reductions run in ascending path order and the engines are
    // schedule-invariant, so threads/chunks must not change a single bit.
    let cfg = smoke_config();
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let run = |opts: BatchOptions| -> Vec<(f32, f32)> {
        let mut trainer =
            GanTrainer::new(&cfg, cfg.steps).expect("trainer").with_batch_options(opts);
        let mut rng = SplitPrng::new(5);
        (0..cfg.steps)
            .map(|_| {
                let s = trainer.train_step(&data, &mut rng).expect("step");
                (s.loss_g, s.loss_d)
            })
            .collect()
    };
    let a = run(BatchOptions { threads: 1, chunk: 12, ..Default::default() });
    let b = run(BatchOptions { threads: 3, chunk: 2, ..Default::default() });
    let c = run(BatchOptions { threads: 4, chunk: 5, ..Default::default() });
    assert_eq!(a, b, "fan-out changed the training bits");
    assert_eq!(a, c, "fan-out changed the training bits");
    // chunk ≥ batch leaves each solve single-chunked, so the ONLY
    // parallelism is the real/fake discriminator-adjoint overlap
    // (pool::join2) — isolating the PR-10 overlap as bit-neutral.
    let d = run(BatchOptions { threads: 2, chunk: 12, ..Default::default() });
    assert_eq!(a, d, "real/fake adjoint overlap changed the training bits");
}

#[test]
fn native_sampling_produces_finite_series() {
    let cfg = smoke_config();
    let mut trainer = GanTrainer::new(&cfg, 1).expect("trainer");
    let fake = trainer.sample(9).expect("sample");
    assert_eq!(fake.n, 9);
    assert_eq!(fake.seq_len, 32);
    assert!(fake.values.iter().all(|v| v.is_finite()));
    let spread = fake.values.iter().cloned().fold(f32::MIN, f32::max)
        - fake.values.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1e-3, "degenerate samples, spread {spread}");
}

#[test]
fn native_sampling_is_bit_reproducible_call_over_call() {
    // The hoisted eval noise/scratch must not change sample()'s contract:
    // every call resets the persistent source, so repeated calls replay the
    // same deterministic series a fresh source would have produced.
    let cfg = smoke_config();
    let mut trainer = GanTrainer::new(&cfg, 1).expect("trainer");
    let a = trainer.sample(5).expect("sample");
    let b = trainer.sample(5).expect("sample");
    assert_eq!(a.values, b.values, "sample() must replay identically");
}

// ---------------------------------------------------------------------------
// Mixed precision: f32 kernels, mixed adjoints, mixed training
// ---------------------------------------------------------------------------

#[test]
fn f32_batched_mlp_bit_identical_to_per_path_across_remainder_batches() {
    // The f32 instantiation of the batched LipSwish forward/VJP against the
    // per-path generic code on the same f32 θ — bitwise, for every SIMD
    // remainder batch (1/3/4/7/8/33 cover sub-lane, partial-lane and
    // multi-lane-plus-tail shapes at LANES = 8).
    let spec = tiny_spec();
    let gl = spec.gen_layout();
    let theta32: Vec<f32> =
        random_params(gl.total, 97).iter().map(|&v| v as f32).collect();
    let zeta = Mlp::from_layout(&gl, "zeta", Activation::Identity).expect("zeta");
    let (ind, od) = (spec.init_noise, spec.state);
    for &b in &REMAINDER_BATCHES {
        // Distinct per-path inputs and output cotangents.
        let xs_aos: Vec<f32> = (0..ind * b).map(|i| 0.07 * (i % 13) as f32 - 0.3).collect();
        let ws_aos: Vec<f32> = (0..od * b).map(|i| 0.9 - 0.05 * (i % 7) as f32).collect();
        let mut xs = vec![0.0f32; ind * b];
        let mut ws = vec![0.0f32; od * b];
        for p in 0..b {
            for i in 0..ind {
                xs[i * b + p] = xs_aos[p * ind + i];
            }
            for k in 0..od {
                ws[k * b + p] = ws_aos[p * od + k];
            }
        }
        let mut out = vec![0.0f32; od * b];
        zeta.forward_batch(&theta32, &xs, &mut out, b);
        let mut gx = vec![0.0f32; ind * b];
        let mut gth = vec![0.0f32; gl.total * b];
        zeta.vjp_batch(&theta32, &xs, &ws, &mut gx, &mut gth, b);
        for p in 0..b {
            let xp = &xs_aos[p * ind..(p + 1) * ind];
            let wp = &ws_aos[p * od..(p + 1) * od];
            let mut op = vec![0.0f32; od];
            zeta.forward(&theta32, xp, &mut op);
            for k in 0..od {
                assert_eq!(out[k * b + p], op[k], "forward b={b} p={p} k={k}");
            }
            let mut gxp = vec![0.0f32; ind];
            let mut gthp = vec![0.0f32; gl.total];
            zeta.vjp(&theta32, xp, wp, &mut gxp, &mut gthp);
            for i in 0..ind {
                assert_eq!(gx[i * b + p], gxp[i], "gx b={b} p={p} i={i}");
            }
            for m in 0..gl.total {
                assert_eq!(gth[m * b + p], gthp[m], "gth b={b} p={p} m={m}");
            }
        }
    }
}

#[test]
fn mixed_adjoint_gradient_deviation_is_small_but_nonzero() {
    // The acceptance gate: the mixed adjoint (f32 forward on the rounded
    // draws of the same Brownian sample, exact f64 backward through the
    // widened tape, with per-step injection AND ddw) deviates from the
    // all-f64 adjoint by strictly more than zero — the f32 path really ran —
    // and by less than 1e-2 relative L1.
    let spec = tiny_spec();
    let dim = spec.state;
    let n = 16usize;
    let theta32: Vec<f32> =
        random_params(spec.gen_layout().total, 13).iter().map(|&v| v as f32).collect();
    let native = NeuralGeneratorBatch::from_f32(&spec, &theta32);
    let batch = 8usize;
    let y0 = aos_to_soa(&aos_start(dim, batch), dim, batch);
    let y032: Vec<f32> = y0.iter().map(|&v| v as f32).collect();
    let noise = CounterGridNoise::new(77, spec.noise, 0.0, 1.0, n);
    let opts = BatchOptions::default();
    let seed = |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
        for i in 0..dim {
            for q in 0..cl {
                lz[i * cl + q] += inject_weight(k, i, p0 + q);
            }
        }
    };
    let full = adjoint_solve_batched_steps(
        &native, &noise, &y0, batch, 0.0, 1.0, n, BackwardMode::Tape, true, &opts, &seed,
    )
    .expect("fault-free by construction"); // test-only unwrap: no injection here
    let cat = |g: &AdjointGrad| {
        let mut c = g.dy0.clone();
        c.extend_from_slice(&g.dtheta);
        c.extend_from_slice(&g.ddw);
        c
    };
    for mode in [BackwardMode::Tape, BackwardMode::Reconstruct] {
        let mixed = adjoint_solve_batched_steps_mixed(
            &native, &native, &noise, &y032, batch, 0.0, 1.0, n, mode, true, &opts, &seed,
        )
        .expect("fault-free by construction"); // test-only unwrap: no injection here
        let rel = relative_l1(&cat(&mixed), &cat(&full));
        assert!(rel > 0.0, "{mode:?}: mixed adjoint must actually run the f32 forward");
        assert!(rel < 1e-2, "{mode:?}: mixed-vs-f64 gradient deviation {rel:e} above bound");
    }
}

#[test]
fn mixed_steps_adjoint_bit_deterministic_across_fanout() {
    // Tape-mode mixed adjoints carry the engines' schedule-invariance
    // guarantee: every thread/chunk fan-out must reproduce the same bits.
    let spec = tiny_spec();
    let dim = spec.state;
    let n = 12usize;
    let theta32: Vec<f32> =
        random_params(spec.gen_layout().total, 29).iter().map(|&v| v as f32).collect();
    let native = NeuralGeneratorBatch::from_f32(&spec, &theta32);
    for &batch in &REMAINDER_BATCHES {
        let y032: Vec<f32> = aos_to_soa(&aos_start(dim, batch), dim, batch)
            .iter()
            .map(|&v| v as f32)
            .collect();
        let noise = CounterGridNoise::new(41, spec.noise, 0.0, 1.0, n);
        let seed = |k: usize, p0: usize, cl: usize, _z: &[f64], lz: &mut [f64]| {
            for i in 0..dim {
                for q in 0..cl {
                    lz[i * cl + q] += inject_weight(k, i, p0 + q);
                }
            }
        };
        let mut reference: Option<AdjointGrad> = None;
        for (threads, chunk) in [(1usize, batch), (1, 2), (3, 2), (2, 4), (4, 3)] {
            let opts = BatchOptions { threads, chunk, ..Default::default() };
            let got = adjoint_solve_batched_steps_mixed(
                &native,
                &native,
                &noise,
                &y032,
                batch,
                0.0,
                1.0,
                n,
                BackwardMode::Tape,
                true,
                &opts,
                &seed,
            )
            .expect("fault-free by construction"); // test-only unwrap: no injection here
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(got.terminal, r.terminal, "terminal b={batch} t={threads} c={chunk}");
                    assert_eq!(got.dy0, r.dy0, "dy0 b={batch} t={threads} c={chunk}");
                    assert_eq!(got.dtheta, r.dtheta, "dtheta b={batch} t={threads} c={chunk}");
                    assert_eq!(got.ddw, r.ddw, "ddw b={batch} t={threads} c={chunk}");
                }
            }
        }
    }
}

#[test]
fn mixed_gan_training_is_bit_deterministic_across_fanout() {
    // The full mixed train step (f32 generator forward, mixed CDE adjoint
    // with ddw, mixed generator adjoint with per-step injection) must stay
    // bit-reproducible for every batch-engine fan-out, exactly like f64.
    let mut cfg = smoke_config();
    cfg.precision = TrainPrecision::Mixed;
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let run = |opts: BatchOptions| -> Vec<(f32, f32)> {
        let mut trainer =
            GanTrainer::new(&cfg, cfg.steps).expect("trainer").with_batch_options(opts);
        let mut rng = SplitPrng::new(5);
        (0..cfg.steps)
            .map(|_| {
                let s = trainer.train_step(&data, &mut rng).expect("step");
                (s.loss_g, s.loss_d)
            })
            .collect()
    };
    let a = run(BatchOptions { threads: 1, chunk: 12, ..Default::default() });
    let b = run(BatchOptions { threads: 3, chunk: 2, ..Default::default() });
    let c = run(BatchOptions { threads: 4, chunk: 5, ..Default::default() });
    assert_eq!(a, b, "fan-out changed the mixed training bits");
    assert_eq!(a, c, "fan-out changed the mixed training bits");
    // Single-chunk solves at threads 2: only the real/fake adjoint overlap
    // runs concurrently (see the f64 twin of this test).
    let d = run(BatchOptions { threads: 2, chunk: 12, ..Default::default() });
    assert_eq!(a, d, "real/fake adjoint overlap changed the mixed training bits");
}

#[test]
fn mixed_training_step_tracks_f64_step() {
    // One adversarial round at each precision from the same init and noise
    // seed: the mixed parameters must differ from f64 (the f32 solves
    // really ran) while the parameter *updates* stay within 1e-2 relative
    // L1 — single-precision forward rounding carried through one Adadelta
    // update, nothing more.
    let cfg = smoke_config();
    let mut cfgm = smoke_config();
    cfgm.precision = TrainPrecision::Mixed;
    let mut data = ou::generate(cfg.data_size, 3, ou::OuParams::default());
    data.normalise_initial();
    let run_one = |cfg: &TrainConfig| {
        let mut tr = GanTrainer::new(cfg, cfg.steps).expect("trainer");
        let mut rng = SplitPrng::new(5);
        let s = tr.train_step(&data, &mut rng).expect("step");
        (tr.theta.clone(), tr.phi.clone(), s.loss_g, s.loss_d)
    };
    let (th64, ph64, lg64, ld64) = run_one(&cfg);
    let (thm, phm, lgm, ldm) = run_one(&cfgm);
    assert_ne!(th64, thm, "mixed step must not be bit-identical to f64");
    let init = GanTrainer::new(&cfg, cfg.steps).expect("trainer");
    let upd = |after: &[f32], before: &[f32]| -> Vec<f64> {
        after.iter().zip(before).map(|(&a, &b)| a as f64 - b as f64).collect()
    };
    let du_t = relative_l1(&upd(&thm, &init.theta), &upd(&th64, &init.theta));
    let du_p = relative_l1(&upd(&phm, &init.phi), &upd(&ph64, &init.phi));
    assert!(du_t > 0.0 && du_t < 1e-2, "θ update deviation {du_t:e}");
    assert!(du_p > 0.0 && du_p < 1e-2, "φ update deviation {du_p:e}");
    assert!((lgm - lg64).abs() <= 1e-2 * lg64.abs().max(1.0), "loss_g {lgm} vs {lg64}");
    assert!((ldm - ld64).abs() <= 1e-2 * ld64.abs().max(1.0), "loss_d {ldm} vs {ld64}");
}

#[test]
fn mixed_sampling_produces_finite_series() {
    let mut cfg = smoke_config();
    cfg.precision = TrainPrecision::Mixed;
    let mut trainer = GanTrainer::new(&cfg, 1).expect("trainer");
    let fake = trainer.sample(9).expect("sample");
    assert_eq!(fake.n, 9);
    assert!(fake.values.iter().all(|v| v.is_finite()));
    let spread = fake.values.iter().cloned().fold(f32::MIN, f32::max)
        - fake.values.iter().cloned().fold(f32::MAX, f32::min);
    assert!(spread > 1e-3, "degenerate mixed samples, spread {spread}");
}
