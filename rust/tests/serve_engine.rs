//! Contract tests for the serving engine (`solvers::serve`):
//!
//! * **Coalescing is invisible in the bits** — requests of widths 1/3/7/33
//!   packed into one SoA mega-batch are each bit-identical to solving that
//!   request as its own batch over the same session noise, across engine
//!   thread/chunk settings.
//! * **Sessions are isolated** — a session's request stream depends only on
//!   its own seed and request counter, never on which other sessions share
//!   the engine or how requests interleave.
//! * **Quarantine is per request** — a fault-injected request (NaN initial
//!   state, or a panicking vector field) surfaces as that request's
//!   structured `SolveError` with request-relative coordinates, while every
//!   other request in the same mega-batch keeps its exact fault-free bits.
//! * **`BatchStepper::reinit` is exact** — a reused stepper re-initialised
//!   in place is bit-identical to a freshly constructed one, for every
//!   in-tree stepper.
//!
//! (The steady-state zero-allocation pin lives in `serve_zero_alloc.rs` —
//! its counting global allocator needs a binary to itself.)

use neuralsde::solvers::systems::TanhDiagonalBatch;
use neuralsde::solvers::{
    integrate_batched, BatchEulerMaruyama, BatchHeun, BatchMidpoint, BatchOptions,
    BatchReversibleHeun, BatchSde, BatchStepper, FaultCause, ServeConfig, ServeEngine,
    SessionNoise, StoredBatchNoise,
};

const T0: f64 = 0.0;
const T1: f64 = 1.0;
const N_STEPS: usize = 20;
const DIM: usize = 4;

fn sde() -> TanhDiagonalBatch {
    TanhDiagonalBatch::new(DIM, 1234)
}

fn y0_for(n_paths: usize, salt: usize) -> Vec<f64> {
    (0..DIM * n_paths)
        .map(|i| 0.05 * ((i + 3 * salt) % 11) as f64 - 0.2)
        .collect()
}

/// The per-request reference: rebuild the session's `k`-th request noise
/// with a replica `SessionNoise` and solve it as its own batch. This is
/// the ground truth the engine's coalesced answers must match bit-for-bit.
fn reference_request(seed: u64, request_idx: u64, n_paths: usize, y0: &[f64]) -> Vec<f64> {
    let mut sess = SessionNoise::new(seed, DIM, n_paths, T0, T1, N_STEPS);
    for _ in 0..request_idx {
        sess.next_request();
    }
    let grid = sess.next_request();
    let noise = StoredBatchNoise::<f64>::from_f32_grid(T0, T1, N_STEPS, DIM, n_paths, grid);
    let opts = BatchOptions { threads: 1, chunk: 7, ..Default::default() };
    integrate_batched::<BatchReversibleHeun, _, _>(
        &sde(),
        &noise,
        y0,
        n_paths,
        T0,
        T1,
        N_STEPS,
        &opts,
    )
    .expect("reference solve faulted")
}

#[test]
fn coalesced_mega_batch_matches_per_request_bitwise() {
    // Four sessions of widths 1, 3, 7, 33 — packed into ONE 44-lane
    // mega-batch (gated admission) — must each reproduce their own
    // per-request solve exactly, for several thread/chunk fan-outs
    // (including chunks that straddle request boundaries).
    let widths = [1usize, 3, 7, 33];
    for &(threads, chunk) in &[(1usize, 64usize), (2, 5), (4, 3)] {
        let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
        cfg.max_batch = 64;
        cfg.threads = threads;
        cfg.chunk = chunk;
        cfg.auto_admit = false;
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
        let sessions: Vec<_> = widths
            .iter()
            .enumerate()
            .map(|(s, &w)| engine.open_session(100 + s as u64, w))
            .collect();
        let tickets: Vec<_> = sessions
            .iter()
            .zip(widths.iter())
            .enumerate()
            .map(|(s, (&sid, &w))| engine.submit(sid, &y0_for(w, s)))
            .collect();
        engine.flush(); // one admission round: all four requests coalesce
        for (s, (t, &w)) in tickets.into_iter().zip(widths.iter()).enumerate() {
            let got = engine.wait(t).expect("request faulted");
            let expect = reference_request(100 + s as u64, 0, w, &y0_for(w, s));
            assert_eq!(
                got, expect,
                "width-{w} request differs from its per-request solve \
                 (threads={threads}, chunk={chunk})"
            );
        }
    }
}

#[test]
fn session_noise_is_isolated_from_interleaving() {
    // Engine 1 interleaves sessions A and B; engine 2 serves A alone.
    // A's requests must be bit-identical in both — the session counter,
    // not global engine traffic, keys the noise.
    let width = 5usize;
    let y0a = y0_for(width, 0);
    let y0b = y0_for(width, 9);
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 32;
    cfg.threads = 2;
    cfg.chunk = 4;

    let mixed = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let a = mixed.open_session(77, width);
    let b = mixed.open_session(99, width);
    let mut mixed_a = Vec::new();
    for round in 0..3 {
        let ta = mixed.submit(a, &y0a);
        let tb = mixed.submit(b, &y0b);
        mixed_a.push(mixed.wait(ta).expect("A faulted"));
        mixed
            .wait(tb)
            .unwrap_or_else(|_| panic!("B faulted in round {round}"));
    }
    drop(mixed);

    let solo = ServeEngine::<BatchReversibleHeun, _>::new(sde(), cfg);
    let a2 = solo.open_session(77, width);
    for (round, from_mixed) in mixed_a.iter().enumerate() {
        let t = solo.submit(a2, &y0a);
        let from_solo = solo.wait(t).expect("A faulted");
        assert_eq!(
            from_mixed, &from_solo,
            "session A round {round} depends on unrelated engine traffic"
        );
        // And both equal the offline per-request reconstruction.
        let expect = reference_request(77, round as u64, width, &y0a);
        assert_eq!(from_solo, expect, "round {round} differs from reference");
    }
}

/// Owned fault-injection wrapper (the engine takes its SDE by value, so the
/// borrowing `guard::PanicOnSentinel` doesn't fit): panics in `drift_batch`
/// whenever any state component equals the sentinel, exactly like its
/// borrowing counterpart.
struct PanickingTanh {
    inner: TanhDiagonalBatch,
    sentinel: f64,
}

impl BatchSde for PanickingTanh {
    fn state_dim(&self) -> usize {
        self.inner.state_dim()
    }
    fn brownian_dim(&self) -> usize {
        self.inner.brownian_dim()
    }
    fn diagonal_noise(&self) -> bool {
        self.inner.diagonal_noise()
    }
    fn drift_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        if y.iter().any(|&v| v == self.sentinel) {
            panic!("injected: sentinel state reached drift");
        }
        self.inner.drift_batch(t, y, out, batch);
    }
    fn diffusion_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        self.inner.diffusion_batch(t, y, out, batch);
    }
    fn diffusion_diag_batch(&self, t: f64, y: &[f64], out: &mut [f64], batch: usize) {
        self.inner.diffusion_diag_batch(t, y, out, batch);
    }
}

#[test]
fn faulted_request_is_quarantined_without_touching_others() {
    const SENTINEL: f64 = 1e30;
    let widths = [3usize, 4, 3];
    let mut cfg = ServeConfig::new(T0, T1, N_STEPS);
    cfg.max_batch = 16;
    cfg.threads = 2;
    cfg.chunk = 4; // chunks straddle request boundaries on purpose
    cfg.auto_admit = false;

    // Baseline: all three requests clean.
    let clean_engine = ServeEngine::<BatchReversibleHeun, _>::new(
        PanickingTanh { inner: sde(), sentinel: SENTINEL },
        cfg,
    );
    let clean_tickets: Vec<_> = widths
        .iter()
        .enumerate()
        .map(|(s, &w)| {
            let sid = clean_engine.open_session(500 + s as u64, w);
            clean_engine.submit(sid, &y0_for(w, s))
        })
        .collect();
    clean_engine.flush();
    let clean: Vec<_> = clean_tickets
        .into_iter()
        .map(|t| clean_engine.wait(t).expect("clean request faulted"))
        .collect();
    drop(clean_engine);

    // Same traffic, but request 1 carries the sentinel in path 2's first
    // component: its drift panics on step one.
    for inject_nan_instead in [false, true] {
        let engine = ServeEngine::<BatchReversibleHeun, _>::new(
            PanickingTanh { inner: sde(), sentinel: SENTINEL },
            cfg,
        );
        let mut tickets = Vec::new();
        for (s, &w) in widths.iter().enumerate() {
            let sid = engine.open_session(500 + s as u64, w);
            let mut y0 = y0_for(w, s);
            if s == 1 {
                // component 0 of path 2: SoA index 0 * w + 2
                y0[2] = if inject_nan_instead { f64::NAN } else { SENTINEL };
            }
            tickets.push(engine.submit(sid, &y0));
        }
        engine.flush();
        for (s, t) in tickets.into_iter().enumerate() {
            if s == 1 {
                let err = engine
                    .wait(t)
                    .expect_err("injected request must surface its fault");
                assert!(
                    err.faults.iter().any(|f| f.path == 2),
                    "fault must carry the request-relative path: {err}"
                );
                if inject_nan_instead {
                    assert!(
                        err.faults.iter().any(|f| f.cause == FaultCause::NonFinite),
                        "NaN y0 must localise as NonFinite: {err}"
                    );
                } else {
                    assert!(
                        err.faults
                            .iter()
                            .any(|f| matches!(&f.cause, FaultCause::VectorFieldPanic { payload }
                                if payload.contains("sentinel"))),
                        "sentinel must localise as VectorFieldPanic: {err}"
                    );
                }
            } else {
                let got = engine.wait(t).expect("bystander request faulted");
                assert_eq!(
                    got, clean[s],
                    "request {s} bits changed by another request's quarantine \
                     (nan={inject_nan_instead})"
                );
            }
        }
        // The engine stays serviceable: the quarantined slot was released
        // and a fresh, clean request on a new session round-trips.
        let sid = engine.open_session(909, 2);
        let t = engine.submit(sid, &y0_for(2, 7));
        engine.flush();
        engine.wait(t).expect("engine wedged after a quarantined request");
    }
}

/// `reinit` on a warmed stepper must be bit-identical to a fresh
/// `for_chunk` — including at a smaller batch than the stepper was warmed
/// at (the serving engine's remainder-chunk shape).
fn reinit_matches_fresh<M: BatchStepper<Elem = f64>>() {
    let sys = sde();
    let warm_batch = 8usize;
    let run_batch = 5usize;
    let y0 = y0_for(run_batch, 3);
    let dw: Vec<f64> = (0..DIM * run_batch).map(|i| 0.01 * (i as f64 - 7.0)).collect();
    let dt = (T1 - T0) / N_STEPS as f64;

    // Warm at a larger batch, then reinit down to the run shape.
    let warm_y0 = vec![0.0f64; DIM * warm_batch];
    let mut reused = M::for_chunk(&sys, T0, &warm_y0, warm_batch);
    reused.reinit(&sys, T0, &y0, run_batch);
    let mut fresh = M::for_chunk(&sys, T0, &y0, run_batch);

    let mut y_reused = y0.clone();
    let mut y_fresh = y0.clone();
    for k in 0..6 {
        let s = T0 + k as f64 * dt;
        reused.step(&sys, s, dt, &dw, &mut y_reused, run_batch);
        fresh.step(&sys, s, dt, &dw, &mut y_fresh, run_batch);
        assert_eq!(y_reused, y_fresh, "step {k}: reinit diverged from for_chunk");
    }
}

#[test]
fn reinit_is_bit_identical_for_every_stepper() {
    reinit_matches_fresh::<BatchEulerMaruyama>();
    reinit_matches_fresh::<BatchMidpoint>();
    reinit_matches_fresh::<BatchHeun>();
    reinit_matches_fresh::<BatchReversibleHeun>();
}
